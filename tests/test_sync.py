"""Layer-granularity gradient sync planning (paper §6.1, Figure 9)."""
from repro.configs import get_arch
from repro.core import (EngineConfig, OobleckEngine, build_profile,
                        build_sync_plan, layer_groups)


def make_engine():
    prof = build_profile(get_arch("gpt3_2_7b"), microbatch=2, seq_len=2048)
    return OobleckEngine(prof, [f"node{i}" for i in range(13)], EngineConfig(
        fault_tolerance=2, global_batch=1024, microbatch=2,
        gpus_per_node=1, n0_override=2))


def test_every_layer_has_every_replica():
    eng = make_engine()
    for g in layer_groups(eng.instances):
        assert len(g.replicas) == len(eng.instances)
        assert all(len(r) >= 1 for r in g.replicas)


def test_figure9_heterogeneous_peers():
    """A layer whose stage boundaries differ across pipelines still gets a
    peer group containing exactly one owner per replica (Fig. 9)."""
    eng = make_engine()
    hetero = [g for g in layer_groups(eng.instances)
              if len({tuple(r) for r in g.replicas}) > 1]
    assert hetero, "13-node plan must include heterogeneous pipelines"
    for g in hetero:
        for grp in g.peer_groups():
            assert len(grp) == len(eng.instances)


def test_buckets_tile_layers_deepest_first():
    eng = make_engine()
    layer_bytes = [l.param_bytes for l in eng.profile.layers]
    plan = build_sync_plan(eng.instances, layer_bytes)
    # deepest-first ordering, contiguous tiling of [0, L)
    spans = [(b.layer_start, b.layer_end) for b in plan]
    assert spans[0][1] == eng.profile.num_layers
    assert spans[-1][0] == 0
    covered = sorted(l for s, e in spans for l in range(s, e))
    assert covered == list(range(eng.profile.num_layers))


def test_bucket_cap_respected():
    eng = make_engine()
    layer_bytes = [l.param_bytes for l in eng.profile.layers]
    cap = 32 * 1024 * 1024
    plan = build_sync_plan(eng.instances, layer_bytes, bucket_cap_bytes=cap)
    for b in plan:
        assert b.nbytes <= max(cap, max(layer_bytes))  # single huge layer ok


def test_sync_groups_shrink_after_failure():
    eng = make_engine()
    n_replicas = len(eng.instances)
    # kill one whole pipeline (its nodes) -> every layer loses one replica
    victim = eng.instances[-1]
    eng.handle_failure(set(victim.nodes))
    for g in layer_groups(eng.instances):
        assert len(g.replicas) <= n_replicas
