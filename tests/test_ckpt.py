"""Async sharded checkpointer (ckpt/checkpoint.py, DESIGN.md §9):
content-addressed incremental shards, non-blocking saves, the
GC-vs-in-flight-save race regression, and layout-independent restore."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.ckpt.checkpoint as ckpt_mod
from repro.ckpt import CheckpointError, CheckpointManager, TrainState, record_hash
from repro.configs import get_arch, reduced
from repro.models import Model
from repro.optim import adamw


@pytest.fixture(scope="module")
def state():
    arch = reduced(get_arch("gpt3_medium"), layers=3)
    model = Model(arch, dtype=jnp.float32, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return arch, params, adamw.init(params)


def _bump_layer(params, i):
    """A copy of ``params`` with only block ``i`` changed."""
    blocks = jax.tree.map(
        lambda t: np.asarray(t).copy() if hasattr(t, "shape") else t,
        params["blocks"])

    def bump(t):
        t = np.asarray(t).copy()
        t[i] = t[i] + 1.0
        return t
    return {**params, "blocks": jax.tree.map(bump, params["blocks"])}


# ----------------------------------------------------------------------
def test_incremental_save_skips_unchanged_shards(tmp_path, state):
    arch, params, opt = state
    mgr = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                            async_mode=False, keep=4)
    mgr.save(TrainState(1, params, opt, {}, 0))
    wrote_first = mgr.stats["saved_shards"]
    assert wrote_first == arch.num_layers + 1        # layers + extra
    # unchanged state: every shard content-addressed-deduped
    mgr.save(TrainState(2, params, opt, {}, 0))
    assert mgr.stats["saved_shards"] == wrote_first
    assert mgr.stats["skipped_shards"] == wrote_first
    # one layer changed: exactly one new shard hits the disk
    mgr.save(TrainState(3, _bump_layer(params, 1), opt, {}, 0))
    assert mgr.stats["saved_shards"] == wrote_first + 1
    assert mgr.list_steps() == [1, 2, 3]
    assert all(mgr.verify(s) for s in (1, 2, 3))


def test_gc_keeps_only_last_k_steps_and_referenced_shards(tmp_path, state):
    arch, params, opt = state
    mgr = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                            async_mode=False, keep=2)
    for s in (1, 2, 3):
        mgr.save(TrainState(s, _bump_layer(params, 0) if s == 3 else params,
                            opt, {}, 0))
    assert mgr.list_steps() == [2, 3]
    assert mgr.stats["gc_steps"] >= 1
    # every kept step still restores bit-exact
    assert mgr.verify(2) and mgr.verify(3)
    r = mgr.restore(params, opt, step=2)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(r.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_save_does_not_block_on_inflight_write(tmp_path, state, monkeypatch):
    """The old manager's save() joined the previous writer thread — a slow
    storage path stalled training for the full write.  The queue-based
    writer must accept the next save immediately."""
    arch, params, opt = state
    mgr = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                            async_mode=True, keep=8)
    release = threading.Event()
    orig = ckpt_mod._save_npz

    def slow(path, rec):
        release.wait(timeout=30)
        orig(path, rec)
    monkeypatch.setattr(ckpt_mod, "_save_npz", slow)
    t0 = time.perf_counter()
    mgr.save(TrainState(1, params, opt, {}, 0))
    mgr.save(TrainState(2, _bump_layer(params, 0), opt, {}, 0))
    enqueue_seconds = time.perf_counter() - t0
    release.set()
    mgr.wait()
    assert enqueue_seconds < 5.0, "save() must not wait for the writer"
    assert mgr.list_steps() == [1, 2]
    assert mgr.verify(1) and mgr.verify(2)


def test_gc_cannot_delete_shards_of_inflight_save(tmp_path, state,
                                                  monkeypatch):
    """REGRESSION (ISSUE 3 satellite): the background writer had written a
    new shard but not yet its manifest; a concurrent GC saw the shard as
    unreferenced and deleted it, leaving the step's manifest pointing at
    a missing file.  In-flight hashes are now pinned under the manager
    lock, so GC must leave them alone."""
    arch, params, opt = state
    mgr = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                            async_mode=True, keep=1)
    mgr.save(TrainState(1, params, opt, {}, 0))
    mgr.wait()

    written = threading.Event()
    resume = threading.Event()
    orig = ckpt_mod._save_manifest

    def stalling(path, meta):
        written.set()               # every shard is durably on disk...
        resume.wait(timeout=30)     # ...but the manifest is not
        orig(path, meta)
    monkeypatch.setattr(ckpt_mod, "_save_manifest", stalling)

    changed = _bump_layer(params, 2)
    mgr.save(TrainState(2, changed, opt, {}, 0))
    assert written.wait(timeout=30)
    new_hash = ckpt_mod.record_hash(mgr._snapshot(
        TrainState(2, changed, opt, {}, 0))["shards"][2][1])
    assert os.path.exists(mgr._shard_path(new_hash))
    mgr.gc()                        # the racing collector
    assert os.path.exists(mgr._shard_path(new_hash)), \
        "GC deleted a shard the in-flight save references"
    resume.set()
    mgr.wait()
    assert mgr.list_steps() == [2]  # keep=1 dropped step 1 afterwards
    assert mgr.verify(2), "in-flight step ended up corrupt"
    r = mgr.restore(changed, opt, step=2)
    for a, b in zip(jax.tree.leaves(changed), jax.tree.leaves(r.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_background_failure_surfaces_on_wait(tmp_path, state, monkeypatch):
    arch, params, opt = state
    mgr = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                            async_mode=True)

    def boom(path, rec):
        raise OSError("disk full")
    monkeypatch.setattr(ckpt_mod, "_save_npz", boom)
    mgr.save(TrainState(1, params, opt, {}, 0))
    with pytest.raises(CheckpointError):
        mgr.wait()
    assert mgr.list_steps() == []   # no manifest -> the step is invisible


def test_verify_returns_false_on_corrupt_shard(tmp_path, state):
    """verify()'s contract is 'False on ANY corruption' — a truncated
    shard (torn write, bit rot) must not raise out of it."""
    arch, params, opt = state
    mgr = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                            async_mode=False)
    mgr.save(TrainState(1, params, opt, {}, 0))
    assert mgr.verify(1)
    victim = mgr._shard_path(mgr._read_manifest(1)["layers"][0]["hash"])
    with open(victim, "r+b") as f:
        f.truncate(16)                  # not even a valid zip any more
    assert mgr.verify(1) is False


def test_record_hash_is_content_based(state):
    arch, params, opt = state
    rec = {"p['w']": np.arange(6, dtype=np.float32).reshape(2, 3)}
    same = {"p['w']": np.arange(6, dtype=np.float32).reshape(2, 3)}
    other = {"p['w']": np.arange(6, dtype=np.float32).reshape(3, 2)}
    assert record_hash(rec) == record_hash(same)
    assert record_hash(rec) != record_hash(other)      # shape matters
    assert record_hash(rec) != record_hash(
        {"p['w']": rec["p['w']"].astype(np.float64)})  # dtype matters


def test_restore_maps_onto_a_different_template_layout(tmp_path):
    """A checkpoint saved under one template set must rebind under
    another (different node count -> different stage tilings): the
    manifest indexes layers, not templates."""
    from repro.core import EngineConfig, OobleckEngine, build_profile
    from repro.runtime import HeteroTrainer

    arch = reduced(get_arch("gpt3_medium"), layers=4)
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive",
                  scan_layers=False)
    params = model.init(jax.random.PRNGKey(3))
    profile = build_profile(arch, microbatch=2, seq_len=16)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, weight_decay=0.0)

    def engine(n):
        return OobleckEngine(
            profile, [f"n{i}" for i in range(n)],
            EngineConfig(fault_tolerance=1, global_batch=16, microbatch=2,
                         gpus_per_node=1, n0_override=2))

    saver = HeteroTrainer(model, engine(5), params, opt_cfg, mode="eager")
    snap = saver.snapshot(data_state={"cursor": 1}, rng_seed=7)
    mgr = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                            async_mode=False)
    mgr.save(snap)

    restored = mgr.restore(snap.params, adamw.init(snap.params))
    # rebind on a DIFFERENT cluster size => different templates/stages
    rebound = HeteroTrainer(model, engine(4), restored.params, opt_cfg,
                            mode="eager")
    for a, b in zip(jax.tree.leaves(rebound.full_params()),
                    jax.tree.leaves(snap.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
