"""Async sharded checkpointer (ckpt/checkpoint.py, DESIGN.md §9):
content-addressed incremental shards, non-blocking saves, the
GC-vs-in-flight-save race regression, and layout-independent restore."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.ckpt.checkpoint as ckpt_mod
from repro.ckpt import CheckpointError, CheckpointManager, TrainState, record_hash
from repro.configs import get_arch, reduced
from repro.models import Model
from repro.optim import adamw


@pytest.fixture(scope="module")
def state():
    arch = reduced(get_arch("gpt3_medium"), layers=3)
    model = Model(arch, dtype=jnp.float32, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return arch, params, adamw.init(params)


def _bump_layer(params, i):
    """A copy of ``params`` with only block ``i`` changed."""
    blocks = jax.tree.map(
        lambda t: np.asarray(t).copy() if hasattr(t, "shape") else t,
        params["blocks"])

    def bump(t):
        t = np.asarray(t).copy()
        t[i] = t[i] + 1.0
        return t
    return {**params, "blocks": jax.tree.map(bump, params["blocks"])}


# ----------------------------------------------------------------------
def test_incremental_save_skips_unchanged_shards(tmp_path, state):
    arch, params, opt = state
    mgr = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                            async_mode=False, keep=4)
    mgr.save(TrainState(1, params, opt, {}, 0))
    wrote_first = mgr.stats["saved_shards"]
    assert wrote_first == arch.num_layers + 1        # layers + extra
    # unchanged state: every shard content-addressed-deduped
    mgr.save(TrainState(2, params, opt, {}, 0))
    assert mgr.stats["saved_shards"] == wrote_first
    assert mgr.stats["skipped_shards"] == wrote_first
    # one layer changed: exactly one new shard hits the disk
    mgr.save(TrainState(3, _bump_layer(params, 1), opt, {}, 0))
    assert mgr.stats["saved_shards"] == wrote_first + 1
    assert mgr.list_steps() == [1, 2, 3]
    assert all(mgr.verify(s) for s in (1, 2, 3))


def test_gc_keeps_only_last_k_steps_and_referenced_shards(tmp_path, state):
    arch, params, opt = state
    mgr = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                            async_mode=False, keep=2)
    for s in (1, 2, 3):
        mgr.save(TrainState(s, _bump_layer(params, 0) if s == 3 else params,
                            opt, {}, 0))
    assert mgr.list_steps() == [2, 3]
    assert mgr.stats["gc_steps"] >= 1
    # every kept step still restores bit-exact
    assert mgr.verify(2) and mgr.verify(3)
    r = mgr.restore(params, opt, step=2)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(r.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_save_does_not_block_on_inflight_write(tmp_path, state, monkeypatch):
    """The old manager's save() joined the previous writer thread — a slow
    storage path stalled training for the full write.  The queue-based
    writer must accept the next save immediately."""
    arch, params, opt = state
    mgr = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                            async_mode=True, keep=8)
    release = threading.Event()
    orig = ckpt_mod._save_npz

    def slow(path, rec):
        release.wait(timeout=30)
        orig(path, rec)
    monkeypatch.setattr(ckpt_mod, "_save_npz", slow)
    t0 = time.perf_counter()
    mgr.save(TrainState(1, params, opt, {}, 0))
    mgr.save(TrainState(2, _bump_layer(params, 0), opt, {}, 0))
    enqueue_seconds = time.perf_counter() - t0
    release.set()
    mgr.wait()
    assert enqueue_seconds < 5.0, "save() must not wait for the writer"
    assert mgr.list_steps() == [1, 2]
    assert mgr.verify(1) and mgr.verify(2)


def test_gc_cannot_delete_shards_of_inflight_save(tmp_path, state,
                                                  monkeypatch):
    """REGRESSION (ISSUE 3 satellite): the background writer had written a
    new shard but not yet its manifest; a concurrent GC saw the shard as
    unreferenced and deleted it, leaving the step's manifest pointing at
    a missing file.  In-flight hashes are now pinned under the manager
    lock, so GC must leave them alone."""
    arch, params, opt = state
    mgr = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                            async_mode=True, keep=1)
    mgr.save(TrainState(1, params, opt, {}, 0))
    mgr.wait()

    written = threading.Event()
    resume = threading.Event()
    orig = ckpt_mod._save_manifest

    def stalling(path, meta):
        written.set()               # every shard is durably on disk...
        resume.wait(timeout=30)     # ...but the manifest is not
        orig(path, meta)
    monkeypatch.setattr(ckpt_mod, "_save_manifest", stalling)

    changed = _bump_layer(params, 2)
    mgr.save(TrainState(2, changed, opt, {}, 0))
    assert written.wait(timeout=30)
    new_hash = ckpt_mod.record_hash(mgr._snapshot(
        TrainState(2, changed, opt, {}, 0))["shards"][2][1])
    assert os.path.exists(mgr._shard_path(new_hash))
    mgr.gc()                        # the racing collector
    assert os.path.exists(mgr._shard_path(new_hash)), \
        "GC deleted a shard the in-flight save references"
    resume.set()
    mgr.wait()
    assert mgr.list_steps() == [2]  # keep=1 dropped step 1 afterwards
    assert mgr.verify(2), "in-flight step ended up corrupt"
    r = mgr.restore(changed, opt, step=2)
    for a, b in zip(jax.tree.leaves(changed), jax.tree.leaves(r.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_background_failure_surfaces_on_wait(tmp_path, state, monkeypatch):
    arch, params, opt = state
    mgr = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                            async_mode=True)

    def boom(path, rec):
        raise OSError("disk full")
    monkeypatch.setattr(ckpt_mod, "_save_npz", boom)
    mgr.save(TrainState(1, params, opt, {}, 0))
    with pytest.raises(CheckpointError):
        mgr.wait()
    assert mgr.list_steps() == []   # no manifest -> the step is invisible


def test_verify_returns_false_on_corrupt_shard(tmp_path, state):
    """verify()'s contract is 'False on ANY corruption' — a truncated
    shard (torn write, bit rot) must not raise out of it."""
    arch, params, opt = state
    mgr = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                            async_mode=False)
    mgr.save(TrainState(1, params, opt, {}, 0))
    assert mgr.verify(1)
    victim = mgr._shard_path(mgr._read_manifest(1)["layers"][0]["hash"])
    with open(victim, "r+b") as f:
        f.truncate(16)                  # not even a valid zip any more
    assert mgr.verify(1) is False


def test_record_hash_is_content_based(state):
    arch, params, opt = state
    rec = {"p['w']": np.arange(6, dtype=np.float32).reshape(2, 3)}
    same = {"p['w']": np.arange(6, dtype=np.float32).reshape(2, 3)}
    other = {"p['w']": np.arange(6, dtype=np.float32).reshape(3, 2)}
    assert record_hash(rec) == record_hash(same)
    assert record_hash(rec) != record_hash(other)      # shape matters
    assert record_hash(rec) != record_hash(
        {"p['w']": rec["p['w']"].astype(np.float64)})  # dtype matters


def test_restore_maps_onto_a_different_template_layout(tmp_path):
    """A checkpoint saved under one template set must rebind under
    another (different node count -> different stage tilings): the
    manifest indexes layers, not templates."""
    from repro.core import EngineConfig, OobleckEngine, build_profile
    from repro.runtime import HeteroTrainer

    arch = reduced(get_arch("gpt3_medium"), layers=4)
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive",
                  scan_layers=False)
    params = model.init(jax.random.PRNGKey(3))
    profile = build_profile(arch, microbatch=2, seq_len=16)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, weight_decay=0.0)

    def engine(n):
        return OobleckEngine(
            profile, [f"n{i}" for i in range(n)],
            EngineConfig(fault_tolerance=1, global_batch=16, microbatch=2,
                         gpus_per_node=1, n0_override=2))

    saver = HeteroTrainer(model, engine(5), params, opt_cfg, mode="eager")
    snap = saver.snapshot(data_state={"cursor": 1}, rng_seed=7)
    mgr = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                            async_mode=False)
    mgr.save(snap)

    restored = mgr.restore(snap.params, adamw.init(snap.params))
    # rebind on a DIFFERENT cluster size => different templates/stages
    rebound = HeteroTrainer(model, engine(4), restored.params, opt_cfg,
                            mode="eager")
    for a, b in zip(jax.tree.leaves(rebound.full_params()),
                    jax.tree.leaves(snap.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ----------------------------------------------------------------------
# Multi-process writers (runtime/multihost.py checkpointing)
# ----------------------------------------------------------------------
def test_nonwriter_saves_shards_but_skips_manifest_and_gc(tmp_path, state):
    """Two processes checkpoint the same trajectory: every process
    writes content-addressed shards, only the elected writer commits
    the per-step MANIFEST and runs gc.  A non-writer's gc could delete
    shards of a step whose manifest hasn't landed yet — it must not run
    one at all."""
    arch, params, opt = state
    w = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                          async_mode=False, keep=1, process_id="proc0",
                          manifest_writer=True)
    nw = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                           async_mode=False, keep=1, process_id="proc1",
                           manifest_writer=False)
    st1 = TrainState(1, params, opt, {}, 0)
    # non-writer lands first: shards durable, step NOT yet visible
    nw.save(st1)
    assert nw.stats["manifests_skipped"] == 1
    assert nw.stats["saved_shards"] == arch.num_layers + 1
    assert nw.list_steps() == []
    # writer commits the same step: every shard dedupes, manifest lands
    w.save(st1)
    assert w.stats["skipped_shards"] == arch.num_layers + 1
    assert w.stats["saved_shards"] == 0
    assert w.list_steps() == [1] and nw.list_steps() == [1]
    assert w.verify(1) and nw.verify(1)
    # non-writer races ahead to step 2 with keep=1: NO gc may run —
    # step 1 (the only committed step) must stay fully restorable
    nw.save(TrainState(2, _bump_layer(params, 0), opt, {}, 0))
    assert nw.stats["gc_steps"] == 0 and nw.stats["gc_shards"] == 0
    assert w.verify(1)
    # writer commits step 2: its gc now retires step 1
    w.save(TrainState(2, _bump_layer(params, 0), opt, {}, 0))
    assert w.list_steps() == [2] and w.verify(2)


def test_two_concurrent_writers_same_step_tolerate_manifest_race(
        tmp_path, state, monkeypatch):
    """Transiently (during a membership change) TWO processes can both
    believe they are the elected writer.  Content-addressing makes the
    outcome identical either way: the loser of the manifest rename
    counts a race and moves on, and the step verifies."""
    arch, params, opt = state
    a = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                          async_mode=False, process_id="proc0")
    b = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                          async_mode=False, process_id="proc1")
    st = TrainState(5, params, opt, {}, 0)
    real_rename = os.rename
    fired = {"done": False}

    def racing(srcp, dstp):
        # A commits step 5 inside B's window between the exists-check
        # and the rename — the exact interleaving two processes hit
        if not fired["done"] and dstp == b._step_dir(5):
            fired["done"] = True
            a.save(st)
        return real_rename(srcp, dstp)
    monkeypatch.setattr(ckpt_mod.os, "rename", racing)
    b.save(st)
    assert b.stats["manifest_races"] == 1
    assert a.stats["manifest_races"] == 0
    assert a.list_steps() == [5] and b.list_steps() == [5]
    assert a.verify(5) and b.verify(5)
    restored = b.restore(st.params, st.opt_state)
    for x, y in zip(jax.tree.leaves(restored.params),
                    jax.tree.leaves(st.params)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_elect_writer_matches_coordinator_view():
    from repro.ckpt import elect_writer
    assert elect_writer({"proc3", "proc1", "proc2"}) == "proc1"
    with pytest.raises(ValueError):
        elect_writer(set())
