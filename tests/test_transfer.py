"""Recovery data plane (runtime/transfer.py, DESIGN.md §9): topology-aware
source selection, parallel-stream makespan under ICI/DCN contention,
chunking, and the engine/simulator accounting built on it."""
import dataclasses

import pytest

from repro.configs import get_arch
from repro.core import (CopyTask, EngineConfig, OobleckEngine, build_profile,
                        verify_replica_coverage)
from repro.core.sync import layer_owner_map
from repro.runtime.transfer import (DCN, ICI, Topology, TransferPlan,
                                    TransferPlanError, TransferStream,
                                    schedule_transfers)
from repro.utils.hw import V5E

GB = 10 ** 9


def _profile(layers=18):
    arch = dataclasses.replace(get_arch("gpt2"), name=f"gpt2_L{layers}",
                               num_layers=layers)
    return build_profile(arch, microbatch=2, seq_len=256)


def make_engine(n_nodes=16, f=1, n0=4, nodes_per_pod=4, layers=18):
    return OobleckEngine(
        _profile(layers), [f"node{i:03d}" for i in range(n_nodes)],
        EngineConfig(fault_tolerance=f, global_batch=512, microbatch=2,
                     gpus_per_node=1, n0_override=n0,
                     nodes_per_pod=nodes_per_pod))


# ----------------------------------------------------------------------
# Topology + source selection
# ----------------------------------------------------------------------
def test_topology_regular_pods_and_links():
    topo = Topology.regular(["a0", "a1", "b0", "b1"], nodes_per_pod=2)
    assert topo.same_pod("a0", "a1") and topo.same_pod("b0", "b1")
    assert not topo.same_pod("a1", "b0")
    assert topo.link_kind("a0", "a1") == ICI
    assert topo.link_kind("a0", "b1") == DCN


def test_unknown_node_is_priced_as_cross_pod():
    """Late joins / hot spares the map has never seen must be priced
    conservatively: DCN to everyone, including each other."""
    topo = Topology.regular(["a0", "a1"], nodes_per_pod=2)
    assert topo.link_kind("a0", "spareX") == DCN
    assert topo.link_kind("spareX", "spareY") == DCN


def test_scheduler_prefers_pod_local_source():
    topo = Topology.regular(["a0", "a1", "b0", "b1"], nodes_per_pod=2)
    # b1 lost layer 3; replicas exist on a0 (cross-pod) and b0 (pod-local).
    # The reconfigurator's least-loaded default picked a0; the data plane
    # must re-route to the ICI replica.
    task = CopyTask(3, "a0", "b1", GB, sources=("a0", "b0"))
    plan = schedule_transfers([task], topo)
    assert len(plan.streams) == 1
    assert plan.streams[0].src == "b0"
    assert plan.streams[0].link == ICI
    assert plan.pod_local_fraction() == 1.0


def test_scheduler_spreads_load_across_pod_local_sources():
    topo = Topology.regular([f"a{i}" for i in range(6)], nodes_per_pod=6)
    tasks = [CopyTask(l, "a0", f"a{2 + l}", GB, sources=("a0", "a1"))
             for l in range(4)]
    plan = schedule_transfers(tasks, topo)
    assert {s.src for s in plan.streams} == {"a0", "a1"}
    per_src = {}
    for s in plan.streams:
        per_src[s.src] = per_src.get(s.src, 0) + s.nbytes
    assert per_src["a0"] == per_src["a1"]


def test_scheduler_never_reads_dead_even_if_default_source_died():
    topo = Topology.regular(["a0", "a1", "a2"], nodes_per_pod=3)
    task = CopyTask(0, "a0", "a2", GB, sources=("a0", "a1"))
    plan = schedule_transfers([task], topo, dead={"a0"})
    assert plan.streams[0].src == "a1"
    with pytest.raises(TransferPlanError):
        schedule_transfers([task], topo, dead={"a0", "a1"})


# ----------------------------------------------------------------------
# Timing: max over streams, contention, pod-local advantage
# ----------------------------------------------------------------------
def _stream(src, dst, nbytes, topo):
    return TransferStream(src, dst, topo.link_kind(src, dst),
                          [CopyTask(0, src, dst, nbytes)])


def test_makespan_is_max_over_streams_not_serial_sum():
    topo = Topology.regular(["a0", "a1", "a2", "a3"], nodes_per_pod=4)
    b = int(50 * GB)                      # 1s over one ICI link
    plan = TransferPlan(streams=[_stream("a0", "a1", b, topo),
                                 _stream("a2", "a3", b, topo)],
                        topology=topo)
    assert plan.makespan() == pytest.approx(1.0, rel=1e-6)
    assert plan.serial_seconds() == pytest.approx(2.0, rel=1e-6)


def test_pod_local_copy_measurably_cheaper_than_cross_pod():
    topo = Topology.regular(["a0", "a1", "b0"], nodes_per_pod=2)
    b = int(50 * GB)
    ici = TransferPlan(streams=[_stream("a0", "a1", b, topo)], topology=topo)
    dcn = TransferPlan(streams=[_stream("a0", "b0", b, topo)], topology=topo)
    assert ici.makespan() == pytest.approx(1.0, rel=1e-6)
    # DCN: 25 GB/s per host -> exactly 2x slower for the same bytes
    assert dcn.makespan() == pytest.approx(2.0, rel=1e-6)
    assert dcn.makespan() > 1.5 * ici.makespan()


def test_dcn_streams_share_the_host_allotment():
    topo = Topology.regular(["a0", "b0", "c0"], nodes_per_pod=1)
    b = int(25 * GB)                      # 1s alone on DCN
    single = TransferPlan(streams=[_stream("a0", "b0", b, topo)],
                          topology=topo)
    double = TransferPlan(streams=[_stream("a0", "b0", b, topo),
                                   _stream("a0", "c0", b, topo)],
                          topology=topo)
    assert single.makespan() == pytest.approx(1.0, rel=1e-6)
    assert double.makespan() == pytest.approx(2.0, rel=1e-6)


def test_ici_streams_use_independent_links_until_nic_saturates():
    topo = Topology.regular([f"a{i}" for i in range(9)], nodes_per_pod=9)
    b = int(50 * GB)
    two = TransferPlan(streams=[_stream("a0", f"a{i}", b, topo)
                                for i in (1, 2)], topology=topo)
    # 2 streams: NIC 200 GB/s / 2 = 100 >= 50 per-link cap -> no slowdown
    assert two.makespan() == pytest.approx(1.0, rel=1e-6)
    eight = TransferPlan(streams=[_stream("a0", f"a{i}", b, topo)
                                  for i in range(1, 9)], topology=topo)
    # 8 streams: NIC 200/8 = 25 GB/s each -> 2x
    assert eight.makespan() == pytest.approx(2.0, rel=1e-6)


def test_progressive_filling_speeds_up_survivor_streams():
    """When a short stream drains, the remaining stream reclaims the
    shared DCN allotment: 25GB+50GB from one host finish at 2s and 3s,
    not at the 2s/4s a fixed-share model would give."""
    topo = Topology.regular(["a0", "b0", "c0"], nodes_per_pod=1)
    plan = TransferPlan(streams=[_stream("a0", "b0", int(25 * GB), topo),
                                 _stream("a0", "c0", int(50 * GB), topo)],
                        topology=topo)
    short, long_ = plan.finish_times()
    assert short == pytest.approx(2.0, rel=1e-6)
    assert long_ == pytest.approx(3.0, rel=1e-6)


def test_exposed_seconds_overlap_with_first_steps():
    topo = Topology.regular(["a0", "a1"], nodes_per_pod=2)
    plan = TransferPlan(streams=[_stream("a0", "a1", int(50 * GB), topo)],
                        topology=topo)
    assert plan.exposed_seconds(0.0) == pytest.approx(1.0, rel=1e-6)
    assert plan.exposed_seconds(0.4) == pytest.approx(0.6, rel=1e-6)
    assert plan.exposed_seconds(5.0) == 0.0


def test_chunks_preserve_layer_boundaries_and_bytes():
    topo = Topology.regular(["a0", "a1"], nodes_per_pod=2)
    tasks = [CopyTask(0, "a0", "a1", 100), CopyTask(1, "a0", "a1", 250)]
    s = TransferStream("a0", "a1", ICI, tasks)
    chunks = s.chunks(chunk_bytes=100)
    assert sum(n for _, n in chunks) == 350
    assert all(n <= 100 for _, n in chunks)
    # a chunk never mixes layers; layer order preserved
    assert [l for l, _ in chunks] == sorted(l for l, _ in chunks)


# ----------------------------------------------------------------------
# Validation
# ----------------------------------------------------------------------
def test_validate_rejects_dead_source():
    topo = Topology.regular(["a0", "a1"], nodes_per_pod=2)
    plan = TransferPlan(streams=[_stream("a0", "a1", GB, topo)],
                        topology=topo)
    plan.validate(dead=set())
    with pytest.raises(TransferPlanError):
        plan.validate(dead={"a0"})


def test_validate_rejects_route_inconsistent_with_pods():
    topo = Topology.regular(["a0", "a1", "b0"], nodes_per_pod=2)
    bad = TransferPlan(
        streams=[TransferStream("a0", "b0", ICI,     # pods say DCN
                                [CopyTask(0, "a0", "b0", GB)])],
        topology=topo)
    with pytest.raises(TransferPlanError):
        bad.validate()


def test_validate_rejects_dropped_bytes():
    topo = Topology.regular(["a0", "a1"], nodes_per_pod=2)
    plan = TransferPlan(streams=[_stream("a0", "a1", GB, topo)],
                        topology=topo)
    with pytest.raises(TransferPlanError):
        plan.validate(expected_bytes=2 * GB)


# ----------------------------------------------------------------------
# Engine integration
# ----------------------------------------------------------------------
def test_engine_failure_plan_reads_only_survivors_on_valid_routes():
    eng = make_engine()
    before_owners = layer_owner_map(eng.instances)
    dead = {eng.instances[0].nodes[-1]}
    result = eng.handle_failure(dead)
    plan = eng.transfer_plan(result, dead=dead)
    plan.validate(dead, expected_bytes=result.copy_bytes())
    assert verify_replica_coverage(eng.instances)
    topo = eng.topology
    for s in plan.streams:
        assert s.src not in dead
        assert s.link == topo.link_kind(s.src, s.dst)
        for t in s.tasks:
            # sources must have owned the layer BEFORE the failure
            assert s.src in before_owners[t.layer]


def test_engine_copy_tasks_carry_every_surviving_replica():
    eng = make_engine()
    owners = layer_owner_map(eng.instances)
    dead = {eng.instances[0].nodes[-1]}
    result = eng.handle_failure(dead)
    for task in result.copy_plan:
        assert task.sources, "data plane needs the candidate set"
        assert set(task.sources) == owners[task.layer] - dead


def test_recovery_breakdown_decomposition():
    eng = make_engine()
    dead = {eng.instances[0].nodes[-1]}
    result = eng.handle_failure(dead)
    bd = eng.recovery_breakdown(result, dead=dead)
    assert set(bd) == {"replan", "transfer", "compile", "barrier"}
    assert bd["replan"] > 0.0            # measured, not assumed
    assert bd["compile"] == 0.0          # warm-cache contract (§8)
    plan = eng.transfer_plan(result, dead=dead)
    assert bd["transfer"] == pytest.approx(plan.makespan())
    assert eng.reconfiguration_seconds(result) == pytest.approx(
        sum(bd.values()))
    # the headline accounting change: max-over-streams, never the
    # serial sum the simulator used to charge
    if len(plan.streams) > 1:
        assert bd["transfer"] < plan.serial_seconds()


def test_cross_pod_failure_costs_more_than_pod_local():
    """The same victim recovered from a topology where its replicas are
    pod-local vs one where every copy crosses pods: DCN recovery must be
    measurably slower (that is the asymmetry DESIGN.md §5 documents)."""
    eng_local = make_engine(nodes_per_pod=16)    # everyone shares a pod
    dead = {eng_local.instances[0].nodes[-1]}
    res_local = eng_local.handle_failure(dead)
    t_local = eng_local.transfer_plan(res_local, dead=dead).makespan()

    eng_cross = make_engine(nodes_per_pod=1)     # every copy rides DCN
    dead_c = {eng_cross.instances[0].nodes[-1]}
    res_cross = eng_cross.handle_failure(dead_c)
    t_cross = eng_cross.transfer_plan(res_cross, dead=dead_c).makespan()
    assert t_cross > 1.5 * t_local


def test_join_gives_new_nodes_real_pod_slots():
    """Nodes that join after bootstrap must not stay singleton/DCN
    forever: the auto-built topology extends its placement order, so
    joiners fill pods together and later recoveries can reach them over
    ICI."""
    eng = make_engine(12, nodes_per_pod=4)
    assert eng.topology.pod_of("new0") == ("solo", "new0")   # unknown yet
    eng.handle_join([f"new{i}" for i in range(4)])
    topo = eng.topology
    assert topo.pod_of("new0") == 3          # 12 initial nodes -> pods 0..2
    assert topo.same_pod("new0", "new3")
    assert topo.link_kind("new0", "new1") == ICI


def test_oobleck_policy_charges_stream_makespan():
    from repro.sim import OobleckPolicy
    prof = _profile(18)
    nodes = [f"n{i}" for i in range(12)]
    pol = OobleckPolicy(prof, nodes, f=1, global_batch=256, microbatch=2,
                        n0=4, nodes_per_pod=4)
    out = pol.recover({nodes[-1]})
    assert out["downtime_seconds"] > 0
    bd = out["breakdown"]
    assert set(bd) == {"replan", "transfer", "compile", "barrier"}
    assert out["downtime_seconds"] == pytest.approx(sum(bd.values()))
