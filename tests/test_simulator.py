"""Discrete-event simulator + policy tests (paper §7 reproduction claims)."""
import pytest

from repro.configs import get_arch
from repro.core import build_profile
from repro.sim import (BambooPolicy, OobleckPolicy, PolicyStopped,
                       VarunaPolicy, controlled_failures, run_sim,
                       spot_trace)

NODES = [f"n{i}" for i in range(30)]


def prof(model="gpt3_2_7b", mb=2, seq=1024):
    return build_profile(get_arch(model), microbatch=mb, seq_len=seq)


def make_policies(p, gb=1024, mb=2):
    return {
        "oobleck": OobleckPolicy(p, NODES, f=2, global_batch=gb,
                                 microbatch=mb, max_stages=12),
        "varuna": VarunaPolicy(p, NODES, global_batch=gb, microbatch=mb,
                               max_stages=12),
        "bamboo": BambooPolicy(p, NODES, global_batch=gb, microbatch=mb,
                               max_stages=12),
    }


def test_no_failures_all_run_and_oobleck_competitive():
    p = prof()
    pols = make_policies(p)
    res = {k: run_sim(v, [], 3600.0, 1024) for k, v in pols.items()
           if v.runnable()}
    assert res["oobleck"].throughput > 0
    # without failures, Oobleck >= Varuna (same planner, no grid waste)
    assert res["oobleck"].throughput >= 0.95 * res["varuna"].throughput


def test_oobleck_degrades_gracefully_with_failure_rate():
    p = prof()
    outs = []
    for interval in (6 * 3600, 600):
        trace = controlled_failures(NODES, interval, stop_at=15)
        pol = OobleckPolicy(p, NODES, f=2, global_batch=1024, microbatch=2,
                            max_stages=12)
        res = run_sim(pol, trace, interval * 17, 1024, min_nodes=15)
        outs.append(res.throughput)
    # 36x more failures must cost Oobleck < 15% throughput (paper: ~2%)
    assert outs[1] > 0.85 * outs[0]


def test_varuna_hurts_more_at_high_failure_rate():
    p = prof()
    t_low, t_high = {}, {}
    for store, interval in ((t_low, 6 * 3600), (t_high, 600)):
        trace = controlled_failures(NODES, interval, stop_at=15)
        for name, pol in make_policies(p).items():
            if not pol.runnable():
                continue
            store[name] = run_sim(pol, trace, interval * 17, 1024,
                                  min_nodes=15).throughput
    oob_drop = t_high["oobleck"] / t_low["oobleck"]
    var_drop = t_high["varuna"] / t_low["varuna"]
    assert oob_drop > var_drop, (oob_drop, var_drop)


def test_bamboo_oom_large_models():
    p = prof("gpt3_6_7b", mb=2, seq=2048)
    pol = BambooPolicy(p, NODES, global_batch=1024, microbatch=2,
                       max_stages=12)
    assert not pol.runnable()           # paper Table 1: X for GPT-3 models
    res = run_sim(pol, [], 3600.0, 1024)
    assert res.stopped_reason == "OOM"
    assert res.throughput == 0.0


def test_bamboo_fixed_overhead_without_failures():
    p = prof("bert_large", mb=4, seq=512)
    bam = BambooPolicy(p, NODES, global_batch=8192, microbatch=4,
                       max_stages=12)
    oob = OobleckPolicy(p, NODES, f=2, global_batch=8192, microbatch=32,
                        max_stages=12)
    r_b = run_sim(bam, [], 3600.0, 8192)
    r_o = run_sim(oob, [], 3600.0, 8192)
    # RC overhead: Bamboo clearly slower even with zero failures (§2.3)
    assert r_b.throughput < 0.8 * r_o.throughput


def test_varuna_rollback_loses_progress():
    p = prof()
    interval = 600.0
    trace = controlled_failures(NODES, interval, stop_at=25)
    pol = VarunaPolicy(p, NODES, global_batch=1024, microbatch=2,
                       max_stages=12)
    res = run_sim(pol, trace, interval * 8, 1024, min_nodes=25)
    assert res.breakdown["downtime"] > 0
    assert res.breakdown["ckpt"] > 0
    assert res.effective_fraction() < 1.0


def test_oobleck_stops_below_floor():
    p = prof()
    pol = OobleckPolicy(p, NODES[:10], f=1, global_batch=1024, microbatch=2,
                        n0=4, max_stages=12)
    trace = controlled_failures(NODES[:10], 100.0, stop_at=5)
    res = run_sim(pol, trace, 1e6, 1024)
    assert res.stopped_reason is not None


def test_spot_trace_shapes():
    trace = spot_trace(NODES, horizon=3600.0, mean_preempt=300.0,
                       mean_recover=600.0, seed=3)
    assert trace, "trace should contain events"
    times = [e.time for e in trace]
    assert times == sorted(times)
    assert {e.kind for e in trace} <= {"fail", "join"}


def test_spot_replay_all_policies_survive():
    p = prof("bert_large", mb=32, seq=512)
    trace = spot_trace(NODES, horizon=4 * 3600.0, mean_preempt=7.7 * 60,
                       mean_recover=15 * 60, seed=11, min_alive=10)
    pols = make_policies(p, gb=8192, mb=32)
    # Bamboo runs at ITS Table-1 microbatch (4): RC + no-remat memory
    pols["bamboo"] = BambooPolicy(prof("bert_large", mb=4, seq=512), NODES,
                                  global_batch=8192, microbatch=4,
                                  max_stages=12)
    for name, pol in pols.items():
        res = run_sim(pol, trace, 4 * 3600.0, 8192)
        assert res.throughput > 0, name
        assert res.events_handled > 0, name
