"""Data-plane regressions: the next-token labels convention and
exactly-once sample accounting under rewind/restore.

The labels bug this pins: ``batch()`` used to emit ``labels = arr[:, :-1]``
— byte-identical to ``tokens`` — so the "LM objective" degenerated to
copying the input token (identity), which a model solves from the
embedding alone.  Labels are now PRE-SHIFTED next-token targets
(``labels[:, t]`` is the target for position ``t``) and every loss
consumes them without an internal shift.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.data import ByteCorpus, DataCursor, GlobalBatchDispenser, SyntheticLM
from repro.models import Model


# ----------------------------------------------------------------------
# 1. Labels are shifted next-token targets
# ----------------------------------------------------------------------
@pytest.mark.parametrize("make_source", [
    lambda: SyntheticLM(vocab_size=97, seq_len=12, seed=3),
    lambda: ByteCorpus(b"the quick brown fox jumps over the lazy dog", 12),
], ids=["synthetic", "bytes"])
def test_labels_are_next_token_targets(make_source):
    src = make_source()
    b = src.batch(range(5))
    assert b["tokens"].shape == b["labels"].shape == (5, 12)
    # labels[:, t] == tokens[:, t+1]: the overlap region must match ...
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    # ... and labels must NOT be the identity copy of tokens (the bug)
    assert not np.array_equal(b["labels"], b["tokens"])
    # the final label is the held-out (seq_len+1)-th token of the sample
    raw = np.stack([src.sample(i) for i in range(5)])
    np.testing.assert_array_equal(b["labels"][:, -1], raw[:, -1])


def test_next_token_objective_trains_differently_from_identity():
    """The identity objective (the bug's effective target) is learnable
    from the current token alone; the true next-token objective is not
    predictable at all on uniform-random data.  Training on FRESH batches
    each step (no memorization) must therefore pin the next-token loss at
    chance (ln V) while the identity loss steadily drops — the two
    trajectories the bug used to conflate."""
    arch = reduced(get_arch("gpt2"), layers=2)
    model = Model(arch, dtype=jnp.float32, remat=False)
    src = SyntheticLM(arch.vocab_size, seq_len=16, seed=0)

    @jax.jit
    def step(params, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch)
        return loss, jax.tree.map(lambda p, g: p - 0.5 * g, params, grads)

    def trajectory(identity):
        params = model.init(jax.random.PRNGKey(1))
        losses = []
        for s in range(20):
            batch = src.batch(range(s * 8, s * 8 + 8))
            if identity:
                batch = dict(batch, labels=batch["tokens"])
            loss, params = step(params, batch)
            losses.append(float(loss))
        return np.asarray(losses)

    next_tok, ident = trajectory(False), trajectory(True)
    assert not np.allclose(next_tok, ident), \
        "labels shift had no effect on the objective"
    ln_v = np.log(arch.vocab_size)
    assert abs(next_tok[-1] - ln_v) < 0.15, \
        "next-token loss on random data must stay at chance"
    assert ident[-1] < next_tok[-1] - 0.15, \
        "identity (copy) objective must train below chance"


# ----------------------------------------------------------------------
# 2. Exactly-once accounting across failures (property-based: hypothesis
#    when available, a seeded dependency-free sweep otherwise)
# ----------------------------------------------------------------------
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _splits(draw, total):
    """A random composition of ``total`` into positive minibatch sizes
    (the per-pipeline batch plan after some reconfiguration)."""
    sizes = []
    left = total
    while left > 0:
        s = draw(1, left)
        sizes.append(s)
        left -= s
    return sizes


def _check_exactly_once(draw):
    """Simulated failure mid-step: the lost iteration is retried with a
    DIFFERENT pipeline split (the replan changed the batch plan), from
    either ``rewind`` or a checkpointed ``state()``.  Every optimizer
    step must still consume exactly [cursor, cursor + GB) — the same
    multiset, each index exactly once, no matter the split."""
    gb = draw(2, 12)
    n_steps = draw(2, 5)
    fail_step = draw(0, n_steps - 1)
    use_restore = bool(draw(0, 1))

    src = SyntheticLM(vocab_size=31, seq_len=4, seed=2)
    disp = GlobalBatchDispenser(src, DataCursor())
    consumed = []
    for step in range(n_steps):
        ckpt = disp.state()
        parts = disp.next_step(_splits(draw, gb))
        idx = np.concatenate([p["_indices"] for p in parts])
        if step == fail_step:
            # the in-flight iteration is lost; give the samples back and
            # re-draw them under the post-failure batch plan
            if use_restore:
                disp.restore(ckpt)
            else:
                disp.rewind(gb)
            parts = disp.next_step(_splits(draw, gb))
            retry_idx = np.concatenate([p["_indices"] for p in parts])
            assert sorted(retry_idx) == sorted(idx), \
                "retry consumed a different sample multiset"
            idx = retry_idx
        consumed.append(idx)

    flat = np.concatenate(consumed)
    assert sorted(flat.tolist()) == list(range(gb * n_steps)), \
        "stream is not exactly-once"
    for k, idx in enumerate(consumed):
        assert sorted(idx.tolist()) == list(range(k * gb, (k + 1) * gb))


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(data=st.data())
    def test_rewind_and_restore_replay_identical_index_multisets(data):
        _check_exactly_once(
            lambda lo, hi: data.draw(st.integers(lo, hi)))
else:
    @pytest.mark.parametrize("seed", range(40))
    def test_rewind_and_restore_replay_identical_index_multisets(seed):
        import random
        rng = random.Random(1000 + seed)
        _check_exactly_once(rng.randint)


def test_rewound_batch_content_is_reproduced_bitwise():
    """Retried iterations see the SAME token arrays, not just the same
    indices (SyntheticLM samples are pure functions of (seed, i))."""
    src = SyntheticLM(vocab_size=31, seq_len=8, seed=4)
    disp = GlobalBatchDispenser(src)
    first = disp.next_step([3, 5])
    disp.rewind(8)
    again = disp.next_step([4, 4])
    a = np.concatenate([p["tokens"] for p in first])
    b = np.concatenate([p["tokens"] for p in again])
    np.testing.assert_array_equal(np.sort(a, axis=0), np.sort(b, axis=0))
    la = np.concatenate([p["labels"] for p in first])
    np.testing.assert_array_equal(la[:, :-1], a[:, 1:])
