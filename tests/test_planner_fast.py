"""Parity + scale guarantees of the optimized planner DP (DESIGN.md §3.2).

The vectorized ``fast`` mode and the dominance-pruned ``peel`` mode must
return EXACTLY the reference ``binary`` recursion's result — identical
``iteration_time`` floats and identical stage sequences — because the
engine treats templates as interchangeable across planner modes.
"""
import dataclasses
import random
import time

import pytest

from repro.configs import get_arch
from repro.core import PipelinePlanner, build_profile, generate_node_spec
from repro.core.templates import PlanningError


def _profile(layers, mb=1, seq=128):
    arch = dataclasses.replace(get_arch("gpt2"), name=f"gpt2_L{layers}",
                               num_layers=layers)
    return build_profile(arch, microbatch=mb, seq_len=seq)


def _hetero_profile(layers, seed=0):
    """Per-layer perturbed costs: breaks the uniform-block ties that hide
    tie-breaking divergence between DP implementations."""
    prof = _profile(layers)
    rng = random.Random(seed)
    perturbed = tuple(
        dataclasses.replace(l,
                            flops_fwd=l.flops_fwd * (0.5 + rng.random()),
                            io_bytes_fwd=l.io_bytes_fwd * (0.5 + rng.random()))
        for l in prof.layers)
    return dataclasses.replace(prof, layers=perturbed)


def _signature(tpl):
    return (tpl.iteration_time,
            [(s.layer_start, s.layer_end, s.num_gpus, s.gpu_offset)
             for s in tpl.stages])


def _plan(profile, mode, n, gpus=1, max_stages=None):
    return PipelinePlanner(profile, gpus_per_node=gpus, mode=mode,
                           max_stages=max_stages).plan(n)


# ----------------------------------------------------------------------
@pytest.mark.parametrize("gpus", [1, 2])
@pytest.mark.parametrize("n", [1, 2, 3])
@pytest.mark.parametrize("layers", [3, 5])
def test_fast_and_peel_match_binary_exactly(layers, n, gpus):
    prof = _profile(layers)
    if prof.num_layers < n:
        pytest.skip("fewer layers than nodes")
    ref = _plan(prof, "binary", n, gpus)
    assert _signature(_plan(prof, "peel", n, gpus)) == _signature(ref)
    assert _signature(_plan(prof, "fast", n, gpus)) == _signature(ref)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("gpus,n", [(1, 3), (1, 5), (2, 3), (4, 2), (4, 4)])
def test_fast_matches_peel_heterogeneous(seed, gpus, n):
    """Property over perturbed per-layer costs: bit-identical results."""
    prof = _hetero_profile(10, seed=seed)
    assert (_signature(_plan(prof, "fast", n, gpus))
            == _signature(_plan(prof, "peel", n, gpus)))


def test_fast_matches_peel_with_max_stages():
    prof = _hetero_profile(12, seed=7)
    for n in (2, 3):
        assert (_signature(_plan(prof, "fast", n, 4, max_stages=2 * n))
                == _signature(_plan(prof, "peel", n, 4, max_stages=2 * n)))


def test_fast_matches_peel_property_random():
    """Randomized property sweep (hypothesis-style, but dependency-free
    so it always runs): random shapes, seeds, and GPU widths."""
    rng = random.Random(1234)
    for _ in range(15):
        layers = rng.randint(3, 12)
        gpus = rng.choice([1, 2, 3, 4])
        prof = _hetero_profile(layers, seed=rng.randint(0, 10 ** 6))
        n = rng.randint(1, min(4, prof.num_layers))
        try:
            ref = _plan(prof, "peel", n, gpus)
        except PlanningError:
            with pytest.raises(PlanningError):
                _plan(prof, "fast", n, gpus)
            continue
        assert _signature(_plan(prof, "fast", n, gpus)) == _signature(ref)


def test_infeasible_raises_same_error():
    prof = _profile(3)   # 5 layers total
    with pytest.raises(PlanningError):
        _plan(prof, "fast", 6)
    with pytest.raises(PlanningError):
        _plan(prof, "peel", 6)


# ----------------------------------------------------------------------
def test_128_node_template_set_under_30s():
    """Acceptance bar: the FULL template set for a 128-node cluster plans
    in seconds (benchmarks/planning_scale.py tracks the trend)."""
    prof = _profile(130, mb=2, seq=1024)
    spec = generate_node_spec(N=128, f=1, n0=4, max_size=prof.num_layers)
    planner = PipelinePlanner(prof, gpus_per_node=1, mode="fast")
    t0 = time.perf_counter()
    templates = planner.plan_all(spec.sizes)
    elapsed = time.perf_counter() - t0
    assert elapsed < 30.0, f"plan_all took {elapsed:.1f}s"
    assert set(templates) == set(spec.sizes)
    for n, tpl in templates.items():
        tpl.validate(prof.num_layers)
        assert tpl.num_nodes == n


def test_fast_multigpu_beats_scalar_state_count():
    """The vectorized rows visit far fewer Python-level states than the
    scalar memo for the same multi-GPU instance."""
    prof = _profile(24, mb=2, seq=512)
    fast = PipelinePlanner(prof, gpus_per_node=4, mode="fast")
    fast.plan(6)
    peel = PipelinePlanner(prof, gpus_per_node=4, mode="peel")
    peel.plan(6)
    assert len(fast._rows) < len(peel._memo)
