"""End-to-end heterogeneous pipeline execution (paper §6) — THE
faithfulness tests:

  1. a heterogeneous pipeline set (2-node + 3-node pipelines, different
     stage boundaries) training on a distributed global batch produces
     EXACTLY the same parameter trajectory as plain full-batch training;
  2. killing a node mid-training recovers from replica state (no
     checkpoint!) and the trajectory continues identically;
  3. replicas never diverge.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core import EngineConfig, OobleckEngine, build_profile
from repro.data import GlobalBatchDispenser, SyntheticLM
from repro.models import Model
from repro.optim import adamw
from repro.runtime import HeteroTrainer

RNG = jax.random.PRNGKey(11)
GB, MB, SEQ = 16, 2, 16


def make_setup(n_nodes=5, f=1, arch_name="gpt3_medium", layers=4):
    arch = reduced(get_arch(arch_name), layers=layers)
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive",
                  scan_layers=False)
    params = model.init(RNG)
    profile = build_profile(arch, microbatch=MB, seq_len=SEQ)
    engine = OobleckEngine(
        profile, [f"n{i}" for i in range(n_nodes)],
        EngineConfig(fault_tolerance=f, global_batch=GB, microbatch=MB,
                     gpus_per_node=1, n0_override=2))
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, clip_norm=1.0,
                                weight_decay=0.0)
    return arch, model, params, engine, opt_cfg


def microbatches(batch, mb_size):
    n = batch["tokens"].shape[0] // mb_size
    return [{k: v[i * mb_size:(i + 1) * mb_size] for k, v in batch.items()
             if not k.startswith("_")} for i in range(n)]


def reference_step(model, params, opt_state, batch, opt_cfg):
    def loss_fn(p):
        loss, metrics = model.loss(p, batch)
        return loss, metrics
    (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    return adamw.apply(opt_cfg, params, grads, opt_state), float(loss)


def test_hetero_equals_fullbatch():
    arch, model, params, engine, opt_cfg = make_setup()
    assert len({i.template.num_nodes for i in engine.instances}) >= 2, \
        "test requires a heterogeneous pipeline set"
    trainer = HeteroTrainer(model, engine, params, opt_cfg)
    source = SyntheticLM(arch.vocab_size, SEQ, seed=5)
    disp = GlobalBatchDispenser(source)

    ref_params = jax.tree.map(jnp.copy, params)
    ref_opt = adamw.init(ref_params)

    for step in range(3):
        sizes = engine.batch.minibatch_sizes()
        batches = disp.next_step(sizes)
        per_pipe = [microbatches(b, MB) for b in batches]
        out = trainer.train_step(per_pipe)

        # reference: same global batch, single device, full-batch grad
        all_idx = np.concatenate([b["_indices"] for b in batches])
        full = source.batch(all_idx)
        ref_batch = {"tokens": jnp.asarray(full["tokens"]),
                     "labels": jnp.asarray(full["labels"])}
        (ref_params, ref_opt, _), ref_loss = reference_step(
            model, ref_params, ref_opt, ref_batch, opt_cfg)

        assert abs(out["loss"] - ref_loss) < 5e-4, (step, out["loss"], ref_loss)
        assert trainer.replica_divergence() < 1e-6

    got = trainer.full_params()
    ref = {k: ref_params[k] for k in got}
    for g, r in zip(jax.tree.leaves(got), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(r),
                                   rtol=2e-4, atol=2e-4)


def test_failure_recovery_continues_trajectory():
    """Kill a node after step 1; recovered training must track the
    no-failure reference (same data stream, same updates)."""
    arch, model, params, engine, opt_cfg = make_setup(n_nodes=5, f=1)
    trainer = HeteroTrainer(model, engine, params, opt_cfg)
    source = SyntheticLM(arch.vocab_size, SEQ, seed=9)
    disp = GlobalBatchDispenser(source)

    ref_params = jax.tree.map(jnp.copy, params)
    ref_opt = adamw.init(ref_params)
    ref_losses = []

    def ref_step():
        nonlocal ref_params, ref_opt
        # replay the same sample stream the trainer consumed
        idx = ref_cursor.pop(0)
        full = source.batch(idx)
        batch = {"tokens": jnp.asarray(full["tokens"]),
                 "labels": jnp.asarray(full["labels"])}
        (ref_params, ref_opt, _), loss = reference_step(
            model, ref_params, ref_opt, batch, opt_cfg)
        ref_losses.append(loss)

    ref_cursor = []

    def drive(step):
        sizes = engine.batch.minibatch_sizes()
        batches = disp.next_step(sizes)
        ref_cursor.append(np.concatenate([b["_indices"] for b in batches]))
        per_pipe = [microbatches(b, MB) for b in batches]
        return trainer.train_step(per_pipe)

    out0 = drive(0); ref_step()
    victim = engine.instances[0].nodes[0]
    info = trainer.handle_failure({victim})
    assert info["num_pipelines"] >= 2
    out1 = drive(1); ref_step()
    out2 = drive(2); ref_step()

    assert abs(out1["loss"] - ref_losses[1]) < 5e-4
    assert abs(out2["loss"] - ref_losses[2]) < 5e-4
    assert trainer.replica_divergence() < 1e-6
    got = trainer.full_params()
    # float32 drift vs the single-program full-batch reference grows
    # with steps; the compiled backward's fusion rounding adds ~1 ULP
    # per step on top of the eager path's
    np.testing.assert_allclose(np.asarray(got["embed"]["table"]),
                               np.asarray(ref_params["embed"]["table"]),
                               rtol=6e-4, atol=6e-4)


def test_moe_pipeline_trains():
    arch, model, params, engine, opt_cfg = make_setup(
        arch_name="granite_moe_1b_a400m", layers=4)
    trainer = HeteroTrainer(model, engine, params, opt_cfg)
    source = SyntheticLM(arch.vocab_size, SEQ, seed=1)
    disp = GlobalBatchDispenser(source)
    losses = []
    for _ in range(3):
        batches = disp.next_step(engine.batch.minibatch_sizes())
        out = trainer.train_step([microbatches(b, MB) for b in batches])
        losses.append(out["loss"])
        assert np.isfinite(out["loss"])
    assert trainer.replica_divergence() < 1e-6


def test_exactly_once_sample_stream_across_reconfig():
    arch, model, params, engine, opt_cfg = make_setup()
    source = SyntheticLM(arch.vocab_size, SEQ, seed=3)
    disp = GlobalBatchDispenser(source)
    seen = []
    batches = disp.next_step(engine.batch.minibatch_sizes())
    seen += [i for b in batches for i in b["_indices"]]
    engine.handle_failure({engine.instances[0].nodes[0]})
    batches = disp.next_step(engine.batch.minibatch_sizes())
    seen += [i for b in batches for i in b["_indices"]]
    assert sorted(seen) == list(range(2 * GB))   # no gaps, no repeats
