"""Gradient-compression coverage (ISSUE 3 satellite): ErrorFeedback's
residual must actually shrink the accumulated compression error across
steps, and wire_bytes must match what the codec really puts on the wire.
(Deterministic — no hypothesis — so this runs on the container floor.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime.compression import (ErrorFeedback, compress, decompress,
                                       roundtrip, wire_bytes)


def _grad(key, shape=(64,), scale=0.01):
    return jax.random.normal(key, shape) * scale


# ----------------------------------------------------------------------
# wire_bytes == bytes the codec output actually occupies
# ----------------------------------------------------------------------
def _actual_bytes(compressed, codec):
    if codec == "int8":
        total = 0
        leaves = jax.tree.leaves(
            compressed, is_leaf=lambda x: isinstance(x, dict) and "q" in x)
        for d in leaves:
            total += d["q"].size * d["q"].dtype.itemsize
            total += np.asarray(d["scale"]).dtype.itemsize
        return total
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(compressed))


@pytest.mark.parametrize("codec", ["none", "bf16", "int8"])
def test_wire_bytes_matches_codec_output(codec):
    tree = {"a": jnp.ones((32, 8), jnp.float32),
            "b": {"c": jnp.ones((7,), jnp.float32)}}
    assert wire_bytes(tree, codec) == _actual_bytes(compress(tree, codec),
                                                    codec)


def test_wire_bytes_counts_one_scale_per_leaf():
    one = {"w": jnp.ones((100,), jnp.float32)}
    two = {"w": jnp.ones((50,), jnp.float32),
           "v": jnp.ones((50,), jnp.float32)}
    # same payload, one extra fp32 scale for the extra leaf
    assert wire_bytes(two, "int8") == wire_bytes(one, "int8") + 4
    assert wire_bytes(one, "bf16") == 200
    assert wire_bytes(one, "none") == 400


def test_decompress_restores_dtype_and_shape():
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (16, 4))}
    for codec in ("bf16", "int8"):
        rt = roundtrip(g, codec)
        assert rt["w"].shape == g["w"].shape
        assert rt["w"].dtype == jnp.float32
    dec = decompress(compress(g, "int8"), "int8")
    assert dec["w"].dtype == jnp.float32


# ----------------------------------------------------------------------
# ErrorFeedback shrinks the accumulated error across steps
# ----------------------------------------------------------------------
def test_error_feedback_shrinks_cumulative_error_across_steps():
    """Over T steps of a CONSTANT gradient, plain int8 compression
    accumulates a bias T*eps; error feedback re-injects the residual so
    the accumulated error stays bounded by one quantization step — the
    mean applied gradient converges to the true one."""
    g = {"w": _grad(jax.random.PRNGKey(2), (128,), scale=0.03)}
    T = 32
    naive_sum = jnp.zeros((128,))
    ef = ErrorFeedback("int8")
    ef_sum = jnp.zeros((128,))
    naive_errs, ef_errs = [], []
    for t in range(1, T + 1):
        naive_sum = naive_sum + roundtrip(g, "int8")["w"]
        ef_sum = ef_sum + ef.apply(g)["w"]
        true_sum = t * g["w"]
        naive_errs.append(float(jnp.max(jnp.abs(naive_sum - true_sum))))
        ef_errs.append(float(jnp.max(jnp.abs(ef_sum - true_sum))))
    # naive error grows ~linearly; EF error stays ~one quantization step
    assert naive_errs[-1] > 4 * naive_errs[3]
    assert ef_errs[-1] < 3 * max(ef_errs[3], 1e-9)
    assert ef_errs[-1] < naive_errs[-1] / 4
    # the per-step MEAN error therefore shrinks like 1/T with EF
    assert ef_errs[-1] / T < naive_errs[-1] / T / 4


def test_error_feedback_residual_bounded_by_quantization_step():
    ef = ErrorFeedback("int8")
    key = jax.random.PRNGKey(5)
    for i in range(16):
        key, k = jax.random.split(key)
        g = {"w": _grad(k, (64,), scale=0.02)}
        ef.apply(g)
        # residual can never exceed the quantization step of what was
        # sent (otherwise it would leak error instead of recycling it)
        step = float(jnp.max(jnp.abs(g["w"] + (ef.residual["w"] * 0)))) / 127
        assert float(jnp.max(jnp.abs(ef.residual["w"]))) <= 2 * step + 1e-8


def test_error_feedback_none_codec_is_identity():
    ef = ErrorFeedback("none")
    g = {"w": jnp.arange(4, dtype=jnp.float32)}
    out = ef.apply(g)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(g["w"]))
    assert ef.residual is None


def test_error_feedback_works_on_nested_buckets():
    """Sync buckets are pytrees (layer -> param dicts); EF must carry a
    residual with the same structure."""
    ef = ErrorFeedback("bf16")
    g = {"attn": {"wq": jnp.full((8, 8), 0.001),
                  "wk": jnp.full((8, 8), -0.002)},
         "mlp": {"w1": jnp.full((8,), 0.0005)}}
    total_sent = jax.tree.map(jnp.zeros_like, g)
    T = 16
    for _ in range(T):
        sent = ef.apply(g)
        total_sent = jax.tree.map(jnp.add, total_sent, sent)
    for leaf_sent, leaf_true in zip(jax.tree.leaves(total_sent),
                                    jax.tree.leaves(g)):
        np.testing.assert_allclose(np.asarray(leaf_sent) / T,
                                   np.asarray(leaf_true), rtol=2e-2,
                                   atol=1e-6)
