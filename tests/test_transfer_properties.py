"""Hypothesis properties of the recovery data plane: for random clusters,
pod layouts and failure sets of size <= f, the scheduled transfer plan
restores full replica coverage, never reads a failed node, and routes
every stream consistently with pod placement."""
import dataclasses

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_arch
from repro.core import (EngineConfig, InsufficientReplicasError,
                        OobleckEngine, build_profile)
from repro.core.sync import layer_owner_map, verify_replica_coverage


@pytest.fixture(scope="module")
def profile():
    arch = dataclasses.replace(get_arch("gpt2"), name="gpt2_L18",
                               num_layers=18)
    return build_profile(arch, microbatch=2, seq_len=256)


def _engine(profile, n_nodes, f, n0, nodes_per_pod):
    return OobleckEngine(
        profile, [f"node{i:03d}" for i in range(n_nodes)],
        EngineConfig(fault_tolerance=f, global_batch=256, microbatch=2,
                     gpus_per_node=1, n0_override=n0,
                     nodes_per_pod=nodes_per_pod))


@settings(max_examples=20, deadline=None)
@given(data=st.data())
def test_random_failure_sets_keep_the_data_plane_contract(data, profile):
    f = data.draw(st.integers(1, 2), label="f")
    n0 = data.draw(st.integers(2, 4), label="n0")
    # enough headroom that ANY failure set of size <= f stays recoverable
    n_nodes = data.draw(
        st.integers((f + 1) * n0 + f, (f + 1) * n0 + f + 8), label="N")
    pods = data.draw(st.integers(1, 8), label="nodes_per_pod")
    eng = _engine(profile, n_nodes, f, n0, pods)

    k = data.draw(st.integers(1, f), label="k")
    dead = set(data.draw(
        st.lists(st.sampled_from(sorted(eng.nodes)), min_size=k, max_size=k,
                 unique=True), label="dead"))

    owners_before = layer_owner_map(eng.instances)
    result = eng.handle_failure(dead)
    plan = eng.transfer_plan(result, dead=dead)

    # 1. full replica coverage restored
    assert verify_replica_coverage(eng.instances)
    owners_after = layer_owner_map(eng.instances)
    assert all(owners_after[l] for l in owners_after)
    assert not any(owners_after[l] & dead for l in owners_after)

    # 2. no stream reads a failed node, and every source actually held
    #    the layer before the failure
    for s in plan.streams:
        assert s.src not in dead
        for t in s.tasks:
            assert s.src in owners_before[t.layer] - dead

    # 3. route consistency with pod placement + nothing dropped
    plan.validate(dead, expected_bytes=result.copy_bytes())
    topo = eng.topology
    for s in plan.streams:
        assert s.link == topo.link_kind(s.src, s.dst)

    # 4. accounting: max-over-streams can never exceed the serial sum
    assert plan.makespan() <= plan.serial_seconds() + 1e-9


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), pods=st.integers(1, 8))
def test_repeated_failures_until_floor_never_break_the_contract(
        seed, pods, profile):
    """Drive failures one node at a time (f=1) until the fault-tolerance
    floor: every intermediate plan must obey the contract; the terminal
    event must raise InsufficientReplicasError, never corrupt."""
    import random
    rng = random.Random(seed)
    eng = _engine(profile, 12, f=1, n0=3, nodes_per_pod=pods)
    while True:
        victim = rng.choice(sorted(eng.nodes))
        if len(eng.nodes) - 1 < (eng.spec.f + 1) * eng.spec.n0:
            with pytest.raises(InsufficientReplicasError):
                eng.handle_failure({victim})
            break
        result = eng.handle_failure({victim})
        plan = eng.transfer_plan(result, dead={victim})
        plan.validate({victim}, expected_bytes=result.copy_bytes())
        assert verify_replica_coverage(eng.instances)
        assert victim not in eng.nodes
