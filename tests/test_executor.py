"""Executor interface + compiled per-template program cache (DESIGN.md §8).

The contract under test:

  1. PARITY — the cached per-(template, microbatch-count) step program
     computes the SAME training step as the eager 1F1B reference:
     per-microbatch NLL bit-identical, per-layer gradients equal to
     float32 ULP noise (XLA fuses the compiled backward, so last-bit
     rounding can differ from the op-by-op eager chain), and the
     trajectory stays locked through a failure -> recover -> step cycle.
  2. ZERO RECOMPILATION — after warm_templates(), a failure, recovery
     and the first post-recovery step trigger no program-cache compiles
     AND no XLA backend compiles (jax.monitoring instrumentation).
  3. NO HOST SYNCS — a train step runs under
     jax.transfer_guard_device_to_host("disallow"): nothing in the
     schedule (compiled or eager reference) forces a device->host copy.
  4. The SPMD fast path and the simulator policy implement the same
     Executor interface.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core import EngineConfig, OobleckEngine, build_profile
from repro.data import GlobalBatchDispenser, SyntheticLM
from repro.models import Model
from repro.optim import adamw
from repro.runtime import (Executor, ExecutorUnsupported, HeteroTrainer,
                           SPMDExecutor, track_compiles,
                           track_host_transfers)

RNG = jax.random.PRNGKey(11)
GB, MB, SEQ = 16, 2, 16


def make_setup(n_nodes=5, f=1, arch_name="gpt3_medium", layers=4):
    arch = reduced(get_arch(arch_name), layers=layers)
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive",
                  scan_layers=False)
    params = model.init(RNG)
    profile = build_profile(arch, microbatch=MB, seq_len=SEQ)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, clip_norm=1.0,
                                weight_decay=0.0)

    def mk_engine():
        return OobleckEngine(
            profile, [f"n{i}" for i in range(n_nodes)],
            EngineConfig(fault_tolerance=f, global_batch=GB, microbatch=MB,
                         gpus_per_node=1, n0_override=2))
    return arch, model, params, opt_cfg, mk_engine


def microbatches(batch, mb_size):
    n = batch["tokens"].shape[0] // mb_size
    return [{k: v[i * mb_size:(i + 1) * mb_size] for k, v in batch.items()
             if not k.startswith("_")} for i in range(n)]


def tree_allclose_ulp(a, b, atol=5e-7, rtol=5e-4):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=atol, rtol=rtol)


def assert_params_track(a, b, lr=1e-3):
    """Post-Adam param agreement: Adam normalizes the update, so a
    gradient element whose ULP noise straddles zero moves by a full
    lr regardless of magnitude — isolated elements may differ by
    O(lr) while any SYSTEMATIC divergence (wrong sync weights, missed
    recovery copy, stale program) moves most elements.  Assert the
    max is bounded by a couple of lr and the differing fraction is
    negligible."""
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        x, y = np.asarray(x), np.asarray(y)
        diff = np.abs(x - y)
        assert diff.max() <= 2.5 * lr, diff.max()
        assert (diff > lr / 10).mean() < 1e-3, (diff > lr / 10).mean()


# ----------------------------------------------------------------------
# 1. Parity
# ----------------------------------------------------------------------
def test_compiled_matches_eager_reference():
    arch, model, params, opt_cfg, mk_engine = make_setup()
    tc = HeteroTrainer(model, mk_engine(), params, opt_cfg, mode="compiled")
    te = HeteroTrainer(model, mk_engine(), params, opt_cfg, mode="eager")
    src = SyntheticLM(arch.vocab_size, SEQ, seed=5)
    dc, de = GlobalBatchDispenser(src), GlobalBatchDispenser(src)

    for step in range(2):
        bc = dc.next_step(tc.engine.batch.minibatch_sizes())
        be = de.next_step(te.engine.batch.minibatch_sizes())
        pbc = [microbatches(b, MB) for b in bc]
        pbe = [microbatches(b, MB) for b in be]

        # per-pipeline: NLL arrays bit-identical, grads ULP-equal
        for rc, re_, mc, me in zip(tc.runs, te.runs, pbc, pbe):
            gc, nc = tc._run_pipeline(rc, mc)
            ge, ne = te._run_pipeline(re_, me)
            np.testing.assert_array_equal(np.asarray(nc), np.asarray(ne))
            assert sorted(gc) == sorted(ge)
            for l in gc:
                tree_allclose_ulp(gc[l], ge[l])

        oc = tc.train_step(pbc)
        oe = te.train_step(pbe)
        if step == 0:
            # identical params -> bit-identical NLL means
            assert float(oc["loss"]) == float(oe["loss"])
        else:
            # params have drifted by grad ULP noise * Adam by now
            assert abs(float(oc["loss"]) - float(oe["loss"])) < 1e-4

    assert_params_track(tc.full_params(), te.full_params())
    assert tc.replica_divergence() == 0.0


def test_parity_holds_through_failure_recover_step():
    """Immediately after a failure -> recover -> step cycle the compiled
    path must still track the eager reference — and serve the step from
    the warmed cache without a single compile."""
    arch, model, params, opt_cfg, mk_engine = make_setup()
    tc = HeteroTrainer(model, mk_engine(), params, opt_cfg, mode="compiled")
    te = HeteroTrainer(model, mk_engine(), params, opt_cfg, mode="eager")
    tc.warm_templates()
    src = SyntheticLM(arch.vocab_size, SEQ, seed=9)
    dc, de = GlobalBatchDispenser(src), GlobalBatchDispenser(src)

    def drive(tr, disp):
        batches = disp.next_step(tr.engine.batch.minibatch_sizes())
        return tr.train_step([microbatches(b, MB) for b in batches])

    drive(tc, dc), drive(te, de)
    victim = tc.engine.instances[0].nodes[0]
    compiles_before = tc.cache.stats.compiles
    tc.recover({victim})
    te.recover({victim})
    oc, oe = drive(tc, dc), drive(te, de)
    assert tc.cache.stats.compiles == compiles_before, \
        "recovery must swap programs by cache lookup, not compile"
    assert abs(float(oc["loss"]) - float(oe["loss"])) < 1e-4
    assert_params_track(tc.full_params(), te.full_params())
    assert tc.replica_divergence() == 0.0
    assert te.replica_divergence() == 0.0


# ----------------------------------------------------------------------
# 2. Zero recompilation after reconfiguration
# ----------------------------------------------------------------------
def test_recover_step_is_recompile_free_for_warmed_set():
    arch, model, params, opt_cfg, mk_engine = make_setup()
    trainer = HeteroTrainer(model, mk_engine(), params, opt_cfg)
    stats = trainer.warm_templates()
    # the warmed set covers every (template, microbatch-count) pair the
    # batch planner can emit for this global batch
    n_templates = len(trainer.engine.templates)
    assert stats["compiles"] >= n_templates * (GB // MB)
    src = SyntheticLM(arch.vocab_size, SEQ, seed=3)
    disp = GlobalBatchDispenser(src)

    def drive():
        batches = disp.next_step(trainer.engine.batch.minibatch_sizes())
        return trainer.train_step([microbatches(b, MB) for b in batches])

    out = drive()                      # steady state: all ops traced once
    out["loss"].block_until_ready()
    victim = trainer.engine.instances[0].nodes[-1]
    with track_compiles() as log:
        trainer.recover({victim})
        out = drive()
        out["loss"].block_until_ready()
    assert log.backend_compiles == 0, \
        f"{log.backend_compiles} XLA compiles during recover->step"


# ----------------------------------------------------------------------
# 3. No host transfers mid-schedule
# ----------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["compiled", "eager"])
def test_train_step_issues_no_host_transfers(mode):
    """The historical bug this pins: the 1F1B walker called float(nll)
    after every last-stage forward, a blocking d2h sync per microbatch.
    Neither path may materialize ANY device array on the host during a
    step (losses/metrics come back as device arrays)."""
    arch, model, params, opt_cfg, mk_engine = make_setup()
    trainer = HeteroTrainer(model, mk_engine(), params, opt_cfg, mode=mode)
    src = SyntheticLM(arch.vocab_size, SEQ, seed=7)
    disp = GlobalBatchDispenser(src)
    batches = disp.next_step(trainer.engine.batch.minibatch_sizes())
    per_pipe = [microbatches(b, MB) for b in batches]
    trainer.train_step(per_pipe)       # trace/compile outside the guard

    # control: the instrumentation really does catch a d2h sync
    with track_host_transfers() as ctl:
        float(jnp.ones(()) + 1)
    assert ctl.device_to_host >= 1

    batches = disp.next_step(trainer.engine.batch.minibatch_sizes())
    per_pipe = [microbatches(b, MB) for b in batches]
    with track_host_transfers() as log:
        out = trainer.train_step(per_pipe)
    assert log.device_to_host == 0, \
        f"{log.device_to_host} device->host transfers inside a train step"
    assert float(out["loss"]) > 0      # sync AFTER the step is fine


# ----------------------------------------------------------------------
# 4. The other executors honour the same interface
# ----------------------------------------------------------------------
def test_spmd_executor_trains_and_refuses_reconfig():
    arch = reduced(get_arch("gpt3_medium"), layers=2)
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive")
    params = model.init(RNG)
    opt_cfg = adamw.AdamWConfig(lr=1e-2, warmup_steps=0, weight_decay=0.0)
    ex = SPMDExecutor(model, params, opt_cfg)
    assert isinstance(ex, Executor)
    src = SyntheticLM(arch.vocab_size, SEQ, seed=2)
    batch = src.batch(np.arange(8))    # fixed batch: loss must overfit
    losses = [float(ex.step(batch)["loss"]) for _ in range(4)]
    assert ex.cache.stats.compiles == 1, "steady state must reuse ONE program"
    assert losses[-1] < losses[0]
    with pytest.raises(ExecutorUnsupported):
        ex.recover({"node0"})
    snap = ex.snapshot()
    assert snap.step == 4
    # snapshot leaves survive later (donating) steps
    emb = np.asarray(snap.params["embed"]["table"]).copy()
    ex.step(src.batch(np.arange(8)))
    np.testing.assert_array_equal(emb, np.asarray(snap.params["embed"]["table"]))


def test_monitor_failure_with_spmd_executor_still_updates_plan():
    """A FAIL event routed to an executor that cannot reconfigure
    (ExecutorUnsupported) must still update the engine's PLAN — the
    caller then rebinds a HeteroTrainer from snapshot() against it."""
    from repro.core.monitor import NodeChangeMonitor
    arch, model, params, opt_cfg, mk_engine = make_setup()
    engine = mk_engine()
    ex = SPMDExecutor(model, params, opt_cfg, engine=engine)
    assert engine.executor is ex
    victim = engine.instances[0].nodes[-1]
    engine.monitor.inject(NodeChangeMonitor.FAIL, [victim])
    engine.monitor.poll(now=0.0)
    assert victim not in set(engine.nodes)
    assert engine.metrics.reconfigurations == 1


def test_oobleck_policy_is_an_executor():
    from repro.core import build_profile
    from repro.sim.policies import OobleckPolicy
    arch = reduced(get_arch("gpt2"), layers=8)
    profile = build_profile(arch, microbatch=2, seq_len=64)
    nodes = [f"n{i}" for i in range(6)]
    pol = OobleckPolicy(profile, nodes, f=1, global_batch=32, microbatch=2,
                        n0=2)
    assert isinstance(pol, Executor)
    assert pol.engine.executor is pol
    out = pol.step()
    assert out["sim_seconds"] > 0 and out["samples"] == 32
    victim = pol.engine.instances[0].nodes[0]
    rec = pol.recover({victim})
    assert rec["downtime_seconds"] > 0
    snap = pol.snapshot()
    assert snap["instances"] and snap["num_microbatches"]


def test_hetero_trainer_snapshot_roundtrips_through_ckpt(tmp_path):
    from repro.ckpt import CheckpointManager
    arch, model, params, opt_cfg, mk_engine = make_setup()
    trainer = HeteroTrainer(model, mk_engine(), params, opt_cfg)
    src = SyntheticLM(arch.vocab_size, SEQ, seed=4)
    disp = GlobalBatchDispenser(src)
    batches = disp.next_step(trainer.engine.batch.minibatch_sizes())
    trainer.train_step([microbatches(b, MB) for b in batches])
    snap = trainer.snapshot(data_state={"cursor": 16}, rng_seed=11)
    assert snap.step == 1
    mgr = CheckpointManager(str(tmp_path), num_layers=arch.num_layers)
    mgr.save(snap, block=True)
    template_opt = adamw.init(snap.params)
    restored = mgr.restore(snap.params, template_opt)
    assert restored.step == 1
    assert restored.data_state == {"cursor": 16}
    for a, b in zip(jax.tree.leaves(restored.params),
                    jax.tree.leaves(snap.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # moments are REAL (non-zero after a step), not re-initialized
    assert any(float(jnp.max(jnp.abs(m))) > 0
               for m in jax.tree.leaves(restored.opt_state.m))


# ----------------------------------------------------------------------
# 5. Kernel hot path (DESIGN.md §11): Pallas fwd+bwd inside the cached
#    per-template programs, still zero-compile across reconfiguration
# ----------------------------------------------------------------------
def test_kernel_path_recover_step_zero_compiles():
    """With attn_impl='kernel', ssd_impl='kernel' AND fuse='fused' the
    per-template step programs contain the Pallas forward AND backward
    kernels plus the fused residual+RMSNorm / QKV epilogues (the hybrid
    arch exercises flash-attention and SSD both).  warm_templates must
    still make failure -> recover -> first-step run with ZERO XLA
    backend compiles, and every grads program key must carry the kernel
    backend signature (the per-kind lowering plan is part of cache
    identity)."""
    from repro.kernels import ops as kops
    arch = reduced(get_arch("hymba_1_5b"), layers=2)
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="kernel",
                  ssd_impl="kernel", fuse="fused", scan_layers=False)
    assert model.fuse == "fused"
    params = model.init(RNG)
    profile = build_profile(arch, microbatch=MB, seq_len=SEQ)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, clip_norm=1.0,
                                weight_decay=0.0)
    from repro.core import EngineConfig, OobleckEngine
    engine = OobleckEngine(
        profile, [f"n{i}" for i in range(5)],
        EngineConfig(fault_tolerance=1, global_batch=8, microbatch=MB,
                     gpus_per_node=1, n0_override=2))
    trainer = HeteroTrainer(model, engine, params, opt_cfg)
    trainer.warm_templates()
    for key in trainer.cache.keys():
        if key[0] == "grads":
            assert key[1] == kops.backend_signature(), key

    src = SyntheticLM(arch.vocab_size, SEQ, seed=21)
    disp = GlobalBatchDispenser(src)

    def drive():
        batches = disp.next_step(trainer.engine.batch.minibatch_sizes())
        return trainer.train_step([microbatches(b, MB) for b in batches])

    out = drive()
    out["loss"].block_until_ready()
    assert bool(jnp.isfinite(out["loss"]))
    victim = trainer.engine.instances[0].nodes[-1]
    with track_compiles() as log:
        trainer.recover({victim})
        out = drive()
        out["loss"].block_until_ready()
    assert log.backend_compiles == 0, \
        f"{log.backend_compiles} XLA compiles during recover->step on " \
        f"the kernel path"
