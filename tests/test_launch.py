"""Launch layer: mesh purity, input specs, HLO parser, sharding specs.

NOTE: these tests run with the default 1-device CPU backend — the
512-device dry-run runs in its own process (launch/dryrun.py sets
XLA_FLAGS before importing jax).  A small-device-count end-to-end dry-run
happens in test_dryrun_subprocess.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, all_archs, all_cells, cells_for, get_arch
from repro.launch.hloparse import analyze, parse_module
from repro.runtime.sharding import ShardingStrategy


def test_mesh_module_import_is_pure():
    """Importing mesh.py must not initialize jax devices."""
    import importlib
    import repro.launch.mesh as m
    importlib.reload(m)
    assert callable(m.make_production_mesh)


def test_cell_enumeration():
    cells = all_cells()
    # 10 archs x 3 shapes + 2 long_500k = 32
    assert len(cells) == 32
    names = {(a.name, s.name) for a, s in cells}
    assert ("mamba2_780m", "long_500k") in names
    assert ("hymba_1_5b", "long_500k") in names
    assert ("qwen2_5_32b", "long_500k") not in names


def test_input_specs_shapes():
    from repro.launch import specs as sp
    from repro.models import Model
    arch = get_arch("phi3_vision_4_2b")
    shape = SHAPES["train_4k"]
    b = sp.batch_specs(arch, shape)
    # frontend tokens are carved out of the text sequence
    assert b["tokens"].shape == (256, 4096 - 576)
    assert b["frontend_embeds"].shape == (256, 576, 3072)
    assert all(isinstance(v, jax.ShapeDtypeStruct) for v in b.values())


def test_hloparse_simple_module():
    text = """
HloModule test

%body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p = (s32[], f32[8,8]) parameter(0)
  %a = f32[8,8]{1,0} get-tuple-element(%p), index=1
  %w = f32[8,8]{1,0} constant(0)
  %dot.1 = f32[8,8]{1,0} dot(%a, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%dot.1), replica_groups=[2,4]<=[8], to_apply=%sum
  %c = s32[] get-tuple-element(%p), index=0
  %one = s32[] constant(1)
  %inc = s32[] add(%c, %one)
  ROOT %t = (s32[], f32[8,8]) tuple(%inc, %ar)
}

%cond (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]) parameter(0)
  %c = s32[] get-tuple-element(%p), index=0
  %lim = s32[] constant(5)
  ROOT %cmp = pred[] compare(%c, %lim), direction=LT
}

ENTRY %main (x: f32[8,8]) -> f32[8,8] {
  %x = f32[8,8]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%zero, %x)
  %w2 = (s32[], f32[8,8]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w2), index=1
}
"""
    st = analyze(text)
    # dot: 2*8*8*8 = 1024 flops, x5 trips
    assert st.dot_flops == pytest.approx(1024 * 5)
    # all-reduce: 2*(4-1)/4 * 256B = 384B, x5
    assert st.collective_bytes == pytest.approx(384 * 5)
    assert st.num_whiles == 1


def test_hloparse_real_program():
    """Parser totals must match XLA's own count on a loop-free program."""
    def f(w, x):
        return jnp.sum((x @ w).astype(jnp.float32))
    w = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    c = jax.jit(f).lower(w, x).compile()
    st = analyze(c.as_text())
    from repro.launch.mesh import cost_analysis_dict
    xla = cost_analysis_dict(c).get("flops", 0)
    assert st.dot_flops == pytest.approx(2 * 16 * 64 * 32, rel=0.01)
    assert st.dot_flops <= xla * 1.05 + 1e5


# ----------------------------------------------------------------------
# Sharding strategy specs (no multi-device needed: specs are symbolic)
# ----------------------------------------------------------------------
class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


@pytest.mark.parametrize("strategy", ["fsdp", "tp"])
def test_param_spec_divisibility_guard(strategy):
    st = ShardingStrategy(strategy=strategy)
    mesh = FakeMesh({"data": 16, "model": 16})
    # dim divisible -> sharded somewhere; prime dim -> fully replicated
    spec = st.param_spec(mesh, "blocks/attn/wq", (28, 2048, 2048))
    assert "model" in spec
    spec = st.param_spec(mesh, "blocks/attn/wq", (28, 2047, 2047))
    assert all(s is None for s in spec)


def test_tp_row_col_assignment():
    st = ShardingStrategy(strategy="tp")
    mesh = FakeMesh({"data": 16, "model": 16})
    wq = st.param_spec(mesh, "blocks/attn/wq", (28, 2048, 4096))
    assert wq[2] == "model" and wq[1] is None      # column parallel
    wo = st.param_spec(mesh, "blocks/attn/wo", (28, 4096, 2048))
    assert wo[1] == "model" and wo[2] is None      # row parallel
    emb = st.param_spec(mesh, "embed/table", (151936, 2048))
    assert emb[0] == "model"                       # vocab sharded


def test_fsdp_batch_axes_include_model():
    st = ShardingStrategy(strategy="fsdp", data_axes=("pod", "data"))
    assert st.batch_axes == ("pod", "data", "model")
    st2 = ShardingStrategy(strategy="tp", data_axes=("data",))
    assert st2.batch_axes == ("data",)


def test_batch_spec_prefix_fallback():
    st = ShardingStrategy(strategy="fsdp", data_axes=("pod", "data"))
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert st.batch_spec(mesh, 512) == P(("pod", "data", "model"))
    assert st.batch_spec(mesh, 256) == P(("pod", "data"))  # 256 % 512 != 0
    assert st.batch_spec(mesh, 2) == P("pod")
    assert st.batch_spec(mesh, 1) == P()


def test_model_flops_definitions():
    from repro.launch.dryrun import model_flops
    arch = get_arch("qwen2_moe_a2_7b")
    tr = model_flops(arch, SHAPES["train_4k"])
    # MoE uses ACTIVE params
    assert tr == pytest.approx(6 * arch.active_params() * 4096 * 256)
    de = model_flops(arch, SHAPES["decode_32k"])
    assert de == pytest.approx(2 * arch.active_params() * 128)
