"""Dynamic reconfiguration (§5) + engine lifecycle (§3.4) + guarantees (§3.2)."""
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_arch
from repro.core import (EngineConfig, InsufficientReplicasError,
                        NodeChangeMonitor, OobleckEngine, build_profile,
                        verify_replica_coverage)


def make_engine(n_nodes=13, f=2, n0=2, gb=1024, mb=2):
    prof = build_profile(get_arch("gpt3_2_7b"), microbatch=mb, seq_len=2048)
    nodes = [f"node{i}" for i in range(n_nodes)]
    return OobleckEngine(prof, nodes, EngineConfig(
        fault_tolerance=f, global_batch=gb, microbatch=mb,
        gpus_per_node=1, n0_override=n0))


def test_bootstrap_uses_all_nodes():
    eng = make_engine()
    assert len(eng.nodes) == 13
    assert len(eng.instances) >= 3          # f+1
    assert sum(eng.batch.num_microbatches) * 2 == 1024


def test_simple_reinstantiation_figure_8a():
    eng = make_engine()
    four = next((i for i in eng.instances if i.template.num_nodes >= 3), None)
    assert four is not None
    victim = four.nodes[-1]
    r = eng.handle_failure({victim})
    assert r.reinstantiated >= 1
    assert victim not in eng.nodes
    assert len(eng.nodes) == 12             # every survivor still used
    assert verify_replica_coverage(eng.instances)


def test_merge_or_borrow_when_below_n0():
    eng = make_engine()
    two = next(i for i in eng.instances if i.template.num_nodes == 2)
    r = eng.handle_failure({two.nodes[0]})
    assert r.merged + r.borrowed >= 1
    assert len(eng.nodes) == 12
    assert verify_replica_coverage(eng.instances)


def test_copy_plan_sources_are_survivors():
    eng = make_engine()
    dead = {eng.instances[0].nodes[0]}
    r = eng.handle_failure(dead)
    for task in r.copy_plan:
        assert task.src_node not in dead
        assert task.nbytes > 0


def test_batch_redistributed_after_failure():
    eng = make_engine()
    r = eng.handle_failure({eng.instances[0].nodes[0]})
    assert sum(eng.batch.num_microbatches) * 2 == 1024  # global batch constant
    assert len(eng.batch.num_microbatches) == len(eng.instances)


def test_insufficient_replicas_checkpoints_and_raises():
    hits = []
    prof = build_profile(get_arch("gpt3_2_7b"), microbatch=2, seq_len=2048)
    eng = OobleckEngine(prof, [f"n{i}" for i in range(6)], EngineConfig(
        fault_tolerance=2, global_batch=512, microbatch=2, gpus_per_node=1,
        n0_override=2), on_checkpoint=lambda: hits.append(1))
    with pytest.raises(InsufficientReplicasError):
        eng.handle_failure({"n0"})          # 5 < (f+1)*n0 = 6
    assert hits == [1]
    assert eng.stopped


def test_f_simultaneous_failures_survivable():
    """§3.2: up to f simultaneous failures never lose the model."""
    eng = make_engine(f=2)
    dead = {eng.instances[0].nodes[0], eng.instances[1].nodes[0]}
    eng.handle_failure(dead)
    assert verify_replica_coverage(eng.instances)
    assert len(eng.instances) >= 1


def test_node_join_replans_globally():
    eng = make_engine()
    eng.handle_failure({eng.instances[0].nodes[0]})
    n_before = len(eng.nodes)
    r = eng.handle_join(["fresh0", "fresh1"])
    assert len(eng.nodes) == n_before + 2
    assert r.globally_replanned


def test_monitor_dispatch():
    eng = make_engine()
    victim = eng.instances[0].nodes[0]
    eng.monitor.inject(NodeChangeMonitor.FAIL, [victim], time=1.0)
    eng.monitor.poll(now=2.0)
    assert victim not in eng.nodes
    eng.monitor.inject(NodeChangeMonitor.WARN, ["nodeX"], time=3.0)
    eng.monitor.poll(now=3.0)
    assert eng.draining


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), kills=st.integers(1, 3))
def test_random_failure_sequences_keep_invariants(seed, kills):
    """Property: any sequence of <=f-sized failure batches keeps
    (a) all surviving nodes in use, (b) full layer coverage,
    (c) the global batch size constant."""
    import random
    rng = random.Random(seed)
    eng = make_engine(n_nodes=13, f=2)
    for _ in range(kills):
        alive = eng.nodes
        if len(alive) - 2 < 6:              # would cross the floor
            break
        dead = set(rng.sample(alive, k=min(2, len(alive))))
        eng.handle_failure(dead)
        assert len(eng.nodes) == len(alive) - len(dead)
        assert verify_replica_coverage(eng.instances)
        assert sum(eng.batch.num_microbatches) * 2 == 1024
        for inst in eng.instances:
            inst.template.validate(eng.profile.num_layers)
