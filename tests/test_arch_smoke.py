"""Per-assigned-architecture smoke tests (task deliverable f).

Each of the 10 archs is instantiated at a REDUCED config of the same
family and runs ONE forward + backward (train) step and one decode step
on CPU, asserting output shapes and finiteness.  Full configs are only
exercised via the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, cells_for, get_arch, reduced
from repro.models import Model

RNG = jax.random.PRNGKey(0)


def make_batch(arch, B=2, S=16):
    tokens = jax.random.randint(RNG, (B, S), 0, arch.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if arch.frontend:
        batch["frontend_embeds"] = jax.random.normal(
            RNG, (B, arch.frontend_tokens, arch.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_train_step_smoke(arch_id):
    arch = reduced(get_arch(arch_id))
    model = Model(arch, dtype=jnp.float32, remat=True)
    params = model.init(RNG)
    batch = make_batch(arch)

    def loss_fn(p):
        loss, metrics = model.loss(p, batch)
        return loss, metrics

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(loss_fn, has_aux=True))(params)
    assert np.isfinite(float(loss)), f"{arch_id}: non-finite loss"
    assert float(loss) > 0
    # gradient pytree mirrors params, finite everywhere
    for path, g in jax.tree_util.tree_leaves_with_path(grads):
        assert np.isfinite(np.asarray(g)).all(), f"{arch_id}: NaN grad at {path}"
    # loss is sane for a |V|-way prediction
    assert float(metrics["nll"]) < np.log(arch.vocab_size) + 1.0


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_step_smoke(arch_id):
    arch = reduced(get_arch(arch_id))
    model = Model(arch, dtype=jnp.float32, remat=False)
    params = model.init(RNG)
    B = 2
    cache = model.init_cache(B, max_len=32)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = jax.jit(model.decode_step)(params, tok, cache,
                                                jnp.int32(0))
    assert logits.shape == (B, 1, arch.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    # cache structurally unchanged
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_assigned_cells(arch_id):
    """Shape-cell bookkeeping: long_500k only for sub-quadratic archs."""
    arch = get_arch(arch_id)
    names = {s.name for s in cells_for(arch)}
    assert {"train_4k", "prefill_32k", "decode_32k"} <= names
    if arch.name in ("mamba2_780m", "hymba_1_5b"):
        assert "long_500k" in names
    else:
        assert "long_500k" not in names


def test_exact_configs_match_task_table():
    """The full configs carry the exact numbers assigned by the task."""
    rows = {
        "mamba2_780m": (48, 1536, 0, 0, 0, 50280),
        "hymba_1_5b": (32, 1600, 25, 5, 5504, 32001),
        "phi3_vision_4_2b": (32, 3072, 32, 32, 8192, 32064),
        "musicgen_large": (48, 2048, 32, 32, 8192, 2048),
        "qwen2_5_32b": (64, 5120, 40, 8, 27648, 152064),
        "qwen3_1_7b": (28, 2048, 16, 8, 6144, 151936),
        "qwen2_5_3b": (36, 2048, 16, 2, 11008, 151936),
        "glm4_9b": (40, 4096, 32, 2, 13696, 151552),
        "qwen2_moe_a2_7b": (24, 2048, 16, 16, 1408, 151936),
        "granite_moe_1b_a400m": (24, 1024, 16, 8, 512, 49155),
    }
    for name, (L, d, H, KV, ff, V) in rows.items():
        a = get_arch(name)
        assert (a.num_layers, a.d_model, a.num_heads, a.num_kv_heads,
                a.d_ff, a.vocab_size) == (L, d, H, KV, ff, V), name
    assert get_arch("mamba2_780m").ssm.state_size == 128
    assert get_arch("hymba_1_5b").ssm.state_size == 16
    assert get_arch("qwen2_moe_a2_7b").moe.num_experts == 60
    assert get_arch("qwen2_moe_a2_7b").moe.top_k == 4
    assert get_arch("granite_moe_1b_a400m").moe.num_experts == 32
    assert get_arch("granite_moe_1b_a400m").moe.top_k == 8
