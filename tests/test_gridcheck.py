"""Grid-write discipline (kernels/gridcheck.py, DESIGN.md §13).

Two layers of coverage: unit tests of the checker itself (revisit
detection, carry rules, Mosaic semantics derivation), and the package
audit — every pallas_call the kernels construct must register a
CallRecord whose outputs are written from exactly one parallel grid
cell (or from declared-sequential axes only)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pl

from repro.kernels import gridcheck, ops
from repro.kernels.gridcheck import (CallRecord, GridWriteError, REGISTRY,
                                     check_grid_writes, checked_pallas_call,
                                     revisit_axes)

RNG = jax.random.PRNGKey(11)


# ----------------------------------------------------------------------
# revisit_axes: index-map probing
# ----------------------------------------------------------------------
def test_revisit_axes_detects_ignored_axis():
    # block index ignores axis 1 entirely -> every j writes block (i, 0)
    rev = revisit_axes((4, 8), lambda i, j: (i, 0))
    assert rev == (1,)


def test_revisit_axes_clean_map_has_none():
    assert revisit_axes((4, 8), lambda i, j: (i, j)) == ()


def test_revisit_axes_reversed_map_is_not_a_revisit():
    # reversed iteration still moves the block index every step
    assert revisit_axes((2, 8), lambda i, c: (i, 7 - c)) == ()


def test_revisit_axes_size_one_axis_skipped():
    # a size-1 axis has a single iteration: nothing to race
    assert revisit_axes((1, 8), lambda i, j: (0, j)) == ()


# ----------------------------------------------------------------------
# check_grid_writes: the discipline
# ----------------------------------------------------------------------
def test_check_rejects_parallel_revisit():
    with pytest.raises(GridWriteError, match="not declared sequential"):
        check_grid_writes(
            "bad", grid=(4, 8),
            out_specs=[pl.BlockSpec((1, 1), lambda i, j: (i, 0))])


def test_check_accepts_declared_sequential_revisit():
    rec = check_grid_writes(
        "ok", grid=(4, 8),
        out_specs=[pl.BlockSpec((1, 1), lambda i, j: (i, 0))],
        sequential_axes=(1,))
    assert rec.revisit_axes == ((1,),) and not rec.single_writer


def test_check_rejects_carry_on_parallel_axis():
    with pytest.raises(GridWriteError, match="corrupt the accumulator"):
        check_grid_writes(
            "bad_carry", grid=(4, 8),
            out_specs=[pl.BlockSpec((1, 1), lambda i, j: (i, j))],
            scratch_carry_axes=(1,), num_scratch=1)


def test_check_rejects_parallel_axis_inside_carry():
    # carry on axis 0 with a parallel axis 1 inside it: the carry would
    # interleave with axis-1 iterations
    with pytest.raises(GridWriteError, match="later axes"):
        check_grid_writes(
            "bad_trailing", grid=(4, 8),
            out_specs=[pl.BlockSpec((1, 1), lambda i, j: (i, j))],
            sequential_axes=(0,), scratch_carry_axes=(0,), num_scratch=1)


def test_check_accepts_innermost_sequential_carry():
    rec = check_grid_writes(
        "ok_carry", grid=(4, 8),
        out_specs=[pl.BlockSpec((1, 1), lambda i, j: (i, j))],
        sequential_axes=(1,), scratch_carry_axes=(1,), num_scratch=1)
    assert rec.scratch_carry_axes == (1,) and not rec.single_writer


def test_mosaic_semantics_derivation():
    params = gridcheck._mosaic_params((2, 3, 4), sequential_axes=(2,))
    assert params["mosaic"]["dimension_semantics"] == (
        "parallel", "parallel", "arbitrary")


def test_checked_pallas_call_executes_and_registers():
    def double(x_ref, o_ref):
        o_ref[...] = x_ref[...] * 2.0

    x = jnp.arange(32, dtype=jnp.float32).reshape(4, 8)
    y = checked_pallas_call(
        "toy_double", double, grid=(4,),
        in_specs=[pl.BlockSpec((1, 8), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 8), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((4, 8), jnp.float32),
        interpret=True)(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) * 2)
    assert REGISTRY["toy_double"].single_writer


def test_checked_pallas_call_raises_before_execution():
    def k(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    with pytest.raises(GridWriteError):
        checked_pallas_call(
            "toy_racy", k, grid=(4, 2),
            in_specs=[pl.BlockSpec((1, 8), lambda i, j: (i, 0))],
            out_specs=pl.BlockSpec((1, 8), lambda i, j: (i, 0)),
            out_shape=jax.ShapeDtypeStruct((4, 8), jnp.float32),
            interpret=True)


# ----------------------------------------------------------------------
# Package audit: every kernel in the tree obeys the discipline
# ----------------------------------------------------------------------
def _exercise_all_kernels():
    """Run fwd+bwd of every Pallas op so each call registers."""
    ks = jax.random.split(RNG, 8)
    q = jax.random.normal(ks[0], (1, 64, 2, 16), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 16), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 16), jnp.float32)
    jax.grad(lambda *a: jnp.sum(ops.flash_attention(*a)), argnums=(0, 1, 2))(
        q, k, v)
    x = jax.random.normal(ks[3], (1, 64, 2, 8), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[4], (1, 64, 2)))
    A = -jnp.exp(jax.random.normal(ks[5], (2,)) * 0.5)
    B = jax.random.normal(ks[6], (1, 64, 2, 4), jnp.float32)
    C = jax.random.normal(ks[7], (1, 64, 2, 4), jnp.float32)
    jax.grad(lambda *a: jnp.sum(ops.ssd(*a)[0]), argnums=(0, 1, 3, 4))(
        x, dt, A, B, C)
    from repro.kernels import fused
    x2 = jax.random.normal(ks[0], (48, 16), jnp.float32)
    r2 = jax.random.normal(ks[1], (48, 16), jnp.float32)
    w = jnp.ones((16,), jnp.float32)
    jax.grad(lambda *a: sum(jnp.sum(t) for t in fused.add_rmsnorm(
        *a, interpret=True)), argnums=(0, 1, 2))(x2, r2, w)
    wq = jax.random.normal(ks[2], (16, 32), jnp.float32)
    jax.grad(lambda x, w: sum(jnp.sum(t) for t in fused.qkv(
        x, w, w, w, interpret=True)), argnums=(0, 1))(x2, wq)


EXPECTED_KERNELS = {
    "flash_fwd", "flash_bwd_dq", "flash_bwd_dk", "flash_bwd_dv",
    "ssd_fwd", "ssd_bwd", "fused_norm_fwd", "fused_norm_bwd",
    "fused_qkv_matmul",
}


def test_every_package_kernel_obeys_grid_discipline():
    """The PR 5 regression pin: no output or scratch ref in the package
    is written from more than one iteration of a parallel grid axis."""
    _exercise_all_kernels()
    missing = EXPECTED_KERNELS - set(REGISTRY)
    assert not missing, f"kernels never registered: {sorted(missing)}"
    for name in EXPECTED_KERNELS:
        rec = REGISTRY[name]
        for i, rev in enumerate(rec.revisit_axes):
            assert set(rev) <= set(rec.sequential_axes), (
                f"{name}: output {i} racy on axes "
                f"{set(rev) - set(rec.sequential_axes)}")
        assert set(rec.scratch_carry_axes) <= set(rec.sequential_axes), name


def test_flash_kernels_are_fully_single_writer():
    """All four flash calls need no sequential axes at all — the entire
    grid may be distributed on any backend."""
    _exercise_all_kernels()
    for name in ("flash_fwd", "flash_bwd_dq", "flash_bwd_dk",
                 "flash_bwd_dv"):
        rec = REGISTRY[name]
        assert rec.single_writer, name
        assert rec.sequential_axes == (), name


def test_ssd_kernels_declare_chunk_axis_sequential():
    """SSD keeps its inter-chunk state carry, but on the declared
    sequential chunk axis (innermost) — legal everywhere a lowering
    serializes it."""
    _exercise_all_kernels()
    for name in ("ssd_fwd", "ssd_bwd"):
        rec = REGISTRY[name]
        assert rec.sequential_axes == (2,), name
        assert rec.scratch_carry_axes == (2,), name
        assert len(rec.grid) == 3 and rec.grid[2] >= 1, name
