"""Resilient serving plane (runtime/serve_exec.py, DESIGN.md §14).

The properties under test are the serving analogue of the training
guarantees:

  1. continuous batching never recompiles or syncs: after warm(), the
     steady-state decode loop issues ZERO device->host transfers and a
     mid-traffic failure -> replan -> drain cycle fires ZERO XLA backend
     compiles (ProgramCache keys are (kind, backend, shapes) only);
  2. token streams are bitwise-identical with and without the failure at
     ANY temperature — sampling keys are fold_in(request key, position),
     a pure function of (request, position), never of batch composition;
  3. dissolved-but-intact replicas MIGRATE live cache rows (extract /
     install + topology-aware CopyTasks) instead of replaying;
  4. joins add capacity without touching in-flight streams.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.models import Model
from repro.runtime import (ProgramCache, track_compiles,
                           track_host_transfers)
from repro.runtime.serve_exec import SamplingParams, ServeExecutor
from repro.launch.serve import build_serving_engine

SLOTS = 2
PROMPT = 5
MAX_NEW = 4
MAX_LEN = 16


@pytest.fixture(scope="module")
def setup():
    arch = reduced(get_arch("qwen3-1.7b"), layers=2)
    model = Model(arch, dtype=jnp.float32, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    return arch, model, params, ProgramCache()


def make_executor(setup, *, nodes=6, temperature=0.0, **kw):
    arch, model, params, cache = setup
    engine = build_serving_engine(
        arch, nodes=[f"node{i}" for i in range(nodes)])
    return ServeExecutor(
        model, params, engine, num_slots=SLOTS, max_len=MAX_LEN,
        max_new_cap=8, sampling=SamplingParams(temperature=temperature),
        sample_key=jax.random.PRNGKey(42), cache=cache, **kw)


def prompts(arch, n, plen=PROMPT):
    rng = np.random.default_rng(11)
    return [rng.integers(0, arch.vocab_size, plen).astype(np.int32)
            for _ in range(n)]


def run_trace(ex, arch, n_req, fail_after=None, join_after=None):
    """Submit n_req prompts, optionally fault/join mid-decode, drain,
    and return the token streams keyed by rid."""
    for p in prompts(arch, n_req):
        ex.submit(p, max_new=MAX_NEW)
    ex.tick()
    ex.tick()
    if fail_after is not None:
        victim = ex.engine.instances[0].nodes[0]
        ex.engine.monitor.inject("fail", [victim])
        ex.engine.monitor.poll(0.0)
    if join_after is not None:
        ex.join(join_after)
    ex.drain()
    assert len(ex.completed) == n_req
    return {r.rid: r.tokens for r in ex.completed}


# ----------------------------------------------------------------------
# 1. Steady state: no device->host traffic, no compiles
# ----------------------------------------------------------------------
def test_decode_loop_issues_no_host_transfers(setup):
    arch = setup[0]
    ex = make_executor(setup)
    for p in prompts(arch, 4):
        ex.submit(p, max_new=MAX_NEW)
    ex.tick()                           # admissions settle outside guard

    # control: the instrumentation really does catch a d2h sync
    with track_host_transfers() as ctl:
        float(jnp.ones(()) + 1)
    assert ctl.device_to_host >= 1

    with track_host_transfers() as log:
        ex.tick()                       # pure decode: no admit, no finish
        ex.tick()
    assert log.device_to_host == 0, \
        f"{log.device_to_host} device->host transfers in the decode loop"
    ex.drain()
    assert len(ex.completed) == 4
    assert all(len(r.tokens) == MAX_NEW for r in ex.completed)


# ----------------------------------------------------------------------
# 2. Failure mid-decode: zero compiles, bitwise-identical streams
# ----------------------------------------------------------------------
@pytest.mark.parametrize("temperature", [0.0, 0.8])
def test_failure_mid_decode_is_recompile_free_and_bitwise(setup,
                                                          temperature):
    arch = setup[0]
    baseline = run_trace(make_executor(setup, temperature=temperature),
                         arch, 6)

    ex = make_executor(setup, temperature=temperature)
    for p in prompts(arch, 6):
        ex.submit(p, max_new=MAX_NEW)
    ex.tick()
    ex.tick()
    with track_compiles() as log:
        victim = ex.engine.instances[0].nodes[0]
        ex.engine.monitor.inject("fail", [victim])
        ex.engine.monitor.poll(0.0)
        ex.drain()
    assert log.backend_compiles == 0, \
        f"{log.backend_compiles} XLA compiles during fail->recover->drain"
    assert ex.last_recovery is not None
    assert ex.last_recovery["policy"] == "replan"
    assert ex.last_recovery["replayed"] >= 1
    assert len(ex.completed) == 6
    streams = {r.rid: r.tokens for r in ex.completed}
    for rid, toks in baseline.items():
        np.testing.assert_array_equal(
            streams[rid], toks,
            f"rid {rid} diverged after failure (T={temperature})")


def test_replayed_requests_keep_streamed_prefix(setup):
    """Tokens already streamed to the client before the failure are
    teacher-forced back in, never regenerated."""
    arch = setup[0]
    ex = make_executor(setup, temperature=0.8)
    for p in prompts(arch, 4):
        ex.submit(p, max_new=MAX_NEW)
    ex.tick()
    ex.tick()                           # every stream has >= 2 tokens out
    pre = {r.rid: np.asarray(rep.out[slot])[:int(rep.ngen_h[slot])]
           for rep in ex.replicas
           for slot, r in enumerate(rep.requests) if r is not None}
    victim = ex.engine.instances[0].nodes[0]
    ex.engine.monitor.inject("fail", [victim])
    ex.engine.monitor.poll(0.0)
    replayed = [r for r in list(ex.queue) if r.replays > 0]
    assert replayed and all(len(r.prior) >= 2 for r in replayed)
    ex.drain()
    for r in ex.completed:
        np.testing.assert_array_equal(r.tokens[:len(pre[r.rid])],
                                      pre[r.rid])


# ----------------------------------------------------------------------
# 3. Sampling determinism
# ----------------------------------------------------------------------
def test_sampling_is_a_pure_function_of_request_and_position(setup):
    arch = setup[0]
    ex = make_executor(setup, temperature=0.9)
    p = prompts(arch, 1)[0]
    ex.submit(p, max_new=MAX_NEW, rid=7)
    ex.submit(p, max_new=MAX_NEW, rid=7)    # same identity -> same stream
    ex.submit(p, max_new=MAX_NEW, rid=8)    # new identity  -> fresh stream
    ex.drain()
    by_order = sorted(ex.completed, key=lambda r: r.arrival_s)
    a, b, c = by_order
    np.testing.assert_array_equal(a.tokens, b.tokens)
    assert not np.array_equal(a.tokens, c.tokens), \
        "independent requests produced identical samples"


def test_greedy_ignores_rid_and_matches_reference_decode(setup):
    """At temperature 0 the slot machinery must reproduce plain
    prefill + argmax decode exactly."""
    arch, model, params, _ = setup
    ex = make_executor(setup)
    p = prompts(arch, 1)[0]
    ex.submit(p, max_new=MAX_NEW)
    ex.drain()
    got = ex.completed[0].tokens

    cache = model.init_cache(1, MAX_LEN)
    toks = list(p)
    ref = []
    for t in range(len(p) + MAX_NEW - 1):
        logits, cache = model.decode_step(
            params, jnp.asarray([[toks[t]]], jnp.int32), cache,
            jnp.asarray(t, jnp.int32))
        if t >= len(p) - 1:
            nxt = int(jnp.argmax(logits[0, 0]))
            ref.append(nxt)
            if t + 1 < len(p) + MAX_NEW:
                toks.append(nxt)
    np.testing.assert_array_equal(got, np.asarray(ref[:MAX_NEW], np.int32))


# ----------------------------------------------------------------------
# 4. Migration of dissolved-but-intact replicas
# ----------------------------------------------------------------------
def test_dissolved_replica_migrates_cache_rows(setup):
    """When a replan dissolves a replica whose nodes all survive, its
    in-flight rows move via extract/install + CopyTasks on the transfer
    topology — and the streams stay bitwise-identical."""
    arch = setup[0]
    baseline = run_trace(make_executor(setup, temperature=0.8), arch, 2)

    ex = make_executor(setup, temperature=0.8)
    for p in prompts(arch, 2):
        ex.submit(p, max_new=MAX_NEW)
    ex.tick()                           # both land on replica 0
    ex.tick()
    old = ex.replicas
    assert old[0].active_mask().sum() == 2 and not old[1].active_mask().any()
    ex.engine.instances = [ex.engine.instances[1]]   # dissolve replica 0
    with track_compiles() as log:
        info = ex._rebind(old, set())
        ex.drain()
    assert log.backend_compiles == 0
    assert info["migrated"] == 2 and info["replayed"] == 0
    assert info["copy_bytes"] > 0
    assert info["transfer_makespan_s"] > 0
    assert len(ex.completed) == 2
    assert all(r.migrations == 1 for r in ex.completed)
    for r in ex.completed:
        np.testing.assert_array_equal(r.tokens, baseline[r.rid])


def test_migration_overflow_falls_back_to_replay(setup):
    """More in-flight rows than free slots: the overflow replays from the
    host-known prefix instead of being dropped."""
    arch = setup[0]
    ex = make_executor(setup, temperature=0.8)
    for p in prompts(arch, 4):          # fills both replicas
        ex.submit(p, max_new=MAX_NEW)
    ex.tick()
    ex.tick()
    old = ex.replicas
    ex.engine.instances = [ex.engine.instances[1]]
    info = ex._rebind(old, set())
    assert info["migrated"] == 0        # target replica has no free slots
    assert info["replayed"] == 2
    ex.drain()
    assert len(ex.completed) == 4


# ----------------------------------------------------------------------
# 5. Join mid-traffic
# ----------------------------------------------------------------------
def test_join_mid_traffic_is_recompile_free_and_bitwise(setup):
    arch = setup[0]
    baseline = run_trace(make_executor(setup, temperature=0.8), arch, 6)
    ex = make_executor(setup, temperature=0.8)
    for p in prompts(arch, 6):
        ex.submit(p, max_new=MAX_NEW)
    ex.tick()
    ex.tick()
    before = len(ex.replicas)
    with track_compiles() as log:
        ex.join(["node6", "node7"])
        ex.drain()
    assert log.backend_compiles == 0
    assert ex.last_recovery["policy"] == "join"
    assert len(ex.replicas) > before
    assert len(ex.completed) == 6
    for r in ex.completed:
        np.testing.assert_array_equal(r.tokens, baseline[r.rid])


# ----------------------------------------------------------------------
# 6. Scheduler semantics
# ----------------------------------------------------------------------
def test_static_admission_waits_for_full_drain(setup):
    """The static baseline only refills an empty replica; continuous
    batching backfills freed slots immediately.  With skewed lengths the
    short request's slot sits idle under static admission."""
    arch = setup[0]
    lengths = [2, 8, 2, 8, 2, 2]

    def finish_ticks(mode):
        ex = make_executor(setup, admission=mode)
        for p, n in zip(prompts(arch, len(lengths)), lengths):
            ex.submit(p, max_new=n)
        ex.drain()
        return ex.ticks

    assert finish_ticks("continuous") < finish_ticks("static")


def test_submit_validates_against_compiled_shapes(setup):
    arch = setup[0]
    ex = make_executor(setup)
    with pytest.raises(ValueError):
        ex.submit(prompts(arch, 1, plen=12)[0], max_new=MAX_LEN)
    with pytest.raises(ValueError):
        ex.submit(prompts(arch, 1)[0], max_new=9)   # > out-ring cap
    snap = ex.snapshot()
    assert snap["in_flight"] == [] and snap["queued"] == []
