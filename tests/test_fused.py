"""Parity of the fused stage epilogues (kernels/fused.py, DESIGN.md §13)
against the unfused reference formulation, across dtypes and odd
(non-block-multiple) shapes, for values AND gradients — plus the ops.py
routing contract (Pallas where the probe lowers, XLA fallback where it
doesn't) and the model-level fused == unfused equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import fused, ops
from repro.models.layers import rms_norm

RNG = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5)


def _norm_inputs(rows, d, dtype):
    ks = jax.random.split(RNG, 3)
    x = jax.random.normal(ks[0], (rows, d)).astype(dtype)
    r = jax.random.normal(ks[1], (rows, d)).astype(dtype)
    w = (jax.random.normal(ks[2], (d,)) * 0.2 + 1.0).astype(dtype)
    return x, r, w


def _unfused_norm(x, r, w, eps=1e-6):
    res = x + r
    return res, rms_norm(w, res, eps)


# ----------------------------------------------------------------------
# add_rmsnorm: Pallas kernel vs unfused layers formulation
# ----------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rows,d", [(64, 64), (33, 48), (7, 96), (129, 40)])
def test_add_rmsnorm_forward_parity(rows, d, dtype):
    x, r, w = _norm_inputs(rows, d, dtype)
    res_f, h_f = fused.add_rmsnorm(x, r, w, block_rows=32, interpret=True)
    res_u, h_u = _unfused_norm(x, r, w)
    assert res_f.dtype == h_f.dtype == dtype
    # the residual add is bit-identical; the norm matches layers.rms_norm
    np.testing.assert_array_equal(np.asarray(res_f), np.asarray(res_u))
    np.testing.assert_allclose(np.asarray(h_f, np.float32),
                               np.asarray(h_u, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rows,d", [(64, 64), (33, 48)])
def test_add_rmsnorm_gradient_parity(rows, d, dtype):
    x, r, w = _norm_inputs(rows, d, dtype)
    ks = jax.random.split(jax.random.PRNGKey(8), 2)
    gres = jax.random.normal(ks[0], (rows, d)).astype(dtype)
    gh = jax.random.normal(ks[1], (rows, d)).astype(dtype)

    def loss(fn):
        def f(x, r, w):
            res, h = fn(x, r, w)
            return (jnp.sum(res.astype(jnp.float32) * gres.astype(jnp.float32))
                    + jnp.sum(h.astype(jnp.float32) * gh.astype(jnp.float32)))
        return jax.grad(f, argnums=(0, 1, 2))

    gk = loss(lambda x, r, w: fused.add_rmsnorm(
        x, r, w, block_rows=32, interpret=True))(x, r, w)
    gu = loss(_unfused_norm)(x, r, w)
    for a, b, nm in zip(gk, gu, ("dx", "dr", "dw")):
        assert a.dtype == b.dtype, nm
        tol = _tol(dtype)
        if nm == "dw" and dtype == jnp.bfloat16:
            # dw is a row reduction: the kernel accumulates fp32
            # partials while the XLA reference rounds through bf16 per
            # row, so the two drift by O(sqrt(rows)·eps_bf16) — compare
            # at reduction, not elementwise, precision
            tol = dict(rtol=5e-2, atol=0.3)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   err_msg=nm, **tol)


def test_add_rmsnorm_ref_matches_layers():
    x, r, w = _norm_inputs(40, 56, jnp.float32)
    res_a, h_a = fused.add_rmsnorm_ref(x, r, w)
    res_b, h_b = _unfused_norm(x, r, w)
    np.testing.assert_array_equal(np.asarray(res_a), np.asarray(res_b))
    np.testing.assert_array_equal(np.asarray(h_a), np.asarray(h_b))


# ----------------------------------------------------------------------
# fused QKV: one concatenated GEMM vs three projections
# ----------------------------------------------------------------------
def _qkv_inputs(rows, d, cq, ckv, dtype, bias):
    ks = jax.random.split(RNG, 7)
    x = jax.random.normal(ks[0], (2, rows, d)).astype(dtype)
    wq = (jax.random.normal(ks[1], (d, cq)) * d ** -0.5).astype(jnp.float32)
    wk = (jax.random.normal(ks[2], (d, ckv)) * d ** -0.5).astype(jnp.float32)
    wv = (jax.random.normal(ks[3], (d, ckv)) * d ** -0.5).astype(jnp.float32)
    if bias:
        b = [jax.random.normal(ks[4 + i], (c,)).astype(jnp.float32)
             for i, c in enumerate((cq, ckv, ckv))]
    else:
        b = [None, None, None]
    return x, wq, wk, wv, b


def _unfused_qkv(x, wq, wk, wv, bq, bk, bv):
    outs = []
    for w, b in ((wq, bq), (wk, bk), (wv, bv)):
        y = x @ w.astype(x.dtype)
        if b is not None:
            y = y + b.astype(x.dtype)
        outs.append(y)
    return tuple(outs)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("bias", [False, True])
@pytest.mark.parametrize("rows,d,cq,ckv", [(32, 64, 64, 32), (21, 48, 40, 24)])
def test_fused_qkv_forward_parity(rows, d, cq, ckv, dtype, bias):
    x, wq, wk, wv, b = _qkv_inputs(rows, d, cq, ckv, dtype, bias)
    out_f = fused.qkv(x, wq, wk, wv, *b, block_m=16, block_n=32,
                      interpret=True)
    out_u = _unfused_qkv(x, wq, wk, wv, *b)
    for a, u, nm in zip(out_f, out_u, "qkv"):
        assert a.shape == u.shape and a.dtype == dtype, nm
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(u, np.float32),
                                   err_msg=nm, **_tol(dtype))


@pytest.mark.parametrize("bias", [False, True])
def test_fused_qkv_gradient_parity(bias):
    x, wq, wk, wv, b = _qkv_inputs(24, 32, 32, 16, jnp.float32, bias)

    def loss(fn):
        def f(x, wq, wk, wv):
            q, k, v = fn(x, wq, wk, wv, *b)
            return jnp.sum(q * q) + jnp.sum(k) + jnp.sum(v * 0.5)
        return jax.grad(f, argnums=(0, 1, 2, 3))

    gk = loss(lambda *a: fused.qkv(*a, block_m=16, block_n=16,
                                   interpret=True))(x, wq, wk, wv)
    gu = loss(_unfused_qkv)(x, wq, wk, wv)
    for a, u, nm in zip(gk, gu, ("dx", "dwq", "dwk", "dwv")):
        assert a.dtype == u.dtype, nm
        np.testing.assert_allclose(np.asarray(a), np.asarray(u),
                                   rtol=1e-4, atol=1e-4, err_msg=nm)


def test_fused_qkv_ref_matches_unfused():
    x, wq, wk, wv, b = _qkv_inputs(16, 32, 32, 16, jnp.float32, True)
    out_a = fused.qkv_ref(x, wq, wk, wv, *b)
    out_u = _unfused_qkv(x, wq, wk, wv, *b)
    for a, u in zip(out_a, out_u):
        np.testing.assert_allclose(np.asarray(a), np.asarray(u),
                                   rtol=1e-6, atol=1e-6)


# ----------------------------------------------------------------------
# ops.py routing + model-level equivalence
# ----------------------------------------------------------------------
def test_ops_fused_routing_follows_probe(monkeypatch):
    """Where the probe says no lowering, ops must take the XLA ref (an
    interpreted Pallas elementwise kernel would LOSE to XLA fusion);
    where it says lowered, the Pallas tiles."""
    calls = {}
    monkeypatch.setattr(fused, "add_rmsnorm",
                        lambda *a, **k: calls.setdefault("pallas", True)
                        or fused.add_rmsnorm_ref(*a[:3]))
    x, r, w = _norm_inputs(16, 32, jnp.float32)
    monkeypatch.setattr(ops, "kernel_lowers", lambda kind, backend=None: False)
    ops.fused_add_rmsnorm(x, r, w)
    assert "pallas" not in calls
    monkeypatch.setattr(ops, "kernel_lowers", lambda kind, backend=None: True)
    monkeypatch.setattr(ops.autotune, "fused_config",
                        lambda *a: {"block_rows": 16, "block_cols": 32})
    ops.fused_add_rmsnorm(x, r, w)
    assert calls.get("pallas")


def test_model_fuse_matches_unfused_model():
    """fuse='fused' and fuse='none' are the same model: identical loss
    and gradients at fp32 tolerances."""
    from repro.configs import get_arch, reduced
    from repro.models import Model
    arch = reduced(get_arch("gpt3_medium"), layers=2)
    batch = {"tokens": jnp.arange(2 * 48, dtype=jnp.int32).reshape(2, 48)
             % arch.vocab_size,
             "labels": jnp.ones((2, 48), jnp.int32)}
    out = {}
    for fuse in ("fused", "none"):
        m = Model(arch, dtype=jnp.float32, attn_impl="blocked", fuse=fuse)
        p = m.init(jax.random.PRNGKey(0))
        loss, _ = m.loss(p, batch)
        grads = jax.grad(lambda p: m.loss(p, batch)[0])(p)
        out[fuse] = (loss, grads)
    np.testing.assert_allclose(out["fused"][0], out["none"][0],
                               rtol=1e-6, atol=1e-6)
    for a, b in zip(jax.tree.leaves(out["fused"][1]),
                    jax.tree.leaves(out["none"][1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-5, atol=5e-5)


def test_model_auto_resolves_fuse():
    from repro.configs import get_arch, reduced
    from repro.models import Model
    arch = reduced(get_arch("gpt3_medium"), layers=2)
    assert Model(arch).fuse == "fused"
    assert Model(arch, fuse="none").fuse == "none"
