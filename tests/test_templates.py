"""Node-spec generation + Frobenius coverage guarantee (paper §4.1.1, App. A)."""
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import PlanningError, coverable, generate_node_spec


def test_paper_example_spec():
    # Figure 4: N=13, f mentioned via examples; templates 2,3,4 is a valid
    # subset; our generator takes the largest p: sizes n0..N-f*n0.
    spec = generate_node_spec(N=13, f=2, n0=2)
    assert spec.sizes[0] == 2
    assert spec.sizes == tuple(range(2, 13 - 2 * 2 + 1))
    assert spec.p == len(spec.sizes)


def test_consecutive_sizes_property():
    spec = generate_node_spec(N=30, f=3, n0=4)
    diffs = {b - a for a, b in zip(spec.sizes, spec.sizes[1:])}
    assert diffs == {1}
    assert spec.max_size() == 30 - 3 * 4


def test_too_small_cluster_raises():
    with pytest.raises(PlanningError):
        generate_node_spec(N=5, f=2, n0=2)  # needs >= 6


def test_invalid_inputs():
    with pytest.raises(PlanningError):
        generate_node_spec(N=10, f=-1, n0=2)
    with pytest.raises(PlanningError):
        generate_node_spec(N=10, f=0, n0=0)


@settings(max_examples=60, deadline=None)
@given(N=st.integers(4, 40), f=st.integers(0, 4), n0=st.integers(1, 5))
def test_theorem_a1_every_feasible_count_coverable(N, f, n0):
    """Thm A.1: every N' in [(f+1)*n0, N] is a sum of >= f+1 template
    sizes.  This is THE fault-tolerance guarantee of the paper."""
    if (f + 1) * n0 > N:
        with pytest.raises(PlanningError):
            generate_node_spec(N=N, f=f, n0=n0)
        return
    try:
        spec = generate_node_spec(N=N, f=f, n0=n0)
    except PlanningError:
        return  # p <= n0-1 edge rejected with exhaustive check — acceptable
    for n_prime in range((f + 1) * n0, N + 1):
        assert coverable(n_prime, spec), (
            f"N'={n_prime} not coverable with sizes {spec.sizes}, f={f}")


def test_below_floor_not_coverable():
    spec = generate_node_spec(N=13, f=2, n0=2)
    assert not coverable(5, spec)   # < (f+1)*n0 = 6
    assert coverable(6, spec)
