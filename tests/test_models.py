"""Model-family correctness: evaluator equivalences + decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SSMConfig, get_arch, reduced
from repro.models import Model
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib

RNG = jax.random.PRNGKey(7)


# ----------------------------------------------------------------------
# SSD evaluator equivalence
# ----------------------------------------------------------------------
@pytest.mark.parametrize("S,chunk", [(16, 4), (17, 4), (32, 8), (5, 8)])
def test_ssd_chunked_matches_scan(S, chunk):
    b, H, P, N = 2, 3, 4, 8
    ks = jax.random.split(RNG, 5)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B = jax.random.normal(ks[3], (b, S, H, N))
    C = jax.random.normal(ks[4], (b, S, H, N))
    y_ref, st_ref = ssm_lib.ssd_scan(x, dt, A, B, C)
    y_chk, st_chk = ssm_lib.ssd_chunked(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(y_chk, y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st_chk, st_ref, rtol=2e-4, atol=2e-4)


def test_ssd_step_continues_scan():
    b, S, H, P, N = 1, 9, 2, 4, 8
    ks = jax.random.split(RNG, 5)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B = jax.random.normal(ks[3], (b, S, H, N))
    C = jax.random.normal(ks[4], (b, S, H, N))
    y_all, _ = ssm_lib.ssd_scan(x, dt, A, B, C)
    state = jnp.zeros((b, H, P, N), jnp.float32)
    for t in range(S):
        y_t, state = ssm_lib.ssd_step(x[:, t], dt[:, t], A, B[:, t], C[:, t],
                                      state)
        np.testing.assert_allclose(y_t, y_all[:, t], rtol=1e-4, atol=1e-4)


def test_conv_step_matches_full():
    b, S, dim, width = 2, 10, 6, 4
    ks = jax.random.split(RNG, 3)
    x = jax.random.normal(ks[0], (b, S, dim))
    w = jax.random.normal(ks[1], (width, dim)) * 0.3
    bias = jax.random.normal(ks[2], (dim,)) * 0.1
    full = ssm_lib.causal_conv1d(x, w, bias)
    state = jnp.zeros((b, width - 1, dim))
    for t in range(S):
        y_t, state = ssm_lib.conv_step(x[:, t], state, w, bias)
        np.testing.assert_allclose(y_t, full[:, t], rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------------
# Attention equivalences
# ----------------------------------------------------------------------
@pytest.mark.parametrize("S,window", [(32, 0), (33, 0), (64, 16), (16, 64)])
def test_blocked_attention_matches_naive(S, window):
    b, H, KV, D = 2, 4, 2, 8
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (b, S, H, D))
    k = jax.random.normal(ks[1], (b, S, KV, D))
    v = jax.random.normal(ks[2], (b, S, KV, D))
    ref = attn_lib._sdpa_naive(q, k, v, causal=True, window=window)
    blk = attn_lib._sdpa_blocked(q, k, v, causal=True, window=window,
                                 block_kv=8)
    np.testing.assert_allclose(blk, ref, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("arch_name", ["qwen3_1_7b", "qwen2_5_3b", "glm4_9b"])
def test_decode_matches_forward(arch_name):
    """Teacher-forced decode must reproduce full-forward logits."""
    arch = reduced(get_arch(arch_name), layers=2)
    m = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive")
    params = m.init(RNG)
    B, S = 1, 8
    tokens = jax.random.randint(RNG, (B, S), 0, arch.vocab_size)
    full_logits, _ = m.forward(params, tokens)
    cache = m.init_cache(B, S)
    step = jax.jit(m.decode_step)
    for t in range(S):
        logits, cache = step(params, tokens[:, t:t + 1], cache, jnp.int32(t))
        np.testing.assert_allclose(
            logits[:, 0], full_logits[:, t], rtol=2e-4, atol=2e-4)


def test_decode_matches_forward_ssm():
    arch = reduced(get_arch("mamba2_780m"), layers=2)
    m = Model(arch, dtype=jnp.float32, remat=False, ssd_impl="scan")
    params = m.init(RNG)
    B, S = 1, 6
    tokens = jax.random.randint(RNG, (B, S), 0, arch.vocab_size)
    full_logits, _ = m.forward(params, tokens)
    cache = m.init_cache(B, S)
    step = jax.jit(m.decode_step)
    for t in range(S):
        logits, cache = step(params, tokens[:, t:t + 1], cache, jnp.int32(t))
        np.testing.assert_allclose(
            logits[:, 0], full_logits[:, t], rtol=5e-4, atol=5e-4)


def test_decode_matches_forward_hybrid():
    arch = reduced(get_arch("hymba_1_5b"), layers=2)
    # full attention at short length (window larger than S)
    m = Model(arch, dtype=jnp.float32, remat=False, ssd_impl="scan",
              attn_impl="naive")
    params = m.init(RNG)
    B, S = 1, 6
    tokens = jax.random.randint(RNG, (B, S), 0, arch.vocab_size)
    full_logits, _ = m.forward(params, tokens)
    cache = m.init_cache(B, S)
    step = jax.jit(m.decode_step)
    for t in range(S):
        logits, cache = step(params, tokens[:, t:t + 1], cache, jnp.int32(t))
        np.testing.assert_allclose(
            logits[:, 0], full_logits[:, t], rtol=5e-4, atol=5e-4)


# ----------------------------------------------------------------------
# MoE
# ----------------------------------------------------------------------
def test_moe_dense_matches_grouped():
    arch = reduced(get_arch("granite_moe_1b_a400m"))
    p = moe_lib.init_moe(RNG, arch)
    x = jax.random.normal(RNG, (2, 8, arch.d_model))
    y_d, aux_d = moe_lib.moe_mlp(p, arch, x)
    y_g, aux_g = moe_lib.moe_mlp_grouped(p, arch, x)
    np.testing.assert_allclose(y_g, y_d, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(aux_g, aux_d, rtol=1e-5, atol=1e-5)


def test_moe_topk_sparsity():
    """Routing uses exactly top_k experts per token."""
    arch = reduced(get_arch("qwen2_moe_a2_7b"))
    p = moe_lib.init_moe(RNG, arch)
    x = jax.random.normal(RNG, (1, 4, arch.d_model))
    logits = x.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    _, top_i = jax.lax.top_k(probs, arch.moe.top_k)
    assert top_i.shape[-1] == arch.moe.top_k


# ----------------------------------------------------------------------
# Sliding-window ring-buffer decode
# ----------------------------------------------------------------------
def test_swa_ring_buffer_decode():
    """Decode beyond the window must keep matching the windowed forward."""
    import dataclasses as dc
    arch = reduced(get_arch("hymba_1_5b"), layers=1)
    arch = dc.replace(arch, sliding_window=4, ssm=None,
                      hybrid_parallel_heads=False, family="dense")
    m = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive")
    params = m.init(RNG)
    B, S = 1, 12
    tokens = jax.random.randint(RNG, (B, S), 0, arch.vocab_size)
    full_logits, _ = m.forward(params, tokens)
    cache = m.init_cache(B, S)
    assert cache["attn"]["k"].shape[2] == 4      # ring buffer = window
    step = jax.jit(m.decode_step)
    for t in range(S):
        logits, cache = step(params, tokens[:, t:t + 1], cache, jnp.int32(t))
        np.testing.assert_allclose(
            logits[:, 0], full_logits[:, t], rtol=3e-4, atol=3e-4)
