"""Fault-injection conformance suite (ISSUE 3): kill nodes mid-step,
during gradient sync, and during an in-flight checkpoint, across all
three Executor implementations (HeteroTrainer compiled+eager,
SPMDExecutor, the simulator's OobleckPolicy).

The contract under test: after any injected failure the engine either
  * recovers to BIT-IDENTICAL params (vs an unfailed reference run at
    the same committed step, and across replicas), or
  * raises InsufficientReplicasError cleanly — params untouched, the
    exit checkpoint valid —
and NEVER leaves a corrupt state (partially-updated layers, a
checkpoint manifest pointing at missing shards, a transfer plan reading
a dead node)."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.ckpt.checkpoint as ckpt_mod
from repro.ckpt import CheckpointManager
from repro.configs import get_arch, reduced
from repro.core import (EngineConfig, InsufficientReplicasError,
                        OobleckEngine, build_profile,
                        verify_replica_coverage)
from repro.core.monitor import NodeChangeMonitor
from repro.data import GlobalBatchDispenser, SyntheticLM
from repro.models import Model
from repro.optim import adamw
from repro.runtime import (Executor, ExecutorUnsupported, HeteroTrainer,
                           SPMDExecutor)
from repro.sim import OobleckPolicy, PolicyStopped, TraceEvent, run_sim

RNG = jax.random.PRNGKey(21)
GB, MB, SEQ = 16, 2, 16


class NodeKilled(RuntimeError):
    """Injected mid-step failure."""


def make_setup(layers=4, n_nodes=5):
    arch = reduced(get_arch("gpt3_medium"), layers=layers)
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive",
                  scan_layers=False)
    params = model.init(RNG)
    profile = build_profile(arch, microbatch=MB, seq_len=SEQ)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, clip_norm=1.0,
                                weight_decay=0.0)

    def mk_engine(**kw):
        return OobleckEngine(
            profile, [f"n{i}" for i in range(n_nodes)],
            EngineConfig(fault_tolerance=1, global_batch=GB, microbatch=MB,
                         gpus_per_node=1, n0_override=2, nodes_per_pod=4),
            **kw)
    return arch, model, params, opt_cfg, mk_engine


def microbatches(batch, mb_size):
    n = batch["tokens"].shape[0] // mb_size
    return [{k: v[i * mb_size:(i + 1) * mb_size] for k, v in batch.items()
             if not k.startswith("_")} for i in range(n)]


def drive(trainer, disp):
    batches = disp.next_step(trainer.engine.batch.minibatch_sizes())
    return trainer.train_step([microbatches(b, MB) for b in batches])


def assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def assert_params_track(a, b, lr=1e-3):
    """Tolerance comparison for runs whose batch PARTITIONING differs
    (same samples, different float association order; Adam turns ULP
    sign flips into O(lr) moves on isolated elements)."""
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        x, y = np.asarray(x), np.asarray(y)
        diff = np.abs(x - y)
        assert diff.max() <= 2.5 * lr, diff.max()
        assert (diff > lr / 10).mean() < 1e-3, (diff > lr / 10).mean()


# ----------------------------------------------------------------------
# 1. HeteroTrainer: kill mid-step and during gradient sync
# ----------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["compiled", "eager"])
@pytest.mark.parametrize("phase", ["grads", "sync"])
def test_hetero_kill_recovers_bit_identical(mode, phase):
    """A failure raised while gradients are being computed ("grads") or
    during the cross-replica sync ("sync") aborts the iteration with NO
    state mutation: post-recovery params are bit-identical to the
    unfailed reference at the same committed step, the lost iteration is
    retried on the SAME samples, and replicas never diverge."""
    arch, model, params, opt_cfg, mk_engine = make_setup()
    ref = HeteroTrainer(model, mk_engine(), params, opt_cfg, mode=mode)
    vic = HeteroTrainer(model, mk_engine(), params, opt_cfg, mode=mode)
    src = SyntheticLM(arch.vocab_size, SEQ, seed=13)
    dr, dv = GlobalBatchDispenser(src), GlobalBatchDispenser(src)

    for _ in range(2):
        drive(ref, dr), drive(vic, dv)
    committed = ref.full_params()

    victim = vic.engine.instances[0].nodes[-1]

    def inject(p):
        if p == phase:
            raise NodeKilled(victim)
    vic.on_phase = inject
    with pytest.raises(NodeKilled):
        drive(vic, dv)
    vic.on_phase = None
    dv.rewind(GB)                       # the in-flight iteration is lost
    info = vic.recover({victim})

    # --- the acceptance bit: recovery == surviving replicas, exactly ---
    assert_trees_equal(vic.full_params(), committed)
    assert vic.replica_divergence() == 0.0
    assert info["transfer"]["bytes"] >= 0
    assert verify_replica_coverage(vic.engine.instances)

    # retried iteration consumes the SAME samples (repartitioned), and
    # both runs keep tracking
    out_v = drive(vic, dv)
    out_r = drive(ref, dr)
    assert dv.state() == dr.state()
    assert abs(float(out_v["loss"]) - float(out_r["loss"])) < 1e-4
    assert_params_track(vic.full_params(), ref.full_params())
    assert vic.replica_divergence() == 0.0


def test_hetero_kill_during_inflight_checkpoint(tmp_path):
    """Failure + recovery while an async checkpoint save is mid-flight:
    the save must complete bit-exact (GC pinning), and recovery must not
    be perturbed by the concurrent writer."""
    arch, model, params, opt_cfg, mk_engine = make_setup()
    trainer = HeteroTrainer(model, mk_engine(), params, opt_cfg,
                            mode="eager")
    src = SyntheticLM(arch.vocab_size, SEQ, seed=17)
    disp = GlobalBatchDispenser(src)
    drive(trainer, disp)
    mgr = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                            async_mode=True, keep=1)

    stalled, resume = threading.Event(), threading.Event()
    orig = ckpt_mod._save_manifest

    def stalling(path, meta):
        stalled.set()
        resume.wait(timeout=30)
        orig(path, meta)
    ckpt_mod._save_manifest = stalling
    try:
        snap = trainer.snapshot(disp.state(), 0)
        mgr.save(snap)                  # async, stalls before the manifest
        assert stalled.wait(timeout=30)
        victim = trainer.engine.instances[0].nodes[-1]
        trainer.recover({victim})       # failure lands mid-checkpoint
        assert trainer.replica_divergence() == 0.0
        assert_trees_equal(trainer.full_params(), snap.params)
        drive(trainer, disp)            # training continues immediately
    finally:
        ckpt_mod._save_manifest = orig
        resume.set()
    mgr.wait()
    steps = mgr.list_steps()
    assert steps == [snap.step]
    assert mgr.verify(snap.step), "in-flight checkpoint ended up corrupt"
    restored = mgr.restore(snap.params, snap.opt_state)
    assert_trees_equal(restored.params, snap.params)


def test_hetero_below_floor_raises_cleanly_with_valid_checkpoint(tmp_path):
    """Killing below (f+1)*n0 must raise InsufficientReplicasError with
    params untouched and the §3.4 exit checkpoint valid + restorable."""
    arch, model, params, opt_cfg, mk_engine = make_setup(n_nodes=5)
    mgr = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                            async_mode=False)
    holder = {}
    engine = mk_engine(on_checkpoint=lambda: mgr.save(
        holder["t"].snapshot(holder["d"].state(), 0), block=True))
    trainer = HeteroTrainer(model, engine, params, opt_cfg, mode="eager")
    src = SyntheticLM(arch.vocab_size, SEQ, seed=19)
    disp = GlobalBatchDispenser(src)
    holder["t"], holder["d"] = trainer, disp
    drive(trainer, disp)
    before = trainer.full_params()

    # 5 nodes, f=1, n0=2: one failure is fine, the second goes below floor
    trainer.recover({engine.instances[0].nodes[-1]})
    assert_trees_equal(trainer.full_params(), before)
    with pytest.raises(InsufficientReplicasError):
        trainer.recover({engine.instances[0].nodes[-1]})
    assert engine.stopped
    # params survived the failed transition bit-exact
    assert_trees_equal(trainer.full_params(), before)
    assert trainer.replica_divergence() == 0.0
    # the exit checkpoint is complete, verifiable, and restores bit-exact
    steps = mgr.list_steps()
    assert len(steps) == 1
    assert mgr.verify(steps[0])
    restored = mgr.restore(before, adamw.init(before))
    assert_trees_equal(restored.params, before)


# ----------------------------------------------------------------------
# 2. SPMDExecutor: failure degrades to a HeteroTrainer rebind
# ----------------------------------------------------------------------
def test_spmd_kill_rebinds_hetero_bit_identical():
    """The single-program SPMD fast path cannot reconfigure in place; its
    conformance contract is: refuse (ExecutorUnsupported), keep the
    engine's PLAN consistent, and let the caller rebind a HeteroTrainer
    from snapshot() with params bit-identical."""
    arch, model, params, opt_cfg, mk_engine = make_setup(layers=2)
    engine = mk_engine()
    ex = SPMDExecutor(model, params, opt_cfg, engine=engine)
    assert isinstance(ex, Executor)
    src = SyntheticLM(arch.vocab_size, SEQ, seed=23)
    batch = src.batch(np.arange(8))
    ex.step(batch)
    with pytest.raises(ExecutorUnsupported):
        ex.recover({engine.instances[0].nodes[-1]})

    # the monitor path swallows ExecutorUnsupported and replans
    victim = engine.instances[0].nodes[-1]
    engine.monitor.inject(NodeChangeMonitor.FAIL, [victim])
    engine.monitor.poll(now=0.0)
    assert victim not in engine.nodes
    assert verify_replica_coverage(engine.instances)

    snap = ex.snapshot()
    rebound = HeteroTrainer(model, engine, snap.params, opt_cfg,
                            mode="eager")
    assert_trees_equal(rebound.full_params(), snap.params)
    assert rebound.replica_divergence() == 0.0
    disp = GlobalBatchDispenser(src)
    out = drive(rebound, disp)
    assert np.isfinite(float(out["loss"]))


# ----------------------------------------------------------------------
# 3. Simulator policy: same contract at plan level
# ----------------------------------------------------------------------
def _sim_profile():
    import dataclasses as dc
    arch = dc.replace(get_arch("gpt2"), name="gpt2_L18", num_layers=18)
    return build_profile(arch, microbatch=2, seq_len=256)


def test_policy_kill_midstep_accounting_and_coverage():
    """A failure landing INSIDE a simulated iteration: the partial
    iteration is charged to fallback (never committed), downtime is the
    data-plane breakdown, and coverage is restored."""
    prof = _sim_profile()
    nodes = [f"n{i}" for i in range(12)]
    pol = OobleckPolicy(prof, nodes, f=1, global_batch=256, microbatch=2,
                        n0=4, nodes_per_pod=4)
    assert isinstance(pol, Executor)
    it = pol.iteration_time()
    events = [TraceEvent(2.5 * it, "fail", (nodes[-1],))]  # mid-iteration 3
    res = run_sim(pol, events, horizon=20 * it, global_batch=256)
    assert res.stopped_reason is None
    assert res.breakdown["fallback"] > 0.0
    assert res.breakdown["downtime"] > 0.0
    assert pol.engine.metrics.lost_iterations == 1
    assert verify_replica_coverage(pol.engine.instances)
    assert sum(pol.engine.batch.num_microbatches) * 2 == 256
    bd = pol.last_breakdown
    assert bd is not None and bd["transfer"] >= 0.0 and bd["compile"] == 0.0


def test_policy_below_floor_stops_cleanly_and_checkpoints():
    prof = _sim_profile()
    hits = []
    pol = OobleckPolicy(prof, [f"n{i}" for i in range(9)], f=1,
                        global_batch=256, microbatch=2, n0=4)
    pol.engine.on_checkpoint = lambda: hits.append(pol.snapshot())
    with pytest.raises(PolicyStopped):
        pol.on_failure(set(list(pol.engine.nodes)[:3]))  # 6 < (f+1)*n0=8
    assert pol.engine.stopped
    assert len(hits) == 1 and hits[0]["instances"]


# ----------------------------------------------------------------------
# 4. Interface conformance across all three implementations
# ----------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["hetero", "spmd", "sim"])
def test_executor_interface_conformance(kind):
    arch, model, params, opt_cfg, mk_engine = make_setup(layers=2)
    if kind == "hetero":
        ex = HeteroTrainer(model, mk_engine(), params, opt_cfg,
                           mode="eager")
    elif kind == "spmd":
        ex = SPMDExecutor(model, params, opt_cfg, engine=mk_engine())
    else:
        ex = OobleckPolicy(_sim_profile(), [f"n{i}" for i in range(10)],
                           f=1, global_batch=256, microbatch=2, n0=4)
    assert isinstance(ex, Executor)
    for method in ("bind", "step", "recover", "join", "snapshot"):
        assert callable(getattr(ex, method))
    victim = ex.engine.instances[0].nodes[-1]
    if kind == "spmd":
        with pytest.raises(ExecutorUnsupported):
            ex.recover({victim})
    else:
        out = ex.recover({victim})
        assert isinstance(out, dict)
        assert victim not in ex.engine.nodes
        assert verify_replica_coverage(ex.engine.instances)
