"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel bodies on CPU) + hypothesis
properties.  Since §11, the BACKWARD is a Pallas kernel too: parity of
the registered custom_vjp rules against the oracle gradients is swept
across dtypes and odd (non-block-multiple) shapes, and the backward is
asserted to actually BE the Pallas path (not an oracle recompute)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:                     # optional locally; CI installs it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.kernels import autotune, ops, ref
from repro.kernels.flash_attention import (flash_attention as fa_kernel,
                                           flash_attention_bwd,
                                           flash_attention_fwd)
from repro.kernels.ssd import ssd as ssd_kernel, ssd_bwd, ssd_fwd

RNG = jax.random.PRNGKey(3)


def _qkv(B, S, H, KV, D, dtype):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, S, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D)).astype(dtype)
    return q, k, v


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------------
# Flash attention sweeps
# ----------------------------------------------------------------------
@pytest.mark.parametrize("S", [16, 64, 100, 160])
@pytest.mark.parametrize("H,KV", [(4, 4), (4, 2), (8, 1)])
def test_flash_attention_shapes(S, H, KV):
    q, k, v = _qkv(2, S, H, KV, 16, jnp.float32)
    out = fa_kernel(q, k, v, block_q=32, block_k=32, interpret=True)
    exp = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(out, exp, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q, k, v = _qkv(1, 64, 4, 2, 32, dtype)
    out = fa_kernel(q, k, v, block_q=32, block_k=32, interpret=True)
    exp = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [8, 32, 200])
def test_flash_attention_window(window):
    q, k, v = _qkv(1, 96, 4, 2, 16, jnp.float32)
    out = fa_kernel(q, k, v, window=window, block_q=32, block_k=32,
                    interpret=True)
    exp = ref.attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(out, exp, rtol=2e-5, atol=2e-5)


def test_flash_attention_block_shape_invariance():
    q, k, v = _qkv(1, 128, 4, 2, 16, jnp.float32)
    a = fa_kernel(q, k, v, block_q=32, block_k=64, interpret=True)
    b = fa_kernel(q, k, v, block_q=128, block_k=16, interpret=True)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------------
# Flash attention BACKWARD (Pallas two-pass kernels)
# ----------------------------------------------------------------------
def _flash_grads(fn, q, k, v, g, window=0):
    _, vjp = jax.vjp(lambda q, k, v: fn(q, k, v), q, k, v)
    return vjp(g)


@pytest.mark.parametrize("S,H,KV,window", [
    (48, 2, 2, 0),        # block-multiple
    (100, 4, 2, 0),       # odd S: padding rows in both bwd kernels
    (37, 4, 1, 8),        # odd S + MQA + window
    (96, 8, 2, 24),       # GQA group sum + window
])
def test_flash_attention_bwd_matches_oracle(S, H, KV, window):
    q, k, v = _qkv(1, S, H, KV, 16, jnp.float32)
    g = jax.random.normal(jax.random.PRNGKey(9), q.shape)
    gk = _flash_grads(
        lambda q, k, v: ops.flash_attention(q, k, v, window, 32, 32),
        q, k, v, g)
    gr = _flash_grads(
        lambda q, k, v: ref.attention_ref(q, k, v, window=window),
        q, k, v, g)
    for a, b, n in zip(gk, gr, ("dq", "dk", "dv")):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4, err_msg=n)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_bwd_dtypes(dtype):
    q, k, v = _qkv(1, 64, 4, 2, 32, dtype)
    g = jax.random.normal(jax.random.PRNGKey(9), q.shape).astype(dtype)
    gk = _flash_grads(
        lambda q, k, v: ops.flash_attention(q, k, v, 0, 32, 32), q, k, v, g)
    gr = _flash_grads(lambda q, k, v: ref.attention_ref(q, k, v), q, k, v, g)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-4)
    for a, b in zip(gk, gr):
        assert a.dtype == b.dtype == dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **tol)


def test_flash_attention_bwd_block_invariance():
    q, k, v = _qkv(1, 128, 4, 2, 16, jnp.float32)
    g = jax.random.normal(jax.random.PRNGKey(2), q.shape)
    out, lse = flash_attention_fwd(q, k, v, block_q=32, block_k=64,
                                   interpret=True)
    a = flash_attention_bwd(q, k, v, out, lse, g, block_q=32, block_k=64,
                            interpret=True)
    b = flash_attention_bwd(q, k, v, out, lse, g, block_q=128, block_k=16,
                            interpret=True)
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, rtol=2e-5, atol=2e-5)


def test_flash_attention_grad_matches_ref():
    q, k, v = _qkv(1, 48, 2, 2, 8, jnp.float32)

    def f_kernel(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v, 0, 16, 16) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_registered_bwd_is_pallas_not_oracle():
    """The custom_vjp backward must BE the Pallas kernels: the grad
    jaxpr contains the fwd pallas_call plus the dq and dkv calls — not
    an oracle recompute (which would show exactly one pallas_call)."""
    q, k, v = _qkv(1, 32, 2, 2, 8, jnp.float32)
    jaxpr = str(jax.make_jaxpr(jax.grad(
        lambda q: jnp.sum(ops.flash_attention(q, k, v, 0, 16, 16))))(q))
    assert jaxpr.count("pallas_call") >= 3, jaxpr.count("pallas_call")

    x, dt, A, B, C = _ssd_inputs(1, 16, 2, 4, 8)
    jaxpr = str(jax.make_jaxpr(jax.grad(
        lambda x: jnp.sum(ops.ssd(x, dt, A, B, C, 8)[0])))(x))
    assert jaxpr.count("pallas_call") >= 2, jaxpr.count("pallas_call")


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(S=st.integers(4, 80), D=st.sampled_from([8, 16]),
           seed=st.integers(0, 99))
    def test_flash_attention_property(S, D, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(ks[0], (1, S, 2, D))
        k = jax.random.normal(ks[1], (1, S, 2, D))
        v = jax.random.normal(ks[2], (1, S, 2, D))
        out = fa_kernel(q, k, v, block_q=16, block_k=16, interpret=True)
        exp = ref.attention_ref(q, k, v)
        np.testing.assert_allclose(out, exp, rtol=3e-5, atol=3e-5)
        # rows are convex combinations of V rows: bounded by V extremes
        assert float(jnp.max(out)) <= float(jnp.max(v)) + 1e-4
        assert float(jnp.min(out)) >= float(jnp.min(v)) - 1e-4

    @settings(max_examples=10, deadline=None)
    @given(S=st.integers(4, 60), seed=st.integers(0, 99))
    def test_flash_attention_bwd_property(S, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        q = jax.random.normal(ks[0], (1, S, 2, 8))
        k = jax.random.normal(ks[1], (1, S, 2, 8))
        v = jax.random.normal(ks[2], (1, S, 2, 8))
        g = jax.random.normal(ks[3], (1, S, 2, 8))
        gk = _flash_grads(
            lambda q, k, v: ops.flash_attention(q, k, v, 0, 16, 16),
            q, k, v, g)
        gr = _flash_grads(lambda q, k, v: ref.attention_ref(q, k, v),
                          q, k, v, g)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)


# ----------------------------------------------------------------------
# SSD sweeps
# ----------------------------------------------------------------------
def _ssd_inputs(b, S, H, P, N, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, S, H, P)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B = jax.random.normal(ks[3], (b, S, H, N)).astype(dtype)
    C = jax.random.normal(ks[4], (b, S, H, N)).astype(dtype)
    return x, dt, A, B, C


@pytest.mark.parametrize("S,chunk", [(32, 8), (40, 16), (7, 8), (128, 32)])
@pytest.mark.parametrize("P,N", [(8, 16), (16, 8)])
def test_ssd_shapes(S, chunk, P, N):
    x, dt, A, B, C = _ssd_inputs(2, S, 3, P, N)
    y, st_out = ssd_kernel(x, dt, A, B, C, chunk=chunk, interpret=True)
    yr, st_ref = ref.ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(y, yr, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st_out, st_ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_dtypes(dtype):
    x, dt, A, B, C = _ssd_inputs(1, 32, 2, 8, 16, dtype)
    y, _ = ssd_kernel(x, dt, A, B, C, chunk=16, interpret=True)
    yr, _ = ref.ssd_ref(x, dt, A, B, C)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **tol)


def test_ssd_chunk_invariance():
    x, dt, A, B, C = _ssd_inputs(1, 64, 2, 8, 8)
    y1, s1 = ssd_kernel(x, dt, A, B, C, chunk=8, interpret=True)
    y2, s2 = ssd_kernel(x, dt, A, B, C, chunk=32, interpret=True)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s1, s2, rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------------------
# SSD BACKWARD (reverse-chunk Pallas kernel)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("S,chunk", [(32, 8), (40, 16), (7, 8), (33, 8)])
def test_ssd_bwd_matches_oracle(S, chunk):
    x, dt, A, B, C = _ssd_inputs(2, S, 3, 8, 16)
    y, state, cst = ssd_fwd(x, dt, A, B, C, chunk=chunk, interpret=True)
    ks = jax.random.split(jax.random.PRNGKey(5), 2)
    gy = jax.random.normal(ks[0], y.shape)
    gs = jax.random.normal(ks[1], state.shape)   # state cotangent too
    got = ssd_bwd(x, dt, A, B, C, cst, gy, gs, chunk=chunk, interpret=True)
    _, vjp = jax.vjp(lambda *a: ref.ssd_ref(*a), x, dt, A, B, C)
    exp = vjp((gy, gs))
    for a, b, n in zip(got, exp, ("dx", "ddt", "dA", "dB", "dC")):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3, err_msg=n)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_bwd_dtypes(dtype):
    x, dt, A, B, C = _ssd_inputs(1, 24, 2, 4, 8, dtype)

    def f_kernel(x, B, C):
        y, _ = ops.ssd(x, dt, A, B, C, 8)
        return jnp.sum((y.astype(jnp.float32)) ** 2)

    def f_ref(x, B, C):
        y, _ = ref.ssd_ref(x, dt, A, B, C)
        return jnp.sum((y.astype(jnp.float32)) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(x, B, C)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, B, C)
    tol = dict(rtol=1e-1, atol=1e-1) if dtype == jnp.bfloat16 else dict(
        rtol=2e-3, atol=2e-3)
    for a, b in zip(gk, gr):
        assert a.dtype == dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **tol)


def test_ssd_grad_matches_ref():
    x, dt, A, B, C = _ssd_inputs(1, 24, 2, 4, 8)

    def f_kernel(*a):
        y, _ = ops.ssd(*a, 8)
        return jnp.sum(y ** 2)

    def f_ref(*a):
        y, _ = ref.ssd_ref(*a)
        return jnp.sum(y ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 3, 4))(x, dt, A, B, C)
    gr = jax.grad(f_ref, argnums=(0, 1, 3, 4))(x, dt, A, B, C)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


def test_ssd_grad_wrt_A_matches_ref():
    x, dt, A, B, C = _ssd_inputs(1, 40, 3, 4, 8)
    gk = jax.grad(lambda A: jnp.sum(ops.ssd(x, dt, A, B, C, 16)[0] ** 2))(A)
    gr = jax.grad(lambda A: jnp.sum(ref.ssd_ref(x, dt, A, B, C)[0] ** 2))(A)
    np.testing.assert_allclose(gk, gr, rtol=1e-3, atol=1e-3)


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(S=st.integers(4, 60), chunk=st.sampled_from([8, 16]),
           seed=st.integers(0, 99))
    def test_ssd_property(S, chunk, seed):
        x, dt, A, B, C = _ssd_inputs(1, S, 2, 4, 8, seed=seed)
        y, st_out = ssd_kernel(x, dt, A, B, C, chunk=chunk, interpret=True)
        yr, st_ref = ref.ssd_ref(x, dt, A, B, C)
        np.testing.assert_allclose(y, yr, rtol=5e-4, atol=5e-4)
        np.testing.assert_allclose(st_out, st_ref, rtol=5e-4, atol=5e-4)

    @settings(max_examples=10, deadline=None)
    @given(S=st.integers(4, 48), chunk=st.sampled_from([8, 16]),
           seed=st.integers(0, 99))
    def test_ssd_bwd_property(S, chunk, seed):
        x, dt, A, B, C = _ssd_inputs(1, S, 2, 4, 8, seed=seed)
        y, state, cst = ssd_fwd(x, dt, A, B, C, chunk=chunk, interpret=True)
        gy = jax.random.normal(jax.random.PRNGKey(seed + 1), y.shape)
        gs = jnp.zeros_like(state)
        got = ssd_bwd(x, dt, A, B, C, cst, gy, gs, chunk=chunk,
                      interpret=True)
        _, vjp = jax.vjp(lambda *a: ref.ssd_ref(*a), x, dt, A, B, C)
        exp = vjp((gy, gs))
        for a, b in zip(got, exp):
            np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-3)


# ----------------------------------------------------------------------
# Model integration + backend gating + autotuner
# ----------------------------------------------------------------------
def test_model_kernel_path_matches_chunked():
    """Model(ssd_impl='kernel') == Model(ssd_impl='chunked')."""
    from repro.configs import get_arch, reduced
    from repro.models import Model
    arch = reduced(get_arch("mamba2_780m"), layers=2)
    mk = Model(arch, dtype=jnp.float32, remat=False, ssd_impl="kernel")
    mc = Model(arch, dtype=jnp.float32, remat=False, ssd_impl="chunked")
    params = mk.init(RNG)
    tokens = jax.random.randint(RNG, (1, 24), 0, arch.vocab_size)
    lk, _ = mk.forward(params, tokens)
    lc, _ = mc.forward(params, tokens)
    np.testing.assert_allclose(lk, lc, rtol=2e-4, atol=2e-4)


def test_model_attention_kernel_path_matches_blocked():
    """Model(attn_impl='kernel') tracks the blocked oracle through the
    full loss AND its gradient (the Pallas bwd in the stage hot path)."""
    from repro.configs import get_arch, reduced
    from repro.models import Model
    arch = reduced(get_arch("gpt3_medium"), layers=2)
    mk = Model(arch, dtype=jnp.float32, remat=False, attn_impl="kernel")
    mb = Model(arch, dtype=jnp.float32, remat=False, attn_impl="blocked")
    params = mk.init(RNG)
    tokens = jax.random.randint(RNG, (1, 24), 0, arch.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    lk, gk = jax.value_and_grad(lambda p: mk.loss(p, batch)[0])(params)
    lb, gb = jax.value_and_grad(lambda p: mb.loss(p, batch)[0])(params)
    np.testing.assert_allclose(lk, lb, rtol=1e-4, atol=1e-4)
    for a, b in zip(jax.tree.leaves(gk), jax.tree.leaves(gb)):
        np.testing.assert_allclose(a, b, rtol=5e-3, atol=5e-3)


def test_model_auto_impl_resolves_for_backend():
    from repro.configs import get_arch, reduced
    from repro.models import Model
    arch = reduced(get_arch("gpt3_medium"), layers=2)
    m = Model(arch, attn_impl="auto", ssd_impl="auto")
    if ops.interpret_mode():
        assert m.attn_impl == "blocked" and m.ssd_impl == "chunked"
    else:
        assert m.attn_impl == "kernel" and m.ssd_impl == "kernel"


def test_backend_signature_gating():
    """Lowering is resolved PER KERNEL, not per platform: the
    single-writer restructure lowers everywhere a Pallas backend
    exists, while the SSD kernels keep a sequential-grid VMEM carry
    that only Mosaic serializes — so TPU lowers everything, GPU lowers
    flash + the fused epilogues but interprets SSD, and CPU (no
    compiled Pallas at all) interprets everything.  The signature that
    program caches key on carries the whole per-kind plan."""
    for kind in ops.KERNEL_KINDS:
        assert ops.kernel_lowers(kind, "tpu"), kind
    assert not ops.interpret_mode("tpu")
    for backend in ("gpu", "cuda", "rocm"):
        for kind in ("flash_fwd", "flash_bwd", "fused_norm", "fused_qkv"):
            assert ops.kernel_lowers(kind, backend), (backend, kind)
        for kind in ("ssd_fwd", "ssd_bwd"):
            assert not ops.kernel_lowers(kind, backend), (backend, kind)
        assert ops.interpret_mode(backend), backend   # any kind interprets
    for kind in ops.KERNEL_KINDS:
        assert not ops.kernel_lowers(kind, "cpu"), kind
    sig = ops.backend_signature()
    backend = jax.default_backend()
    # (backend, process topology, per-kind plan): the topology leg keeps
    # single- and multi-process compilations of the same template from
    # colliding in a shared cache
    assert sig == (backend, ops.process_topology(),
                   ops.lowering_plan(backend))
    assert sig[1][:2] == (jax.process_count(), jax.process_index())
    assert dict(sig[2]) == {k: ops.kernel_lowers(k, backend)
                            for k in ops.KERNEL_KINDS}


def test_lowering_probe_runs_on_live_backend_and_caches(monkeypatch):
    """On the LIVE backend the verdict comes from a one-shot try-compile
    of the kernel structure, cached per (kind, backend) — not from the
    static capability table."""
    ops._reset_lowering_cache()
    try:
        calls = []
        orig = ops._PROBES["flash_fwd"]

        def spy():
            calls.append(1)
            return orig()

        monkeypatch.setitem(ops._PROBES, "flash_fwd", spy)
        first = ops.kernel_lowers("flash_fwd")
        second = ops.kernel_lowers("flash_fwd")
        assert first == second
        assert len(calls) == 1                      # one-shot, then cached
        # CPU's Pallas is interpret-only: the probe must discover that
        if jax.default_backend() == "cpu":
            assert first is False
    finally:
        ops._reset_lowering_cache()


def test_autotune_offline_deterministic(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(tmp_path / "at.json"))
    cache = autotune.AutotuneCache()
    a = cache.get("flash", "cpu", jnp.float32, (2048, 64))
    b = cache.get("flash", "cpu", jnp.float32, (2048, 64))
    assert a == b and a["block_q"] >= 128   # big blocks for interpreter
    assert cache.get("flash", "tpu", jnp.float32, (2048, 64)) == {
        "block_q": 128, "block_k": 128}     # MXU-aligned
    assert cache.get("ssd", "tpu", jnp.float32, (2048, 64, 128)) == {
        "chunk": 128}
    # tiny shapes never exceed their bucket
    small = cache.get("flash", "cpu", jnp.float32, (16, 16))
    assert small["block_q"] <= 16


def test_autotune_ragged_shapes_get_distinct_entries(tmp_path):
    """Regression: the pow2-only bucket used to collide e.g. seq 1000
    onto 1024's entry — blocks tuned on the clean power were served to
    ragged lengths whose padding/tail tiling is different.  Ragged
    lengths now keep their own identity under the pow2 roof, and head
    dims are always keyed exactly."""
    assert autotune.shape_bucket(1024) == "1024"
    assert autotune.shape_bucket(1000) == "1024r1000"
    assert autotune.shape_bucket(129) != autotune.shape_bucket(256)
    assert autotune._seq_of("1024r1000") == 1000
    path = str(tmp_path / "a.json")
    c = autotune.AutotuneCache(path)
    c.put("flash", "cpu", jnp.float32, (autotune.shape_bucket(1024), 64),
          {"block_q": 512, "block_k": 512})
    # the measured pow2 entry must NOT shadow the ragged length...
    assert c.peek("flash", "cpu", jnp.float32,
                  (autotune.shape_bucket(1000), 64)) is None
    # ...which falls back to the offline default instead
    assert c.get("flash", "cpu", jnp.float32,
                 (autotune.shape_bucket(1000), 64))["block_q"] >= 128
    # non-pow2 head dims never share an entry with pow2 ones
    c.put("flash", "cpu", jnp.float32, ("1024", 80),
          {"block_q": 64, "block_k": 64})
    assert c.get("flash", "cpu", jnp.float32, ("1024", 64)) == {
        "block_q": 512, "block_k": 512}
    assert c.get("flash", "cpu", jnp.float32, ("1024", 80)) == {
        "block_q": 64, "block_k": 64}


def test_flash_config_routes_ragged_seq_via_ragged_bucket(monkeypatch):
    seen = {}
    orig = autotune._CACHE.peek

    def spy(kind, backend, dtype, shape):
        seen["shape"] = shape
        return orig(kind, backend, dtype, shape)

    monkeypatch.setattr(autotune._CACHE, "peek", spy)
    autotune.flash_config("cpu", jnp.float32, 1000, 64)
    assert seen["shape"] == ("1024r1000", 64)


def test_offline_heuristic_is_per_kernel_capability():
    """GPU lowers flash/fused but interprets SSD: the offline defaults
    must follow the per-kind probe, not a platform aggregate."""
    c = autotune.AutotuneCache("/nonexistent/never-loaded.json")
    assert c.get("flash", "gpu", jnp.float32, (2048, 64)) == {
        "block_q": 128, "block_k": 128}           # compiled heuristic
    assert c.get("fused", "gpu", jnp.float32,
                 (2048, 768))["block_rows"] == 128
    # seq 64: compiled heuristic would say 128, interpreter caps at the
    # bucket — SSD on gpu must take the interpreter branch
    assert c.get("ssd", "gpu", jnp.float32, (64, 64, 32)) == {"chunk": 64}
    assert c.get("ssd", "tpu", jnp.float32, (64, 64, 32)) == {"chunk": 128}


def test_packaged_offline_table_consulted(monkeypatch):
    """A measured entry checked into autotune_offline.json wins over the
    heuristic for its exact key (and only that key)."""
    key = autotune._key("flash", "tpu", jnp.float32, ("2048", 64))
    monkeypatch.setattr(autotune, "_PACKAGED",
                        {key: {"block_q": 256, "block_k": 256}})
    c = autotune.AutotuneCache("/nonexistent/never-loaded.json")
    assert c.get("flash", "tpu", jnp.float32, ("2048", 64)) == {
        "block_q": 256, "block_k": 256}
    assert c.get("flash", "tpu", jnp.float32, ("1024", 64)) == {
        "block_q": 128, "block_k": 128}


def test_autotune_persistence_roundtrip(tmp_path):
    path = str(tmp_path / "autotune.json")
    c1 = autotune.AutotuneCache(path)
    c1.put("flash", "cpu", jnp.float32, (1024, 64),
           {"block_q": 256, "block_k": 256})
    c2 = autotune.AutotuneCache(path)         # fresh process simulation
    assert c2.get("flash", "cpu", jnp.float32, (1024, 64)) == {
        "block_q": 256, "block_k": 256}
    with open(path) as f:
        table = json.load(f)
    assert any("flash|cpu" in k for k in table)


def test_autotune_offline_fallbacks_not_persisted(tmp_path):
    """save() must only write measured entries: a persisted snapshot of
    the offline defaults would shadow future offline-table updates."""
    path = str(tmp_path / "a.json")
    c = autotune.AutotuneCache(path)
    c.get("flash", "cpu", jnp.float32, (1024, 64))      # offline fallback
    c.put("ssd", "tpu", jnp.float32, (1024, 64, 128), {"chunk": 64})
    with open(path) as f:
        table = json.load(f)
    assert list(table) == ["ssd|tpu|float32|1024x64x128"]


def test_autotune_env_triggers_measured_tuning(tmp_path, monkeypatch):
    """REPRO_AUTOTUNE=1 routes config misses through measured tuning."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "1")
    monkeypatch.setattr(autotune, "_CACHE",
                        autotune.AutotuneCache(str(tmp_path / "x.json")))
    called = {}

    def fake_tune(backend, dtype, seq, d, **kw):
        called["args"] = (backend, seq, d)
        return {"block_q": 64, "block_k": 64}

    monkeypatch.setattr(autotune, "tune_flash", fake_tune)
    cfg = autotune.flash_config("cpu", jnp.float32, 128, 16)
    assert cfg == {"block_q": 64, "block_k": 64}
    assert called["args"] == ("cpu", 128, 16)
    # without the env var, misses fall back to the offline table
    monkeypatch.delenv("REPRO_AUTOTUNE")
    called.clear()
    autotune.flash_config("cpu", jnp.float32, 256, 16)
    assert not called


def test_autotune_config_feeds_ops(monkeypatch):
    """ops.flash_attention with default blocks consults the autotuner."""
    seen = {}
    orig = autotune.flash_config

    def spy(backend, dtype, seq, d):
        seen["args"] = (backend, seq, d)
        return orig(backend, dtype, seq, d)

    monkeypatch.setattr(autotune, "flash_config", spy)
    q, k, v = _qkv(1, 32, 2, 2, 8, jnp.float32)
    ops.flash_attention(q, k, v)
    assert seen["args"] == (jax.default_backend(), 32, 8)
