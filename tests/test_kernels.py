"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles
(interpret=True executes the kernel bodies on CPU) + hypothesis
properties.  Task deliverable (c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as fa_kernel
from repro.kernels.ssd import ssd as ssd_kernel

RNG = jax.random.PRNGKey(3)


def _qkv(B, S, H, KV, D, dtype):
    ks = jax.random.split(RNG, 3)
    q = jax.random.normal(ks[0], (B, S, H, D)).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, KV, D)).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, KV, D)).astype(dtype)
    return q, k, v


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-5, atol=2e-5)


# ----------------------------------------------------------------------
# Flash attention sweeps
# ----------------------------------------------------------------------
@pytest.mark.parametrize("S", [16, 64, 100, 160])
@pytest.mark.parametrize("H,KV", [(4, 4), (4, 2), (8, 1)])
def test_flash_attention_shapes(S, H, KV):
    q, k, v = _qkv(2, S, H, KV, 16, jnp.float32)
    out = fa_kernel(q, k, v, block_q=32, block_k=32, interpret=True)
    exp = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(out, exp, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q, k, v = _qkv(1, 64, 4, 2, 32, dtype)
    out = fa_kernel(q, k, v, block_q=32, block_k=32, interpret=True)
    exp = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [8, 32, 200])
def test_flash_attention_window(window):
    q, k, v = _qkv(1, 96, 4, 2, 16, jnp.float32)
    out = fa_kernel(q, k, v, window=window, block_q=32, block_k=32,
                    interpret=True)
    exp = ref.attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(out, exp, rtol=2e-5, atol=2e-5)


def test_flash_attention_block_shape_invariance():
    q, k, v = _qkv(1, 128, 4, 2, 16, jnp.float32)
    a = fa_kernel(q, k, v, block_q=32, block_k=64, interpret=True)
    b = fa_kernel(q, k, v, block_q=128, block_k=16, interpret=True)
    np.testing.assert_allclose(a, b, rtol=2e-5, atol=2e-5)


def test_flash_attention_grad_matches_ref():
    q, k, v = _qkv(1, 48, 2, 2, 8, jnp.float32)

    def f_kernel(q, k, v):
        return jnp.sum(ops.flash_attention(q, k, v, 0, 16, 16) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(ref.attention_ref(q, k, v) ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(S=st.integers(4, 80), D=st.sampled_from([8, 16]),
       seed=st.integers(0, 99))
def test_flash_attention_property(S, D, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, S, 2, D))
    k = jax.random.normal(ks[1], (1, S, 2, D))
    v = jax.random.normal(ks[2], (1, S, 2, D))
    out = fa_kernel(q, k, v, block_q=16, block_k=16, interpret=True)
    exp = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(out, exp, rtol=3e-5, atol=3e-5)
    # rows are convex combinations of V rows: bounded by V extremes
    assert float(jnp.max(out)) <= float(jnp.max(v)) + 1e-4
    assert float(jnp.min(out)) >= float(jnp.min(v)) - 1e-4


# ----------------------------------------------------------------------
# SSD sweeps
# ----------------------------------------------------------------------
def _ssd_inputs(b, S, H, P, N, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, S, H, P)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    B = jax.random.normal(ks[3], (b, S, H, N)).astype(dtype)
    C = jax.random.normal(ks[4], (b, S, H, N)).astype(dtype)
    return x, dt, A, B, C


@pytest.mark.parametrize("S,chunk", [(32, 8), (40, 16), (7, 8), (128, 32)])
@pytest.mark.parametrize("P,N", [(8, 16), (16, 8)])
def test_ssd_shapes(S, chunk, P, N):
    x, dt, A, B, C = _ssd_inputs(2, S, 3, P, N)
    y, st_out = ssd_kernel(x, dt, A, B, C, chunk=chunk, interpret=True)
    yr, st_ref = ref.ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(y, yr, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(st_out, st_ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_dtypes(dtype):
    x, dt, A, B, C = _ssd_inputs(1, 32, 2, 8, 16, dtype)
    y, _ = ssd_kernel(x, dt, A, B, C, chunk=16, interpret=True)
    yr, _ = ref.ssd_ref(x, dt, A, B, C)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **tol)


def test_ssd_chunk_invariance():
    x, dt, A, B, C = _ssd_inputs(1, 64, 2, 8, 8)
    y1, s1 = ssd_kernel(x, dt, A, B, C, chunk=8, interpret=True)
    y2, s2 = ssd_kernel(x, dt, A, B, C, chunk=32, interpret=True)
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(s1, s2, rtol=2e-4, atol=2e-4)


def test_ssd_grad_matches_ref():
    x, dt, A, B, C = _ssd_inputs(1, 24, 2, 4, 8)

    def f_kernel(*a):
        y, _ = ops.ssd(*a, 8)
        return jnp.sum(y ** 2)

    def f_ref(*a):
        y, _ = ref.ssd_ref(*a)
        return jnp.sum(y ** 2)

    gk = jax.grad(f_kernel, argnums=(0, 1, 3, 4))(x, dt, A, B, C)
    gr = jax.grad(f_ref, argnums=(0, 1, 3, 4))(x, dt, A, B, C)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(S=st.integers(4, 60), chunk=st.sampled_from([8, 16]),
       seed=st.integers(0, 99))
def test_ssd_property(S, chunk, seed):
    x, dt, A, B, C = _ssd_inputs(1, S, 2, 4, 8, seed=seed)
    y, st_out = ssd_kernel(x, dt, A, B, C, chunk=chunk, interpret=True)
    yr, st_ref = ref.ssd_ref(x, dt, A, B, C)
    np.testing.assert_allclose(y, yr, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(st_out, st_ref, rtol=5e-4, atol=5e-4)


def test_model_kernel_path_matches_chunked():
    """Model(ssd_impl='kernel') == Model(ssd_impl='chunked')."""
    from repro.configs import get_arch, reduced
    from repro.models import Model
    arch = reduced(get_arch("mamba2_780m"), layers=2)
    mk = Model(arch, dtype=jnp.float32, remat=False, ssd_impl="kernel")
    mc = Model(arch, dtype=jnp.float32, remat=False, ssd_impl="chunked")
    params = mk.init(RNG)
    tokens = jax.random.randint(RNG, (1, 24), 0, arch.vocab_size)
    lk, _ = mk.forward(params, tokens)
    lc, _ = mc.forward(params, tokens)
    np.testing.assert_allclose(lk, lc, rtol=2e-4, atol=2e-4)
