"""Hypothesis property tests of the paper's guarantees (§3.2, App. A/B)
and gradient-compression invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import assume, given, settings, strategies as st

from repro.configs import get_arch
from repro.core import (EngineConfig, OobleckEngine, PlanningError,
                        build_profile, coverable, generate_node_spec,
                        layer_groups)
from repro.runtime.compression import (ErrorFeedback, roundtrip, wire_bytes)


@pytest.fixture(scope="module")
def profile():
    return build_profile(get_arch("gpt3_2_7b"), microbatch=2, seq_len=1024)


# ----------------------------------------------------------------------
# Theorem B.1 (merge availability)
# ----------------------------------------------------------------------
@settings(max_examples=50, deadline=None)
@given(N=st.integers(8, 40), f=st.integers(1, 3), n0=st.integers(2, 4),
       k=st.integers(1, 3))
def test_theorem_b1_merged_template_exists(N, f, n0, k):
    """Merging a failed (n0-k)-node pipeline with an n0-node one yields
    2*n0-k nodes; a template must exist for that size whenever the
    cluster can still hold f+1 replicas."""
    assume((f + 2) * n0 <= N)         # precondition in the proof
    assume(k < n0)
    try:
        spec = generate_node_spec(N=N, f=f, n0=n0)
    except PlanningError:
        assume(False)
    merged = 2 * n0 - k
    assert n0 <= merged <= spec.max_size(), (
        f"no template for merged size {merged}; sizes {spec.sizes}")


# ----------------------------------------------------------------------
# §3.2: worst case f, general case beyond f
# ----------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_figure2_general_case_beyond_f(profile, seed):
    """Figure 2b: > f random failures are survivable as long as one copy
    of every layer remains (engine recovers or raises, never corrupts)."""
    import random
    rng = random.Random(seed)
    eng = OobleckEngine(profile, [f"n{i}" for i in range(13)], EngineConfig(
        fault_tolerance=2, global_batch=1024, microbatch=2,
        gpus_per_node=1, n0_override=2))
    # kill 3 > f = 2 nodes scattered over DIFFERENT pipelines
    instances = eng.instances
    assume(len(instances) >= 3)
    dead = {inst.nodes[0] for inst in rng.sample(instances, 3)}
    eng.handle_failure(dead)          # must not raise: one copy per layer
    for g in layer_groups(eng.instances):
        assert all(len(r) >= 1 for r in g.replicas)


def test_figure2_worst_case_stage_wipeout(profile):
    """Figure 2a: losing every replica of one stage is unrecoverable —
    the array-level trainer must detect it rather than continue."""
    import dataclasses

    import jax.numpy as jnp

    from repro.configs import reduced
    from repro.models import Model
    from repro.optim import adamw
    from repro.runtime import HeteroTrainer

    arch = reduced(get_arch("gpt3_medium"), layers=4)
    prof = build_profile(arch, microbatch=2, seq_len=16)
    eng = OobleckEngine(prof, [f"n{i}" for i in range(4)], EngineConfig(
        fault_tolerance=1, global_batch=8, microbatch=2, gpus_per_node=1,
        n0_override=2))
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive",
                  scan_layers=False)
    trainer = HeteroTrainer(model, eng, model.init(jax.random.PRNGKey(0)),
                            adamw.AdamWConfig())
    # both pipelines have 2 nodes; node index 0 of each holds stage 0.
    dead = {inst.nodes[0] for inst in eng.instances}
    with pytest.raises((AssertionError, Exception)):
        trainer.handle_failure(dead)


# ----------------------------------------------------------------------
# Coverage is monotone: adding nodes never breaks instantiability
# ----------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(N=st.integers(6, 36), f=st.integers(0, 3))
def test_coverage_monotone(N, f):
    n0 = 2
    assume((f + 1) * n0 <= N)
    spec = generate_node_spec(N=N, f=f, n0=n0)
    prev = None
    for n in range((f + 1) * n0, N + 1):
        cov = coverable(n, spec)
        assert cov, f"gap at {n} with sizes {spec.sizes}"
        prev = cov


# ----------------------------------------------------------------------
# Gradient compression
# ----------------------------------------------------------------------
@pytest.mark.parametrize("codec,rel_tol", [("bf16", 1e-2), ("int8", 2e-2)])
def test_codec_roundtrip_error_bounded(codec, rel_tol):
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (256, 64))}
    rt = roundtrip(g, codec)
    err = float(jnp.max(jnp.abs(rt["w"] - g["w"])))
    assert err <= rel_tol * float(jnp.max(jnp.abs(g["w"])))
    assert wire_bytes(g, codec) < wire_bytes(g, "none")


def test_error_feedback_unbiased_over_time():
    """Sum of compressed grads + final residual == sum of true grads."""
    ef = ErrorFeedback("int8")
    key = jax.random.PRNGKey(1)
    total_true = jnp.zeros((32,))
    total_sent = jnp.zeros((32,))
    for i in range(20):
        key, k = jax.random.split(key)
        g = {"w": jax.random.normal(k, (32,)) * 0.01}
        total_true = total_true + g["w"]
        sent = ef.apply(g)
        total_sent = total_sent + sent["w"]
    drift = total_sent + ef.residual["w"] - total_true
    np.testing.assert_allclose(np.asarray(drift), 0.0, atol=1e-5)
