"""Regression tests for two control-plane bugfixes (hypothesis-free so
they always run):

1. ``flat_schedule`` must RAISE on a malformed per-stage sequence —
   the historical behavior was an infinite loop (``progressed`` stays
   False but ``while len(out) < total`` never exits; the ``assert``
   vanished under ``python -O``).
2. ``distribute_microbatches``'s incremental-delta descent must return
   counts BIT-IDENTICAL to the retained full-recompute reference,
   including on tie-heavy instances where fp rounding of the two
   objective forms differs.

Plus hypothesis properties (skipped when hypothesis is absent) for the
ADAPTED schedules (DESIGN.md §12): random (pipelines, stages,
failure-set) instances must never route a microbatch to a dead
pipeline, must execute every surviving AND re-routed microbatch's F and
B exactly once per stage on exactly one host, and must raise
``ScheduleError`` — never hang — on infeasible inputs.
"""
import itertools
import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.batch import (_distribute_microbatches_reference, _objective,
                              distribute_microbatches)
from repro.core.templates import PlanningError
from repro.runtime.schedule import (ScheduleError, adapt_reroute,
                                    adapted_flat_schedule, adapted_per_stage,
                                    flat_schedule, one_f_one_b)


# ----------------------------------------------------------------------
# flat_schedule deadlock
# ----------------------------------------------------------------------
def test_flat_schedule_valid_still_works():
    flat = flat_schedule(3, 4)
    assert len(flat) == 2 * 3 * 4


def test_flat_schedule_raises_on_backward_before_forward():
    # stage 0 tries to run B(0) before any forward exists anywhere
    per_stage = [[("B", 0), ("F", 0)], [("F", 0), ("B", 0)]]
    with pytest.raises(ScheduleError) as ei:
        flat_schedule(2, 1, per_stage=per_stage)
    # the error names the stuck (stage, op, mb) heads
    assert "(0, 'B', 0)" in str(ei.value)


def test_flat_schedule_raises_on_missing_upstream_microbatch():
    # stage 1 waits for F(1) from stage 0, which never produces it
    per_stage = [[("F", 0)], [("F", 0), ("F", 1)]]
    with pytest.raises(ScheduleError) as ei:
        flat_schedule(2, 2, per_stage=per_stage)
    assert "(1, 'F', 1)" in str(ei.value)
    assert "2/3" in str(ei.value)          # progress made before the stall


def test_flat_schedule_raises_on_cyclic_wait():
    # both stages' heads wait on the other: classic deadlock shape
    per_stage = [[("B", 0), ("F", 0)], [("B", 0), ("F", 0)]]
    with pytest.raises(ScheduleError):
        flat_schedule(2, 1, per_stage=per_stage)


def test_flat_schedule_custom_valid_sequence_accepted():
    per_stage = one_f_one_b(4, 3)
    flat = flat_schedule(4, 3, per_stage=per_stage)
    assert len(flat) == sum(len(ops) for ops in per_stage)


# ----------------------------------------------------------------------
# distribute_microbatches: incremental descent == reference, bitwise
# ----------------------------------------------------------------------
def test_descent_bit_identical_random_instances():
    rng = random.Random(7)
    for trial in range(400):
        x = rng.randint(2, 8)
        total = rng.randint(x, 160)
        kind = trial % 3
        if kind == 0:
            times = [rng.uniform(0.1, 10.0) for _ in range(x)]
        elif kind == 1:                      # tie-heavy: integer times
            times = [float(rng.randint(1, 6)) for _ in range(x)]
        else:                                # tie-heavy: repeated values
            times = [rng.choice([0.5, 1.0, 1.0, 2.0]) for _ in range(x)]
        assert (distribute_microbatches(times, total)
                == _distribute_microbatches_reference(times, total)), (
            times, total)


def test_descent_bit_identical_large_instance():
    rng = random.Random(13)
    times = [rng.uniform(0.5, 5.0) for _ in range(64)]
    assert (distribute_microbatches(times, 512)
            == _distribute_microbatches_reference(times, 512))


@pytest.mark.parametrize("times,total", [
    ([1.0, 2.0, 4.0], 14),
    ([1.0, 1.0, 1.0], 9),
    ([0.3, 0.7, 1.9, 2.2], 21),
    ([5.0, 1.0], 11),
])
def test_bruteforce_optimality_small(times, total):
    counts = distribute_microbatches(times, total)
    assert sum(counts) == total and min(counts) >= 1
    best = min(
        (c for c in itertools.product(range(1, total + 1), repeat=len(times))
         if sum(c) == total),
        key=lambda c: _objective(list(c), times))
    assert _objective(counts, times) <= _objective(list(best), times) + 1e-9


def test_bruteforce_optimality_larger_instances():
    """Satellite: brute-force cross-check extended beyond the original
    3-pipeline/14-mb case."""
    rng = random.Random(3)
    for _ in range(6):
        x = rng.randint(2, 4)
        total = rng.randint(x, 24)
        times = [rng.uniform(0.2, 4.0) for _ in range(x)]
        counts = distribute_microbatches(times, total)
        best = min(
            (c for c in itertools.product(range(1, total + 1), repeat=x)
             if sum(c) == total),
            key=lambda c: _objective(list(c), times))
        assert (_objective(counts, times)
                <= _objective(list(best), times) + 1e-9), (times, total)


def test_infeasible_still_raises():
    with pytest.raises(PlanningError):
        distribute_microbatches([1.0, 1.0, 1.0], 2)


# ----------------------------------------------------------------------
# adapted schedules: deterministic base cases (hypothesis-free)
# ----------------------------------------------------------------------
def test_adapt_reroute_balanced_and_deterministic():
    routes = adapt_reroute([3, 3, 3], {0})
    assert routes == adapt_reroute([3, 3, 3], {0})
    hosted = [g for r in routes.values() for g in r]
    assert sorted(hosted) == [(0, 0), (0, 1), (0, 2)]
    # balanced: loads 3+2 and 3+1 (or vice versa), never 3+3 and 3+0
    loads = sorted(3 + len(routes.get(p, [])) for p in (1, 2))
    assert loads == [4, 5]


def test_adapt_reroute_infeasible_raises():
    with pytest.raises(ScheduleError):
        adapt_reroute([2, 2], {0, 1})          # no survivor left
    with pytest.raises(ScheduleError):
        adapt_reroute([2, 2], {5})             # out of range


def test_adapted_schedule_guests_fill_host_tail():
    """Guests are appended to the host's microbatch stream, so the
    host's own (native) 1F1B prefix is untouched — the guests ride the
    drain-phase bubbles."""
    S, counts = 3, [2, 2]
    per_host = adapted_per_stage(S, counts, {1})
    native = one_f_one_b(S, 2)
    for s in range(S):
        ops = per_host[0][s]
        assert len(ops) == 2 * 4               # F+B for 2 native + 2 guests
        native_positions = [o for o in ops if o[1][0] == 0]
        assert native_positions == [(op, (0, mb)) for op, mb in native[s]]


if HAVE_HYPOTHESIS:
    @given(num_stages=st.integers(1, 5),
           mb_counts=st.lists(st.integers(1, 8), min_size=2, max_size=6),
           data=st.data())
    @settings(max_examples=120, deadline=None)
    def test_adapted_schedule_properties(num_stages, mb_counts, data):
        """Random (pipelines, stages, failure-set <= f = X-1): the
        adapted schedule must (a) never place an op on a dead pipeline,
        (b) execute every surviving and re-routed microbatch's F and B
        exactly once per stage on exactly one host, and (c) cover no
        other microbatches."""
        X = len(mb_counts)
        dead = set(data.draw(
            st.lists(st.integers(0, X - 1), min_size=1, max_size=X - 1,
                     unique=True), label="dead"))
        flat = adapted_flat_schedule(num_stages, mb_counts, dead)

        # (a) ops only run on surviving hosts
        assert set(flat).isdisjoint(dead)
        assert set(flat) == set(range(X)) - dead

        # (b)+(c): per-(src,mb) execution counts, and host uniqueness
        host_of = {}
        expected = {(p, i) for p in range(X) for i in range(mb_counts[p])}
        seen = set()
        for host, ops in flat.items():
            per_tag = {}
            for s, op, tag in ops:
                assert tag in expected
                assert host_of.setdefault(tag, host) == host, \
                    f"microbatch {tag} split across hosts"
                per_tag.setdefault(tag, []).append((s, op))
                seen.add(tag)
            for tag, sops in per_tag.items():
                for s in range(num_stages):
                    assert sops.count((s, "F")) == 1, (tag, s)
                    assert sops.count((s, "B")) == 1, (tag, s)
        assert seen == expected, "a microbatch was lost or invented"

    @given(mb_counts=st.lists(st.integers(1, 8), min_size=1, max_size=6),
           num_stages=st.integers(1, 5))
    @settings(max_examples=40, deadline=None)
    def test_adapted_schedule_infeasible_raises_not_hangs(mb_counts,
                                                          num_stages):
        """All pipelines dead, or a dead index out of range: always a
        ScheduleError, never a hang or partial schedule."""
        with pytest.raises(ScheduleError):
            adapted_flat_schedule(num_stages, mb_counts,
                                  set(range(len(mb_counts))))
        with pytest.raises(ScheduleError):
            adapted_flat_schedule(num_stages, mb_counts,
                                  {len(mb_counts) + 1})
