"""GPU–stage mapping DP (paper §4.1.2): structure, optimality, memoization."""
import math

import pytest

from repro.core import PipelinePlanner, build_profile, estimate_iteration_time
from repro.core.planner import _combine, _min_segments, _Sol
from repro.configs import get_arch


def test_template_structure(small_profile):
    pl = PipelinePlanner(small_profile, gpus_per_node=1)
    tpl = pl.plan(4)
    tpl.validate(small_profile.num_layers)
    assert tpl.num_stages >= 4           # pigeonhole: >= 1 stage per node
    assert tpl.num_nodes == 4
    # stages tile the layer range exactly
    assert tpl.stages[0].layer_start == 0
    assert tpl.stages[-1].layer_end == small_profile.num_layers


def test_peel_equals_binary(small_profile):
    """Both division strategies explore the same stage-sequence space."""
    peel = PipelinePlanner(small_profile, gpus_per_node=1, mode="peel",
                           max_stages=4).plan(3)
    binary = PipelinePlanner(small_profile, gpus_per_node=1, mode="binary",
                             max_stages=4).plan(3)
    assert math.isclose(peel.iteration_time, binary.iteration_time,
                        rel_tol=1e-9)


def test_homogeneous_closed_form():
    """For a uniform-cost model, T1+T2+T3 == exact 1F1B makespan
    (N_b + S - 1)(F+B)."""
    prof = build_profile(get_arch("gpt2"), microbatch=1, seq_len=128)
    pl = PipelinePlanner(prof, gpus_per_node=1)
    tpl = pl.plan(2)
    s, ts = tpl.num_stages, tpl.stage_times
    if len(set(round(t, 12) for t in ts)) == 1:  # exactly homogeneous
        t = ts[0]
        assert math.isclose(tpl.iteration_time, (4 * s + s - 1) * t, rel_tol=1e-9)


def test_multi_gpu_stage_never_straddles_nodes(gpt27_profile):
    pl = PipelinePlanner(gpt27_profile, gpus_per_node=4)
    tpl = pl.plan(3)
    for st in tpl.stages:
        assert st.gpu_offset + st.num_gpus <= 4


def test_memoization_shared_across_templates(gpt27_profile):
    pl = PipelinePlanner(gpt27_profile, gpus_per_node=1, mode="peel")
    pl.plan(6)
    hits_before = len(pl._memo)
    pl.plan(5)   # should reuse sub-states
    # planning the smaller template grows the memo only modestly
    assert len(pl._memo) < hits_before * 2


def test_fast_rows_shared_across_templates(gpt27_profile):
    pl = PipelinePlanner(gpt27_profile, gpus_per_node=1, mode="fast")
    pl.plan(6)
    rows_before = len(pl._rows)
    pl.plan(5)   # M=1 rows are keyed (S', S') — fully shared
    assert len(pl._rows) < rows_before * 2


def test_iteration_time_monotone_in_microbatches(gpt27_profile):
    pl = PipelinePlanner(gpt27_profile, gpus_per_node=1)
    tpl = pl.plan(4)
    times = [estimate_iteration_time(tpl, nb) for nb in (4, 8, 16, 64)]
    assert times == sorted(times)


def test_combine_math():
    # left slower: k* stays left, T3 accumulates right's T1 (Eq. 3 case 1)
    left = _Sol(0, t1=10.0, t3=4.0, k_star=1, t_max=4.0, cut=None)
    right = _Sol(0, t1=6.0, t3=2.0, k_star=0, t_max=3.0, cut=None)
    total, t1, t3, k, tmax = _combine(left, right, s_left=2, s_total=4)
    assert (t1, t3, k, tmax) == (16.0, 10.0, 1, 4.0)
    assert total == t1 + (16 - 4 + 1 - 1) * 4.0 + t3
    # right slower: k* shifts by s_left (Eq. 3 case 2)
    total, t1, t3, k, tmax = _combine(right, left, s_left=2, s_total=4)
    assert (k, tmax, t3) == (2 + 1, 4.0, 4.0)


def test_min_segments():
    assert _min_segments(4, 0, 4) == 1
    assert _min_segments(4, 2, 4) == 2   # 2 in node A + 2 in node B
    assert _min_segments(8, 0, 4) == 2
    assert _min_segments(9, 3, 4) == 3   # 1 + 4 + 4


def test_more_nodes_not_slower_per_microbatch(gpt27_profile):
    """Steady-state per-microbatch time should improve with more nodes."""
    pl = PipelinePlanner(gpt27_profile, gpus_per_node=1)
    t3 = pl.plan(3)
    t6 = pl.plan(6)
    assert (t6.stage_times[t6.slowest_stage]
            < t3.stage_times[t3.slowest_stage])
