"""End-to-end mini dry-run in a SUBPROCESS with a small forced device
count (8 devices, 2x4 mesh) — validates the whole lower->compile->
roofline pipeline without polluting this process's 1-device backend.
The production 512-device sweep runs via launch/dryrun.py."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys, dataclasses
    import jax
    from repro.configs import get_arch, reduced, ShapeConfig
    from repro.runtime.sharding import ShardingStrategy
    from repro.runtime import spmd
    from repro.launch import specs as sp
    from repro.launch.hloparse import analyze
    from repro.optim import adamw

    from repro.launch.mesh import cost_analysis_dict, make_mesh_compat
    mesh = make_mesh_compat((2, 4), ("data", "model"))
    arch = reduced(get_arch(sys.argv[1]), layers=2, d_model=64, vocab=512)
    shape = ShapeConfig("tiny", seq_len=64, global_batch=8, kind=sys.argv[2])
    strategy = ShardingStrategy(strategy="fsdp", data_axes=("data",))
    model = spmd.build_model(arch, strategy, mesh, shape.global_batch)
    model = dataclasses.replace(model, loss_chunk=16)
    pshape = sp.params_shape(model)
    with mesh:
        if shape.kind == "train":
            oshape = sp.opt_shape(model, pshape)
            bundle = spmd.train_bundle(model, adamw.AdamWConfig(), strategy,
                                       mesh, pshape, oshape, shape)
            lowered = bundle.jit().lower(pshape, oshape,
                                         sp.batch_specs(arch, shape))
        else:
            tok, cache, pos = sp.decode_specs(arch, shape, model)
            bundle = spmd.decode_bundle(model, strategy, mesh, pshape,
                                        cache, shape)
            lowered = bundle.jit().lower(pshape, tok, cache, pos)
        compiled = lowered.compile()
    st = analyze(compiled.as_text(), default_group=4)
    ma = compiled.memory_analysis()
    print(json.dumps({
        "flops": st.dot_flops,
        "coll": st.collective_bytes,
        "temps": ma.temp_size_in_bytes,
        "xla_flops": cost_analysis_dict(compiled).get("flops", 0.0),
    }))
""")


# slow container / CI runners can override the subprocess budget
TIMEOUT = int(os.environ.get("REPRO_DRYRUN_TIMEOUT", "600"))


def run(arch, kind):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT, arch, kind],
                         capture_output=True, text=True, env=env,
                         timeout=TIMEOUT)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("arch,kind", [
    ("qwen3_1_7b", "train"),
    ("granite_moe_1b_a400m", "train"),
    ("mamba2_780m", "train"),
    ("hymba_1_5b", "decode"),
    ("qwen2_5_3b", "decode"),
])
def test_mini_dryrun_compiles_and_counts(arch, kind):
    r = run(arch, kind)
    assert r["flops"] > 0
    assert r["temps"] > 0
    # trip-count-aware parse must cover XLA's loop-once count; decode
    # programs are tiny, so non-dot (elementwise) flops — which the
    # parser deliberately ignores — carry more relative weight there.
    floor = 0.9 if kind == "train" else 0.6
    assert r["flops"] >= floor * r["xla_flops"]


# ----------------------------------------------------------------------
# the resilient-training driver, per recovery policy, in a subprocess
# (mirrors the README quickstart: tiny model, kill a node mid-run)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("policy", ["replan", "adapt", "auto"])
def test_train_driver_recovers_under_each_policy(policy):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--nodes", "9", "--n0", "2", "--f", "1",
         "--global-batch", "12", "--microbatch", "2", "--seq-len", "16",
         "--layers", "2", "--steps", "4", "--kill-at", "1", "--no-warm",
         "--recovery-policy", policy],
        capture_output=True, text=True, env=env, timeout=TIMEOUT)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "[fail] killed" in out.stdout
    assert "[done]" in out.stdout
    if policy == "adapt":
        assert "adapted schedule" in out.stdout
        assert "zero state copied" in out.stdout
