"""Recovery-equivalence suite for the adaptive recovery subsystem
(DESIGN.md §12): ReCycle-style schedule adaptation, hot-spare promotion
and the per-event ``auto`` selector.

The headline guarantee this locks down: for whole-pipeline failures the
adaptation re-routes the dead replica's microbatches through the SAME
``distribute_batch`` a replan would run, so (instances, batch) are
structurally identical under both policies — training under the adapted
schedule is BITWISE identical to a full replan on the surviving data,
while copying zero bytes and compiling nothing.  And the ``auto``
selector never picks a policy whose predicted downtime exceeds the best
actually-measured one.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core import (AdaptationError, EngineConfig, OobleckEngine,
                        build_profile, verify_replica_coverage)
from repro.data import GlobalBatchDispenser, SyntheticLM
from repro.models import Model
from repro.optim import adamw
from repro.runtime import HeteroTrainer, track_compiles
from repro.sim import (OobleckPolicy, rack_failure_bursts, run_sim,
                       scale_cycle, spot_preemption_wave)

RNG = jax.random.PRNGKey(11)
GB, MB, SEQ = 12, 2, 16


# ----------------------------------------------------------------------
# engine-level helpers (analytic only — no JAX arrays)
# ----------------------------------------------------------------------
def _profile(layers=18, mb=2, seq=256):
    arch = dataclasses.replace(get_arch("gpt2"), name=f"gpt2_L{layers}",
                               num_layers=layers)
    return build_profile(arch, microbatch=mb, seq_len=seq)


def make_engine(n_nodes, f=1, n0=4, gb=1024, mb=2, layers=18,
                policy="replan", spares=()):
    eng = OobleckEngine(
        _profile(layers), [f"node{i:03d}" for i in range(n_nodes)],
        EngineConfig(fault_tolerance=f, global_batch=gb, microbatch=mb,
                     gpus_per_node=1, n0_override=n0,
                     recovery_policy=policy))
    eng.spare_nodes = list(spares)
    return eng


# ----------------------------------------------------------------------
# trainer-level helpers (the validated 9-node / n0=2 / f=1 config:
# three 3-node pipelines; killing one leaves 6 >= (f+1)*n0 = 4 nodes)
# ----------------------------------------------------------------------
def make_trainer(policy):
    arch = reduced(get_arch("gpt3_medium"), layers=2)
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive",
                  scan_layers=False)
    params = model.init(RNG)
    profile = build_profile(arch, microbatch=MB, seq_len=SEQ)
    engine = OobleckEngine(
        profile, [f"n{i}" for i in range(9)],
        EngineConfig(fault_tolerance=1, global_batch=GB, microbatch=MB,
                     gpus_per_node=1, n0_override=2,
                     recovery_policy=policy))
    trainer = HeteroTrainer(model, engine, params, opt_cfg=adamw.AdamWConfig(
        lr=1e-3, warmup_steps=0, clip_norm=1.0, weight_decay=0.0))
    return arch, engine, trainer


def microbatches(batch, mb_size):
    n = batch["tokens"].shape[0] // mb_size
    return [{k: v[i * mb_size:(i + 1) * mb_size] for k, v in batch.items()
             if not k.startswith("_")} for i in range(n)]


def drive(trainer, disp):
    sizes = trainer.engine.batch.minibatch_sizes()
    batches = disp.next_step(sizes)
    return trainer.train_step([microbatches(b, MB) for b in batches])


# ----------------------------------------------------------------------
# 1. bitwise equivalence: adapted schedule vs full replan
# ----------------------------------------------------------------------
def test_adapt_bitwise_equals_replan_and_is_copy_compile_free():
    """Twin trainers on identical params/data.  A whole pipeline dies;
    one recovers by replan, the other by schedule adaptation.  Losses
    and the full parameter trees must stay EXACTLY equal (not approx —
    the adapted batch distribution is the replan's), the adaptation
    must copy zero bytes, and — after warm_templates() — fire zero XLA
    compiles from failure to the next completed step."""
    _, eng_a, tr_a = make_trainer("replan")
    arch, eng_b, tr_b = make_trainer("adapt")
    assert [i.nodes for i in eng_a.instances] == \
        [i.nodes for i in eng_b.instances]

    # reachable counts for THIS scenario: (2,2,2) before, (3,3) after
    tr_b.warm_templates(mb_counts=[2, 3])
    disp_a = GlobalBatchDispenser(SyntheticLM(arch.vocab_size, SEQ, seed=5))
    disp_b = GlobalBatchDispenser(SyntheticLM(arch.vocab_size, SEQ, seed=5))

    out_a, out_b = drive(tr_a, disp_a), drive(tr_b, disp_b)
    assert float(out_a["loss"]) == float(out_b["loss"])

    victims = set(eng_a.instances[0].nodes)
    info_a = tr_a.handle_failure(set(victims))
    with track_compiles() as log:
        info_b = tr_b.handle_failure(set(victims))
        out_b = drive(tr_b, disp_b)
        jnp.asarray(out_b["loss"]).block_until_ready()
    assert log.backend_compiles == 0, \
        f"{log.backend_compiles} XLA compiles during adapt->step"

    assert info_a["policy"] == "replan"
    assert info_b["policy"] == "adapt"
    assert info_b["copied_bytes"] == 0
    assert info_b["breakdown"]["transfer"] == 0.0
    assert info_b["breakdown"]["compile"] == 0.0
    # whole-pipeline kill: adapt == replan structurally => zero exposure
    assert info_b["breakdown"]["reroute"] == 0.0
    assert [i.nodes for i in eng_a.instances] == \
        [i.nodes for i in eng_b.instances]
    assert eng_a.batch.num_microbatches == eng_b.batch.num_microbatches

    out_a = drive(tr_a, disp_a)
    assert float(out_a["loss"]) == float(out_b["loss"])
    out_a, out_b = drive(tr_a, disp_a), drive(tr_b, disp_b)
    assert float(out_a["loss"]) == float(out_b["loss"])

    got_a, got_b = tr_a.full_params(), tr_b.full_params()
    for a, b in zip(jax.tree.leaves(got_a), jax.tree.leaves(got_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert tr_b.replica_divergence() < 1e-6
    assert eng_b.metrics.adaptations == 1


# ----------------------------------------------------------------------
# 2. structural identity at the plan level (fast, analytic)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("n_nodes,n0,gb", [(12, 4, 256), (24, 4, 1024)])
def test_whole_pipeline_kill_adapt_structurally_equals_replan(n_nodes, n0, gb):
    eng_a = make_engine(n_nodes, f=1, n0=n0, gb=gb)
    eng_b = make_engine(n_nodes, f=1, n0=n0, gb=gb)
    dead = set(eng_b.instances[0].nodes)

    ref_iter = eng_b.adaptation_reference_iteration(dead)
    plan = eng_b.plan_adaptation(dead)
    eng_b.apply_adaptation(plan, dead=dead)
    eng_a.handle_failure(set(dead))

    assert [i.nodes for i in eng_a.instances] == \
        [i.nodes for i in eng_b.instances]
    assert eng_a.batch.num_microbatches == eng_b.batch.num_microbatches
    assert verify_replica_coverage(eng_b.instances)
    assert plan.parked_nodes == ()          # the whole replica died
    bd = eng_b.adapt_cost_model().breakdown(plan, ref_iter)
    assert bd["reroute"] == 0.0
    assert bd["transfer"] == 0.0 and bd["compile"] == 0.0


def test_partial_kill_parks_survivors_and_reroutes_guests():
    eng = make_engine(12, f=1, n0=4, gb=256)
    inst = eng.instances[0]
    victim = inst.nodes[-1]
    plan = eng.plan_adaptation({victim})
    # the damaged replica's healthy nodes park as hot spares
    assert set(plan.parked_nodes) == set(inst.nodes) - {victim}
    assert plan.total_guests > 0
    assert sum(plan.mb_after) * eng.config.microbatch == 256
    eng.apply_adaptation(plan, dead={victim})
    assert set(plan.parked_nodes) <= set(eng.spare_nodes)
    assert victim not in eng.nodes


# ----------------------------------------------------------------------
# 3. the auto selector vs MEASURED per-policy downtime
# ----------------------------------------------------------------------
def _measure_all(dead, spares):
    """Actually run every feasible policy on identically-constructed
    engines and return its measured downtime."""
    measured = {}
    eng = make_engine(24, spares=spares)
    try:
        res = eng.handle_failure(set(dead))
        measured["replan"] = sum(
            eng.recovery_breakdown(res, dead=set(dead)).values())
    except Exception:
        pass
    eng = make_engine(24, spares=spares)
    try:
        ref = eng.adaptation_reference_iteration(set(dead))
        plan = eng.plan_adaptation(set(dead))
        eng.apply_adaptation(plan, dead=set(dead))
        measured["adapt"] = eng.adapt_cost_model().downtime_seconds(plan, ref)
    except AdaptationError:
        pass
    eng = make_engine(24, spares=spares)
    try:
        res = eng.plan_spare_promotion(set(dead))
        eng.apply_spare_promotion(res, dead=set(dead))
        measured["spare"] = sum(
            eng.recovery_breakdown(res, dead=set(dead)).values())
    except AdaptationError:
        pass
    return measured


@pytest.mark.parametrize("kind", ["whole_pipeline", "partial_with_spares",
                                  "partial_no_spares"])
def test_auto_never_predicts_worse_than_best_measured(kind):
    """ISSUE acceptance: for every failure event, the policy auto picks
    must not have a higher predicted downtime than the BEST downtime
    actually measured across all feasible policies (0.05 s tolerance
    covers the wall-clock jitter of the measured replan leg)."""
    spares = ("spareA", "spareB") if kind == "partial_with_spares" else ()
    eng = make_engine(24, spares=spares)
    if kind == "whole_pipeline":
        dead = set(eng.instances[0].nodes)
    else:
        dead = {eng.instances[0].nodes[-1], eng.instances[1].nodes[-1]}
    sel = eng.select_recovery_policy(dead)
    chosen, preds = sel["policy"], sel["predictions"]
    assert preds[chosen]["feasible"]
    measured = _measure_all(dead, spares)
    assert measured, "no policy could handle the event"
    # an adaptation vetoed by the slowdown cap is excluded from "best":
    # the veto is a steady-state throughput constraint, not a downtime
    # misprediction — auto may not choose it at any downtime
    eligible = {p: m for p, m in measured.items()
                if p != "adapt" or preds["adapt"].get("slowdown_ok", True)}
    best = min(eligible.values())
    assert preds[chosen]["downtime"] <= best + 0.05, \
        (chosen, preds[chosen]["downtime"], measured)


def test_auto_prefers_adapt_for_whole_pipeline_kill():
    """Exposure is zero and no state moves: adaptation strictly
    dominates a replan for a whole-replica death."""
    eng = make_engine(24)
    dead = set(eng.instances[0].nodes)
    sel = eng.select_recovery_policy(dead)
    assert sel["policy"] == "adapt"
    assert sel["predictions"]["adapt"]["downtime"] < \
        sel["predictions"]["replan"]["downtime"]


def test_slowdown_cap_vetoes_overloaded_adaptation():
    """With the cap at ~1x, any adaptation that slows the iteration past
    the replan outcome is excluded and auto degrades to replan/spare."""
    eng = make_engine(24)
    eng.config.adapt_max_slowdown = 1.0
    # partial kill: survivors absorb guests -> iteration grows
    dead = {eng.instances[0].nodes[-1]}
    preds = eng.predict_recovery(dead)
    if preds["adapt"]["feasible"] and not preds["adapt"]["slowdown_ok"]:
        assert eng.select_recovery_policy(dead)["policy"] != "adapt"


# ----------------------------------------------------------------------
# 4. per-family simulation: auto's decision log is self-consistent
# ----------------------------------------------------------------------
NODES = [f"n{i:03d}" for i in range(24)]
FAMILIES = {
    "rack_bursts": lambda: rack_failure_bursts(
        NODES, rack_size=4, horizon=40_000.0, mean_interval=4000.0,
        seed=3, min_alive=12),
    "preemption_wave": lambda: spot_preemption_wave(
        NODES, horizon=40_000.0, mean_wave=5000.0, wave_frac=0.15,
        grace=120.0, seed=7, min_alive=12),
    "scale_cycle": lambda: scale_cycle(
        NODES, horizon=40_000.0, period=8000.0, step=4, lo=16, hi=24),
}


@pytest.mark.parametrize("family", sorted(FAMILIES))
def test_auto_decision_log_per_family(family):
    pol = OobleckPolicy(_profile(), NODES, f=1, global_batch=1024,
                        microbatch=2, n0=4, recovery_policy="auto")
    events = FAMILIES[family]()
    assert any(e.kind == "fail" for e in events)
    res = run_sim(pol, events, horizon=40_000.0, global_batch=1024,
                  min_nodes=12)
    assert res.stopped_reason is None
    assert res.events_handled > 0
    assert pol.decisions, "auto handled failures but logged no decisions"
    for d in pol.decisions:
        assert d["chosen"] in d["predicted"]
        # auto only deviates from replan when the prediction says the
        # alternative is at least as cheap (slowdown vetoes can force
        # replan even when adapt predicts cheaper — never the reverse)
        if d["chosen"] != "replan" and "replan" in d["predicted"]:
            assert (d["predicted"][d["chosen"]]
                    <= d["predicted"]["replan"] + 1e-9), d
    assert pol.stats.adaptations == \
        sum(d["chosen"] == "adapt" for d in pol.decisions)
    assert pol.stats.spare_promotions == \
        sum(d["chosen"] == "spare" for d in pol.decisions)


def test_fixed_policies_log_no_decisions():
    pol = OobleckPolicy(_profile(), NODES, f=1, global_batch=1024,
                        microbatch=2, n0=4, recovery_policy="adapt")
    pol.on_failure(set(pol.engine.instances[0].nodes))
    assert pol.stats.adaptations == 1
    assert pol.decisions == []      # nothing was compared


# ----------------------------------------------------------------------
# 5. infeasibility: errors, not hangs or crashes
# ----------------------------------------------------------------------
def test_adapt_infeasible_when_every_replica_damaged():
    eng = make_engine(24)
    dead = {inst.nodes[-1] for inst in eng.instances}
    with pytest.raises(AdaptationError):
        eng.plan_adaptation(dead)


def test_adapt_policy_falls_back_to_replan_when_infeasible():
    pol = OobleckPolicy(_profile(), NODES, f=1, global_batch=1024,
                        microbatch=2, n0=4, recovery_policy="adapt")
    # damage EVERY replica (adapt infeasible) but at a different stage
    # position each, so every layer keeps a surviving owner and the
    # replan fallback can still recover
    dead = {inst.nodes[i] for i, inst in enumerate(pol.engine.instances)}
    seconds = pol.on_failure(dead)
    assert seconds > 0.0
    assert pol.stats.adaptations == 0
    assert pol.stats.reconfigurations == 1      # the replan fallback
    assert "transfer" in pol.last_breakdown
    assert not (dead & set(pol.engine.nodes))


# ----------------------------------------------------------------------
# 6. hot-spare promotion
# ----------------------------------------------------------------------
def test_spare_promotion_fills_dead_slot_without_replanning():
    eng = make_engine(24, spares=("spareA", "spareB"))
    before = [i.template for i in eng.instances]
    batch_before = eng.batch
    victim = eng.instances[0].nodes[-1]
    result = eng.plan_spare_promotion({victim})
    assert result.batch is batch_before          # batch untouched
    assert [i.template for i in result.instances] == before
    flat = [n for i in result.instances for n in i.nodes]
    assert victim not in flat and "spareA" in flat
    assert result.spare_nodes == ["spareB"]
    # every copied layer lands on the promoted spare, sourced from a
    # surviving owner
    assert result.copy_plan
    for task in result.copy_plan:
        assert task.dst_node == "spareA"
        assert task.src_node != victim
    eng.apply_spare_promotion(result, dead={victim})
    assert eng.metrics.spare_promotions == 1
    assert verify_replica_coverage(eng.instances)
    assert eng.spare_nodes == ["spareB"]


def test_spare_promotion_infeasible_without_spares():
    eng = make_engine(24)
    with pytest.raises(AdaptationError):
        eng.plan_spare_promotion({eng.instances[0].nodes[-1]})
