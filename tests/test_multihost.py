"""Multi-process execution backend (DESIGN.md §15), REAL processes.

The acceptance contract of the ConfigurationEngine/ExecutionEngine
split, against subprocess-spawned workers on localhost:

  1. LIFECYCLE (3 workers) — train in bitwise lockstep with the
     single-process HeteroTrainer; SIGKILL a worker: the death is
     detected through the coordination channel (socket EOF /
     heartbeat — no injected event), survivors agree on a
     reconfiguration epoch, layer state moves between processes as
     actual socket transfers, the survivors recompile NOTHING, and the
     post-recovery losses are BITWISE equal to the single-process
     trainer driven through the same failure trace.  Checkpoints from
     the surviving processes elect one manifest writer.
  2. CONFORMANCE + JOIN + FAULT INJECTION (2 workers) —
     MultiHostExecutor honours the same Executor interface as every
     other runtime: step parity, snapshot round-trip, elastic join
     through the same two-phase commit; then SIGKILL the lead rank
     MID-STEP — the in-flight iteration is lost without mutating state
     (§3.3, WorkerLost), and the survivor recovers and continues the
     reference trace bitwise.

Heavy (each worker compiles its program set); guarded by the same
REPRO_DRYRUN_TIMEOUT budget as the other subprocess suites.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.configs import get_arch, reduced
from repro.core import EngineConfig, OobleckEngine, build_profile
from repro.data import GlobalBatchDispenser, SyntheticLM
from repro.models import Model
from repro.optim import adamw
from repro.runtime import Executor, HeteroTrainer, WorkerLost
from repro.runtime.multihost import (MultiHostExecutor, ShardTrainer,
                                     make_job_spec)

GB, MB, SEQ, L = 16, 2, 16, 4
NODES = [f"n{i}" for i in range(5)]
# explicit hosting: rank 1 hosts exactly n2 — a NON-lead member of
# replica (n0, n1, n2) — so SIGKILLing it damages one replica while
# both surviving ranks keep their steady-state lead assignments (the
# strict zero-recompile window applies: no survivor traces anything
# new), stays above the (f+1)*n0 floor, and the shrunk replica's
# rebind still moves layer state between processes
HOSTING = {"n0": 0, "n1": 0, "n2": 1, "n3": 2, "n4": 2}
TIMEOUT = float(os.environ.get("REPRO_DRYRUN_TIMEOUT", "600"))


def _spec(hosting, procs):
    return make_job_spec(arch="gpt3_medium", layers=L, seq_len=SEQ,
                         microbatch=MB, global_batch=GB, f=1, n0=2,
                         nodes=NODES, hosting=hosting, procs=procs,
                         seed=11)


def _reference():
    arch = reduced(get_arch("gpt3_medium"), layers=L)
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive",
                  scan_layers=False)
    params = model.init(jax.random.PRNGKey(11))
    profile = build_profile(arch, microbatch=MB, seq_len=SEQ)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, clip_norm=1.0,
                                weight_decay=0.0)
    engine = OobleckEngine(profile, list(NODES),
                           EngineConfig(fault_tolerance=1, global_batch=GB,
                                        microbatch=MB, gpus_per_node=1,
                                        n0_override=2))
    trainer = HeteroTrainer(model, engine, params, opt_cfg, mode="compiled")
    return arch, trainer


def _microbatches(batch):
    n = batch["tokens"].shape[0] // MB
    return [{k: v[i * MB:(i + 1) * MB] for k, v in batch.items()
             if not k.startswith("_")} for i in range(n)]


def _feed(disp, engine):
    return [_microbatches(b)
            for b in disp.next_step(engine.batch.minibatch_sizes())]


def _bitwise(a, b):
    return np.asarray(a).tobytes() == np.asarray(b).tobytes()


def test_multihost_is_an_executor_subclass():
    assert issubclass(MultiHostExecutor, Executor)
    assert issubclass(ShardTrainer, Executor)


def test_replan_fingerprint_is_hash_seed_independent():
    """Every process dry-runs the failure plan independently; the plan
    fingerprint (which includes the copy plan's source picks) must not
    depend on the interpreter's string-hash seed.  Regression: the copy
    planner used to break load ties by SET iteration order."""
    import json
    import subprocess
    import sys

    import repro
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    prog = (
        "import json, sys\n"
        "from repro.runtime.multihost import build_setup, make_job_spec\n"
        "spec = json.loads(sys.argv[1])\n"
        "*_, engine = build_setup(spec)\n"
        "spares = [n for n in engine.spare_nodes if n != 'n2']\n"
        "r = engine.reconf.on_failure(engine.instances, {'n2'},"
        " spares=spares)\n"
        "print(engine.plan_fingerprint(r))\n")
    fps = set()
    for seed in ("0", "1", "2"):
        env = dict(os.environ,
                   PYTHONHASHSEED=seed,
                   PYTHONPATH=src + os.pathsep + os.environ.get(
                       "PYTHONPATH", ""),
                   JAX_PLATFORMS="cpu")
        out = subprocess.run(
            [sys.executable, "-c", prog, json.dumps(_spec(HOSTING, 3))],
            env=env, capture_output=True, text=True, timeout=TIMEOUT)
        assert out.returncode == 0, out.stderr
        fps.add(out.stdout.strip())
    assert len(fps) == 1, fps


def test_sigkill_lifecycle_parity_zero_compiles(tmp_path):
    arch, ref = _reference()
    ref.warm_templates()
    src = SyntheticLM(arch.vocab_size, SEQ, seed=5)
    d_ref, d_mh = GlobalBatchDispenser(src), GlobalBatchDispenser(src)

    with MultiHostExecutor(_spec(HOSTING, 3), rpc_timeout=TIMEOUT) as mh:
        assert mh.engine.plan_fingerprint() == ref.engine.plan_fingerprint()
        mh.warm_templates()

        # bitwise lockstep with the single-process trainer
        for _ in range(2):
            o_ref = ref.step(_feed(d_ref, ref.engine))
            o_mh = mh.step(_feed(d_mh, mh.engine))
            assert _bitwise(o_ref["loss"], o_mh["loss"])
            assert _bitwise(o_ref["grad_norm"], o_mh["grad_norm"])
        assert mh.replica_divergence() == 0
        mh.mark_compiles()      # steady state: all step glue ops traced

        # SIGKILL a worker; detection comes from the channel
        # (EOF/heartbeat), NOT from an injected event
        mh.kill_worker(1)
        dead, ranks = mh.detected_dead(timeout=30.0)
        assert dead == {"n2"} and ranks == {1}

        # two-phase agreed reconfiguration; the replacement node's
        # state crosses processes over the data plane
        info = mh.recover(dead)
        ref.recover({"n2"})
        assert info["epoch"] == ref.engine.epoch == 1
        assert info["fetched_bytes"] > 0 and info["fetches"] >= 1
        # same plan as the single-process trainer, structurally (the
        # fingerprint's instance ids differ: the two-phase protocol
        # consumes extra reconfigurator ids for its PREPARE dry-run)
        assert ([i.nodes for i in mh.engine.instances]
                == [i.nodes for i in ref.engine.instances])
        assert (mh.engine.batch.num_microbatches
                == ref.engine.batch.num_microbatches)

        # post-recovery: bitwise lockstep continues, survivors
        # recompiled NOTHING
        for _ in range(2):
            o_ref = ref.step(_feed(d_ref, ref.engine))
            o_mh = mh.step(_feed(d_mh, mh.engine))
            assert _bitwise(o_ref["loss"], o_mh["loss"])
        compiles = mh.compile_counts()
        assert sorted(compiles) == [0, 2]
        assert all(v == 0 for v in compiles.values()), compiles
        assert mh.replica_divergence() == 0

        # full state: snapshot params bitwise-equal to the reference
        snap_mh, snap_ref = mh.snapshot(), ref.snapshot()
        assert snap_mh.step == snap_ref.step
        for x, y in zip(jax.tree.leaves(snap_mh.params),
                        jax.tree.leaves(snap_ref.params)):
            assert _bitwise(x, y)

        # multi-writer checkpoint: every lead writes shards, exactly
        # one elected process commits the manifest
        stats = mh.save_checkpoint(str(tmp_path))
        wrote = [r for r, s in stats.items() if s["manifests_skipped"] == 0]
        assert len(wrote) == 1
        mgr = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                                async_mode=False)
        assert mgr.list_steps() == [snap_mh.step]
        assert mgr.verify(snap_mh.step)


def test_two_proc_conformance_step_snapshot_join():
    arch, ref = _reference()
    src = SyntheticLM(arch.vocab_size, SEQ, seed=9)
    d_ref, d_mh = GlobalBatchDispenser(src), GlobalBatchDispenser(src)
    hosting = {"n0": 0, "n1": 0, "n2": 0, "n3": 1, "n4": 1}

    with MultiHostExecutor(_spec(hosting, 2), rpc_timeout=TIMEOUT) as mh:
        assert isinstance(mh, Executor)
        o_ref = ref.step(_feed(d_ref, ref.engine))
        o_mh = mh.step(_feed(d_mh, mh.engine))
        assert _bitwise(o_ref["loss"], o_mh["loss"])

        # elastic join rides the same two-phase commit
        info = mh.join(["n5"])
        ref.join(["n5"])
        assert info["epoch"] == ref.engine.epoch
        assert mh.engine.plan_fingerprint() == ref.engine.plan_fingerprint()
        assert "n5" in mh.hosting

        o_ref = ref.step(_feed(d_ref, ref.engine))
        o_mh = mh.step(_feed(d_mh, mh.engine))
        assert _bitwise(o_ref["loss"], o_mh["loss"])
        assert mh.replica_divergence() == 0

        snap_mh, snap_ref = mh.snapshot(), ref.snapshot()
        for x, y in zip(jax.tree.leaves(snap_mh.params),
                        jax.tree.leaves(snap_ref.params)):
            assert _bitwise(x, y)

        # fault injection: SIGKILL the rank leading replica(s) while a
        # step is in flight — the iteration is LOST (§3.3), nothing
        # commits anywhere, and both sides drop the batch
        batches = _feed(d_mh, mh.engine)
        _feed(d_ref, ref.engine)
        mh.kill_worker(1)
        with pytest.raises(WorkerLost) as e:
            mh.step(batches)
        assert 1 in e.value.ranks
        dead, ranks = mh.detected_dead(timeout=30.0)
        assert dead == {"n3", "n4"} and ranks == {1}

        info = mh.recover(dead)
        ref.recover({"n3", "n4"})
        assert info["epoch"] == ref.engine.epoch
        assert ([i.nodes for i in mh.engine.instances]
                == [i.nodes for i in ref.engine.instances])

        # the lost iteration left state untouched: the sole survivor
        # continues in bitwise lockstep with the reference trace
        o_ref = ref.step(_feed(d_ref, ref.engine))
        o_mh = mh.step(_feed(d_mh, mh.engine))
        assert _bitwise(o_ref["loss"], o_mh["loss"])
