"""Coordination channel + heartbeat failure detection (DESIGN.md §15).

Unit-level contract of the multi-process control plane, no subprocesses:

  1. WIRE — the framed header+blobs format round-trips bit-exactly,
     including the pytree and microbatch packers recovery and the step
     protocol ride on.
  2. HEARTBEAT — the alive -> suspect -> dead state machine under an
     injected clock: SUSPECT only past ``timeout``, DEAD only past
     ``timeout * (1 + backoff)``, each death reported exactly ONCE, and
     DEAD is sticky (a fenced member's beats are discarded — a zombie
     can't resurrect into a reconfigured plan).
  3. RPC — CoordinatorServer <-> WorkerChannel over real localhost
     sockets (threads, not processes): request/response routing,
     concurrent broadcast, per-rank payloads, remote-exception
     propagation, and the disconnect-as-failure signal: closing a
     worker's socket makes pending calls raise WorkerLost and poll_dead
     report the rank, with ``strict=False`` returning the survivors'
     replies instead.
"""
import socket
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import elect_writer
from repro.core.monitor import HeartbeatConfig, HeartbeatTracker
from repro.runtime.coordination import (CoordinatorServer, DataServer,
                                        WorkerChannel, WorkerLost, data_call,
                                        pack_batches, pack_tree, recv_msg,
                                        send_msg, unpack_batches, unpack_tree)


# ----------------------------------------------------------------------
# 1. Wire format
# ----------------------------------------------------------------------
def test_framing_roundtrip_header_and_blobs():
    a, b = socket.socketpair()
    try:
        blobs = [b"", b"x" * 3, np.arange(7, dtype=np.float32).tobytes()]
        send_msg(a, {"type": "t", "k": [1, "two"]}, blobs)
        send_msg(a, {"type": "empty"})
        h1, b1 = recv_msg(b)
        h2, b2 = recv_msg(b)
        assert h1 == {"type": "t", "k": [1, "two"]} and b1 == blobs
        assert h2 == {"type": "empty"} and b2 == []
    finally:
        a.close()
        b.close()


def test_framing_eof_raises_connection_error():
    a, b = socket.socketpair()
    send_msg(a, {"type": "t"})
    a.close()
    h, _ = recv_msg(b)
    assert h["type"] == "t"
    with pytest.raises(ConnectionError):
        recv_msg(b)
    b.close()


def test_pack_tree_roundtrips_bitwise():
    tree = {"p": {"w": np.linspace(0, 1, 12, dtype=np.float32).reshape(3, 4),
                  "b": np.arange(3, dtype=np.int32)},
            "m": {"w": np.full((3, 4), np.pi, np.float32),
                  "b": np.zeros(3, np.float32)}}
    spec, blobs = pack_tree(tree)
    out = unpack_tree(tree, spec, blobs)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert np.asarray(x).tobytes() == np.asarray(y).tobytes()
        assert np.asarray(x).dtype == np.asarray(y).dtype


def test_unpack_tree_rejects_structure_mismatch():
    tree = {"a": np.zeros(2, np.float32), "b": np.ones(2, np.float32)}
    spec, blobs = pack_tree(tree)
    with pytest.raises(ValueError):
        unpack_tree({"a": tree["a"], "c": tree["b"]}, spec, blobs)
    with pytest.raises(ValueError):
        unpack_tree({"a": tree["a"]}, spec, blobs)


def test_pack_batches_roundtrip():
    per_pipeline = [
        [{"tokens": np.arange(8, dtype=np.int32).reshape(2, 4),
          "labels": np.ones((2, 4), np.int32)} for _ in range(3)],
        [{"tokens": np.zeros((2, 4), np.int32),
          "labels": np.full((2, 4), 7, np.int32)}],
    ]
    spec, blobs = pack_batches(per_pipeline)
    out = unpack_batches(spec, blobs)
    assert len(out) == 2 and [len(p) for p in out] == [3, 1]
    for mbs_in, mbs_out in zip(per_pipeline, out):
        for mi, mo in zip(mbs_in, mbs_out):
            assert sorted(mi) == sorted(mo)
            for k in mi:
                np.testing.assert_array_equal(mi[k], mo[k])


# ----------------------------------------------------------------------
# 2. Heartbeat state machine (injected clock)
# ----------------------------------------------------------------------
def _tracker():
    clock = {"t": 0.0}
    cfg = HeartbeatConfig(interval=0.5, timeout=3.0, backoff=1.0)
    return HeartbeatTracker(cfg, now_fn=lambda: clock["t"]), clock, cfg


def test_heartbeat_alive_suspect_dead_thresholds():
    tr, clock, cfg = _tracker()
    tr.register("w0")
    assert cfg.dead_after == 6.0
    clock["t"] = 3.0
    assert tr.status("w0") == HeartbeatTracker.ALIVE     # silence == timeout
    clock["t"] = 3.01
    assert tr.status("w0") == HeartbeatTracker.SUSPECT
    clock["t"] = 6.0
    assert tr.status("w0") == HeartbeatTracker.SUSPECT   # == dead_after
    clock["t"] = 6.01
    assert tr.status("w0") == HeartbeatTracker.DEAD


def test_heartbeat_beat_resets_silence():
    tr, clock, _ = _tracker()
    tr.register("w0")
    clock["t"] = 2.9
    assert tr.beat("w0")
    clock["t"] = 5.8                        # 2.9s of silence since beat
    assert tr.status("w0") == HeartbeatTracker.ALIVE


def test_heartbeat_poll_reports_each_death_once_and_fences():
    tr, clock, _ = _tracker()
    tr.register("w0")
    tr.register("w1")
    clock["t"] = 1.0
    tr.beat("w1")
    clock["t"] = 6.5                        # w0 silent 6.5s, w1 silent 5.5s
    assert tr.poll() == ["w0"]
    assert tr.poll() == []                  # reported exactly once
    assert tr.beat("w0") is False           # fenced: beat discarded
    assert tr.status("w0") == HeartbeatTracker.DEAD
    clock["t"] = 7.2                        # w1 now past dead_after too
    assert tr.poll() == ["w1"]
    assert tr.alive() == []


def test_heartbeat_mark_dead_is_instant_and_sticky():
    tr, clock, _ = _tracker()
    tr.register("w0")
    tr.mark_dead("w0")                      # socket EOF path: no timeout
    assert tr.status("w0") == HeartbeatTracker.DEAD
    assert tr.beat("w0") is False
    assert tr.poll() == ["w0"]


def test_elect_writer_is_deterministic_min():
    assert elect_writer(["proc2", "proc0", "proc1"]) == "proc0"
    assert elect_writer(["proc1"]) == "proc1"
    with pytest.raises(ValueError):
        elect_writer([])


# ----------------------------------------------------------------------
# 3. RPC over real sockets (threaded workers)
# ----------------------------------------------------------------------
class _ThreadWorker:
    """A WorkerChannel served from a thread — the coordinator cannot
    tell it apart from a real subprocess."""

    def __init__(self, addr, rank, handlers, beat_interval=0.05):
        self.channel = WorkerChannel(addr, rank, hello={"tag": f"w{rank}"},
                                     beat_interval=beat_interval)
        self.thread = threading.Thread(
            target=self.channel.serve, args=(handlers,), daemon=True)
        self.thread.start()


def _echo_handlers(rank):
    def echo(header, blobs):
        return {"rank": rank, "x": header.get("x")}, [b + b"!" for b in blobs]

    def boom(header, blobs):
        raise RuntimeError(f"boom from {rank}")

    return {"echo": echo, "boom": boom}


@pytest.fixture
def cluster():
    server = CoordinatorServer(2, HeartbeatConfig(interval=0.05,
                                                  timeout=0.5, backoff=1.0))
    workers = [_ThreadWorker(server.addr, r, _echo_handlers(r))
               for r in range(2)]
    hellos = server.accept_workers(timeout=10)
    try:
        yield server, workers, hellos
    finally:
        for w in workers:
            w.channel.close()
        server.close()


def test_rpc_call_and_broadcast(cluster):
    server, _, hellos = cluster
    assert {r: h["tag"] for r, h in hellos.items()} == {0: "w0", 1: "w1"}
    h, blobs = server.call(1, {"type": "echo", "x": 5}, [b"ab"], timeout=10)
    assert (h["rank"], h["x"], blobs) == (1, 5, [b"ab!"])
    replies = server.broadcast_call({"type": "echo", "x": 9}, timeout=10)
    assert {r: h["rank"] for r, (h, _) in replies.items()} == {0: 0, 1: 1}


def test_rpc_multi_call_per_rank_payloads(cluster):
    server, _, _ = cluster
    replies = server.multi_call(
        {0: ({"type": "echo", "x": "a"}, [b"0"]),
         1: ({"type": "echo", "x": "b"}, [b"1"])}, timeout=10)
    assert replies[0][0]["x"] == "a" and replies[1][0]["x"] == "b"
    assert replies[0][1] == [b"0!"] and replies[1][1] == [b"1!"]


def test_rpc_remote_exception_carries_traceback(cluster):
    server, _, _ = cluster
    with pytest.raises(RuntimeError, match="boom from 0"):
        server.call(0, {"type": "boom"}, timeout=10)
    # the channel survives a handler error
    h, _ = server.call(0, {"type": "echo", "x": 1}, timeout=10)
    assert h["rank"] == 0


def test_rpc_disconnect_is_instant_failure(cluster):
    server, workers, _ = cluster
    workers[1].channel.close()              # EOF -> mark_dead, no timeout
    with pytest.raises(WorkerLost) as e:
        server.call(1, {"type": "echo"}, timeout=10)
    assert e.value.ranks == [1]
    assert server.poll_dead() == [1]
    assert server.alive_ranks() == [0]
    # strict broadcast names the corpse; lenient returns the survivors
    with pytest.raises(WorkerLost):
        server.broadcast_call({"type": "echo", "x": 2}, timeout=10)
    replies = server.broadcast_call({"type": "echo", "x": 2}, timeout=10,
                                    strict=False)
    assert list(replies) == [0] and replies[0][0]["x"] == 2


def test_data_server_roundtrip_and_error():
    def handler(header, blobs):
        if header.get("x") == "bad":
            raise ValueError("nope")
        return {"ok": True}, [blobs[0] * 2]

    srv = DataServer(handler)
    try:
        h, blobs = data_call(srv.addr, {"type": "get", "x": 1}, [b"ab"])
        assert h["ok"] and blobs == [b"abab"]
        with pytest.raises(RuntimeError, match="nope"):
            data_call(srv.addr, {"type": "get", "x": "bad"}, [b""])
    finally:
        srv.close()
