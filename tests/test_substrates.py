"""Substrate tests: 1F1B schedule, optimizer, data pipeline, checkpoint."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_arch, reduced
from repro.core import PipelinePlanner, build_profile, estimate_iteration_time
from repro.data import ByteCorpus, DataCursor, GlobalBatchDispenser, SyntheticLM
from repro.models import Model
from repro.optim import adamw
from repro.runtime.schedule import flat_schedule, one_f_one_b, simulate_makespan


# ----------------------------------------------------------------------
# 1F1B schedule
# ----------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(S=st.integers(1, 6), M=st.integers(1, 12))
def test_1f1b_complete_and_dependency_safe(S, M):
    per_stage = one_f_one_b(S, M)
    for ops in per_stage:
        fs = [mb for op, mb in ops if op == "F"]
        bs = [mb for op, mb in ops if op == "B"]
        assert fs == list(range(M)) and bs == list(range(M))
        # in-flight microbatches never exceed the 1F1B bound
        inflight = 0
        peak = 0
        for op, mb in ops:
            inflight += 1 if op == "F" else -1
            peak = max(peak, inflight)
        assert peak <= min(S, M) + 1
    flat = flat_schedule(S, M)  # raises on deadlock
    assert len(flat) == 2 * S * M


def test_makespan_matches_planner_estimate():
    """For homogeneous stages the planner's T1+T2+T3 must equal the
    event-driven 1F1B makespan (both equal (N_b + S - 1)(F + B))."""
    f, b = 2.0, 4.0
    for S in (2, 3, 5):
        nb = 4 * S
        got = simulate_makespan([f] * S, [b] * S, nb)
        assert abs(got - (nb + S - 1) * (f + b)) < 1e-9


def test_makespan_planner_consistency_real_profile(gpt27_profile):
    pl = PipelinePlanner(gpt27_profile, gpus_per_node=1)
    tpl = pl.plan(4)
    nb = 4 * tpl.num_stages
    fwd = [gpt27_profile.stage_fwd(s.layer_start, s.layer_end, s.num_gpus)
           for s in tpl.stages]
    bwd = [gpt27_profile.stage_bwd(s.layer_start, s.layer_end, s.num_gpus)
           for s in tpl.stages]
    sim = simulate_makespan(fwd, bwd, nb)
    est = estimate_iteration_time(tpl, nb)
    # the analytic critical path is a (tight-ish) estimate of the event sim
    assert 0.5 * sim <= est <= 1.5 * sim


# ----------------------------------------------------------------------
# Optimizer
# ----------------------------------------------------------------------
def test_adamw_descends_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, weight_decay=0.0,
                            clip_norm=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.apply(cfg, params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_adamw_clip():
    cfg = adamw.AdamWConfig(lr=0.1, clip_norm=1.0, warmup_steps=0)
    grads = {"w": jnp.array([300.0, 400.0])}
    clipped, norm = adamw.clip_by_global_norm(grads, 1.0)
    assert abs(float(norm) - 500.0) < 1e-3
    assert abs(float(jnp.linalg.norm(clipped["w"])) - 1.0) < 1e-5


def test_schedule_warmup_and_cosine():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                            min_lr_ratio=0.1)
    lr0 = float(adamw.schedule(cfg, jnp.int32(1)))
    lr10 = float(adamw.schedule(cfg, jnp.int32(10)))
    lr100 = float(adamw.schedule(cfg, jnp.int32(100)))
    assert lr0 < lr10
    assert abs(lr10 - 1.0) < 1e-6
    assert abs(lr100 - 0.1) < 1e-6


# ----------------------------------------------------------------------
# Data pipeline
# ----------------------------------------------------------------------
def test_synthetic_deterministic():
    src = SyntheticLM(100, 8, seed=4)
    a = src.sample(42)
    b = src.sample(42)
    np.testing.assert_array_equal(a, b)
    assert not np.array_equal(src.sample(42), src.sample(43))


def test_dispenser_exactly_once_under_resplit():
    src = SyntheticLM(100, 8, seed=4)
    disp = GlobalBatchDispenser(src)
    seen = []
    for sizes in [(4, 4, 8), (6, 10), (16,), (2, 2, 2, 10)]:
        batches = disp.next_step(sizes)
        assert [b["tokens"].shape[0] for b in batches] == list(sizes)
        seen += [i for b in batches for i in b["_indices"]]
    assert sorted(seen) == list(range(64))


def test_dispenser_rewind_and_restore():
    src = SyntheticLM(100, 8)
    disp = GlobalBatchDispenser(src)
    disp.next_step((8,))
    disp.rewind(8)                   # lost iteration retried
    state = disp.state()
    again = disp.next_step((8,))
    assert list(again[0]["_indices"]) == list(range(8))
    disp2 = GlobalBatchDispenser(src)
    disp2.restore(state)
    assert disp2.cursor.next_index == state["next_index"]


def test_byte_corpus():
    corpus = ByteCorpus(b"the quick brown fox jumps over the lazy dog " * 10,
                        seq_len=16)
    b = corpus.batch([0, 1, 2])
    assert b["tokens"].shape == (3, 16)
    assert b["tokens"].max() < 256


# ----------------------------------------------------------------------
# Checkpoint
# ----------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    from repro.ckpt import CheckpointManager, TrainState
    arch = reduced(get_arch("gpt3_medium"), layers=3)
    model = Model(arch, dtype=jnp.float32, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init(params)
    mgr = CheckpointManager(str(tmp_path), num_layers=arch.num_layers,
                            async_mode=False)
    mgr.save(TrainState(step=7, params=params, opt_state=opt,
                        data_state={"next_index": 123}, rng_seed=5))
    assert mgr.list_steps() == [7]
    restored = mgr.restore(params, opt)
    assert restored.step == 7
    assert restored.data_state["next_index"] == 123
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_gc(tmp_path):
    from repro.ckpt import CheckpointManager, TrainState
    arch = reduced(get_arch("gpt3_medium"), layers=2)
    model = Model(arch, dtype=jnp.float32, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw.init(params)
    mgr = CheckpointManager(str(tmp_path), num_layers=2, async_mode=True,
                            keep=2)
    for step in (1, 2, 3):
        mgr.save(TrainState(step, params, opt, {"next_index": 0}, 0))
    mgr.wait()
    assert mgr.list_steps() == [2, 3]       # keep=2 garbage-collects step 1


def test_checkpoint_partial_write_invisible(tmp_path):
    """A step directory without MANIFEST.json must be ignored."""
    from repro.ckpt import CheckpointManager
    os.makedirs(tmp_path / "step_00000009")
    mgr = CheckpointManager(str(tmp_path), num_layers=1)
    assert mgr.list_steps() == []
