"""Scale features: 1000-node planning, greedy instantiation, straggler
rebalancing, elastic joins at scale."""
import time

import pytest

from repro.configs import get_arch
from repro.core import (EngineConfig, OobleckEngine, build_profile,
                        choose_plan, generate_node_spec)
from repro.core.instantiator import greedy_counts
from repro.core.planner import PipelinePlanner


@pytest.fixture(scope="module")
def big_profile():
    return build_profile(get_arch("gpt3_6_7b"), microbatch=2, seq_len=2048)


def test_thousand_node_bootstrap_is_fast(big_profile):
    """Planning + instantiation for 1024 nodes must take seconds, not
    minutes (paper §7.4: 'Oobleck simply instantiates more of the
    smaller pipelines' at scale)."""
    nodes = [f"n{i}" for i in range(1024)]
    t0 = time.perf_counter()
    eng = OobleckEngine(big_profile, nodes, EngineConfig(
        fault_tolerance=3, global_batch=8192, microbatch=2,
        gpus_per_node=1, n0_override=8, max_stages=12))
    elapsed = time.perf_counter() - t0
    assert elapsed < 60, f"bootstrap took {elapsed:.1f}s"
    assert len(eng.nodes) == 1024          # every node used
    assert len(eng.instances) >= 4         # f+1
    # templates capped at the layer count, sizes consecutive
    assert eng.spec.sizes[0] == 8
    assert eng.spec.sizes[-1] <= big_profile.num_layers


def test_thousand_node_failures(big_profile):
    nodes = [f"n{i}" for i in range(1024)]
    eng = OobleckEngine(big_profile, nodes, EngineConfig(
        fault_tolerance=3, global_batch=8192, microbatch=2,
        gpus_per_node=1, n0_override=8, max_stages=12))
    t0 = time.perf_counter()
    eng.handle_failure({eng.instances[0].nodes[0],
                        eng.instances[1].nodes[0],
                        eng.instances[2].nodes[0]})
    elapsed = time.perf_counter() - t0
    assert elapsed < 30, f"reconfig took {elapsed:.1f}s"
    assert len(eng.nodes) == 1021


def test_greedy_counts_exact_and_feasible(big_profile):
    spec = generate_node_spec(N=500, f=3, n0=8, max_size=20)
    planner = PipelinePlanner(big_profile, gpus_per_node=1, max_stages=12)
    templates = planner.plan_all(spec.sizes)
    counts = greedy_counts(tuple(spec.sizes), templates, 500, 4)
    assert sum(c * s for c, s in zip(counts, spec.sizes)) == 500
    assert sum(counts) >= 4


def test_greedy_matches_exact_on_small(big_profile):
    """Where exact enumeration is tractable, greedy must stay within 10%
    throughput of the optimum."""
    spec = generate_node_spec(N=40, f=2, n0=8, max_size=16)
    planner = PipelinePlanner(big_profile, gpus_per_node=1, max_stages=12)
    templates = planner.plan_all(spec.sizes)
    exact = choose_plan(templates, spec, 40, 4096, 2, exact_threshold=64)
    greedy = choose_plan(templates, spec, 40, 4096, 2, exact_threshold=1)
    assert greedy.throughput >= 0.9 * exact.throughput


def test_straggler_rebalance(big_profile):
    eng = OobleckEngine(big_profile, [f"n{i}" for i in range(40)],
                        EngineConfig(fault_tolerance=2, global_batch=4096,
                                     microbatch=2, gpus_per_node=1,
                                     n0_override=8, max_stages=12))
    base = eng.batch.num_microbatches
    # pipeline 0 observed 3x slower than the rest
    times = [3.0] + [1.0] * (len(base) - 1)
    plan = eng.rebalance(times)
    assert sum(plan.num_microbatches) == sum(base)
    assert plan.num_microbatches[0] < min(plan.num_microbatches[1:])
