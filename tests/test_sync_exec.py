"""Compiled bucketed gradient-sync data plane (DESIGN.md §10) — the
parity suite:

  1. PARITY — the bucketed compiled sync tail computes the SAME synced
     gradients as the eager per-layer oracle: BITWISE for codec="none"
     (same per-element multiply/add order), bounded error for bf16/int8,
     and the error-feedback residual keeps the time-averaged applied
     gradient convergent to the true one.
  2. ZERO RECOMPILATION — warm_templates() also warms bucket programs:
     a failure -> recover -> step cycle fires no XLA backend compiles,
     including the sync tail, for codec="none" AND for int8.
  3. RECONFIGURATION SAFETY — error-feedback residuals are keyed by
     bucket signature and dropped when recover/join changes the layout
     (the shape-mismatch regression), and training continues cleanly.
  4. SHARED COST MODEL — the engine and the simulator policy price the
     sync tail through ONE implementation and agree exactly; the
     hierarchical ICI/DCN path is cheaper than a flat DCN ring.
  5. WIRE ACCOUNTING — flat_wire_bytes matches the bytes the flat codec
     actually produces (one int8 scale per bucket, not per leaf).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, reduced
from repro.core import EngineConfig, OobleckEngine, build_profile
from repro.core.sync import (SyncBucket, SyncCostModel, build_sync_plan,
                             flat_wire_bytes, split_span)
from repro.data import GlobalBatchDispenser, SyntheticLM
from repro.models import Model
from repro.optim import adamw
from repro.runtime import HeteroTrainer, track_compiles
from repro.runtime.compression import (ErrorFeedback, encode_flat,
                                       encoded_nbytes, roundtrip_flat)
from repro.runtime.sync_exec import BucketedSync, perlayer_sync
from repro.runtime.executor import ProgramCache
from repro.utils import hw as hwlib

RNG = jax.random.PRNGKey(7)
GB, MB, SEQ = 16, 2, 16


def make_setup(n_nodes=5, f=1, layers=4, clip=1.0):
    arch = reduced(get_arch("gpt3_medium"), layers=layers)
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive",
                  scan_layers=False)
    params = model.init(RNG)
    profile = build_profile(arch, microbatch=MB, seq_len=SEQ)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, clip_norm=clip,
                                weight_decay=0.0)

    def mk_engine(**kw):
        return OobleckEngine(
            profile, [f"n{i}" for i in range(n_nodes)],
            EngineConfig(fault_tolerance=f, global_batch=GB, microbatch=MB,
                         gpus_per_node=1, n0_override=2, **kw))
    return arch, model, params, opt_cfg, mk_engine


def microbatches(batch, mb_size=MB):
    n = batch["tokens"].shape[0] // mb_size
    return [{k: v[i * mb_size:(i + 1) * mb_size] for k, v in batch.items()
             if not k.startswith("_")} for i in range(n)]


def drive(trainer, disp):
    batches = disp.next_step(trainer.engine.batch.minibatch_sizes())
    return trainer.train_step([microbatches(b) for b in batches])


def synced_of(trainer, all_grads, weights):
    """The synced per-layer gradient trees the trainer's tail consumes,
    via its own data plane (bucketed: unflatten the reduced buffers)."""
    if trainer.sync_mode == "perlayer":
        return perlayer_sync(all_grads, weights, trainer.num_layers)
    plan = trainer._bucket_plan()
    red = trainer._bsync.reduce(plan, all_grads, weights)
    out = {}
    for b, flat in zip(plan, red.flats):
        off = 0
        for l in b.lids:
            leaves, treedef = jax.tree_util.tree_flatten(all_grads[0][l])
            got = []
            for leaf in leaves:
                got.append(flat[off:off + leaf.size].reshape(leaf.shape))
                off += leaf.size
            out[l] = jax.tree_util.tree_unflatten(treedef, got)
    return out


def grads_and_weights(trainer, disp):
    batches = disp.next_step(trainer.engine.batch.minibatch_sizes())
    per_pipe = [microbatches(b) for b in batches]
    all_grads, weights = [], []
    for run, mbs in zip(trainer.runs, per_pipe):
        g, _ = trainer._run_pipeline(run, mbs)
        all_grads.append(g)
        weights.append(len(mbs))
    return all_grads, weights


# ----------------------------------------------------------------------
# 1. Parity
# ----------------------------------------------------------------------
def test_bucketed_synced_grads_bitwise_equal_eager_for_codec_none():
    arch, model, params, opt_cfg, mk_engine = make_setup()
    tr = HeteroTrainer(model, mk_engine(), params, opt_cfg, mode="compiled")
    src = SyntheticLM(arch.vocab_size, SEQ, seed=21)
    disp = GlobalBatchDispenser(src)
    all_grads, weights = grads_and_weights(tr, disp)

    got = synced_of(tr, all_grads, weights)
    want = perlayer_sync(all_grads, weights, tr.num_layers)
    assert sorted(got) == sorted(want)
    for l in got:
        for a, b in zip(jax.tree.leaves(got[l]), jax.tree.leaves(want[l])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bucketed_trajectory_bitwise_equal_perlayer_without_clip():
    """With clipping off the scale is exactly 1.0 on both paths, so the
    whole parameter trajectory must be BITWISE identical."""
    arch, model, params, opt_cfg, mk_engine = make_setup(clip=0.0)
    tb = HeteroTrainer(model, mk_engine(), params, opt_cfg, mode="compiled")
    tp = HeteroTrainer(model, mk_engine(), params, opt_cfg, mode="compiled",
                       sync_mode="perlayer")
    src = SyntheticLM(arch.vocab_size, SEQ, seed=23)
    db, dp = GlobalBatchDispenser(src), GlobalBatchDispenser(src)
    for _ in range(3):
        ob, op = drive(tb, db), drive(tp, dp)
        assert float(ob["loss"]) == float(op["loss"])
    for a, b in zip(jax.tree.leaves(tb.full_params()),
                    jax.tree.leaves(tp.full_params())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert tb.replica_divergence() == 0.0


@pytest.mark.parametrize("codec,rtol", [("bf16", 8e-3), ("int8", 3e-2)])
def test_codec_synced_grads_bounded_error(codec, rtol):
    arch, model, params, opt_cfg, mk_engine = make_setup()
    tr = HeteroTrainer(model, mk_engine(), params, opt_cfg, mode="compiled",
                       codec=codec)
    src = SyntheticLM(arch.vocab_size, SEQ, seed=29)
    disp = GlobalBatchDispenser(src)
    all_grads, weights = grads_and_weights(tr, disp)
    got = synced_of(tr, all_grads, weights)
    want = perlayer_sync(all_grads, weights, tr.num_layers)
    # int8 quantizes each replica contribution with a per-BUCKET scale,
    # so the bound is relative to the largest true gradient element
    gmax = max(float(jnp.max(jnp.abs(t))) for l in want
               for t in jax.tree.leaves(want[l]))
    for l in want:
        for a, b in zip(jax.tree.leaves(got[l]), jax.tree.leaves(want[l])):
            a, b = np.asarray(a), np.asarray(b)
            assert np.abs(a - b).max() <= rtol * gmax, \
                (l, np.abs(a - b).max(), gmax)


def test_error_feedback_mean_applied_converges_to_true_gradient():
    """Feed the SAME gradients every step through the int8 bucketed
    plane: with per-bucket error feedback the cumulative applied
    gradient tracks the true sum (error stays ~one quantization step
    instead of growing linearly)."""
    arch, model, params, opt_cfg, mk_engine = make_setup()
    tr = HeteroTrainer(model, mk_engine(), params, opt_cfg, mode="compiled",
                       codec="int8")
    src = SyntheticLM(arch.vocab_size, SEQ, seed=31)
    disp = GlobalBatchDispenser(src)
    all_grads, weights = grads_and_weights(tr, disp)
    true = perlayer_sync(all_grads, weights, tr.num_layers)
    probe = 1                              # a block layer
    true_leaf = np.asarray(jax.tree.leaves(true[probe])[0])

    plan = tr._bucket_plan()
    bucket = next(b for b in plan if probe in b.lids)
    off = 0
    for l in bucket.lids:
        if l == probe:
            break
        off += sum(leaf.size for leaf in jax.tree.leaves(all_grads[0][l]))
    leaf0 = jax.tree.leaves(all_grads[0][probe])[0]

    T = 12
    total = np.zeros_like(true_leaf)
    errs = []
    for t in range(1, T + 1):
        red = tr._bsync.reduce(plan, all_grads, weights)
        tr._bsync.commit_residuals(red)
        flat = red.flats[plan.index(bucket)]
        applied = np.asarray(flat[off:off + leaf0.size]).reshape(leaf0.shape)
        total += applied
        errs.append(np.abs(total - t * true_leaf).max())
    # bounded, not linearly growing: late error ~ early error
    assert errs[-1] < 4 * max(errs[1], 1e-9), errs
    # and the mean applied gradient converges to the true one
    assert errs[-1] / T < 0.02 * max(np.abs(true_leaf).max(), 1e-12)


def test_hierarchical_cross_pod_reduction_matches_flat_to_reassociation():
    """With 2-node pods the replica leads span pods, so the bucketed
    plane takes the executed two-level path (pod partial sums, then the
    cross-pod exchange).  That is a reassociation of the same sum: equal
    to the per-layer oracle up to fp32 ULP, and replicas stay
    bit-identical because every replica consumes the SAME buffer."""
    arch, model, params, opt_cfg, mk_engine = make_setup()
    tr = HeteroTrainer(model, mk_engine(nodes_per_pod=2), params, opt_cfg,
                       mode="compiled")
    assert any(b.hierarchical for b in tr._bucket_plan()), \
        "2-node pods must force a cross-pod peer group"
    src = SyntheticLM(arch.vocab_size, SEQ, seed=43)
    disp = GlobalBatchDispenser(src)
    all_grads, weights = grads_and_weights(tr, disp)
    got = synced_of(tr, all_grads, weights)
    want = perlayer_sync(all_grads, weights, tr.num_layers)
    for l in want:
        for a, b in zip(jax.tree.leaves(got[l]), jax.tree.leaves(want[l])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-6, atol=1e-7)
    out = drive(tr, disp)
    assert np.isfinite(float(out["loss"]))
    assert tr.replica_divergence() == 0.0


# ----------------------------------------------------------------------
# 2. Zero recompilation, including bucket programs
# ----------------------------------------------------------------------
@pytest.mark.parametrize("codec", ["none", "int8"])
def test_recover_step_zero_compiles_with_warmed_bucket_programs(codec):
    arch, model, params, opt_cfg, mk_engine = make_setup()
    tr = HeteroTrainer(model, mk_engine(), params, opt_cfg, mode="compiled",
                       codec=codec)
    tr.warm_templates()
    src = SyntheticLM(arch.vocab_size, SEQ, seed=37)
    disp = GlobalBatchDispenser(src)
    out = drive(tr, disp)
    out["loss"].block_until_ready()
    victim = tr.engine.instances[0].nodes[-1]
    compiles_before = tr.cache.stats.compiles
    with track_compiles() as log:
        tr.recover({victim})
        out = drive(tr, disp)
        out["loss"].block_until_ready()
    assert tr.cache.stats.compiles == compiles_before
    assert log.backend_compiles == 0, \
        f"{log.backend_compiles} XLA compiles during recover->step ({codec})"


# ----------------------------------------------------------------------
# 3. Reconfiguration drops stale error-feedback residuals
# ----------------------------------------------------------------------
def test_residuals_keyed_by_bucket_signature_dropped_on_recover():
    """The regression this pins: after a template change the bucket
    layout (spans/sizes) changes; a residual carried across that
    boundary would shape-mismatch the new buckets.  recover() must drop
    stale keys and training must continue cleanly."""
    arch, model, params, opt_cfg, mk_engine = make_setup()
    tr = HeteroTrainer(model, mk_engine(), params, opt_cfg, mode="compiled",
                       codec="int8")
    src = SyntheticLM(arch.vocab_size, SEQ, seed=41)
    disp = GlobalBatchDispenser(src)
    drive(tr, disp)
    keys_before = set(tr._bsync.ef.residuals)
    assert keys_before, "int8 training must carry residuals"
    old_plan_sigs = {b.signature for b in tr._bucket_plan()}

    victim = tr.engine.instances[0].nodes[0]
    tr.recover({victim})
    new_plan = tr._bucket_plan()
    new_sigs = {b.signature for b in new_plan}
    assert new_sigs != old_plan_sigs, \
        "test needs a reconfiguration that changes the bucket layout"
    # every surviving residual key is valid for the NEW layout
    valid = {("ef", b.signature, "int8", r)
             for b in new_plan for r in range(len(tr.engine.instances))}
    assert set(tr._bsync.ef.residuals) <= valid
    out = drive(tr, disp)                  # and training continues
    assert np.isfinite(float(out["loss"]))
    assert tr.replica_divergence() == 0.0


def test_error_feedback_keyed_apply_survives_layout_change():
    """compression.ErrorFeedback: keyed apply drops a stale residual
    whose structure no longer matches, instead of crashing; retain()
    evicts keys a new layout cannot use."""
    ef = ErrorFeedback("int8")
    g_a = {"w": jnp.full((8, 4), 0.01), "b": jnp.full((4,), -0.02)}
    ef.apply(g_a, key=("bucket", 0, 4))
    assert ef.get(("bucket", 0, 4)) is not None
    # same key, NEW shapes (the reconfigured bucket layout): must not
    # raise, must re-seed the residual against the new structure
    g_b = {"w": jnp.full((6, 4), 0.01)}
    out = ef.apply(g_b, key=("bucket", 0, 4))
    assert jax.tree.structure(out) == jax.tree.structure(g_b)
    res = ef.get(("bucket", 0, 4))
    assert jax.tree.structure(res) == jax.tree.structure(g_b)
    # retain drops everything the new layout doesn't cover
    ef.apply(g_a, key=("bucket", 4, 6))
    dropped = ef.retain([("bucket", 0, 4)])
    assert dropped == 1
    assert ef.get(("bucket", 4, 6)) is None
    # legacy single-tree API still works and is retained
    legacy = ErrorFeedback("int8")
    legacy.apply(g_a)
    legacy.retain([])
    assert legacy.residual is not None


# ----------------------------------------------------------------------
# 4. Shared sync cost model: engine == simulator, hierarchy pays off
# ----------------------------------------------------------------------
def test_engine_and_simulator_agree_on_sync_tail():
    """The policy delegates to the engine (one implementation), and the
    engine's wiring matches an INDEPENDENTLY constructed SyncCostModel
    over the same plan/topology/codec — catching drift in either."""
    from repro.sim.policies import OobleckPolicy
    arch = reduced(get_arch("gpt2"), layers=8)
    profile = build_profile(arch, microbatch=2, seq_len=64)
    nodes = [f"n{i}" for i in range(6)]
    pol = OobleckPolicy(profile, nodes, f=1, global_batch=32, microbatch=2,
                        n0=2, nodes_per_pod=2, codec="bf16")
    expected = SyncCostModel(
        hw=profile.hw, codec="bf16",
        topology=pol.engine.topology).tail_seconds(
            pol.engine.sync_plan(), profile.layer_bwd_seconds())
    assert expected > 0
    assert pol.sync_tail_seconds() == expected
    assert pol.engine._sync_tail_seconds() == expected
    # the tail is part of what the simulator charges per iteration
    assert pol.iteration_time() > expected


def test_hierarchical_cross_pod_beats_flat_dcn_ring():
    class Topo:
        def pod_of(self, n):
            return int(n[1:]) // 4        # 4-node pods

    bucket = SyncBucket(0, 4, ((tuple(f"n{i}" for i in range(8)),)),
                        64 * 1024 * 1024)
    hier = SyncCostModel(topology=Topo())
    flat_dcn, _ = SyncCostModel(topology=None)._group_seconds(
        [f"n{i}" for i in range(8)], hier.bucket_wire_bytes(bucket))
    # price the flat path at DCN (what a naive cross-pod ring pays)
    flat_dcn *= hwlib.V5E.ici_bandwidth / hwlib.V5E.dcn_bandwidth
    got, crossed = hier.bucket_seconds(bucket)
    assert crossed
    assert got < flat_dcn, (got, flat_dcn)


def test_codec_shrinks_modeled_tail():
    arch = reduced(get_arch("gpt2"), layers=8)
    profile = build_profile(arch, microbatch=2, seq_len=64)

    def tail(codec):
        eng = OobleckEngine(
            profile, [f"n{i}" for i in range(6)],
            EngineConfig(fault_tolerance=1, global_batch=32, microbatch=2,
                         gpus_per_node=1, n0_override=2, codec=codec))
        return eng._sync_tail_seconds()

    t_none, t_bf16, t_int8 = tail("none"), tail("bf16"), tail("int8")
    assert t_none > t_bf16 > t_int8 > 0


def test_schedule_overlap_exposes_only_the_spill():
    """Deep buckets hide behind the remaining backward; the tail is what
    the shallowest bucket spills past the end of backward."""
    groups = ((("a", "b"),),)
    plan = [SyncBucket(2, 4, groups, 1 << 20),
            SyncBucket(0, 2, groups, 1 << 20)]
    m = SyncCostModel()
    slow_bwd = [1.0, 1.0, 1.0, 1.0]       # plenty of hiding budget
    fast_bwd = [1e-9] * 4                  # nothing to hide behind
    rows = m.schedule(plan, slow_bwd)
    assert rows[0].ready_s == 2.0 and rows[1].ready_s == 4.0
    exposed_slow = m.tail_seconds(plan, slow_bwd)
    exposed_fast = m.tail_seconds(plan, fast_bwd)
    comm_total = sum(r.comm_s for r in rows)
    # with fast backward EVERYTHING is exposed; with slow backward only
    # the last bucket's reduction can spill
    assert abs(exposed_fast - comm_total) < 1e-9
    assert exposed_slow <= rows[-1].comm_s + 1e-12


def test_split_span_matches_build_sync_plan_cap_splits():
    """The warmer and the planner must agree on cap-splitting — that is
    what makes reconfiguration zero-compile for bucket programs."""
    arch, model, params, opt_cfg, mk_engine = make_setup()
    eng = mk_engine()
    layer_bytes = [l.param_bytes for l in eng.profile.layers]
    cap = max(layer_bytes) * 2 + 1        # force real splits
    plan = build_sync_plan(eng.instances, layer_bytes, bucket_cap_bytes=cap)
    spans = {(b.layer_start, b.layer_end) for b in plan}
    # every planner bucket is a cap-split of SOME boundary-pair span
    cover = set()
    bounds = sorted({0, eng.profile.num_layers}
                    | {st.layer_start for t in eng.templates.values()
                       for st in t.stages}
                    | {st.layer_end for t in eng.templates.values()
                       for st in t.stages})
    for i, s in enumerate(bounds):
        for e in bounds[i + 1:]:
            cover |= set(split_span(s, e, layer_bytes, cap))
    assert spans <= cover, spans - cover


# ----------------------------------------------------------------------
# 5. Wire accounting: one scale per FLAT bucket
# ----------------------------------------------------------------------
@pytest.mark.parametrize("codec", ["none", "bf16", "int8"])
def test_flat_wire_bytes_matches_encoded_size(codec):
    flat = jax.random.normal(jax.random.PRNGKey(3), (1000,)) * 0.01
    enc = encode_flat(flat, codec)
    assert flat_wire_bytes(flat.size, codec) == encoded_nbytes(enc, codec)


def test_flat_int8_uses_one_scale_per_bucket_not_per_leaf():
    from repro.runtime.compression import wire_bytes
    tree = {"a": jnp.ones((100,)), "b": jnp.ones((50,)),
            "c": {"d": jnp.ones((25,))}}
    n = 175
    # tree-shaped wire format pays one scale per leaf...
    assert wire_bytes(tree, "int8") == n + 4 * 3
    # ...the flattened bucket pays exactly one
    assert flat_wire_bytes(n, "int8") == n + 4
    rt = roundtrip_flat(jnp.concatenate([jnp.ravel(x) for x in
                                         jax.tree.leaves(tree)]), "int8")
    assert rt.shape == (n,) and rt.dtype == jnp.float32


def test_cost_model_prices_flat_wire_bytes():
    bucket = SyncBucket(0, 2, ((("a", "b"),),), nbytes=1000)  # bf16 bytes
    elements = 500
    assert SyncCostModel(codec="none").bucket_wire_bytes(bucket) == 4 * elements
    assert SyncCostModel(codec="bf16").bucket_wire_bytes(bucket) == 2 * elements
    assert SyncCostModel(codec="int8").bucket_wire_bytes(bucket) == elements + 4
