"""Shared fixtures.  NOTE: XLA_FLAGS/device-count tricks are deliberately
NOT set here — smoke tests and benches must see 1 real CPU device; the
multi-pod dry-run sets its own flags in its own process (launch/dryrun.py).
"""
import pytest

from repro.configs import get_arch
from repro.core import build_profile


@pytest.fixture(scope="session")
def gpt27_profile():
    return build_profile(get_arch("gpt3_2_7b"), microbatch=2, seq_len=2048)


@pytest.fixture(scope="session")
def small_profile():
    """A small uniform profile: 10 layers, cheap to plan."""
    return build_profile(get_arch("gpt2"), microbatch=1, seq_len=512)
