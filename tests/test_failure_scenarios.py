"""Scenario-diverse failure handling at hundred-node scale (DESIGN.md §7):
correlated rack bursts through the reconfigurator, warn-grace draining
through the simulator, and the new trace generators."""
import dataclasses

import pytest

from repro.configs import get_arch
from repro.core import (EngineConfig, InsufficientReplicasError,
                        OobleckEngine, build_profile,
                        verify_replica_coverage)
from repro.sim import (OobleckPolicy, Policy, TraceEvent, VarunaPolicy,
                       rack_failure_bursts, run_sim, scale_cycle,
                       spot_preemption_wave)


def _profile(layers=66, mb=2, seq=1024):
    arch = dataclasses.replace(get_arch("gpt2"), name=f"gpt2_L{layers}",
                               num_layers=layers)
    return build_profile(arch, microbatch=mb, seq_len=seq)


def make_engine(n_nodes, f=2, n0=4, gb=4096, mb=2, layers=66):
    prof = _profile(layers)
    nodes = [f"node{i:03d}" for i in range(n_nodes)]
    return OobleckEngine(prof, nodes, EngineConfig(
        fault_tolerance=f, global_batch=gb, microbatch=mb,
        gpus_per_node=1, n0_override=n0))


def _check_recovered(eng, expected_nodes, f, mb, gb):
    assert sorted(eng.nodes) == sorted(expected_nodes)
    assert len(eng.instances) >= f + 1
    assert verify_replica_coverage(eng.instances)
    for inst in eng.instances:
        assert inst.template.num_nodes == len(inst.nodes)
    assert sum(eng.batch.num_microbatches) * mb == gb


# ----------------------------------------------------------------------
def test_rack_burst_recovery_at_64_nodes():
    """A whole rack (8 nodes spanning several pipelines) dies at once."""
    eng = make_engine(64)
    alive = set(eng.nodes)
    # hit nodes across different pipelines: one from each of 8 instances
    burst = {inst.nodes[-1] for inst in eng.instances[:8]}
    if len(burst) < 8:    # fewer than 8 pipelines: take a contiguous rack
        burst = set(sorted(alive)[:8])
    result = eng.handle_failure(set(burst))
    _check_recovered(eng, alive - burst, f=2, mb=2, gb=4096)
    assert result.reinstantiated + result.borrowed + result.merged > 0 or \
        result.globally_replanned


def test_repeated_bursts_until_floor_at_96_nodes():
    """Repeated correlated bursts must keep recovering until the
    (f+1)*n0 contract is violated, then raise InsufficientReplicas."""
    f, n0, mb, gb = 1, 4, 2, 2048
    eng = make_engine(96, f=f, n0=n0, gb=gb, mb=mb)
    rack = 16
    raised = False
    for _ in range(12):
        survivors = list(eng.nodes)
        burst = set(survivors[:rack])
        if len(survivors) - len(burst) < (f + 1) * n0:
            with pytest.raises(InsufficientReplicasError):
                eng.handle_failure(burst)
            raised = True
            break
        eng.handle_failure(burst)
        _check_recovered(eng, set(survivors) - burst, f=f, mb=mb, gb=gb)
    assert raised, "never reached the fault-tolerance floor"


def test_burst_wiping_out_whole_pipelines():
    """Killing entire pipelines (not just members) leaves the rest able
    to re-cover the batch."""
    eng = make_engine(64, f=2, n0=4)
    victims = set(eng.instances[0].nodes) | set(eng.instances[1].nodes)
    alive = set(eng.nodes) - victims
    eng.handle_failure(victims)
    _check_recovered(eng, alive, f=2, mb=2, gb=4096)


def test_warned_failure_through_engine_event_path_loses_nothing():
    """WARN then FAIL via the monitor: the engine knows the victim was
    drained, so the failure costs no lost iteration."""
    from repro.core import NodeChangeMonitor
    eng = make_engine(12, f=1, n0=4, gb=1024, layers=18)
    warned = eng.instances[0].nodes[-1]
    eng.monitor.inject(NodeChangeMonitor.WARN, [warned], time=1.0)
    eng.monitor.poll(now=1.0)
    assert eng.draining == {warned}
    eng.monitor.inject(NodeChangeMonitor.FAIL, [warned], time=2.0)
    eng.monitor.poll(now=2.0)
    assert warned not in eng.nodes
    assert eng.metrics.lost_iterations == 0
    assert not eng.draining
    # an UNwarned failure still loses the in-flight iteration
    eng.handle_failure({eng.instances[0].nodes[-1]})
    assert eng.metrics.lost_iterations == 1


def test_short_grace_still_counts_lost_iteration():
    """If the fail lands before the drain could complete, the engine must
    NOT pretend the warned iteration was saved (the simulator passes the
    ground truth; only the monitor path infers from the warning)."""
    prof = _profile(18, mb=2, seq=256)
    nodes = [f"n{i}" for i in range(12)]
    pol = OobleckPolicy(prof, nodes, f=1, global_batch=256, microbatch=2,
                        n0=4)
    it = pol.iteration_time()
    events = [TraceEvent(0.1 * it, "warn", ("n11",)),
              TraceEvent(0.2 * it, "fail", ("n11",))]   # grace << iteration
    res = run_sim(pol, events, horizon=100 * it, global_batch=256)
    assert res.drained_nodes == 0
    assert res.breakdown["fallback"] > 0.0
    assert pol.engine.metrics.lost_iterations == 1


def test_engine_spare_nodes_rejoin_on_next_reconfiguration():
    eng = make_engine(24, f=1, n0=4, gb=1024)
    eng.spare_nodes = ["spare0", "spare1", "spare2", "spare3"]
    victim = eng.instances[0].nodes[-1]
    eng.handle_failure({victim})
    assert set(eng.spare_nodes) == set()
    assert {"spare0", "spare1", "spare2", "spare3"} <= set(eng.nodes)
    assert victim not in eng.nodes
    _check_recovered(eng, [n for n in [f"node{i:03d}" for i in range(24)]
                           if n != victim] + ["spare0", "spare1", "spare2",
                                             "spare3"],
                     f=1, mb=2, gb=1024)


def test_spare_node_death_is_pruned_not_resurrected():
    """A preempted hot spare must leave the spare pool for good: it costs
    no reconfiguration, and a later failure must not fold the dead node
    back into a pipeline."""
    prof = _profile(18, mb=2, seq=256)
    nodes = [f"n{i}" for i in range(12)]
    pol = OobleckPolicy(prof, nodes, f=1, global_batch=256, microbatch=2,
                        n0=4)
    pol.engine.spare_nodes = ["spareA", "spareB"]
    before = pol.stats.reconfigurations
    assert pol.on_failure({"spareA"}) == 0.0
    assert pol.stats.reconfigurations == before       # no reconfig charged
    assert pol.engine.spare_nodes == ["spareB"]
    pol.on_failure({nodes[-1]})                       # real failure
    assert "spareA" not in pol.engine.nodes
    assert "spareB" in pol.engine.nodes               # live spare rejoined


def test_merged_pool_in_capped_gap_keeps_spares():
    """A handcrafted capped template set {5, 6} has no decomposition for
    a pool of 8: the reconfigurator must run the largest coverable
    prefix and park the remainder as spares, not crash."""
    from repro.core import NodeSpec, PipelinePlanner
    from repro.core.reconfigure import PipelineInstance, Reconfigurator
    prof = _profile(10)
    templates = PipelinePlanner(prof, gpus_per_node=1).plan_all((5, 6))
    spec = NodeSpec(n0=5, p=2, sizes=(5, 6), f=0, N=16)
    rec = Reconfigurator(templates, spec, prof, global_batch=256,
                         microbatch=2)
    names = [f"m{i:02d}" for i in range(16)]
    insts = [PipelineInstance(1, templates[5], names[:5]),
             PipelineInstance(2, templates[6], names[5:11]),
             PipelineInstance(3, templates[5], names[11:])]
    # head of A, head of B, tail of C die: survivors pool to 2 + 2 + 4 = 8
    dead = set(names[:3]) | set(names[5:9]) | {names[15]}
    result = rec.on_failure(insts, dead)
    assert len(result.instances) == 1
    assert result.instances[0].template.num_nodes == 6
    assert len(result.spare_nodes) == 2
    covered = {n for i in result.instances for n in i.nodes}
    assert covered | set(result.spare_nodes) == set(names) - dead


def test_merge_pool_larger_than_biggest_template_decomposes():
    """A burst can merge survivors into a pool with no exact template;
    the reconfigurator must split it into covered sizes (beyond Thm B.1's
    two-pipeline case)."""
    from repro.core.reconfigure import Reconfigurator
    eng = make_engine(24, f=1, n0=4)
    parts = eng.reconf._decompose(sum(eng.spec.sizes[:2]) + 1)
    assert sum(parts) == sum(eng.spec.sizes[:2]) + 1
    assert all(p in eng.templates for p in parts)
    with pytest.raises(Exception):
        eng.reconf._decompose(1)          # below n0: impossible


# ----------------------------------------------------------------------
# trace generators
# ----------------------------------------------------------------------
NODES = [f"n{i:03d}" for i in range(64)]


def test_rack_bursts_deterministic_and_correlated():
    a = rack_failure_bursts(NODES, rack_size=8, horizon=3600.0,
                            mean_interval=300.0, seed=42)
    b = rack_failure_bursts(NODES, rack_size=8, horizon=3600.0,
                            mean_interval=300.0, seed=42)
    assert a == b
    fails = [e for e in a if e.kind == "fail"]
    assert fails, "no bursts generated"
    assert any(len(e.nodes) > 1 for e in fails), "bursts must be correlated"
    # each burst stays within one rack
    racks = {n: i // 8 for i, n in enumerate(NODES)}
    for e in fails:
        assert len({racks[n] for n in e.nodes}) == 1


def test_rack_bursts_respect_min_alive():
    events = rack_failure_bursts(NODES, rack_size=8, horizon=10 ** 5,
                                 mean_interval=60.0, seed=0, min_alive=16)
    alive = set(NODES)
    for e in sorted(events, key=lambda x: x.time):
        if e.kind == "fail":
            alive -= set(e.nodes)
            assert len(alive) >= 16
        else:
            alive |= set(e.nodes)


@pytest.mark.parametrize("seed", [0, 1, 7])
def test_traces_never_fail_dead_nodes(seed):
    """Stochastic generators must not warn/fail nodes that are currently
    down (a rack cannot die while its repair is pending)."""
    streams = [
        rack_failure_bursts(NODES, rack_size=4, horizon=10 ** 5,
                            mean_interval=500.0, seed=seed,
                            repair_time=2000.0),
        spot_preemption_wave(NODES, horizon=10 ** 5, mean_wave=600.0,
                             wave_frac=0.3, grace=120.0, seed=seed,
                             mean_recover=1500.0),
        # grace longer than the period: warns must not reach back past
        # the victim's own rejoin
        scale_cycle(NODES, horizon=5000.0, period=50.0, step=4, lo=48,
                    grace=70.0),
    ]
    for events in streams:
        down = set()
        for e in sorted(events, key=lambda x: x.time):
            if e.kind in ("warn", "fail"):
                assert not (set(e.nodes) & down), \
                    f"{e.kind} at t={e.time:.0f} hits dead nodes"
            if e.kind == "fail":
                down |= set(e.nodes)
            elif e.kind == "join":
                down -= set(e.nodes)


def test_preemption_wave_warns_before_failing():
    events = spot_preemption_wave(NODES, horizon=7200.0, mean_wave=600.0,
                                  wave_frac=0.2, grace=120.0, seed=3)
    warns = [(e.time, e.nodes) for e in events if e.kind == "warn"]
    fails = [e for e in events if e.kind == "fail"]
    assert fails
    for f in fails:
        assert any(n == f.nodes and abs(f.time - t - 120.0) < 1e-9
                   for t, n in warns)


def test_scale_cycle_bounds_and_termination():
    events = scale_cycle(NODES, horizon=10_000.0, period=100.0, step=4,
                         lo=32, grace=10.0)
    alive = set(NODES)
    for e in sorted(events, key=lambda x: x.time):
        if e.kind == "fail":
            alive -= set(e.nodes)
        elif e.kind == "join":
            alive |= set(e.nodes)
        assert 32 <= len(alive) <= 64
    warns = [e for e in events if e.kind == "warn"]
    assert warns, "grace>0 must announce removals"
    # degenerate cycle terminates
    assert scale_cycle(NODES, horizon=10_000.0, period=100.0, step=4,
                       lo=64, hi=64) == []


# ----------------------------------------------------------------------
# warn-grace draining in the simulator
# ----------------------------------------------------------------------
class _StubPolicy(Policy):
    name = "stub"

    def __init__(self, n, it=10.0, down=5.0, drain=False):
        self.supports_draining = drain
        self._n = n
        self._it = it
        self._down = down
        self.warned = []

    def iteration_time(self):
        return self._it

    def on_warning(self, nodes):
        self.warned.extend(nodes)

    def on_failure(self, dead):
        self._n -= len(dead)
        return self._down

    def on_join(self, nodes):
        self._n += len(nodes)
        return self._down

    def num_nodes(self):
        return self._n


def test_drain_capable_policy_loses_no_work():
    """warn at t=12, fail at t=152 (grace >> iteration): the draining
    policy removes the node at an iteration boundary — zero fallback."""
    events = [TraceEvent(12.0, "warn", ("a",)),
              TraceEvent(152.0, "fail", ("a",))]
    pol = _StubPolicy(8, drain=True)
    res = run_sim(pol, events, horizon=300.0, global_batch=64)
    assert res.drained_nodes == 1
    assert res.breakdown["fallback"] == 0.0
    assert res.breakdown["downtime"] == 5.0
    assert pol.num_nodes() == 7
    assert pol.warned == ["a"]


def test_non_draining_policy_pays_fallback():
    events = [TraceEvent(12.0, "warn", ("a",)),
              TraceEvent(152.0, "fail", ("a",))]
    pol = _StubPolicy(8, drain=False)
    res = run_sim(pol, events, horizon=300.0, global_batch=64)
    assert res.drained_nodes == 0
    assert res.breakdown["fallback"] > 0.0
    assert pol.num_nodes() == 7


def test_too_short_grace_degrades_to_interruption():
    """fail lands mid-iteration before any boundary: drain cannot help."""
    events = [TraceEvent(12.0, "warn", ("a",)),
              TraceEvent(14.0, "fail", ("a",))]
    pol = _StubPolicy(8, it=10.0, drain=True)
    res = run_sim(pol, events, horizon=300.0, global_batch=64)
    assert res.drained_nodes == 0
    assert res.breakdown["fallback"] > 0.0


def test_oobleck_policy_drains_through_engine_event_path():
    prof = _profile(18, mb=2, seq=256)
    nodes = [f"n{i}" for i in range(12)]
    pol = OobleckPolicy(prof, nodes, f=1, global_batch=256, microbatch=2,
                        n0=4)
    events = spot_preemption_wave(nodes, horizon=50_000.0, mean_wave=8000.0,
                                  wave_frac=0.15, grace=3600.0, seed=5,
                                  min_alive=8)
    assert any(e.kind == "warn" for e in events)
    res = run_sim(pol, events, horizon=50_000.0, global_batch=256)
    assert res.stopped_reason is None
    assert res.drained_nodes > 0
    assert res.breakdown["fallback"] == 0.0     # every wave was drained
    assert pol.stats.reconfigurations >= 1
    assert not pol.engine.draining              # cleared after reconfig
    assert pol.engine.metrics.lost_iterations == 0  # drains lose no work


def test_varuna_ignores_warnings():
    prof = _profile(18, mb=2, seq=256)
    nodes = [f"n{i}" for i in range(12)]
    pol = VarunaPolicy(prof, nodes, global_batch=256, microbatch=2, n0=4)
    events = [TraceEvent(100.0, "warn", ("n11",)),
              TraceEvent(100_000.0, "fail", ("n11",))]
    res = run_sim(pol, events, horizon=150_000.0, global_batch=256)
    assert res.drained_nodes == 0
    assert pol.stats.restarts == 1
