"""shard_map pipeline-parallel forward == plain forward, on a real
multi-device host mesh (subprocess with 4 forced devices)."""
import json
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_arch, reduced
    from repro.models import Model
    from repro.runtime.spmd_pipeline import pipeline_logits

    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((4,), ("stage",))
    arch = reduced(get_arch("gpt3_medium"), layers=8)   # 8 blocks / 4 stages
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive")
    params = model.init(jax.random.PRNGKey(0))
    M, B, S = 3, 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (M, B, S), 0,
                                arch.vocab_size)
    with mesh:
        piped = pipeline_logits(model, params, tokens, mesh)
    ref = jnp.stack([model.forward(params, tokens[i])[0] for i in range(M)])
    err = float(jnp.max(jnp.abs(piped - ref)))
    print(json.dumps({"err": err, "shape": list(piped.shape)}))
""")


def test_shard_map_pipeline_matches_forward():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["err"] < 1e-4, r
    assert r["shape"][0] == 3


TRAIN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_arch, reduced
    from repro.launch.mesh import make_mesh_compat
    from repro.models import Model
    from repro.models.layers import cross_entropy
    from repro.optim import adamw
    from repro.runtime.spmd_pipeline import (make_pipeline_train_step,
                                             pipeline_loss)

    mesh = make_mesh_compat((4,), ("stage",))
    arch = reduced(get_arch("gpt3_medium"), layers=8)
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive")
    params = model.init(jax.random.PRNGKey(0))
    M, B, S = 3, 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (M, B, S), 0,
                                arch.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(2), (M, B, S), 0,
                                arch.vocab_size)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, clip_norm=1.0,
                                weight_decay=0.0)

    def ref_loss(p):
        nll = jnp.stack([cross_entropy(model.forward(p, tokens[i])[0][:, :-1],
                                       labels[i][:, :-1]) for i in range(M)])
        return jnp.mean(nll)

    with mesh:
        # the SAME schedule differentiates: grads through the pipelined
        # scan/ppermute program equal plain full-model grads
        gp = jax.grad(lambda p: pipeline_loss(model, p, tokens, labels,
                                              mesh))(params)
        gr = jax.grad(ref_loss)(params)
        gerr = max(float(jnp.max(jnp.abs(a - b)))
                   for a, b in zip(jax.tree.leaves(gp), jax.tree.leaves(gr)))

        # one donated SPMD program trains end to end
        step = make_pipeline_train_step(model, opt_cfg, mesh)
        opt = adamw.init(params)
        p_ref, o_ref, _ = adamw.apply(opt_cfg, params, gr, opt)
        p2, o2, stats = step(params, opt, tokens, labels)
        perr = max(float(jnp.max(jnp.abs(a - b)))
                   for a, b in zip(jax.tree.leaves(p2),
                                   jax.tree.leaves(p_ref)))
    print(json.dumps({"gerr": gerr, "perr": perr,
                      "loss": float(stats["loss"])}))
""")


def test_shard_map_pipeline_train_step_matches_reference():
    """Backward through the shard_map schedule (transposed ppermutes) +
    in-program AdamW == plain full-model training, on 4 real devices."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", TRAIN_SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["gerr"] < 1e-5, r
    assert r["perr"] < 1e-5, r
    assert 0 < r["loss"] < 20
