"""shard_map pipeline-parallel forward == plain forward, on a real
multi-device host mesh (subprocess with 4 forced devices)."""
import json
import os
import subprocess
import sys
import textwrap

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_arch, reduced
    from repro.models import Model
    from repro.runtime.spmd_pipeline import pipeline_logits

    mesh = jax.make_mesh((4,), ("stage",),
                         axis_types=(jax.sharding.AxisType.Auto,))
    arch = reduced(get_arch("gpt3_medium"), layers=8)   # 8 blocks / 4 stages
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive")
    params = model.init(jax.random.PRNGKey(0))
    M, B, S = 3, 2, 8
    tokens = jax.random.randint(jax.random.PRNGKey(1), (M, B, S), 0,
                                arch.vocab_size)
    with mesh:
        piped = pipeline_logits(model, params, tokens, mesh)
    ref = jnp.stack([model.forward(params, tokens[i])[0] for i in range(M)])
    err = float(jnp.max(jnp.abs(piped - ref)))
    print(json.dumps({"err": err, "shape": list(piped.shape)}))
""")


def test_shard_map_pipeline_matches_forward():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    r = json.loads(out.stdout.strip().splitlines()[-1])
    assert r["err"] < 1e-4, r
    assert r["shape"][0] == 3
