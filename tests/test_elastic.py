"""Elastic scale-up at array level: node joins re-plan globally, new
pipelines copy state from replicas, and the training trajectory is
preserved (same global batch, same updates)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.core import EngineConfig, OobleckEngine, build_profile
from repro.data import GlobalBatchDispenser, SyntheticLM
from repro.models import Model
from repro.optim import adamw
from repro.runtime import HeteroTrainer

RNG = jax.random.PRNGKey(4)
GB, MB, SEQ = 16, 2, 16


def microbatches(batch, mb):
    n = batch["tokens"].shape[0] // mb
    return [{k: v[i * mb:(i + 1) * mb] for k, v in batch.items()
             if not k.startswith("_")} for i in range(n)]


def test_join_preserves_trajectory():
    arch = reduced(get_arch("gpt3_medium"), layers=4)
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive",
                  scan_layers=False)
    params = model.init(RNG)
    profile = build_profile(arch, microbatch=MB, seq_len=SEQ)
    engine = OobleckEngine(profile, [f"n{i}" for i in range(5)],
                           EngineConfig(fault_tolerance=1, global_batch=GB,
                                        microbatch=MB, gpus_per_node=1,
                                        n0_override=2))
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, weight_decay=0.0)
    trainer = HeteroTrainer(model, engine, params, opt_cfg)
    source = SyntheticLM(arch.vocab_size, SEQ, seed=2)
    disp = GlobalBatchDispenser(source)

    # reference on a fixed cluster
    ref_params = jax.tree.map(jnp.copy, params)
    ref_opt = adamw.init(ref_params)

    def ref_step(indices):
        nonlocal ref_params, ref_opt
        full = source.batch(indices)
        batch = {"tokens": jnp.asarray(full["tokens"]),
                 "labels": jnp.asarray(full["labels"])}
        def loss_fn(p):
            return model.loss(p, batch)
        (_, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(ref_params)
        ref_params, ref_opt, _ = adamw.apply(opt_cfg, ref_params, grads,
                                             ref_opt)

    def drive():
        batches = disp.next_step(engine.batch.minibatch_sizes())
        idx = np.concatenate([b["_indices"] for b in batches])
        out = trainer.train_step([microbatches(b, MB) for b in batches])
        return out, idx

    out0, idx0 = drive(); ref_step(idx0)
    n_before = len(engine.nodes)
    info = trainer.handle_join(["fresh0", "fresh1", "fresh2"])
    assert len(engine.nodes) == n_before + 3
    assert info["num_pipelines"] >= 2
    out1, idx1 = drive(); ref_step(idx1)

    assert trainer.replica_divergence() < 1e-6
    got = trainer.full_params()
    np.testing.assert_allclose(np.asarray(got["embed"]["table"]),
                               np.asarray(ref_params["embed"]["table"]),
                               rtol=2e-4, atol=2e-4)
    # new nodes actually host state
    hosted = {n for inst in engine.instances for n in inst.nodes}
    assert {"fresh0", "fresh1", "fresh2"} <= hosted


def test_join_beyond_original_n_keeps_spares():
    """Joins beyond the original N may be uncoverable by the fixed
    template set; the engine must use the largest coverable subset."""
    arch = reduced(get_arch("gpt3_medium"), layers=4)
    profile = build_profile(arch, microbatch=MB, seq_len=SEQ)
    engine = OobleckEngine(profile, [f"n{i}" for i in range(4)],
                           EngineConfig(fault_tolerance=1, global_batch=GB,
                                        microbatch=MB, gpus_per_node=1,
                                        n0_override=2))
    assert engine.spec.sizes == (2,)         # N=4, f=1: only 2-node pipes
    r = engine.handle_join(["j0", "j1", "j2"])  # 7 nodes: 6 usable, 1 spare
    assert len(r.spare_nodes) == 1
    assert len(engine.nodes) == 6
    assert all(i.template.num_nodes == 2 for i in engine.instances)
