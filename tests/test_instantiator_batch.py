"""Coin-change instantiation (§4.2.1) + batch distribution (§4.2.2)."""
import itertools

import pytest
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (PipelinePlanner, PlanningError, choose_plan,
                        distribute_microbatches, enumerate_feasible_sets,
                        generate_node_spec)
from repro.core.batch import (_distribute_microbatches_reference, _objective,
                              distribute_batch, recommend_global_batch)


def test_paper_figure7_example():
    """Figure 7: sizes (2,3,4), N=7 — feasible sets are exactly the
    combinations summing to 7."""
    sets = enumerate_feasible_sets((2, 3, 4), 7, min_count=1)
    as_tuples = sorted(sets)
    expected = sorted([(2, 1, 0), (0, 1, 1)])
    assert as_tuples == expected


def test_enumeration_matches_bruteforce():
    sizes = (2, 3, 4, 5)
    for N in (8, 11, 13):
        got = sorted(enumerate_feasible_sets(sizes, N, min_count=1))
        brute = sorted(
            x for x in itertools.product(*(range(N // s + 1) for s in sizes))
            if sum(a * b for a, b in zip(x, sizes)) == N and sum(x) >= 1)
        assert got == brute


def test_min_count_filter():
    sets = enumerate_feasible_sets((2, 3, 4), 8, min_count=3)
    assert all(sum(x) >= 3 for x in sets)
    assert (0, 0, 2) not in sets
    assert (4, 0, 0) in sets


@settings(max_examples=60, deadline=None)
@given(total=st.integers(4, 240),
       times=st.lists(st.floats(0.1, 10.0), min_size=2, max_size=10))
def test_batch_distribution_feasible_and_locally_optimal(total, times):
    if total < len(times):
        with pytest.raises(PlanningError):
            distribute_microbatches(times, total)
        return
    counts = distribute_microbatches(times, total)
    assert sum(counts) == total
    assert all(c >= 1 for c in counts)
    # 1-exchange local optimality of the Eq. 6 objective
    base = _objective(counts, times)
    for i in range(len(counts)):
        if counts[i] <= 1:
            continue
        for j in range(len(counts)):
            if i == j:
                continue
            trial = list(counts)
            trial[i] -= 1
            trial[j] += 1
            assert _objective(trial, times) >= base - 1e-9


@settings(max_examples=60, deadline=None)
@given(total=st.integers(2, 160),
       times=st.lists(st.one_of(st.floats(0.1, 10.0),
                                st.integers(1, 5).map(float)),
                      min_size=2, max_size=8))
def test_incremental_descent_matches_reference(total, times):
    """The O(1)-delta descent is bit-identical to the retained
    full-recompute oracle — including integer-time tie storms where the
    two objective forms round differently in the last ulp."""
    if total < len(times):
        return
    assert (distribute_microbatches(times, total)
            == _distribute_microbatches_reference(times, total))


def test_batch_distribution_exact_small_bruteforce():
    times = [1.0, 2.0, 4.0]
    total = 14
    counts = distribute_microbatches(times, total)
    best = min(
        (c for c in itertools.product(range(1, total + 1), repeat=3)
         if sum(c) == total),
        key=lambda c: _objective(list(c), times))
    assert _objective(counts, times) <= _objective(list(best), times) + 1e-9


def test_faster_pipeline_gets_more_microbatches():
    counts = distribute_microbatches([1.0, 2.0], 30)
    assert counts[0] > counts[1]
    # loads should be near equal
    assert abs(counts[0] * 1.0 - counts[1] * 2.0) <= 2.0


def test_recommend_global_batch():
    assert recommend_global_batch(5, 4, 18) == 20
    assert recommend_global_batch(3, 2, 100) == 100


def test_choose_plan_uses_all_nodes(gpt27_profile):
    spec = generate_node_spec(N=13, f=2, n0=2)
    planner = PipelinePlanner(gpt27_profile, gpus_per_node=1)
    templates = planner.plan_all(spec.sizes)
    plan = choose_plan(templates, spec, 13, global_batch=1024, microbatch=2)
    assert sum(c * s for c, s in zip(plan.counts, plan.sizes)) == 13
    assert plan.num_pipelines >= 3      # f+1
    assert sum(plan.batch.num_microbatches) * 2 == 1024


def test_choose_plan_infeasible_batch_raises(gpt27_profile):
    spec = generate_node_spec(N=13, f=2, n0=2)
    planner = PipelinePlanner(gpt27_profile, gpus_per_node=1)
    templates = planner.plan_all(spec.sizes)
    with pytest.raises(PlanningError):
        # f+1 = 3 pipelines minimum but only 2 microbatches available
        choose_plan(templates, spec, 13, global_batch=4, microbatch=2)
