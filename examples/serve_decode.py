"""Continuous-batching serving example across three model families
(dense GQA, Mamba2 SSD, hybrid Hymba): slot-cache decode with
in-program sampling, plus a node failure injected mid-traffic on the
dense arch — every request still completes (runtime/serve_exec.py).

    PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch.serve import main as serve


def main():
    for arch in ("qwen3-1.7b", "mamba2-780m", "hymba-1.5b"):
        print(f"\n=== {arch} ===")
        fail = ["--fail-at", "3"] if arch == "qwen3-1.7b" else []
        serve(["--arch", arch, "--batch", "2", "--prompt-len", "8",
               "--decode-steps", "8", "--layers", "2", "--requests", "4",
               *fail])


if __name__ == "__main__":
    main()
