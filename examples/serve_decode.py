"""Batched serving example: prefill + KV/SSM-cache decode across three
model families (dense GQA, Mamba2 SSD, hybrid Hymba).

    PYTHONPATH=src python examples/serve_decode.py
"""
from repro.launch.serve import main as serve


def main():
    for arch in ("qwen3-1.7b", "mamba2-780m", "hymba-1.5b"):
        print(f"\n=== {arch} ===")
        serve(["--arch", arch, "--batch", "2", "--prompt-len", "8",
               "--decode-steps", "8", "--layers", "2"])


if __name__ == "__main__":
    main()
