"""Quickstart: plan pipeline templates, train through a failure, recover.

    PYTHONPATH=src python examples/quickstart.py

Walks the full Oobleck lifecycle on a 5-node simulated cluster:
  1. memory-driven node spec + pipeline templates (paper §4.1),
  2. max-throughput instantiation + batch distribution (§4.2),
  3. real heterogeneous 1F1B training with layer-granular sync (§6),
  4. a node failure -> recovery from replica state, no checkpoint (§5).
"""
import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced
from repro.core import EngineConfig, OobleckEngine, build_profile
from repro.data import ByteCorpus, GlobalBatchDispenser
from repro.launch.train import _TEXT, microbatches
from repro.models import Model
from repro.optim import adamw
from repro.runtime import HeteroTrainer


def main():
    arch = reduced(get_arch("gpt3_medium"), layers=4)
    profile = build_profile(arch, microbatch=2, seq_len=32)
    nodes = [f"node{i}" for i in range(5)]
    engine = OobleckEngine(profile, nodes, EngineConfig(
        fault_tolerance=1, global_batch=16, microbatch=2,
        gpus_per_node=1, n0_override=2))

    print("== planning ==")
    for n, tpl in engine.templates.items():
        print(f"  template n={n}: {tpl.num_stages} stages, "
              f"layers per stage {[s.num_layers for s in tpl.stages]}, "
              f"est iter {tpl.iteration_time * 1e3:.1f}ms")
    print(f"  instantiated: {[i.template.num_nodes for i in engine.instances]}"
          f" pipelines; microbatches {engine.batch.num_microbatches}")

    print("== training ==")
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive",
                  scan_layers=False)
    params = model.init(jax.random.PRNGKey(0))
    trainer = HeteroTrainer(model, engine, params,
                            adamw.AdamWConfig(lr=3e-3, warmup_steps=0,
                                              weight_decay=0.0))
    disp = GlobalBatchDispenser(ByteCorpus(_TEXT * 50, seq_len=32))
    for step in range(3):
        batches = disp.next_step(engine.batch.minibatch_sizes())
        out = trainer.train_step([microbatches(b, 2) for b in batches])
        print(f"  step {step}: loss {out['loss']:.4f}")

    print("== failure ==")
    victim = engine.instances[0].nodes[-1]
    info = trainer.handle_failure({victim})
    print(f"  killed {victim}; copied {info['copied_bytes'] / 1e6:.1f}MB "
          f"of layer state from replicas; pipelines now "
          f"{[i.template.num_nodes for i in engine.instances]}")

    for step in range(3, 5):
        batches = disp.next_step(engine.batch.minibatch_sizes())
        out = trainer.train_step([microbatches(b, 2) for b in batches])
        print(f"  step {step}: loss {out['loss']:.4f} "
              f"(replica divergence {trainer.replica_divergence():.1e})")
    print("done — training continued through the failure without restart.")


if __name__ == "__main__":
    main()
