"""Below-floor lifecycle (paper §3.4): when failures push the cluster
under (f+1)*n0 nodes, Oobleck checkpoints, exits, and a later run
restores the exact training state (step, params, optimizer moments,
data cursor) once nodes are back.

    PYTHONPATH=src python examples/checkpoint_restart.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs import get_arch, reduced
from repro.core import (EngineConfig, InsufficientReplicasError,
                        OobleckEngine, build_profile)
from repro.data import ByteCorpus, GlobalBatchDispenser
from repro.launch.train import _TEXT, microbatches
from repro.models import Model
from repro.optim import adamw
from repro.runtime import HeteroTrainer


def main():
    arch = reduced(get_arch("gpt3_medium"), layers=3)
    profile = build_profile(arch, microbatch=2, seq_len=32)
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive",
                  scan_layers=False)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=0, weight_decay=0.0)
    disp = GlobalBatchDispenser(ByteCorpus(_TEXT * 50, seq_len=32))
    ckpt_dir = tempfile.mkdtemp(prefix="oobleck_ckpt_")
    mgr = CheckpointManager(ckpt_dir, num_layers=arch.num_layers,
                            async_mode=False)

    nodes = [f"n{i}" for i in range(4)]
    engine = OobleckEngine(profile, nodes, EngineConfig(
        fault_tolerance=1, global_batch=16, microbatch=2, gpus_per_node=1,
        n0_override=2))
    trainer = HeteroTrainer(model, engine, params, opt_cfg)

    for step in range(2):
        batches = disp.next_step(engine.batch.minibatch_sizes())
        out = trainer.train_step([microbatches(b, 2) for b in batches])
        print(f"[run1 step {step}] loss={out['loss']:.4f}")

    # two failures push the cluster below (f+1)*n0=4 -> checkpoint + exit
    try:
        trainer.handle_failure({nodes[0]})
        trainer.handle_failure({nodes[1]})
    except InsufficientReplicasError as e:
        print(f"[run1] below floor: {e}")
        # Executor.snapshot() reassembles params AND real Adam moments
        # from replica-0 layer states (runtime/executor.py contract)
        mgr.save(trainer.snapshot(disp.state(), 0))
        print(f"[run1] checkpointed step 2 to {ckpt_dir}")

    # --- later: nodes are back; restore and continue --------------------
    template = model.init(jax.random.PRNGKey(0))
    template["head"] = jax.tree.map(jnp.copy, template["embed"])  # untied
    restored = mgr.restore(template, adamw.init(template))
    print(f"[run2] restored step={restored.step} "
          f"data_cursor={restored.data_state}")
    engine2 = OobleckEngine(profile, [f"m{i}" for i in range(5)],
                            EngineConfig(fault_tolerance=1, global_batch=16,
                                         microbatch=2, gpus_per_node=1,
                                         n0_override=2))
    trainer2 = HeteroTrainer(model, engine2, restored.params, opt_cfg)
    disp2 = GlobalBatchDispenser(ByteCorpus(_TEXT * 50, seq_len=32))
    disp2.restore(restored.data_state)
    for step in range(restored.step, restored.step + 2):
        batches = disp2.next_step(engine2.batch.minibatch_sizes())
        out = trainer2.train_step([microbatches(b, 2) for b in batches])
        print(f"[run2 step {step}] loss={out['loss']:.4f}")
    print("done — resumed exactly where run 1 stopped.")


if __name__ == "__main__":
    main()
