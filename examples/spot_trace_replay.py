"""Spot-instance trace replay (paper §7.3): Oobleck vs Varuna vs Bamboo
throughput under preemptions + node recoveries, on the calibrated
discrete-event simulator.

    PYTHONPATH=src python examples/spot_trace_replay.py
"""
from repro.configs import get_arch
from repro.core import build_profile
from repro.sim import (BambooPolicy, OobleckPolicy, VarunaPolicy, run_sim,
                       spot_trace)

HORIZON = 6 * 3600.0


def bar(x, scale):
    return "#" * max(1, int(x / scale))


def main():
    nodes = [f"n{i}" for i in range(30)]
    prof = build_profile(get_arch("gpt3_2_7b"), microbatch=2, seq_len=2048)
    trace = spot_trace(nodes, HORIZON, mean_preempt=7.7 * 60,
                       mean_recover=15 * 60, seed=42, min_alive=10)
    fails = sum(1 for e in trace if e.kind == "fail")
    joins = sum(1 for e in trace if e.kind == "join")
    print(f"EC2-like trace: {fails} preemptions, {joins} recoveries "
          f"over {HORIZON / 3600:.0f}h\n")

    results = {}
    for pol in (
        OobleckPolicy(prof, nodes, f=2, global_batch=1024, microbatch=2,
                      max_stages=12),
        VarunaPolicy(prof, nodes, global_batch=1024, microbatch=2,
                     max_stages=12),
        BambooPolicy(prof, nodes, global_batch=1024, microbatch=2,
                     max_stages=12),
    ):
        res = run_sim(pol, trace, HORIZON, 1024)
        results[pol.name] = res
        thpt = "OOM" if res.stopped_reason == "OOM" else f"{res.throughput:7.2f}"
        print(f"{pol.name:8s} {thpt} samples/s "
              f"effective={res.effective_fraction():.2%} "
              f"events={res.events_handled}")

    print("\nthroughput (samples/s):")
    ok = {k: v for k, v in results.items() if v.throughput > 0}
    scale = max(v.throughput for v in ok.values()) / 40
    for k, v in ok.items():
        print(f"  {k:8s} {bar(v.throughput, scale)} {v.throughput:.1f}")
    print("\nbreakdown (fraction of wall clock):")
    for k, v in ok.items():
        total = max(sum(v.breakdown.values()), 1e-9)
        parts = ", ".join(f"{n}={x / total:.2%}" for n, x in
                          sorted(v.breakdown.items()) if x > 0)
        print(f"  {k:8s} {parts}")


if __name__ == "__main__":
    main()
