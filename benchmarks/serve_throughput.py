"""Serving-plane benchmark (DESIGN.md §14): continuous batching vs the
static-batch baseline, and recovery downtime through an injected
mid-decode failure.

Three measured legs over the same skewed request trace (mostly short
generations plus a long tail — the regime continuous batching exists
for):

  static           admit a full batch, drain it completely, refill
  continuous       backfill freed slots every tick (Orca-style)
  continuous+fail  continuous, with a node killed mid-traffic; the
                   decode pipelines replan from the template set and
                   every stream finishes bitwise-identical to the
                   unfailed leg with ZERO XLA recompiles

Headline assertions (acceptance criteria):
  * continuous tokens/s >= 2x static tokens/s
  * backend_compiles == 0 across fail -> recover -> drain
  * the failed leg completes every request, streams bitwise-equal

    PYTHONPATH=src:. python benchmarks/serve_throughput.py [--json out]
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv
from repro.configs import get_arch, reduced
from repro.launch.serve import build_serving_engine, percentile
from repro.models import Model
from repro.runtime import ProgramCache, track_compiles
from repro.runtime.serve_exec import SamplingParams, ServeExecutor


def request_trace(n_req: int, short: int, long: int, period: int,
                  vocab: int, prompt_len: int, seed: int = 0):
    """Skewed lengths: one long generation per ``period`` requests, the
    rest short — the workload static batching wastes slots on."""
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, vocab, prompt_len).astype(np.int32)
               for _ in range(n_req)]
    lengths = [long if i % period == 0 else short for i in range(n_req)]
    return prompts, lengths


def run_leg(model, params, arch, cache, prompts, lengths, *,
            mode: str, slots: int, prompt_len: int, fail_at=None):
    max_new = max(lengths)
    engine = build_serving_engine(
        arch, nodes=[f"node{i}" for i in range(6)])
    ex = ServeExecutor(
        model, params, engine, num_slots=slots,
        max_len=prompt_len + max_new, max_new_cap=max_new,
        sampling=SamplingParams(temperature=0.0),
        prompt_buckets=[prompt_len, prompt_len + max_new],
        sample_key=jax.random.PRNGKey(7), admission=mode, cache=cache)
    for p, n in zip(prompts, lengths):
        ex.submit(p, max_new=n)

    t0 = time.perf_counter()
    compiles = 0
    if fail_at is None:
        ex.drain()
    else:
        for _ in range(fail_at):
            ex.tick()
        with track_compiles() as log:
            victim = engine.instances[0].nodes[0]
            engine.monitor.inject("fail", [victim])
            engine.monitor.poll(time.perf_counter())
            ex.drain()
        compiles = log.backend_compiles
    wall_s = time.perf_counter() - t0

    assert len(ex.completed) == len(prompts), \
        f"{mode}: {len(ex.completed)}/{len(prompts)} requests completed"
    total_tokens = sum(len(r.tokens) for r in ex.completed)
    ttft = [r.first_token_s - r.arrival_s for r in ex.completed]
    return {
        "mode": mode + ("" if fail_at is None else "+fail"),
        "requests": len(prompts),
        "total_tokens": total_tokens,
        "wall_s": wall_s,
        "tokens_per_s": total_tokens / wall_s,
        "ttft_p50_ms": percentile(ttft, 50) * 1e3,
        "ttft_p99_ms": percentile(ttft, 99) * 1e3,
        "ticks": ex.ticks,
        "backend_compiles_after_failure": compiles,
        "recovery": ex.last_recovery,
        "streams": {r.rid: r.tokens for r in ex.completed},
    }


def main(csv=None, argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=4)
    ap.add_argument("--short", type=int, default=4)
    ap.add_argument("--long", type=int, default=40)
    ap.add_argument("--period", type=int, default=4,
                    help="every Nth request generates --long tokens")
    ap.add_argument("--fail-at", type=int, default=6)
    ap.add_argument("--json", default="")
    args = ap.parse_args(argv)

    csv = csv or Csv()
    arch = reduced(get_arch(args.arch), layers=args.layers)
    model = Model(arch, dtype=jnp.float32, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    cache = ProgramCache()           # shared: every leg reuses programs
    prompts, lengths = request_trace(
        args.requests, args.short, args.long, args.period,
        arch.vocab_size, args.prompt_len)

    legs = {}
    for mode, fail_at in (("static", None), ("continuous", None),
                          ("continuous", args.fail_at)):
        leg = run_leg(model, params, arch, cache, prompts, lengths,
                      mode=mode, slots=args.slots,
                      prompt_len=args.prompt_len, fail_at=fail_at)
        legs[leg["mode"]] = leg
        rec = leg["recovery"] or {}
        csv.add(f"serve_throughput,{leg['mode']}",
                leg["wall_s"] * 1e6,
                f"tok/s={leg['tokens_per_s']:.1f}"
                f"|ttft_p50={leg['ttft_p50_ms']:.1f}ms"
                f"|ttft_p99={leg['ttft_p99_ms']:.1f}ms"
                f"|ticks={leg['ticks']}"
                + (f"|downtime={rec['downtime_s'] * 1e3:.1f}ms"
                   f"|replayed={rec['replayed']}" if rec else ""))

    cont, stat = legs["continuous"], legs["static"]
    failed = legs["continuous+fail"]

    # acceptance: continuous batching >= 2x static tokens/s on the
    # skewed trace, and the failure leg recovers without compiling
    speedup = cont["tokens_per_s"] / stat["tokens_per_s"]
    assert speedup >= 2.0, \
        f"continuous batching speedup {speedup:.2f}x < 2x over static"
    assert failed["backend_compiles_after_failure"] == 0, \
        "recovery must reuse warmed programs (zero XLA compiles)"
    assert failed["recovery"] is not None
    for rid, toks in cont["streams"].items():
        np.testing.assert_array_equal(
            failed["streams"][rid], toks,
            f"stream {rid} diverged through the failure")

    results = {k: {kk: vv for kk, vv in leg.items() if kk != "streams"}
               for k, leg in legs.items()}
    results["summary"] = {
        "continuous_vs_static_speedup": speedup,
        "recovery_downtime_ms":
            failed["recovery"]["downtime_s"] * 1e3,
        "ttft_p99_through_failure_ms": failed["ttft_p99_ms"],
        "bitwise_identical_through_failure": True,
    }
    csv.add("serve_throughput,summary", 0.0,
            f"speedup={speedup:.2f}x"
            f"|downtime={results['summary']['recovery_downtime_ms']:.1f}ms"
            f"|p99_through_fail={failed['ttft_p99_ms']:.1f}ms")
    if args.json:
        import os
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    main()
