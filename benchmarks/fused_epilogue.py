"""Fused stage-epilogue microbench (DESIGN.md §13).

Times the two fusions the stage hot path routes through kernels/ops.py —
the residual-add+RMSNorm block epilogue (``ops.fused_add_rmsnorm``) and
the fused QKV projection (``ops.fused_qkv``) — against the UNFUSED
reference they replaced: the op-granular formulation, each primitive op
its own dispatch with intermediates materialized between them, and
gradients pulled back op by op.  Each cell times the TRAINING PATH
(forward + backward), because that is what the warmed per-template step
programs execute; the fused side runs as one compiled program exactly
as the model does, so the speedup column is the fusion win the block
epilogue actually banks: one dispatch instead of a dozen, fused
pointwise epilogues, no op-boundary materialization.  On compiled
backends the Pallas tiles add an occupancy win on top; the ``lowered``
column records the probe verdict per cell.

``kernel_roofline`` imports these cells into BENCH_kernels.json, where
CI gates speedup >= 1.15x at every shape (min-over-repeats).

    PYTHONPATH=src:. python benchmarks/fused_epilogue.py \
        --json BENCH_fused_epilogue.json
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from benchmarks.common import Csv
from repro.kernels.autotune import _time

#: (rows, d_model) — token-rows x width of the block epilogue; includes
#: a ragged row count (non-block-multiple) on purpose.
NORM_SHAPES = [(512, 512), (2048, 768), (1027, 640)]
#: (rows, d_model, q_cols, kv_cols) — GQA-shaped projections (kv < q).
QKV_SHAPES = [(512, 512, 512, 256), (1024, 768, 768, 256),
              (777, 512, 384, 192)]

#: the acceptance floor CI gates on (min-over-repeats)
SPEEDUP_FLOOR = 1.15


def _norm_cell(shape, iters: int) -> Dict:
    from repro.kernels import ops
    rows, d = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (rows, d))
    r = jax.random.normal(ks[1], (rows, d))
    w = jax.random.normal(ks[2], (d,)) * 0.2 + 1.0

    def loss_fused(x, r, w):
        res, h = ops.fused_add_rmsnorm(x, r, w)
        return jnp.sum(res) + jnp.sum(h)

    fused = jax.jit(jax.grad(loss_fused, argnums=(0, 1, 2)))

    # op-granular reference: each primitive its own dispatch, VJP
    # pulled back op by op (what the pre-fusion epilogue paid)
    add = jax.jit(lambda a, b: a + b)
    var = jax.jit(lambda t: jnp.mean(t * t, axis=-1, keepdims=True))
    scale = jax.jit(lambda t, v: t * jax.lax.rsqrt(v + 1e-6))
    wmul = jax.jit(lambda t, w: t * w)

    def loss_unfused(x, r, w):
        res = add(x, r)
        x32 = res.astype(jnp.float32)
        h = wmul(scale(x32, var(x32)).astype(res.dtype), w)
        return jnp.sum(res) + jnp.sum(h)

    unfused = jax.grad(loss_unfused, argnums=(0, 1, 2))

    fused_s = _time(fused, x, r, w, iters=iters)
    unfused_s = _time(unfused, x, r, w, iters=iters)
    return {
        "kernel": "fused_add_rmsnorm", "shape": list(shape),
        "backend": ops.resolve_backend(),
        "lowered": ops.kernel_lowers("fused_norm"),
        "fused_s": fused_s, "unfused_s": unfused_s,
        "fused_speedup": unfused_s / fused_s,
        # fwd 3 passes over [rows, d] + bwd ~5 (grads for x, r, w)
        "fused_gbps": 8 * rows * d * 4 / fused_s / 1e9,
    }


def _qkv_cell(shape, iters: int) -> Dict:
    from repro.kernels import ops
    rows, d, cq, ckv = shape
    ks = jax.random.split(jax.random.PRNGKey(1), 7)
    x = jax.random.normal(ks[0], (1, rows, d))
    wq = jax.random.normal(ks[1], (d, cq)) * d ** -0.5
    wk = jax.random.normal(ks[2], (d, ckv)) * d ** -0.5
    wv = jax.random.normal(ks[3], (d, ckv)) * d ** -0.5
    bq = jax.random.normal(ks[4], (cq,)) * 0.1
    bk = jax.random.normal(ks[5], (ckv,)) * 0.1
    bv = jax.random.normal(ks[6], (ckv,)) * 0.1

    def loss_fused(x, wq, wk, wv):
        q, k, v = ops.fused_qkv(x, wq, wk, wv, bq, bk, bv)
        return jnp.sum(q) + jnp.sum(k) + jnp.sum(v)

    fused = jax.jit(jax.grad(loss_fused, argnums=(0, 1, 2, 3)))

    mm = jax.jit(lambda x, w: x @ w)
    badd = jax.jit(lambda t, b: t + b)

    def loss_unfused(x, wq, wk, wv):
        q = badd(mm(x, wq), bq)
        k = badd(mm(x, wk), bk)
        v = badd(mm(x, wv), bv)
        return jnp.sum(q) + jnp.sum(k) + jnp.sum(v)

    unfused = jax.grad(loss_unfused, argnums=(0, 1, 2, 3))

    fused_s = _time(fused, x, wq, wk, wv, iters=iters)
    unfused_s = _time(unfused, x, wq, wk, wv, iters=iters)
    flops = 3 * 2 * rows * d * (cq + 2 * ckv)        # fwd + ~2x bwd
    return {
        "kernel": "fused_qkv", "shape": list(shape),
        "backend": ops.resolve_backend(),
        "lowered": ops.kernel_lowers("fused_qkv"),
        "fused_s": fused_s, "unfused_s": unfused_s,
        "fused_speedup": unfused_s / fused_s,
        "fused_gflops": flops / fused_s / 1e9,
    }


def fused_cells(iters: int = 3) -> List[Dict]:
    cells = [_norm_cell(s, iters) for s in NORM_SHAPES]
    cells += [_qkv_cell(s, iters) for s in QKV_SHAPES]
    return cells


def report(csv: Csv, cells: List[Dict], check: bool = True) -> None:
    for c in cells:
        name = f"fused/{c['kernel']}/" + "x".join(map(str, c["shape"]))
        csv.add(f"{name}/fused_s", c["fused_s"] * 1e6,
                f"speedup={c['fused_speedup']:.2f}x")
        csv.add(f"{name}/unfused_s", c["unfused_s"] * 1e6,
                f"lowered={c['lowered']}")
        if check:
            assert c["fused_speedup"] >= SPEEDUP_FLOOR, (
                f"fused path below the {SPEEDUP_FLOOR}x floor at {name}: "
                f"{c['fused_speedup']:.3f}x")


def main(csv: Optional[Csv] = None, argv: Optional[List[str]] = None) -> Dict:
    csv = csv or Csv()
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="write cells to this path (BENCH_fused_epilogue"
                         ".json)")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--no-check", action="store_true",
                    help="report without asserting the speedup floor")
    args = ap.parse_args(argv if argv is not None else [])
    from repro.kernels import ops
    cells = fused_cells(iters=args.iters)
    report(csv, cells, check=not args.no_check)
    result = {"backend": ops.resolve_backend(),
              "lowering_plan": [list(kv) for kv in
                                ops.lowering_plan(ops.resolve_backend())],
              "speedup_floor": SPEEDUP_FLOOR, "iters": args.iters,
              "cells": cells}
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.json}")
    return result


if __name__ == "__main__":
    import sys
    main(argv=sys.argv[1:])
