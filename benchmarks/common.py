"""Shared benchmark scaffolding.

Every benchmark emits CSV rows ``name,us_per_call,derived`` where
``us_per_call`` is the wall time spent computing that cell (planning or
simulation cost — the planner latency IS the paper's Table 3 metric) and
``derived`` is the reproduced quantity (throughput in samples/s, seconds,
or a fraction).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Tuple

from repro.configs import get_arch
from repro.core import build_profile

#: Paper Table 1: model, (global batch, varuna/oobleck microbatch,
#: bamboo microbatch or None=X (OOM), seq len)
TABLE1 = {
    "bert_large": (8192, 32, 4, 512),
    "gpt2": (8192, 32, 1, 1024),
    "gpt3_medium": (8192, 16, None, 2048),
    "gpt3_2_7b": (1024, 2, None, 2048),
    "gpt3_6_7b": (1024, 2, None, 2048),
}

NUM_NODES = 30
FAULT_TOLERANCE = 2
FREQS = {"6h": 6 * 3600, "1h": 3600, "10m": 600}


def profile_for(model: str, microbatch: int):
    gb, mb, bmb, seq = TABLE1[model]
    return build_profile(get_arch(model), microbatch=microbatch, seq_len=seq)


class Csv:
    def __init__(self):
        self.rows: List[Tuple[str, float, str]] = []

    def add(self, name: str, us_per_call: float, derived) -> None:
        self.rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def timed(fn: Callable):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6
