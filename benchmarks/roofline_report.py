"""Roofline benchmark: reads the dry-run artifact (artifacts/dryrun.json,
produced by ``python -m repro.launch.dryrun``) and reports the three
roofline terms per (arch x shape x mesh).  Skips gracefully when the
artifact has not been generated yet."""
from __future__ import annotations

import json
import os

from benchmarks.common import Csv

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "dryrun.json")


def main(csv: Csv | None = None) -> None:
    csv = csv or Csv()
    path = os.path.abspath(ARTIFACT)
    if not os.path.exists(path):
        csv.add("roofline/skipped", 0.0,
                "run `PYTHONPATH=src python -m repro.launch.dryrun` first")
        return
    with open(path) as f:
        cells = json.load(f)["cells"]
    for cell in cells:
        if cell.get("status") != "ok":
            csv.add(f"roofline/{cell['key']}", 0.0,
                    f"status={cell.get('status')}")
            continue
        r = cell["roofline"]
        name = f"roofline/{cell['key']}"
        csv.add(f"{name}/compute_s", cell.get("compile_us", 0.0),
                f"{r['compute_s']:.6f}")
        csv.add(f"{name}/memory_s", 0.0, f"{r['memory_s']:.6f}")
        csv.add(f"{name}/collective_s", 0.0, f"{r['collective_s']:.6f}")
        csv.add(f"{name}/bottleneck", 0.0, r["bottleneck"])
        csv.add(f"{name}/useful_flops_frac", 0.0,
                f"{r['model_flops_ratio']:.3f}")


if __name__ == "__main__":
    main()
