"""Roofline benchmarks.

Part 1 (``--dryrun``-artifact report): reads artifacts/dryrun.json
(produced by ``python -m repro.launch.dryrun``) and reports the three
roofline terms per (arch x shape x mesh).  Skips gracefully when the
artifact has not been generated yet.

Part 2 (kernel fwd+bwd roofline, always runnable): times the Pallas
flash-attention and SSD kernels — forward AND the registered custom_vjp
BACKWARD — against the jnp-oracle recompute backward they replaced
(``ops.oracle_attention_vjp`` / ``ops.oracle_ssd_vjp``, the pre-§11
bwd rules), plus the fused stage epilogues against their op-granular
unfused reference (benchmarks/fused_epilogue.py).  Every cell carries a
``lowered`` column — the per-kind verdict of the one-shot lowering
probe (DESIGN.md §13) under which it ran.  Emits ``BENCH_kernels.json``
and ASSERTS, at every benchmarked shape, that the Pallas backward
beats the oracle backward and the fused epilogues clear the 1.15x
speedup floor; block sizes come from the autotuner exactly as the
stage hot path resolves them.

    PYTHONPATH=src:. python benchmarks/roofline_report.py \
        --json BENCH_kernels.json
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from benchmarks.common import Csv
from repro.kernels.autotune import _time

ARTIFACT = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                        "dryrun.json")

#: (B, S, H, KV, D) — long sequences, where the O(S²)-materializing
#: oracle backward is at its worst and real training runs.
FLASH_SHAPES = [(1, 1024, 2, 2, 64), (1, 2048, 2, 2, 64)]
#: (B, S, H, P, N)
SSD_SHAPES = [(1, 1024, 4, 32, 32), (1, 2048, 2, 64, 32)]


def _flash_cell(shape, iters: int) -> Dict:
    from repro.kernels import autotune, ops, ref
    from repro.kernels import flash_attention as fa
    B, S, H, KV, D = shape
    backend = ops.resolve_backend()
    interp_f = not ops.kernel_lowers("flash_fwd", backend)
    interp_b = not ops.kernel_lowers("flash_bwd", backend)
    cfg = autotune.flash_config(backend, jnp.float32, S, D)
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, KV, D))
    v = jax.random.normal(ks[2], (B, S, KV, D))
    g = jax.random.normal(ks[3], q.shape)

    fwd_pallas = _time(jax.jit(lambda q, k, v: fa.flash_attention(
        q, k, v, block_q=cfg["block_q"], block_k=cfg["block_k"],
        interpret=interp_f)), q, k, v, iters=iters)
    fwd_oracle = _time(jax.jit(ref.attention_ref), q, k, v, iters=iters)

    out, lse = fa.flash_attention_fwd(
        q, k, v, block_q=cfg["block_q"], block_k=cfg["block_k"],
        interpret=interp_f)
    bwd_pallas = _time(jax.jit(lambda q, k, v, out, lse, g:
                               fa.flash_attention_bwd(
                                   q, k, v, out, lse, g,
                                   block_q=cfg["block_q"],
                                   block_k=cfg["block_k"],
                                   interpret=interp_b)),
                       q, k, v, out, lse, g, iters=iters)
    bwd_oracle = _time(jax.jit(ops.oracle_attention_vjp), q, k, v, g,
                       iters=iters)
    # causal matmul flops: fwd 2 GEMMs over S²/2 positions, bwd 5 GEMMs
    fwd_flops = 2 * 2 * B * H * (S * S // 2) * D
    return {
        "kernel": "flash_attention", "shape": list(shape),
        "blocks": cfg, "backend": backend,
        "lowered": not (interp_f or interp_b),
        "fwd_pallas_s": fwd_pallas, "fwd_oracle_s": fwd_oracle,
        "bwd_pallas_s": bwd_pallas, "bwd_oracle_s": bwd_oracle,
        "bwd_speedup": bwd_oracle / bwd_pallas,
        "fwd_gflops": fwd_flops / fwd_pallas / 1e9,
        "bwd_gflops": 2.5 * fwd_flops / bwd_pallas / 1e9,
    }


def _ssd_cell(shape, iters: int) -> Dict:
    from repro.kernels import autotune, ops, ref
    from repro.kernels import ssd as ssdk
    B, S, H, P, N = shape
    backend = ops.resolve_backend()
    interp_f = not ops.kernel_lowers("ssd_fwd", backend)
    interp_b = not ops.kernel_lowers("ssd_bwd", backend)
    chunk = autotune.ssd_config(backend, jnp.float32, S, P, N)["chunk"]
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, H, N))
    Cm = jax.random.normal(ks[4], (B, S, H, N))

    fwd_pallas = _time(jax.jit(lambda x, dt, A, Bm, Cm: ssdk.ssd(
        x, dt, A, Bm, Cm, chunk=chunk, interpret=interp_f)[0]),
        x, dt, A, Bm, Cm, iters=iters)
    fwd_oracle = _time(jax.jit(lambda x, dt, A, Bm, Cm:
                               ref.ssd_ref(x, dt, A, Bm, Cm)[0]),
                       x, dt, A, Bm, Cm, iters=iters)

    y, state, cst = ssdk.ssd_fwd(x, dt, A, Bm, Cm, chunk=chunk,
                                 interpret=interp_f)
    gy = jax.random.normal(jax.random.PRNGKey(7), y.shape)
    gs = jnp.zeros_like(state)
    bwd_pallas = _time(jax.jit(lambda *a: ssdk.ssd_bwd(
        *a, chunk=chunk, interpret=interp_b)),
        x, dt, A, Bm, Cm, cst, gy, gs, iters=iters)
    bwd_oracle = _time(
        jax.jit(lambda x, dt, A, Bm, Cm, gy, gs: ops.oracle_ssd_vjp(
            x, dt, A, Bm, Cm, (gy, gs))),
        x, dt, A, Bm, Cm, gy, gs, iters=iters)
    # intra-chunk [Q,Q] GEMMs dominate: ~3 per chunk fwd
    fwd_flops = 2 * 3 * B * H * S * chunk * max(P, N)
    return {
        "kernel": "ssd", "shape": list(shape), "chunk": chunk,
        "backend": backend, "lowered": not (interp_f or interp_b),
        "fwd_pallas_s": fwd_pallas, "fwd_oracle_s": fwd_oracle,
        "bwd_pallas_s": bwd_pallas, "bwd_oracle_s": bwd_oracle,
        "bwd_speedup": bwd_oracle / bwd_pallas,
        "fwd_gflops": fwd_flops / fwd_pallas / 1e9,
        "bwd_gflops": 2.5 * fwd_flops / bwd_pallas / 1e9,
    }


def kernel_roofline(csv: Csv, iters: int = 3,
                    check: bool = True) -> Dict:
    """fwd+bwd kernel roofline; asserts at every shape that the Pallas
    backward beats the oracle-recompute backward and the fused
    epilogues clear their speedup floor (acceptance criteria).  Every
    cell records the per-kind ``lowered`` verdict it ran under — on a
    lowered cell the margin is the compiled kernel's, on an
    interpreted cell the algorithmic one (O(S) vs O(S²) recompute);
    the gate holds in BOTH modes."""
    from benchmarks import fused_epilogue
    from repro.kernels import ops
    cells: List[Dict] = []
    for shape in FLASH_SHAPES:
        cells.append(_flash_cell(shape, iters))
    for shape in SSD_SHAPES:
        cells.append(_ssd_cell(shape, iters))
    for c in cells:
        name = f"kernels/{c['kernel']}/" + "x".join(map(str, c["shape"]))
        csv.add(f"{name}/fwd_pallas_s", c["fwd_pallas_s"] * 1e6,
                f"{c['fwd_gflops']:.2f}GF/s")
        csv.add(f"{name}/bwd_pallas_s", c["bwd_pallas_s"] * 1e6,
                f"{c['bwd_gflops']:.2f}GF/s")
        csv.add(f"{name}/bwd_oracle_s", c["bwd_oracle_s"] * 1e6,
                f"speedup={c['bwd_speedup']:.2f}x lowered={c['lowered']}")
        if check:
            assert c["bwd_pallas_s"] < c["bwd_oracle_s"], (
                f"Pallas backward slower than the oracle backward at "
                f"{name} (lowered={c['lowered']}): "
                f"{c['bwd_pallas_s']:.4f}s vs {c['bwd_oracle_s']:.4f}s")
    fcells = fused_epilogue.fused_cells(iters=iters)
    fused_epilogue.report(csv, fcells, check=check)
    backend = ops.resolve_backend()
    return {"backend": backend,
            "lowering_plan": [list(kv) for kv in
                              ops.lowering_plan(backend)],
            "iters": iters, "cells": cells + fcells}


def dryrun_report(csv: Csv) -> None:
    path = os.path.abspath(ARTIFACT)
    if not os.path.exists(path):
        csv.add("roofline/skipped", 0.0,
                "run `PYTHONPATH=src python -m repro.launch.dryrun` first")
        return
    with open(path) as f:
        cells = json.load(f)["cells"]
    for cell in cells:
        if cell.get("status") != "ok":
            csv.add(f"roofline/{cell['key']}", 0.0,
                    f"status={cell.get('status')}")
            continue
        r = cell["roofline"]
        name = f"roofline/{cell['key']}"
        csv.add(f"{name}/compute_s", cell.get("compile_us", 0.0),
                f"{r['compute_s']:.6f}")
        csv.add(f"{name}/memory_s", 0.0, f"{r['memory_s']:.6f}")
        csv.add(f"{name}/collective_s", 0.0, f"{r['collective_s']:.6f}")
        csv.add(f"{name}/bottleneck", 0.0, r["bottleneck"])
        csv.add(f"{name}/useful_flops_frac", 0.0,
                f"{r['model_flops_ratio']:.3f}")


def main(csv: Optional[Csv] = None, argv: Optional[List[str]] = None) -> Dict:
    csv = csv or Csv()
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="write the kernel roofline to this path "
                         "(BENCH_kernels.json)")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--no-check", action="store_true",
                    help="report without asserting bwd beats the oracle")
    args = ap.parse_args(argv if argv is not None else [])
    dryrun_report(csv)
    result = kernel_roofline(csv, iters=args.iters,
                             check=not args.no_check)
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.json}")
    return result


if __name__ == "__main__":
    import sys
    main(argv=sys.argv[1:])
