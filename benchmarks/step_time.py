"""Step-time benchmark: compiled per-template programs vs the eager
reference (ISSUE 2 / DESIGN.md §8).

Two numbers matter for resilient training:

  * steady_state_s   — wall-clock of one training step once programs
                       are cached (median over --steps);
  * reconfig_s       — reconfiguration-to-first-step latency: kill a
                       node, recover from replicas, run the next step.
                       With a warmed template-keyed cache this swaps
                       programs by lookup (zero compiles — asserted via
                       cache counters); the eager path re-traces.

Emits CSV rows (benchmarks/common.py convention) and, with --json, a
machine-readable artifact for the perf trajectory / CI upload.

    PYTHONPATH=src:. python benchmarks/step_time.py --json artifacts/step_time.json
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks.common import Csv
from repro.configs import get_arch, reduced
from repro.core import EngineConfig, OobleckEngine, build_profile
from repro.data import GlobalBatchDispenser, SyntheticLM
from repro.models import Model
from repro.optim import adamw
from repro.runtime import HeteroTrainer


def microbatches(batch, mb_size):
    n = batch["tokens"].shape[0] // mb_size
    return [{k: v[i * mb_size:(i + 1) * mb_size] for k, v in batch.items()
             if not k.startswith("_")} for i in range(n)]


def bench_mode(mode: str, model, profile, params, opt_cfg, args,
               csv: Csv) -> Dict:
    nodes = [f"n{i}" for i in range(args.nodes)]
    engine = OobleckEngine(profile, nodes, EngineConfig(
        fault_tolerance=args.f, global_batch=args.global_batch,
        microbatch=args.microbatch, gpus_per_node=1, n0_override=args.n0))
    trainer = HeteroTrainer(model, engine, params, opt_cfg, mode=mode)
    warm_s = 0.0
    if mode == "compiled":
        t0 = time.perf_counter()
        trainer.warm_templates()
        warm_s = time.perf_counter() - t0
    src = SyntheticLM(model.arch.vocab_size, args.seq_len, seed=0)
    disp = GlobalBatchDispenser(src)

    def drive():
        batches = disp.next_step(engine.batch.minibatch_sizes())
        out = trainer.train_step(
            [microbatches(b, args.microbatch) for b in batches])
        out["loss"].block_until_ready()
        return out

    drive()                                    # settle caches in BOTH modes
    times: List[float] = []
    for _ in range(args.steps):
        t0 = time.perf_counter()
        drive()
        times.append(time.perf_counter() - t0)
    steady = sorted(times)[len(times) // 2]

    victim = engine.instances[0].nodes[-1]
    compiles_before = trainer.cache.stats.compiles
    t0 = time.perf_counter()
    trainer.recover({victim})
    drive()
    reconfig = time.perf_counter() - t0
    recompiles = trainer.cache.stats.compiles - compiles_before

    csv.add(f"step_time/{mode}/steady_state_s", steady * 1e6, f"{steady:.4f}")
    csv.add(f"step_time/{mode}/reconfig_to_first_step_s", reconfig * 1e6,
            f"{reconfig:.4f}")
    return {"mode": mode, "steady_state_s": steady,
            "reconfig_to_first_step_s": reconfig,
            "warm_seconds": warm_s, "recompiles_after_failure": recompiles,
            "cache": trainer.cache.stats.as_dict()}


def main(csv=None, argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt3_medium")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--nodes", type=int, default=5)
    ap.add_argument("--f", type=int, default=1)
    ap.add_argument("--n0", type=int, default=2)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--microbatch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=5)
    ap.add_argument("--json", default="")
    # under the run.py driver (csv passed, argv untouched) ignore
    # sys.argv — it holds the driver's suite selector, not our flags
    if argv is None and csv is not None:
        argv = []
    args = ap.parse_args(argv)

    arch = reduced(get_arch(args.arch), layers=args.layers)
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive",
                  scan_layers=False)
    params = model.init(jax.random.PRNGKey(0))
    profile = build_profile(arch, microbatch=args.microbatch,
                            seq_len=args.seq_len)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, weight_decay=0.0)

    csv = csv or Csv()
    compiled = bench_mode("compiled", model, profile, params, opt_cfg,
                          args, csv)
    eager = bench_mode("eager", model, profile, params, opt_cfg, args, csv)

    result = {
        "config": {k: getattr(args, k.replace("-", "_"))
                   for k in ("arch", "layers", "nodes", "global_batch",
                             "microbatch", "seq_len", "steps")},
        "compiled": compiled, "eager": eager,
        "speedup_steady_state":
            eager["steady_state_s"] / compiled["steady_state_s"],
        "speedup_reconfig":
            eager["reconfig_to_first_step_s"]
            / compiled["reconfig_to_first_step_s"],
    }
    csv.add("step_time/speedup/steady_state", 0.0,
            f"{result['speedup_steady_state']:.1f}x")
    csv.add("step_time/speedup/reconfig_to_first_step", 0.0,
            f"{result['speedup_reconfig']:.1f}x")
    assert compiled["recompiles_after_failure"] == 0, \
        "warmed cache must serve reconfiguration without compiling"
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.json}")
    return result


if __name__ == "__main__":
    out = main()
    print(f"steady-state speedup:  {out['speedup_steady_state']:.1f}x")
    print(f"reconfig-to-first-step speedup: {out['speedup_reconfig']:.1f}x")
