"""Paper Table 4: impact of checkpointing overhead — Varuna vs Varuna
with free checkpoints (overhead removed, frequency raised to every 2
iterations) vs Oobleck, on BERT-Large and GPT-3 6.7b."""
from __future__ import annotations

from benchmarks.common import (FAULT_TOLERANCE, FREQS, NUM_NODES, TABLE1,
                               Csv, profile_for, timed)
from repro.sim import OobleckPolicy, VarunaPolicy, controlled_failures, run_sim

MODELS = ("bert_large", "gpt3_6_7b")
MAX_STAGES = 12


def main(csv: Csv | None = None) -> None:
    csv = csv or Csv()
    nodes = [f"n{i}" for i in range(NUM_NODES)]
    for model in MODELS:
        gb, mb, _, seq = TABLE1[model]
        prof = profile_for(model, mb)
        for label, interval in FREQS.items():
            trace = controlled_failures(nodes, interval, stop_at=NUM_NODES // 2)
            horizon = interval * (NUM_NODES // 2 + 2)
            variants = {
                "varuna": lambda: VarunaPolicy(
                    prof, nodes, global_batch=gb, microbatch=mb,
                    max_stages=MAX_STAGES),
                "varuna_no_ckpt": lambda: VarunaPolicy(
                    prof, nodes, global_batch=gb, microbatch=mb,
                    ckpt_overhead=False, ckpt_every=2, max_stages=MAX_STAGES),
                "oobleck": lambda: OobleckPolicy(
                    prof, nodes, f=FAULT_TOLERANCE, global_batch=gb,
                    microbatch=mb, max_stages=MAX_STAGES),
            }
            for vname, mk in variants.items():
                def cell():
                    res = run_sim(mk(), trace, horizon, gb,
                                  min_nodes=NUM_NODES // 2)
                    return f"{res.throughput:.2f}"
                derived, us = timed(cell)
                csv.add(f"table4/{model}/{label}/{vname}", us, derived)


if __name__ == "__main__":
    main()
