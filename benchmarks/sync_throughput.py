"""Sync-tail throughput: compiled bucketed data plane vs the retained
eager per-layer path (ISSUE 4 / DESIGN.md §10).

Measures ONLY the step's tail — cross-replica gradient sync +
global-norm clip + AdamW commit — with identical gradients as input:

  * eager_per_layer_s   — the pre-§10 runtime path: O(layers x
                          replicas) jax.tree.map dispatches for the
                          weighted average, a per-leaf chain for the
                          norm, one update-program call per layer per
                          replica;
  * compiled_bucketed_s — the engine's sync plan executed as cached
                          per-bucket programs: pack each bucket into one
                          flat buffer, one weighted-reduction chain per
                          bucket (deepest-first), one donated AdamW
                          program per bucket per replica.

Also reports the SHARED cost model's view (per-bucket overlapped
schedule, exposed tail, wire bytes per codec) and asserts the engine
and the simulator policy price it identically.

Emits CSV rows plus, with --json, the machine-readable BENCH_sync.json
CI artifact.

    PYTHONPATH=src:. python benchmarks/sync_throughput.py \
        --json artifacts/BENCH_sync.json
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from benchmarks.common import Csv
from repro.configs import get_arch, reduced
from repro.core import EngineConfig, OobleckEngine, build_profile
from repro.data import GlobalBatchDispenser, SyntheticLM
from repro.models import Model
from repro.optim import adamw
from repro.runtime import HeteroTrainer


def microbatches(batch, mb_size):
    n = batch["tokens"].shape[0] // mb_size
    return [{k: v[i * mb_size:(i + 1) * mb_size] for k, v in batch.items()
             if not k.startswith("_")} for i in range(n)]


def make_trainer(args, model, profile, params, opt_cfg, sync_mode, codec):
    nodes = [f"n{i}" for i in range(args.nodes)]
    engine = OobleckEngine(profile, nodes, EngineConfig(
        fault_tolerance=args.f, global_batch=args.global_batch,
        microbatch=args.microbatch, gpus_per_node=1, n0_override=args.n0,
        codec=codec))
    return HeteroTrainer(model, engine, params, opt_cfg, mode="compiled",
                         sync_mode=sync_mode, codec=codec)


def grads_of(trainer, args):
    src = SyntheticLM(trainer.model.arch.vocab_size, args.seq_len, seed=0)
    disp = GlobalBatchDispenser(src)
    batches = disp.next_step(trainer.engine.batch.minibatch_sizes())
    per_pipe = [microbatches(b, args.microbatch) for b in batches]
    all_grads, weights = [], []
    for run, mbs in zip(trainer.runs, per_pipe):
        g, _ = trainer._run_pipeline(run, mbs)
        all_grads.append(g)
        weights.append(len(mbs))
    jax.tree.leaves(all_grads[-1])[0].block_until_ready()
    return all_grads, weights


def bench_tail(trainer, all_grads, weights, iters: int) -> float:
    def tail():
        gn = trainer._sync_and_update(all_grads, weights)
        gn.block_until_ready()
        # fence every replica's update chain, not just the dispatch
        for run in trainer.runs:
            jax.tree.leaves(run.states[0]["p"])[0].block_until_ready()

    tail(); tail()                          # settle caches / first dispatch
    times: List[float] = []
    for _ in range(iters):
        t0 = time.perf_counter()
        tail()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def main(csv=None, argv=None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt3_medium")
    ap.add_argument("--layers", type=int, default=32)
    ap.add_argument("--d-model", type=int, default=32,
                    help="tiny layers keep the tail dispatch-bound — the "
                         "regime the data plane targets (many small "
                         "layers per bucket)")
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--nodes", type=int, default=9)
    ap.add_argument("--f", type=int, default=2)
    ap.add_argument("--n0", type=int, default=3)
    ap.add_argument("--global-batch", type=int, default=24)
    ap.add_argument("--microbatch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=16)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--codec", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--json", default="")
    ap.add_argument("--no-assert", action="store_true",
                    help="skip the >=3x acceptance assertion (small runs)")
    # under the run.py driver (csv passed, argv untouched) ignore
    # sys.argv — it holds the driver's suite selector, not our flags
    if argv is None and csv is not None:
        argv = []
    args = ap.parse_args(argv)

    arch = reduced(get_arch(args.arch), layers=args.layers,
                   d_model=args.d_model, vocab=args.vocab)
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive",
                  scan_layers=False)
    params = model.init(jax.random.PRNGKey(0))
    profile = build_profile(arch, microbatch=args.microbatch,
                            seq_len=args.seq_len)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, clip_norm=1.0,
                                weight_decay=0.0)
    csv = csv or Csv()

    te = make_trainer(args, model, profile, params, opt_cfg,
                      sync_mode="perlayer", codec="none")
    tb = make_trainer(args, model, profile, params, opt_cfg,
                      sync_mode="bucketed", codec=args.codec)
    replicas = len(tb.engine.instances)
    plan = tb._bucket_plan()

    grads_e = grads_of(te, args)
    grads_b = grads_of(tb, args)
    eager_s = bench_tail(te, *grads_e, args.iters)
    bucketed_s = bench_tail(tb, *grads_b, args.iters)
    speedup = eager_s / bucketed_s

    csv.add("sync_throughput/eager_per_layer_s", eager_s * 1e6,
            f"{eager_s:.5f}")
    csv.add("sync_throughput/compiled_bucketed_s", bucketed_s * 1e6,
            f"{bucketed_s:.5f}")
    csv.add("sync_throughput/speedup", 0.0, f"{speedup:.1f}x")
    csv.add("sync_throughput/buckets", 0.0, str(len(plan)))

    # ---- the shared cost model's view (engine == simulator, both
    # pinned against an independently constructed SyncCostModel) -------
    from repro.core.sync import SyncCostModel
    from repro.sim.policies import OobleckPolicy
    sched = tb.engine.sync_schedule()
    pol = OobleckPolicy(profile, [f"n{i}" for i in range(args.nodes)],
                        f=args.f, global_batch=args.global_batch,
                        microbatch=args.microbatch, n0=args.n0,
                        codec=args.codec)
    tail_engine = tb.engine._sync_tail_seconds()
    tail_policy = pol.sync_tail_seconds()
    tail_independent = SyncCostModel(
        hw=profile.hw, codec=args.codec,
        topology=pol.engine.topology).tail_seconds(
            pol.engine.sync_plan(), profile.layer_bwd_seconds())
    assert tail_engine == tail_policy == tail_independent, \
        f"engine ({tail_engine}), simulator ({tail_policy}) and the " \
        f"shared model ({tail_independent}) must agree on the sync tail"
    csv.add("sync_throughput/modeled_exposed_tail_s", 0.0,
            f"{tail_engine:.2e}")

    result = {
        "config": {k: getattr(args, k) for k in
                   ("arch", "layers", "nodes", "f", "n0", "global_batch",
                    "microbatch", "seq_len", "iters", "codec")},
        "replicas": replicas,
        "num_layers": tb.num_layers,
        "buckets": [{"layers": list(b.lids), "elements": b.n,
                     "hierarchical": b.hierarchical} for b in plan],
        "eager_per_layer_s": eager_s,
        "compiled_bucketed_s": bucketed_s,
        "speedup": speedup,
        "modeled": {
            "exposed_tail_s": tail_engine,
            "simulator_tail_s": tail_policy,
            "agreement": tail_engine == tail_policy,
            "schedule": [{"layers": [r.layer_start, r.layer_end],
                          "wire_bytes": r.wire_bytes, "comm_s": r.comm_s,
                          "ready_s": r.ready_s, "end_s": r.end_s,
                          "hierarchical": r.hierarchical}
                         for r in sched],
        },
        "cache": tb.cache.stats.as_dict(),
    }
    if args.json:
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(result, f, indent=2)
        print(f"wrote {args.json}")
    if not args.no_assert and args.layers >= 24 and replicas >= 3:
        assert speedup >= 3.0, \
            f"compiled bucketed sync must beat the eager per-layer path " \
            f">=3x at {args.layers} layers / {replicas} replicas " \
            f"(got {speedup:.2f}x)"
    return result


if __name__ == "__main__":
    out = main()
    print(f"replicas={out['replicas']} layers={out['num_layers']} "
          f"buckets={len(out['buckets'])}")
    print(f"eager per-layer tail:    {out['eager_per_layer_s'] * 1e3:.2f} ms")
    print(f"compiled bucketed tail:  {out['compiled_bucketed_s'] * 1e3:.2f} ms")
    print(f"speedup: {out['speedup']:.1f}x")
