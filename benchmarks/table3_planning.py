"""Paper Table 3: pipeline-template planning latency (seconds) for
varying (#nodes, #GPUs/node, #layers).

Runs the REAL planner (divide-and-conquer DP with memoization) and
reports wall-clock per single-template plan, plus the memoization win
when planning the full consecutive template set (§4.1.2: the largest
template fills the caches for the rest)."""
from __future__ import annotations

import dataclasses

from benchmarks.common import Csv, timed
from repro.configs import get_arch
from repro.core import PipelinePlanner, build_profile

GRID_NODES = (8, 16, 24)
#: extra sizes only the vectorized DP visits in reasonable time — the
#: scale axis feeding the perf trajectory (see also planning_scale.py)
GRID_NODES_FAST = (8, 16, 24, 48)
GRID_GPUS = (1, 4)
GRID_LAYERS = (24, 32, 64)


def profile_with_layers(layers: int):
    arch = dataclasses.replace(get_arch("gpt2"), name=f"gpt2_L{layers}",
                               num_layers=layers)
    return build_profile(arch, microbatch=2, seq_len=1024)


def main(csv: Csv | None = None) -> None:
    csv = csv or Csv()
    for layers in GRID_LAYERS:
        prof = profile_with_layers(layers)
        for gpus in GRID_GPUS:
            for n in GRID_NODES:
                planner = PipelinePlanner(prof, gpus_per_node=gpus,
                                          mode="peel", max_stages=2 * n)
                tpl, us = timed(lambda: planner.plan(n))
                csv.add(f"table3/plan/L{layers}/n{n}/g{gpus}", us,
                        f"{us / 1e6:.3f}s")
                # memoized follow-up: the (n-1)-node template reuses cache
                _, us2 = timed(lambda: planner.plan(n - 1))
                csv.add(f"table3/plan_memoized/L{layers}/n{n - 1}/g{gpus}",
                        us2, f"{us2 / 1e6:.3f}s")
            for n in GRID_NODES_FAST:
                if prof.num_layers < n:
                    continue
                # fresh planner per n with the same max_stages cap as the
                # peel rows: cold latency over the identical search space
                # (warm reuse is `plan_memoized`'s job)
                fast = PipelinePlanner(prof, gpus_per_node=gpus,
                                       mode="fast", max_stages=2 * n)
                _, us = timed(lambda: fast.plan(n))
                csv.add(f"table3/plan_fast/L{layers}/n{n}/g{gpus}", us,
                        f"{us / 1e6:.3f}s")


if __name__ == "__main__":
    main()
