"""Benchmark driver — one module per paper table/figure.

Emits ``name,us_per_call,derived`` CSV rows:
  table2_throughput  — Table 2 (throughput vs failure frequency)
  table3_planning    — Table 3 (planning latency)
  table4_ckpt        — Table 4 (checkpoint-overhead ablation)
  fig10_spot_traces  — Figure 10 / Appendix C (spot instance replay)
  fig11_breakdown    — Figure 11 (time-occupation breakdown)
  roofline_report    — §Roofline terms from the dry-run artifact + the
                       kernel fwd/bwd roofline (Pallas vs oracle bwd,
                       per-cell ``lowered`` verdicts) + fused cells
  fused_epilogue     — fused residual+RMSNorm / QKV epilogues vs the
                       op-granular unfused reference (train path)
  planning_scale     — beyond-paper: planner/reconfig latency vs cluster size
  step_time          — compiled per-template programs vs eager reference
                       (steady-state + reconfiguration-to-first-step)
  recovery_latency   — failure->first-step decomposition through the
                       recovery data plane (replan / transfer / compile),
                       pod-local vs cross-pod stream makespans
  sync_throughput    — compiled bucketed gradient-sync data plane vs the
                       eager per-layer tail (sync + clip + AdamW), plus
                       the shared per-bucket overlap cost model
  recovery_policy    — per-policy recovery downtime (replan vs schedule
                       adaptation vs the per-event auto selector) across
                       the scenario families
  serve_throughput   — serving plane: continuous batching vs static
                       batching tokens/s + TTFT percentiles, and
                       recovery downtime through an injected mid-decode
                       failure (zero-recompile, bitwise streams)

Machine-readable results are ALSO written to the repo root as
``BENCH_<suite>.json`` (roofline -> BENCH_kernels.json) so benchmark
trajectories live in the tree, not only in CI artifacts.
"""
from __future__ import annotations

import os
import sys
import time

from benchmarks.common import Csv

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    from benchmarks import (fig10_spot_traces, fig11_breakdown,
                            fused_epilogue, planning_scale,
                            recovery_latency, recovery_policy,
                            roofline_report, serve_throughput, step_time,
                            sync_throughput, table2_throughput,
                            table3_planning, table4_ckpt_ablation)
    only = sys.argv[1] if len(sys.argv) > 1 else None

    def bench_json(name: str):
        return ["--json", os.path.join(ROOT, f"BENCH_{name}.json")]

    # suite -> (fn, argv or None); argv-taking suites persist BENCH_*.json
    suites = {
        "table2": (table2_throughput.main, None),
        "table3": (table3_planning.main, None),
        "table4": (table4_ckpt_ablation.main, None),
        "fig10": (fig10_spot_traces.main, None),
        "fig11": (fig11_breakdown.main, None),
        "roofline": (roofline_report.main, bench_json("kernels")),
        "fused_epilogue": (fused_epilogue.main,
                           bench_json("fused_epilogue")),
        "planning_scale": (planning_scale.main, None),
        "step_time": (step_time.main, bench_json("step_time")),
        "recovery_latency": (recovery_latency.main, bench_json("recovery")),
        "recovery_policy": (recovery_policy.main,
                            bench_json("recovery_policy")),
        "sync_throughput": (sync_throughput.main, bench_json("sync")),
        "serve": (serve_throughput.main, bench_json("serve")),
    }
    if only is not None and only not in suites:
        print(f"unknown suite {only!r}; choose from: {', '.join(suites)}",
              file=sys.stderr)
        raise SystemExit(2)
    csv = Csv()
    print("name,us_per_call,derived")
    for name, (fn, argv) in suites.items():
        if only and only != name:
            continue
        t0 = time.perf_counter()
        if argv is None:
            fn(csv)
        else:
            fn(csv, argv)
        print(f"# {name} done in {time.perf_counter() - t0:.1f}s",
              file=sys.stderr)


if __name__ == "__main__":
    main()
