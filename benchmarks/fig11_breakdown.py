"""Paper Figure 11: wall-clock occupation breakdown (compute / fallback /
downtime / checkpoint) for each framework at the 10-minute failure rate,
BERT-Large and GPT-3 6.7b."""
from __future__ import annotations

from benchmarks.common import (FAULT_TOLERANCE, NUM_NODES, TABLE1, Csv,
                               profile_for, timed)
from repro.sim import (BambooPolicy, OobleckPolicy, VarunaPolicy,
                       controlled_failures, run_sim)

MODELS = ("bert_large", "gpt3_6_7b")
MAX_STAGES = 12


def main(csv: Csv | None = None) -> None:
    csv = csv or Csv()
    nodes = [f"n{i}" for i in range(NUM_NODES)]
    interval = 600.0
    for model in MODELS:
        gb, mb, bamboo_mb, seq = TABLE1[model]
        prof = profile_for(model, mb)
        trace = controlled_failures(nodes, interval, stop_at=NUM_NODES // 2)
        horizon = interval * (NUM_NODES // 2 + 2)
        mks = {
            "oobleck": lambda: OobleckPolicy(prof, nodes, f=FAULT_TOLERANCE,
                                             global_batch=gb, microbatch=mb,
                                             max_stages=MAX_STAGES),
            "varuna": lambda: VarunaPolicy(prof, nodes, global_batch=gb,
                                           microbatch=mb,
                                           max_stages=MAX_STAGES),
            "bamboo": lambda: BambooPolicy(
                profile_for(model, bamboo_mb) if bamboo_mb else prof, nodes,
                global_batch=gb, microbatch=bamboo_mb or mb,
                max_stages=MAX_STAGES),
        }
        for pname, mk in mks.items():
            def cell():
                if pname == "bamboo" and bamboo_mb is None:
                    return None
                return run_sim(mk(), trace, horizon, gb,
                               min_nodes=NUM_NODES // 2)
            res, us = timed(cell)
            if res is None or res.stopped_reason == "OOM":
                csv.add(f"fig11/{model}/{pname}/oom", us, "1.00")
                continue
            total = max(sum(res.breakdown.values()), 1e-9)
            for k, v in sorted(res.breakdown.items()):
                csv.add(f"fig11/{model}/{pname}/{k}", us, f"{v / total:.3f}")


if __name__ == "__main__":
    main()
