"""Paper Figure 10 / Appendix C: average throughput replaying
spot-instance availability traces (EC2-like: preemption every ~7.7 min;
GCP-like: ~10.3 min) for 12 simulated hours, with node joins."""
from __future__ import annotations

from benchmarks.common import (FAULT_TOLERANCE, NUM_NODES, TABLE1, Csv,
                               profile_for, timed)
from repro.sim import (BambooPolicy, OobleckPolicy, VarunaPolicy, run_sim,
                       spot_trace)

MODELS = ("bert_large", "gpt2", "gpt3_2_7b", "gpt3_6_7b")
TRACES = {"ec2": 7.7 * 60, "gcp": 10.3 * 60}
HORIZON = 12 * 3600.0
MAX_STAGES = 12


def main(csv: Csv | None = None) -> None:
    csv = csv or Csv()
    nodes = [f"n{i}" for i in range(NUM_NODES)]
    for model in MODELS:
        gb, mb, bamboo_mb, seq = TABLE1[model]
        prof = profile_for(model, mb)
        for tname, mean_preempt in TRACES.items():
            trace = spot_trace(nodes, HORIZON, mean_preempt,
                               mean_recover=mean_preempt * 2, seed=17,
                               min_alive=max(10, NUM_NODES // 3))
            mks = {
                "oobleck": lambda: OobleckPolicy(
                    prof, nodes, f=FAULT_TOLERANCE, global_batch=gb,
                    microbatch=mb, max_stages=MAX_STAGES),
                "varuna": lambda: VarunaPolicy(
                    prof, nodes, global_batch=gb, microbatch=mb,
                    max_stages=MAX_STAGES),
                "bamboo": lambda: BambooPolicy(
                    profile_for(model, bamboo_mb) if bamboo_mb else prof,
                    nodes, global_batch=gb, microbatch=bamboo_mb or mb,
                    max_stages=MAX_STAGES),
            }
            for pname, mk in mks.items():
                def cell():
                    if pname == "bamboo" and bamboo_mb is None:
                        return "OOM"
                    res = run_sim(mk(), trace, HORIZON, gb)
                    if res.stopped_reason == "OOM":
                        return "OOM"
                    return f"{res.throughput:.2f}"
                derived, us = timed(cell)
                csv.add(f"fig10/{model}/{tname}/{pname}", us, derived)


if __name__ == "__main__":
    main()
