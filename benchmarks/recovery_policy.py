"""Per-policy recovery downtime across the PR 1 scenario families
(DESIGN.md §12): replan vs ReCycle-style schedule adaptation vs the
per-event auto selector, all through the REAL engine wrapped by the
simulator's OobleckPolicy.

Per (family, policy) cell: total simulated downtime, throughput, the
adaptation / spare-promotion / reconfiguration counts, and — for auto —
the per-event decision log (chosen policy + predicted downtimes).

Headline assertion (acceptance criterion): ``auto`` STRICTLY reduces
total simulated downtime vs always-replan on at least two of the three
scenario families.  The third (preemption waves) is allowed to tie:
mass drains damage most replicas at once, the slowdown cap vetoes the
adaptation, and auto correctly degenerates to replan.

    PYTHONPATH=src:. python benchmarks/recovery_policy.py [--json out]
"""
from __future__ import annotations

import argparse
import collections
import dataclasses
import json
import time

from benchmarks.common import Csv
from repro.configs import get_arch
from repro.core import build_profile
from repro.sim import (OobleckPolicy, rack_failure_bursts, run_sim,
                       scale_cycle, spot_preemption_wave)

POLICIES = ("replan", "adapt", "auto")


def _profile(layers=66):
    arch = dataclasses.replace(get_arch("gpt2"), name=f"gpt2_L{layers}",
                               num_layers=layers)
    return build_profile(arch, microbatch=2, seq_len=1024)


def families(nodes, horizon):
    """The three PR 1 scenario families, fixed seeds (benchmarks must be
    reproducible run-to-run)."""
    return {
        "rack_bursts": rack_failure_bursts(
            nodes, rack_size=8, horizon=horizon, mean_interval=1800,
            seed=11, min_alive=24),
        "preemption_wave": spot_preemption_wave(
            nodes, horizon=horizon, mean_wave=2400, wave_frac=0.15,
            grace=120, seed=7, min_alive=24),
        "scale_cycle": scale_cycle(
            nodes, horizon=horizon, period=3600, step=8, lo=32, hi=64),
    }


def run_cell(csv: Csv, profile, nodes, events, horizon, family: str,
             policy: str, results: dict) -> dict:
    pol = OobleckPolicy(profile, nodes, f=2, global_batch=4096,
                        microbatch=2, n0=4, recovery_policy=policy)
    t0 = time.perf_counter()
    res = run_sim(pol, list(events), horizon=horizon, global_batch=4096,
                  min_nodes=24)
    wall_us = (time.perf_counter() - t0) * 1e6
    decisions = collections.Counter(d["chosen"] for d in pol.decisions)
    row = {
        "downtime_s": res.breakdown["downtime"],
        "compute_s": res.breakdown["compute"],
        "throughput": res.throughput,
        "committed_samples": res.committed_samples,
        "events_handled": res.events_handled,
        "reconfigurations": pol.stats.reconfigurations,
        "adaptations": pol.stats.adaptations,
        "spare_promotions": pol.stats.spare_promotions,
        "decisions": dict(decisions),
        "decision_log": pol.decisions,
        "stopped": res.stopped_reason,
    }
    name = f"recovery_policy,{family},{policy}"
    csv.add(name, wall_us,
            f"downtime={row['downtime_s']:.2f}s"
            f"|thpt={row['throughput']:.1f}"
            f"|adapts={row['adaptations']}"
            f"|promos={row['spare_promotions']}"
            f"|reconf={row['reconfigurations']}")
    results[name] = row
    return row


def main(csv=None, argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=64)
    ap.add_argument("--horizon", type=int, default=6 * 3600)
    ap.add_argument("--layers", type=int, default=66)
    ap.add_argument("--json", default="")
    args = ap.parse_args(argv)

    csv = csv or Csv()
    results: dict = {}
    profile = _profile(args.layers)
    nodes = [f"node{i:03d}" for i in range(args.nodes)]
    fams = families(nodes, args.horizon)
    per_family: dict = {}
    for family, events in fams.items():
        per_family[family] = {
            policy: run_cell(csv, profile, nodes, events, args.horizon,
                             family, policy, results)
            for policy in POLICIES}

    # acceptance criterion: auto strictly beats always-replan on >= 2 of
    # the 3 families, and never does worse than it anywhere.  The strict
    # margin (0.05 s) filters the wall-clock noise of the measured
    # replan leg — a "win" must come from a genuinely cheaper policy,
    # not from microseconds of planner-timing jitter.
    strict_wins = [f for f, cells in per_family.items()
                   if cells["auto"]["downtime_s"]
                   < cells["replan"]["downtime_s"] - 0.05]
    for f, cells in per_family.items():
        assert (cells["auto"]["downtime_s"]
                <= cells["replan"]["downtime_s"] + 0.05), \
            f"auto must never lose to replan on downtime ({f})"
    assert len(strict_wins) >= 2, \
        (f"auto must strictly reduce downtime on >= 2/3 families, "
         f"got {strict_wins}")
    # the wins must come from actually adapting/promoting, not noise
    for f in strict_wins:
        assert (per_family[f]["auto"]["adaptations"]
                + per_family[f]["auto"]["spare_promotions"]) > 0, \
            f"auto's win on {f} must come from adapt/spare events"
    results["summary"] = {
        "strict_wins": strict_wins,
        "downtime": {f: {p: cells[p]["downtime_s"] for p in POLICIES}
                     for f, cells in per_family.items()},
    }
    if args.json:
        import os
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    main()
