"""Failure -> first-step latency through the recovery data plane
(DESIGN.md §9), decomposed into replan / transfer / compile phases.

Analytic mode (default) drives the REAL reconfigurator + transfer
scheduler on target-hardware constants (utils/hw.py) for clusters of
16..64 nodes and three failure shapes:

  * single     — one node dies;
  * rack       — a whole pod dies as one correlated burst;
  * cross_pod  — the same single failure, but under a pathological
                 topology where every replica is in a different pod, so
                 every recovery copy rides DCN instead of ICI.

Each row reports the phase decomposition, the stream count, the
pod-local byte fraction, and the SERIAL sum-of-bytes accounting the
simulator used to charge — the max-over-parallel-streams makespan must
beat it whenever more than one stream is in flight.

``--real`` additionally runs a small HeteroTrainer end-to-end on actual
arrays: warm the template cache, kill a node, and wall-clock the
recover() call (replan + data-plane state copies) and the first
post-recovery step, asserting the compile leg is ZERO (cache hit).

    PYTHONPATH=src:. python benchmarks/recovery_latency.py [--real]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

from benchmarks.common import Csv
from repro.configs import get_arch
from repro.core import EngineConfig, OobleckEngine, build_profile


def _profile(layers=26):
    arch = dataclasses.replace(get_arch("gpt2"), name=f"gpt2_L{layers}",
                               num_layers=layers)
    return build_profile(arch, microbatch=2, seq_len=1024)


def make_engine(profile, n_nodes, nodes_per_pod, f=2, n0=4):
    return OobleckEngine(
        profile, [f"node{i:03d}" for i in range(n_nodes)],
        EngineConfig(fault_tolerance=f, global_batch=1024, microbatch=2,
                     gpus_per_node=1, n0_override=n0,
                     nodes_per_pod=nodes_per_pod))


def one_failure(csv: Csv, profile, n_nodes, nodes_per_pod, scenario: str,
                results: dict) -> None:
    eng = make_engine(profile, n_nodes, nodes_per_pod)
    if scenario == "rack":
        # a correlated burst spanning pipelines: one node from each of
        # the first k replicas dies at once (power/ToR failure shape) —
        # every damaged pipeline reinstantiates and copies state, capped
        # so at least one replica of every layer survives
        floor = (eng.spec.f + 1) * eng.spec.n0
        k = min(len(eng.instances) - 1, 4, len(eng.nodes) - floor)
        dead = {inst.nodes[-1] for inst in eng.instances[:max(k, 1)]}
    else:
        dead = {eng.instances[0].nodes[-1]}
    t0 = time.perf_counter()
    result = eng.handle_failure(dead)
    plan = eng.transfer_plan(result, dead=dead)
    bd = {"replan": result.replan_seconds, "transfer": plan.makespan(),
          "compile": 0.0, "barrier": 1.0}
    wall_us = (time.perf_counter() - t0) * 1e6
    total = sum(bd.values())
    row = {"replan_s": bd["replan"], "transfer_s": bd["transfer"],
           "compile_s": bd["compile"], "barrier_s": bd["barrier"],
           "total_s": total, "streams": len(plan.streams),
           "pod_local": plan.pod_local_fraction(),
           "serial_s": plan.serial_seconds(),
           "bytes": plan.total_bytes}
    name = f"recovery,n={n_nodes},pods={nodes_per_pod},{scenario}"
    csv.add(name, wall_us,
            f"replan={bd['replan']:.4f}s|transfer={bd['transfer']:.3f}s"
            f"|compile=0s|total={total:.3f}s|streams={len(plan.streams)}"
            f"|podlocal={plan.pod_local_fraction():.2f}"
            f"|serial={plan.serial_seconds():.3f}s")
    results[name] = row


def real_run(csv: Csv, results: dict) -> None:
    import jax
    import jax.numpy as jnp
    from repro.configs import reduced
    from repro.data import GlobalBatchDispenser, SyntheticLM
    from repro.models import Model
    from repro.optim import adamw
    from repro.runtime import HeteroTrainer, track_compiles

    arch = reduced(get_arch("gpt3_medium"), layers=4)
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive",
                  scan_layers=False)
    params = model.init(jax.random.PRNGKey(0))
    profile = build_profile(arch, microbatch=2, seq_len=32)
    engine = OobleckEngine(
        profile, [f"n{i}" for i in range(5)],
        EngineConfig(fault_tolerance=1, global_batch=16, microbatch=2,
                     gpus_per_node=1, n0_override=2, nodes_per_pod=4))
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, weight_decay=0.0)
    trainer = HeteroTrainer(model, engine, params, opt_cfg)
    t0 = time.perf_counter()
    trainer.warm_templates()
    warm_s = time.perf_counter() - t0
    disp = GlobalBatchDispenser(SyntheticLM(arch.vocab_size, 32, seed=1))

    def microbatches(batch):
        return [{k: v[i * 2:(i + 1) * 2] for k, v in batch.items()
                 if not k.startswith("_")}
                for i in range(batch["tokens"].shape[0] // 2)]

    def drive():
        batches = disp.next_step(engine.batch.minibatch_sizes())
        out = trainer.train_step([microbatches(b) for b in batches])
        out["loss"].block_until_ready()
        return out

    drive()
    victim = engine.instances[0].nodes[-1]
    with track_compiles() as log:
        t0 = time.perf_counter()
        info = trainer.recover({victim})
        recover_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        drive()
        first_step_s = time.perf_counter() - t0
    assert log.backend_compiles == 0, "warm cache must make compile=0"
    replan_s = info["breakdown"]["replan"]
    row = {"warm_s": warm_s, "replan_s": replan_s,
           "copy_exec_s": recover_s - replan_s,
           "first_step_s": first_step_s, "compiles": log.backend_compiles,
           "modeled_transfer_s": info["transfer"]["seconds"],
           "copied_bytes": info["copied_bytes"]}
    csv.add("recovery,real,5nodes,kill1",
            (recover_s + first_step_s) * 1e6,
            f"replan={replan_s:.4f}s|copy_exec={row['copy_exec_s']:.3f}s"
            f"|first_step={first_step_s:.3f}s|compiles=0")
    results["real"] = row


def multihost_run(csv: Csv, results: dict, procs: int) -> None:
    """SIGKILL-a-worker recovery latency through the multi-process
    backend (runtime/multihost.py): wall-clocks heartbeat detection,
    the two-phase agreed replan, the cross-process state pulls, and the
    first post-recovery step, asserting zero XLA recompiles on every
    survivor."""
    from repro.data import GlobalBatchDispenser, SyntheticLM
    from repro.launch.train import _multiproc_hosting
    from repro.runtime.multihost import MultiHostExecutor, make_job_spec

    nodes = [f"n{i}" for i in range(5)]
    spec = make_job_spec(arch="gpt3_medium", layers=4, seq_len=32,
                         microbatch=2, global_batch=16, f=1, n0=2,
                         nodes=nodes, nodes_per_pod=4,
                         hosting=_multiproc_hosting(nodes, procs),
                         procs=procs, seed=0)
    import repro.configs as _configs
    vocab = _configs.reduced(_configs.get_arch("gpt3_medium"),
                             layers=4).vocab_size
    disp = GlobalBatchDispenser(SyntheticLM(vocab, 32, seed=1))

    def microbatches(batch):
        return [{k: v[i * 2:(i + 1) * 2] for k, v in batch.items()
                 if not k.startswith("_")}
                for i in range(batch["tokens"].shape[0] // 2)]

    with MultiHostExecutor(spec) as mh:
        t0 = time.perf_counter()
        mh.warm_templates()
        warm_s = time.perf_counter() - t0

        def drive():
            batches = disp.next_step(mh.engine.batch.minibatch_sizes())
            return mh.step([microbatches(b) for b in batches])

        drive()
        mh.mark_compiles()          # steady state: glue ops traced
        victim = max(mh.procs)
        t0 = time.perf_counter()
        mh.kill_worker(victim)
        dead, _ = mh.detected_dead(timeout=30.0)
        detect_s = time.perf_counter() - t0
        assert dead, "heartbeat channel must surface the SIGKILL"
        t0 = time.perf_counter()
        info = mh.recover(dead)
        recover_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        drive()
        first_step_s = time.perf_counter() - t0
        compiles = mh.compile_counts()
        assert all(v == 0 for v in compiles.values()), \
            f"warm cache must make the compile leg 0, got {compiles}"
        bd = info["breakdown"]
        row = {"procs": procs, "warm_s": warm_s, "detect_s": detect_s,
               "recover_s": recover_s, "first_step_s": first_step_s,
               "replan_s": bd["replan"], "transfer_s": bd["transfer"],
               "compile_s": bd["compile"], "barrier_s": bd["barrier"],
               "commit_s": bd["commit"],
               "fetched_bytes": info["fetched_bytes"],
               "fetches": info["fetches"], "epoch": info["epoch"],
               "survivor_compiles": sum(compiles.values())}
        csv.add(f"recovery,multihost,procs={procs},sigkill1",
                (detect_s + recover_s + first_step_s) * 1e6,
                f"detect={detect_s:.2f}s|replan={bd['replan']:.3f}s"
                f"|transfer={bd['transfer']:.3f}s|commit={bd['commit']:.3f}s"
                f"|barrier={bd['barrier']:.3f}s|first_step={first_step_s:.3f}s"
                f"|fetched={info['fetched_bytes'] / 1e6:.1f}MB|compiles=0")
        results["multihost"] = row


def main(csv=None, argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="*", default=[16, 32, 64])
    ap.add_argument("--layers", type=int, default=26)
    ap.add_argument("--real", action="store_true",
                    help="also run the small real-arrays measurement")
    ap.add_argument("--procs", type=int, default=0,
                    help="also run the SIGKILL-a-worker measurement "
                         "through the multi-process backend with N "
                         "worker processes")
    ap.add_argument("--json", default="")
    args = ap.parse_args(argv)

    csv = csv or Csv()
    results: dict = {}
    profile = _profile(args.layers)
    for n in args.sizes:
        one_failure(csv, profile, n, nodes_per_pod=8, scenario="single",
                    results=results)
        one_failure(csv, profile, n, nodes_per_pod=8, scenario="rack",
                    results=results)
        # pathological: every node its own pod -> every copy rides DCN
        one_failure(csv, profile, n, nodes_per_pod=1, scenario="cross_pod",
                    results=results)
    if args.real:
        real_run(csv, results)
    if args.procs:
        multihost_run(csv, results, args.procs)

    # headline checks the acceptance criteria name
    for n in args.sizes:
        local = results[f"recovery,n={n},pods=8,single"]
        cross = results[f"recovery,n={n},pods=1,cross_pod"]
        assert cross["transfer_s"] > local["transfer_s"], \
            "pod-local copies must be cheaper than cross-pod"
        if local["streams"] > 1:
            assert local["transfer_s"] < local["serial_s"], \
                "max-over-streams must beat the serial sum"
    if args.json:
        import os
        os.makedirs(os.path.dirname(args.json) or ".", exist_ok=True)
        with open(args.json, "w") as f:
            json.dump(results, f, indent=2)
    return results


if __name__ == "__main__":
    main()
