"""Paper Table 2: throughput (samples/s) under controlled failure
frequencies (6h / 1h / 10m), 30 -> 15 nodes monotonic, for Bamboo /
Varuna / Oobleck across the five Table-1 models."""
from __future__ import annotations

from benchmarks.common import (FAULT_TOLERANCE, FREQS, NUM_NODES, TABLE1,
                               Csv, profile_for, timed)
from repro.sim import (BambooPolicy, OobleckPolicy, VarunaPolicy,
                       controlled_failures, run_sim)

MAX_STAGES = 12


def main(csv: Csv | None = None) -> None:
    csv = csv or Csv()
    nodes = [f"n{i}" for i in range(NUM_NODES)]
    for model, (gb, mb, bamboo_mb, seq) in TABLE1.items():
        prof = profile_for(model, mb)
        bprof = profile_for(model, bamboo_mb) if bamboo_mb else prof
        for label, interval in FREQS.items():
            trace = controlled_failures(nodes, interval, stop_at=NUM_NODES // 2)
            horizon = interval * (NUM_NODES // 2 + 2)
            for mk in (
                lambda: OobleckPolicy(prof, nodes, f=FAULT_TOLERANCE,
                                      global_batch=gb, microbatch=mb,
                                      max_stages=MAX_STAGES),
                lambda: VarunaPolicy(prof, nodes, global_batch=gb,
                                     microbatch=mb, max_stages=MAX_STAGES),
                lambda: BambooPolicy(bprof, nodes, global_batch=gb,
                                     microbatch=bamboo_mb or mb,
                                     max_stages=MAX_STAGES),
            ):
                def cell():
                    pol = mk()
                    if bamboo_mb is None and pol.name == "bamboo":
                        return pol.name, "OOM"
                    res = run_sim(pol, trace, horizon, gb,
                                  min_nodes=NUM_NODES // 2)
                    if res.stopped_reason == "OOM":
                        return pol.name, "OOM"
                    return pol.name, f"{res.throughput:.2f}"
                (name, derived), us = timed(cell)
                csv.add(f"table2/{model}/{label}/{name}", us, derived)


if __name__ == "__main__":
    main()
