"""Planner + reconfiguration scaling to hundred-node clusters.

Not a paper table — the paper's evaluation stops at 30 nodes (Table 3
plans at most 13x8 GPUs).  This suite tracks the two latencies that
matter for resilience at scale:

  * ``scale/plan_all/n{N}/{mode}``   — wall-clock to plan the FULL
    consecutive template set for an N-node cluster (the §4.1 offline
    phase: what a job pays once at submission).  ``fast`` is the
    vectorized DP, ``peel`` the dominance-pruned scalar recursion.
  * ``scale/bootstrap/n{N}``         — engine construction end-to-end
    (node spec + templates + instantiation + batch planning).
  * ``scale/reconfig/n{N}/...``      — wall-clock of the reconfiguration
    decision (template lookup + borrow/merge + copy plan + batch
    redistribution) for a correlated rack burst and a preemption wave,
    plus the estimated downtime seconds from the copy plan (derived
    column) — the §5 claim that recovery stays instant at any size.

The acceptance bar tracked by tests/test_planner_fast.py: the 128-node
template set must plan in under 30 s.
"""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import Csv, timed
from repro.configs import get_arch
from repro.core import (EngineConfig, OobleckEngine, PipelinePlanner,
                        build_profile, generate_node_spec)

CLUSTERS = (16, 32, 64, 128)
RACK = 8                     # nodes per failure domain
LAYERS = 130                 # blocks; profile adds embed + head


def profile_with_layers(layers: int):
    arch = dataclasses.replace(get_arch("gpt2"), name=f"gpt2_L{layers}",
                               num_layers=layers)
    return build_profile(arch, microbatch=2, seq_len=1024)


def main(csv: Csv | None = None) -> None:
    csv = csv or Csv()
    prof = profile_with_layers(LAYERS)

    for n in CLUSTERS:
        spec = generate_node_spec(N=n, f=1, n0=4, max_size=prof.num_layers)
        for mode in ("peel", "fast"):
            planner = PipelinePlanner(prof, gpus_per_node=1, mode=mode)
            _, us = timed(lambda: planner.plan_all(spec.sizes))
            csv.add(f"scale/plan_all/n{n}/{mode}", us,
                    f"{us / 1e6:.3f}s/{len(spec.sizes)}tpl")

        nodes = [f"n{i}" for i in range(n)]
        t0 = time.perf_counter()
        eng = OobleckEngine(prof, nodes, EngineConfig(
            fault_tolerance=1, global_batch=4096, microbatch=2,
            gpus_per_node=1, n0_override=4))
        csv.add(f"scale/bootstrap/n{n}",
                (time.perf_counter() - t0) * 1e6,
                f"{eng.metrics.planning_seconds:.3f}s")

        # correlated rack burst: one failure domain dies at once
        rack = set(nodes[:min(RACK, n // 4)])
        result, us = timed(lambda: eng.handle_failure(set(rack)))
        csv.add(f"scale/reconfig/n{n}/rack{len(rack)}", us,
                f"{eng.reconfiguration_seconds(result):.2f}s_downtime")

        # preemption wave: 10% of the survivors vanish together
        wave = set(eng.nodes[:: max(1, len(eng.nodes) // max(1, n // 10))]
                   [:n // 10])
        if wave:
            result, us = timed(lambda: eng.handle_failure(set(wave)))
            csv.add(f"scale/reconfig/n{n}/wave{len(wave)}", us,
                    f"{eng.reconfiguration_seconds(result):.2f}s_downtime")

        # capacity returns: the rack is repaired and rejoins
        result, us = timed(lambda: eng.handle_join(sorted(rack)))
        csv.add(f"scale/rejoin/n{n}/{len(rack)}", us,
                f"{eng.reconfiguration_seconds(result):.2f}s_downtime")

    # multi-GPU nodes: the (s, k, m) scan explodes for the scalar DP —
    # this is where the vectorized rows pay off hardest
    prof4 = profile_with_layers(64)
    for n in (8, 16):
        for mode in ("peel", "fast"):
            planner = PipelinePlanner(prof4, gpus_per_node=4, mode=mode)
            _, us = timed(lambda: planner.plan(n))
            csv.add(f"scale/plan_multigpu/n{n}/g4/{mode}", us,
                    f"{us / 1e6:.3f}s")


if __name__ == "__main__":
    main()
