"""Layer-granular checkpointing with async snapshot and atomic manifest.

The checkpoint unit is one LAYER's state (params + both Adam moments) —
the same unit Oobleck copies between replicas during reconfiguration, so
the restart path (used only when < (f+1)*n0 nodes remain, §3.4) and the
live-copy path share a format.

Layout:
    <dir>/step_<N>/layer_<i>.npz      one record per model layer
    <dir>/step_<N>/extra.npz          embed/head/final-norm + opt scalars
    <dir>/step_<N>/MANIFEST.json      written LAST via atomic rename;
                                      a step without a manifest is garbage
Async mode snapshots arrays on the caller thread (cheap host copy) and
writes on a daemon thread — training resumes immediately, matching the
CheckFreq-style overlap discussed in §7.4.3.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        key = prefix + jax.tree_util.keystr(path)
        out[key] = np.asarray(leaf)
    return out


@dataclasses.dataclass
class TrainState:
    step: int
    params: Any
    opt_state: Any
    data_state: Dict
    rng_seed: int


class CheckpointManager:
    def __init__(self, directory: str, num_layers: int,
                 async_mode: bool = True, keep: int = 2):
        self.dir = directory
        self.num_layers = num_layers
        self.async_mode = async_mode
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, state: TrainState, block: bool = False) -> None:
        # Snapshot to host numpy NOW (consistent view), write async.
        payload = self._snapshot(state)
        if self.async_mode and not block:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(payload,), daemon=True)
            self._thread.start()
        else:
            self._write(payload)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _snapshot(self, state: TrainState) -> Dict:
        params, opt = state.params, state.opt_state
        layers: List[Dict[str, np.ndarray]] = []
        blocks = params["blocks"]
        m_blocks = opt.m["blocks"]
        v_blocks = opt.v["blocks"]
        for i in range(self.num_layers):
            rec: Dict[str, np.ndarray] = {}
            rec.update(_flatten(jax.tree.map(lambda t: t[i], blocks), "p"))
            rec.update(_flatten(jax.tree.map(lambda t: t[i], m_blocks), "m"))
            rec.update(_flatten(jax.tree.map(lambda t: t[i], v_blocks), "v"))
            layers.append(rec)
        extra: Dict[str, np.ndarray] = {}
        for part in ("embed", "final_norm", "head"):
            if part in params:
                extra.update(_flatten(params[part], f"p/{part}"))
                extra.update(_flatten(opt.m[part], f"m/{part}"))
                extra.update(_flatten(opt.v[part], f"v/{part}"))
        extra["opt_step"] = np.asarray(opt.step)
        return {
            "step": state.step,
            "layers": layers,
            "extra": extra,
            "meta": {"step": state.step, "num_layers": self.num_layers,
                     "data_state": state.data_state,
                     "rng_seed": state.rng_seed},
        }

    def _write(self, payload: Dict) -> None:
        step = payload["step"]
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
        try:
            for i, rec in enumerate(payload["layers"]):
                np.savez(os.path.join(tmp, f"layer_{i:04d}.npz"), **rec)
            np.savez(os.path.join(tmp, "extra.npz"), **payload["extra"])
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump(payload["meta"], f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        finally:
            if os.path.exists(tmp):
                shutil.rmtree(tmp, ignore_errors=True)
        self._gc()

    def _gc(self) -> None:
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def list_steps(self) -> List[int]:
        out = []
        for name in sorted(os.listdir(self.dir)):
            full = os.path.join(self.dir, name)
            if (name.startswith("step_")
                    and os.path.exists(os.path.join(full, "MANIFEST.json"))):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def restore(self, template_params: Any, template_opt: Any,
                step: Optional[int] = None) -> TrainState:
        """Restore into the structure of (template_params, template_opt)."""
        steps = self.list_steps()
        if not steps:
            raise FileNotFoundError(f"no complete checkpoint in {self.dir}")
        step = steps[-1] if step is None else step
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "MANIFEST.json")) as f:
            meta = json.load(f)

        def load_into(tree, record, prefix):
            flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
            leaves = []
            for path, leaf in flat:
                key = prefix + jax.tree_util.keystr(path)
                arr = record[key]
                assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
                leaves.append(arr.astype(leaf.dtype))
            return jax.tree_util.tree_unflatten(treedef, leaves)

        blocks_t = jax.tree.map(lambda t: t[0], template_params["blocks"])
        p_layers, m_layers, v_layers = [], [], []
        for i in range(meta["num_layers"]):
            rec = dict(np.load(os.path.join(d, f"layer_{i:04d}.npz")))
            p_layers.append(load_into(blocks_t, rec, "p"))
            m_layers.append(load_into(blocks_t, rec, "m"))
            v_layers.append(load_into(blocks_t, rec, "v"))
        stack = lambda layers: jax.tree.map(lambda *xs: np.stack(xs), *layers)
        extra = dict(np.load(os.path.join(d, "extra.npz")))
        params = {"blocks": stack(p_layers)}
        m = {"blocks": stack(m_layers)}
        v = {"blocks": stack(v_layers)}
        for part in ("embed", "final_norm", "head"):
            if part in template_params:
                params[part] = load_into(template_params[part], extra, f"p/{part}")
                m[part] = load_into(template_params[part], extra, f"m/{part}")
                v[part] = load_into(template_params[part], extra, f"v/{part}")
        opt = type(template_opt)(step=extra["opt_step"], m=m, v=v)
        return TrainState(step=meta["step"], params=params, opt_state=opt,
                          data_state=meta["data_state"],
                          rng_seed=meta["rng_seed"])
