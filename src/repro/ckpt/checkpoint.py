"""Async sharded checkpointing with content-addressed layer shards
(DESIGN.md §9).

The checkpoint unit is one LAYER's state (params + both Adam moments) —
the same unit Oobleck copies between replicas during reconfiguration, so
the restart path (used only when < (f+1)*n0 nodes remain, §3.4), the
live-copy data plane (runtime/transfer.py) and the storage format all
share a granularity.

Layout:
    <dir>/shards/<hash>.npz           content-addressed layer records
    <dir>/step_<N>/MANIFEST.json      layer index -> shard hash + sizes,
                                      written LAST via atomic rename; a
                                      step without a manifest is garbage

Properties:

  * **content hashes** — a shard's name is the sha256 of its arrays
    (keys, dtypes, shapes, bytes), so identical layer states are stored
    once no matter how many steps reference them;
  * **incremental saves** — a layer whose hash is already on disk is
    skipped entirely (``stats["skipped_shards"]``); only changed state
    pays write bandwidth;
  * **async** — ``save()`` snapshots arrays to host numpy on the caller
    thread (a consistent view) and enqueues the write to ONE daemon
    writer thread; training resumes immediately and never waits for a
    previous save (the CheckFreq-style overlap of §7.4.3);
  * **safe GC** — garbage collection runs under the manager lock and
    pins every hash of queued/in-flight saves, so a background save can
    never lose a shard it is about to reference (the race the old
    per-step layout had: GC deleting the step still being written);
  * **layout-independent restore** — manifests know layers, not
    templates; ``restore`` reassembles the canonical stacked-block tree
    for ANY template set to rebind against, and ``layer_record`` serves
    single layers (the granularity a partially-restored pipeline needs).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import queue
import shutil
import tempfile
import threading
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        key = prefix + jax.tree_util.keystr(path)
        out[key] = np.asarray(leaf)
    return out


def record_hash(rec: Dict[str, np.ndarray]) -> str:
    """Content hash of one shard: keys, dtypes, shapes and raw bytes.
    (Hashing the LOGICAL content, not the .npz file — zip containers
    embed timestamps and are not byte-stable.)"""
    h = hashlib.sha256()
    for key in sorted(rec):
        a = np.ascontiguousarray(rec[key])
        h.update(key.encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    return h.hexdigest()[:32]


def record_nbytes(rec: Dict[str, np.ndarray]) -> int:
    return sum(int(a.nbytes) for a in rec.values())


def _save_npz(path: str, rec: Dict[str, np.ndarray]) -> None:
    """Single seam for shard writes (tests hook it to stall the writer
    mid-save and prove GC cannot hurt an in-flight step)."""
    np.savez(path, **rec)


def _save_manifest(path: str, meta: Dict) -> None:
    """Seam for the manifest write — the other half of the GC race
    window: shards durable, manifest not yet visible."""
    with open(path, "w") as f:
        json.dump(meta, f)


@dataclasses.dataclass
class TrainState:
    step: int
    params: Any
    opt_state: Any
    data_state: Dict
    rng_seed: int


class CheckpointError(RuntimeError):
    """A background save failed; surfaced on wait()/the next save."""


def elect_writer(live_ids) -> str:
    """Deterministic manifest-writer election for multi-process saves:
    every process computes the same winner from the same live set (the
    coordinator's heartbeat view), so exactly one process commits the
    per-step MANIFEST while all of them write content-addressed shards.
    Lowest id wins — stable across calls, no communication needed."""
    ids = sorted(live_ids)
    if not ids:
        raise ValueError("no live processes to elect a writer from")
    return ids[0]


class CheckpointManager:
    def __init__(self, directory: str, num_layers: int,
                 async_mode: bool = True, keep: int = 2,
                 process_id: str = "proc0", manifest_writer: bool = True):
        self.dir = directory
        self.num_layers = num_layers
        self.async_mode = async_mode
        self.keep = keep
        # multi-process safety (DESIGN.md §15): every process may write
        # shards (content-addressed, so concurrent identical writes are
        # idempotent) but only the ELECTED writer commits the per-step
        # MANIFEST and runs gc — a non-writer's gc could otherwise
        # delete shards of a step whose manifest hasn't landed yet.
        self.process_id = process_id
        self.manifest_writer = manifest_writer
        self.stats: Dict[str, int] = {"saves": 0, "saved_shards": 0,
                                      "skipped_shards": 0, "gc_shards": 0,
                                      "gc_steps": 0, "manifest_races": 0,
                                      "manifests_skipped": 0}
        self._lock = threading.Lock()
        self._pinned: Dict[str, int] = {}      # hash -> pending refcount
        # bounded: each payload is a full host snapshot, so backpressure
        # kicks in only when storage falls 2 saves behind (the old
        # manager blocked on EVERY save; unbounded would risk host OOM)
        self._queue: "queue.Queue[Dict]" = queue.Queue(maxsize=2)
        self._worker: Optional[threading.Thread] = None
        self._errors: List[BaseException] = []
        os.makedirs(self.shard_dir, exist_ok=True)

    @property
    def shard_dir(self) -> str:
        return os.path.join(self.dir, "shards")

    def _shard_path(self, h: str) -> str:
        return os.path.join(self.shard_dir, f"{h}.npz")

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    # ------------------------------------------------------------------
    # Save
    # ------------------------------------------------------------------
    def save(self, state: TrainState, block: bool = False) -> None:
        """Snapshot to host numpy NOW (consistent view), hash each layer
        shard, and hand the write to the background thread — the caller
        never waits for a previous save to finish."""
        self._raise_pending_errors()
        payload = self._snapshot(state)
        self.stats["saves"] += 1
        if self.async_mode and not block:
            with self._lock:
                for h, _ in payload["shards"]:
                    self._pinned[h] = self._pinned.get(h, 0) + 1
            self._ensure_worker()
            self._queue.put(payload)
        else:
            self.wait()                 # keep manifest order monotonic
            self._write(payload)

    def wait(self) -> None:
        """Block until every queued save is durable; re-raise background
        failures."""
        if self._worker is not None:
            self._queue.join()
        self._raise_pending_errors()

    def _raise_pending_errors(self) -> None:
        with self._lock:
            errors, self._errors = self._errors, []
        if errors:
            raise CheckpointError(
                f"async checkpoint save failed: {errors[0]!r}") from errors[0]

    def _ensure_worker(self) -> None:
        if self._worker is None or not self._worker.is_alive():
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    def _drain(self) -> None:
        while True:
            payload = self._queue.get()
            try:
                self._write(payload)
            except BaseException as e:      # surfaced on wait()/next save
                with self._lock:
                    self._errors.append(e)
            finally:
                if payload.get("pinned"):
                    with self._lock:
                        for h, _ in payload["shards"]:
                            n = self._pinned.get(h, 0) - 1
                            if n <= 0:
                                self._pinned.pop(h, None)
                            else:
                                self._pinned[h] = n
                self._queue.task_done()

    # ------------------------------------------------------------------
    def _snapshot(self, state: TrainState) -> Dict:
        params, opt = state.params, state.opt_state
        blocks = params["blocks"]
        m_blocks = opt.m["blocks"]
        v_blocks = opt.v["blocks"]
        layer_entries: List[Dict] = []
        shards: List[Tuple[str, Dict[str, np.ndarray]]] = []
        seen: Set[str] = set()

        def add(rec: Dict[str, np.ndarray]) -> Dict:
            h = record_hash(rec)
            if h not in seen:
                seen.add(h)
                shards.append((h, rec))
            return {"hash": h, "nbytes": record_nbytes(rec)}

        for i in range(self.num_layers):
            rec: Dict[str, np.ndarray] = {}
            rec.update(_flatten(jax.tree.map(lambda t: t[i], blocks), "p"))
            rec.update(_flatten(jax.tree.map(lambda t: t[i], m_blocks), "m"))
            rec.update(_flatten(jax.tree.map(lambda t: t[i], v_blocks), "v"))
            layer_entries.append(add(rec))
        extra: Dict[str, np.ndarray] = {}
        for part in ("embed", "final_norm", "head"):
            if part in params:
                extra.update(_flatten(params[part], f"p/{part}"))
                extra.update(_flatten(opt.m[part], f"m/{part}"))
                extra.update(_flatten(opt.v[part], f"v/{part}"))
        extra["opt_step"] = np.asarray(opt.step)
        return {
            "step": state.step,
            "shards": shards,
            "pinned": True,
            "meta": {"step": state.step, "num_layers": self.num_layers,
                     "data_state": state.data_state,
                     "rng_seed": state.rng_seed,
                     "layers": layer_entries,
                     "extra": add(extra)},
        }

    def _write(self, payload: Dict) -> None:
        # 1. shards (content-addressed: existing hash == incremental skip)
        for h, rec in payload["shards"]:
            final = self._shard_path(h)
            if os.path.exists(final):
                self.stats["skipped_shards"] += 1
                continue
            fd, tmp = tempfile.mkstemp(dir=self.shard_dir, prefix=".tmp_",
                                       suffix=".npz")
            os.close(fd)
            try:
                _save_npz(tmp, rec)
                os.replace(tmp, final)
            finally:
                if os.path.exists(tmp):
                    os.remove(tmp)
            self.stats["saved_shards"] += 1
        # 2. manifest, LAST, via atomic rename of the step dir — writer
        # only; shard-only processes stop here (their bytes are already
        # durable and content-addressed, the writer's manifest will
        # reference them)
        if not self.manifest_writer:
            self.stats["manifests_skipped"] += 1
            return
        step = payload["step"]
        tmp = tempfile.mkdtemp(dir=self.dir, prefix=".tmp_")
        try:
            _save_manifest(os.path.join(tmp, "MANIFEST.json"),
                           payload["meta"])
            final = self._step_dir(step)
            with self._lock:
                if os.path.exists(final):
                    shutil.rmtree(final)
                try:
                    os.rename(tmp, final)
                except OSError:
                    # ANOTHER PROCESS committed this step between our
                    # exists-check and rename (two elected writers can
                    # only race transiently, during a membership change).
                    # Content-addressing makes the outcome identical
                    # either way: verify theirs and count the race.
                    if not os.path.exists(
                            os.path.join(final, "MANIFEST.json")):
                        raise
                    self.stats["manifest_races"] += 1
        finally:
            if os.path.exists(tmp):
                shutil.rmtree(tmp, ignore_errors=True)
        self.gc()

    # ------------------------------------------------------------------
    # GC: never touches a shard an in-flight save references
    # ------------------------------------------------------------------
    def gc(self) -> None:
        with self._lock:
            steps = self._list_steps_locked()
            drop, kept = steps[:-self.keep], steps[-self.keep:]
            referenced: Set[str] = set(self._pinned)
            for s in kept:
                meta = self._read_manifest(s)
                referenced.update(e["hash"] for e in meta["layers"])
                referenced.add(meta["extra"]["hash"])
            for s in drop:
                shutil.rmtree(self._step_dir(s), ignore_errors=True)
                self.stats["gc_steps"] += 1
            for name in os.listdir(self.shard_dir):
                if not name.endswith(".npz") or name.startswith(".tmp_"):
                    continue
                if name[:-len(".npz")] not in referenced:
                    try:
                        os.remove(os.path.join(self.shard_dir, name))
                        self.stats["gc_shards"] += 1
                    except OSError:
                        pass

    # kept under its historical name for callers/tests
    _gc = gc

    # ------------------------------------------------------------------
    # Read side
    # ------------------------------------------------------------------
    def _list_steps_locked(self) -> List[int]:
        out = []
        for name in sorted(os.listdir(self.dir)):
            full = os.path.join(self.dir, name)
            if (name.startswith("step_")
                    and os.path.exists(os.path.join(full, "MANIFEST.json"))):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def list_steps(self) -> List[int]:
        return self._list_steps_locked()

    def _read_manifest(self, step: int) -> Dict:
        with open(os.path.join(self._step_dir(step), "MANIFEST.json")) as f:
            return json.load(f)

    def _load_shard(self, h: str) -> Dict[str, np.ndarray]:
        return dict(np.load(self._shard_path(h)))

    def layer_record(self, step: int, layer: int) -> Dict[str, np.ndarray]:
        """One layer's flat state record ('p...'/'m...'/'v...' keys) —
        the same unit the recovery data plane moves between replicas."""
        meta = self._read_manifest(step)
        return self._load_shard(meta["layers"][layer]["hash"])

    def verify(self, step: int) -> bool:
        """Recompute every referenced shard's content hash: True iff the
        step is bit-exact on disk (fault-injection suites assert this —
        an interrupted/concurrent save must never leave a listed step
        corrupt)."""
        try:
            meta = self._read_manifest(step)
            hashes = [e["hash"] for e in meta["layers"]]
            hashes.append(meta["extra"]["hash"])
            return all(record_hash(self._load_shard(h)) == h for h in hashes)
        except Exception:
            # the contract is "False on ANY corruption": a truncated
            # .npz raises BadZipFile/EOFError, a mangled manifest
            # JSONDecodeError — none of them may escape
            return False

    def restore(self, template_params: Any, template_opt: Any,
                step: Optional[int] = None) -> TrainState:
        """Restore into the structure of (template_params, template_opt).

        The manifest indexes layers, not pipeline templates: the same
        checkpoint restores under ANY template layout (different node
        counts, stage tilings) — the caller rebinds the result against
        whatever template set the current cluster supports."""
        steps = self.list_steps()
        if not steps:
            raise FileNotFoundError(f"no complete checkpoint in {self.dir}")
        step = steps[-1] if step is None else step
        meta = self._read_manifest(step)

        def load_into(tree, record, prefix):
            flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
            leaves = []
            for path, leaf in flat:
                key = prefix + jax.tree_util.keystr(path)
                arr = record[key]
                assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
                leaves.append(arr.astype(leaf.dtype))
            return jax.tree_util.tree_unflatten(treedef, leaves)

        blocks_t = jax.tree.map(lambda t: t[0], template_params["blocks"])
        p_layers, m_layers, v_layers = [], [], []
        for i in range(meta["num_layers"]):
            rec = self._load_shard(meta["layers"][i]["hash"])
            p_layers.append(load_into(blocks_t, rec, "p"))
            m_layers.append(load_into(blocks_t, rec, "m"))
            v_layers.append(load_into(blocks_t, rec, "v"))
        stack = lambda layers: jax.tree.map(lambda *xs: np.stack(xs), *layers)
        extra = self._load_shard(meta["extra"]["hash"])
        params = {"blocks": stack(p_layers)}
        m = {"blocks": stack(m_layers)}
        v = {"blocks": stack(v_layers)}
        for part in ("embed", "final_norm", "head"):
            if part in template_params:
                params[part] = load_into(template_params[part], extra, f"p/{part}")
                m[part] = load_into(template_params[part], extra, f"m/{part}")
                v[part] = load_into(template_params[part], extra, f"v/{part}")
        opt = type(template_opt)(step=extra["opt_step"], m=m, v=v)
        return TrainState(step=meta["step"], params=params, opt_state=opt,
                          data_state=meta["data_state"],
                          rng_seed=meta["rng_seed"])
