from repro.ckpt.checkpoint import (CheckpointError, CheckpointManager,
                                   TrainState, elect_writer, record_hash)

__all__ = ["CheckpointError", "CheckpointManager", "TrainState",
           "elect_writer", "record_hash"]
