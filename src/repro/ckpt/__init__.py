from repro.ckpt.checkpoint import (CheckpointError, CheckpointManager,
                                   TrainState, record_hash)

__all__ = ["CheckpointError", "CheckpointManager", "TrainState",
           "record_hash"]
