from repro.ckpt.checkpoint import CheckpointManager, TrainState

__all__ = ["CheckpointManager", "TrainState"]
