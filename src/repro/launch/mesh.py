"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips over
("data", "model"); multi-pod: 2x16x16 = 512 chips with the extra "pod"
axis as an outer data-parallel dimension (pipeline-replica groups per
pod; cross-pod traffic is the layer-bucket gradient sync, which rides
DCN — see DESIGN.md §5).

``make_mesh_compat``/``cost_analysis_dict`` absorb JAX API drift: the
``axis_types=`` kwarg (jax.sharding.AxisType) and the dict-valued
``Compiled.cost_analysis()`` only exist on newer JAX; on the installed
floor we construct the mesh without axis types (Auto is the default
behaviour there anyway) and unwrap the legacy one-element list.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax


def make_mesh_compat(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """jax.make_mesh with explicit Auto axis types when the installed
    JAX has them (>= 0.5), plain construction otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``Compiled.cost_analysis()`` as a dict on every supported JAX
    (older releases return a one-element list of dicts)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def data_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def mesh_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
