"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips over
("data", "model"); multi-pod: 2x16x16 = 512 chips with the extra "pod"
axis as an outer data-parallel dimension (pipeline-replica groups per
pod; cross-pod traffic is the layer-bucket gradient sync, which rides
DCN — see DESIGN.md §5).
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def data_axes(multi_pod: bool) -> Tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


def mesh_chips(mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
