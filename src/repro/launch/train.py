"""End-to-end resilient training driver (deliverable b).

Runs REAL training (forward/backward/optimizer on actual arrays) through
the Oobleck stack: planner -> templates -> heterogeneous pipeline
instances -> compiled per-template step programs -> layer-granular sync
-> AdamW, with failure injection, recovery-from-replicas,
checkpointing, and restart.  The runtime sits behind the Executor
interface (runtime/executor.py): training steps are cached-program
calls, reconfiguration swaps programs by cache lookup, and checkpoint
hooks go through ``Executor.snapshot()``.

Container-friendly: uses a reduced config by default (--full to use the
exact assigned config — sized for the production mesh, not a CPU).

    PYTHONPATH=src python -m repro.launch.train \
        --arch glm4-9b --nodes 5 --f 1 --steps 6 --kill-at 3
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs import get_arch, reduced
from repro.core import EngineConfig, OobleckEngine, build_profile
from repro.data import ByteCorpus, GlobalBatchDispenser, SyntheticLM

_TEXT = (b"Oobleck enables resilient distributed training of large models "
         b"with guaranteed fault tolerance using pipeline templates. "
         b"It instantiates f+1 logically equivalent heterogeneous pipeline "
         b"replicas and recovers from failures by copying model states "
         b"from surviving replicas instead of restarting from checkpoints. ")
from repro.models import Model
from repro.optim import adamw
from repro.runtime import HeteroTrainer


def microbatches(batch, mb_size):
    n = batch["tokens"].shape[0] // mb_size
    return [{k: v[i * mb_size:(i + 1) * mb_size] for k, v in batch.items()
             if not k.startswith("_")} for i in range(n)]


def _multiproc_hosting(nodes, procs):
    """node -> worker rank.  The LAST rank hosts exactly one node, so
    killing it (--kill-at) drops one node — the smallest failure a
    process death can model — and leaves the survivors above the
    (f+1)*n0 floor in the default 5-node/f=1 setup."""
    ranks = list(range(procs))
    host = {nodes[-1]: ranks[-1]}
    rest = nodes[:-1]
    per = -(-len(rest) // max(1, procs - 1)) if procs > 1 else len(rest)
    for i, n in enumerate(rest):
        host[n] = min(i // per, procs - 2) if procs > 1 else 0
    return host


def run_multiproc(args) -> dict:
    """--procs N: the same training loop through the multi-process
    backend (runtime/multihost.py) — coordinator here, N spawned worker
    processes execute; --kill-at SIGKILLs a worker and recovery runs
    from heartbeat detection, not an injected event."""
    from repro.runtime.multihost import MultiHostExecutor, make_job_spec

    nodes = [f"node{i}" for i in range(args.nodes)]
    spec = make_job_spec(
        arch=args.arch, layers=args.layers, seq_len=args.seq_len,
        microbatch=args.microbatch, global_batch=args.global_batch,
        f=args.f, n0=args.n0, nodes=nodes, nodes_per_pod=args.pods,
        hosting=_multiproc_hosting(nodes, args.procs), procs=args.procs,
        seed=args.seed,
        opt={"lr": 3e-3, "warmup_steps": 0, "weight_decay": 0.0})
    source = ByteCorpus(_TEXT * 50, seq_len=args.seq_len)
    disp = GlobalBatchDispenser(source)
    losses = []
    with MultiHostExecutor(spec) as mh:
        engine = mh.engine
        print(f"[plan] procs={args.procs} hosting={mh.hosting} "
              f"pipelines={[i.template.num_nodes for i in engine.instances]}")
        t0 = time.perf_counter()
        mh.warm_templates()
        print(f"[warm] all workers warm in {time.perf_counter() - t0:.1f}s")
        for step in range(args.steps):
            if step == args.kill_at:
                victim = max(mh.procs)
                mh.kill_worker(victim)
                dead, ranks = mh.detected_dead(timeout=30.0)
                t0 = time.perf_counter()
                info = mh.recover(dead)
                bd = info["breakdown"]
                print(f"[fail] SIGKILL rank {victim} -> heartbeat detected "
                      f"{sorted(dead)} dead; recovered in "
                      f"{time.perf_counter() - t0:.2f}s (epoch "
                      f"{info['epoch']}, {info['fetched_bytes'] / 1e6:.1f}MB "
                      f"pulled cross-process in {info['fetches']} fetches, "
                      f"replan {bd['replan'] * 1e3:.0f}ms, commit "
                      f"{bd['commit'] * 1e3:.0f}ms)")
            batches = disp.next_step(engine.batch.minibatch_sizes())
            out = mh.step(
                [microbatches(b, args.microbatch) for b in batches])
            losses.append(float(out["loss"]))
            print(f"[step {step}] loss={losses[-1]:.4f} "
                  f"pipelines={out['num_pipelines']} "
                  f"divergence={mh.replica_divergence()}")
        compiles = mh.compile_counts()
        print(f"[done] loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
              f"worker compiles since warm: {compiles}")
    assert losses[-1] < losses[0], "training must reduce the loss"
    return {"losses": losses}


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt3-medium")
    ap.add_argument("--nodes", type=int, default=5)
    ap.add_argument("--f", type=int, default=1)
    ap.add_argument("--n0", type=int, default=2)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--microbatch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--pods", type=int, default=8,
                    help="nodes per pod for the recovery data plane "
                         "(intra-pod copies ride ICI, cross-pod DCN)")
    ap.add_argument("--kill-at", type=int, default=-1,
                    help="inject a node failure before this step")
    ap.add_argument("--join-at", type=int, default=-1)
    ap.add_argument("--recovery-policy", default="replan",
                    choices=["replan", "adapt", "auto"],
                    help="failure response: 'replan' reconfigures from "
                         "templates and copies state from replicas; "
                         "'adapt' re-routes the damaged replica's "
                         "microbatches to surviving peers (ReCycle-style, "
                         "zero copy, zero recompile); 'auto' picks per "
                         "event by predicted downtime")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--codec", default="none",
                    choices=["none", "bf16", "int8"],
                    help="wire codec for cross-replica gradient sync "
                         "(bucketed data plane, with per-bucket error "
                         "feedback; 'none' is bitwise-exact)")
    ap.add_argument("--eager", action="store_true",
                    help="use the eager reference path instead of the "
                         "compiled per-template program cache")
    ap.add_argument("--no-warm", action="store_true",
                    help="skip bootstrap warming of the full template set")
    ap.add_argument("--attn-impl", default="naive",
                    choices=["naive", "blocked", "kernel", "auto"],
                    help="attention path for stage layers; 'kernel' is "
                         "the Pallas fwd+bwd hot path, 'auto' selects it "
                         "wherever a compiled lowering exists")
    ap.add_argument("--ssd-impl", default="chunked",
                    choices=["chunked", "scan", "kernel", "auto"],
                    help="SSD path for Mamba2/hybrid stage layers")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--procs", type=int, default=0,
                    help="run through the multi-process backend with N "
                         "worker processes (runtime/multihost.py); "
                         "--kill-at then SIGKILLs a worker and recovery "
                         "runs from heartbeat detection")
    args = ap.parse_args(argv)

    if args.procs > 0:
        return run_multiproc(args)

    if args.eager and args.codec != "none":
        # the eager per-layer oracle has no wire codec; keep the engine's
        # pricing and the [sync] report consistent with what actually runs
        print(f"[sync] --eager ignores --codec {args.codec}: the per-layer "
              f"reference path syncs uncompressed")
        args.codec = "none"
    arch = get_arch(args.arch)
    if not args.full:
        arch = reduced(arch, layers=args.layers)
    model = Model(arch, dtype=jnp.float32, remat=False,
                  attn_impl=args.attn_impl, ssd_impl=args.ssd_impl,
                  scan_layers=False)
    params = model.init(jax.random.PRNGKey(args.seed))

    profile = build_profile(arch, microbatch=args.microbatch,
                            seq_len=args.seq_len)
    nodes = [f"node{i}" for i in range(args.nodes)]
    engine = OobleckEngine(profile, nodes, EngineConfig(
        fault_tolerance=args.f, global_batch=args.global_batch,
        microbatch=args.microbatch, gpus_per_node=1, n0_override=args.n0,
        nodes_per_pod=args.pods, codec=args.codec,
        recovery_policy=args.recovery_policy))
    print(f"[plan] templates={list(engine.templates)} "
          f"pipelines={[i.template.num_nodes for i in engine.instances]} "
          f"microbatches={engine.batch.num_microbatches}")
    sched = engine.sync_schedule()
    print(f"[sync] {len(sched)} buckets, codec={args.codec}, "
          f"wire={sum(r.wire_bytes for r in sched) / 1e6:.1f}MB, "
          f"modeled exposed tail {engine._sync_tail_seconds() * 1e3:.2f}ms "
          f"on target hw")

    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=0, weight_decay=0.0)
    trainer = HeteroTrainer(model, engine, params, opt_cfg,
                            mode="eager" if args.eager else "compiled",
                            codec=args.codec)
    if not args.eager and not args.no_warm:
        t0 = time.perf_counter()
        stats = trainer.warm_templates()
        print(f"[warm] {stats['compiles']} programs compiled for "
              f"{len(engine.templates)} templates in "
              f"{time.perf_counter() - t0:.1f}s — any reconfiguration now "
              f"swaps programs by lookup")
    source = ByteCorpus(_TEXT * 50, seq_len=args.seq_len)
    disp = GlobalBatchDispenser(source)
    mgr = None
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir, num_layers=arch.num_layers)
        # the engine checkpoints through the executor snapshot on an
        # unrecoverable shrink (< (f+1)*n0 nodes), §3.4
        engine.on_checkpoint = lambda: mgr.save(
            trainer.snapshot(disp.state(), args.seed), block=True)

    losses = []
    for step in range(args.steps):
        if step == args.kill_at:
            victim = engine.instances[0].nodes[-1]
            t0 = time.perf_counter()
            info = trainer.recover({victim})
            wall = time.perf_counter() - t0
            if info["policy"] == "adapt":
                bd = info["breakdown"]
                print(f"[fail] killed {victim}: adapted schedule in "
                      f"{wall:.2f}s (zero state copied, re-routed "
                      f"microbatches to {info['num_pipelines']} surviving "
                      f"pipelines, parked {info['parked_nodes']} as spares, "
                      f"modeled reroute exposure {bd['reroute'] * 1e3:.1f}ms "
                      f"on target hw, program cache: {info['cache']})")
            else:
                xfer = info["transfer"]
                print(f"[fail] killed {victim}: recovered from replicas in "
                      f"{wall:.2f}s ({info['policy']}; "
                      f"copied {info['copied_bytes'] / 1e6:.0f}MB of state over "
                      f"{xfer['streams']} streams, "
                      f"{xfer['pod_local_fraction']:.0%} pod-local, modeled "
                      f"transfer {xfer['seconds'] * 1e3:.1f}ms on target hw, "
                      f"program cache: {info['cache']}), "
                      f"pipelines={[i.template.num_nodes for i in engine.instances]}")
        if step == args.join_at:
            raise SystemExit("join-at requires the elastic example; see "
                             "examples/spot_trace_replay.py")
        batches = disp.next_step(engine.batch.minibatch_sizes())
        out = trainer.step(
            [microbatches(b, args.microbatch) for b in batches])
        losses.append(float(out["loss"]))        # host sync at step edge
        print(f"[step {step}] loss={losses[-1]:.4f} "
              f"pipelines={out['num_pipelines']} "
              f"divergence={trainer.replica_divergence():.2e}")
        if mgr and args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            mgr.save(trainer.snapshot(disp.state(), args.seed))
    if mgr:
        mgr.wait()
    assert losses[-1] < losses[0], "training must reduce the loss"
    print(f"[done] loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"(cache: {trainer.cache.stats.as_dict()})")
    return {"losses": losses}


if __name__ == "__main__":
    main()
