"""Optimized-HLO analysis for the roofline (launch/dryrun.py).

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so a
scan-over-layers program under-reports FLOPs/collectives by ~L x.  This
parser reconstructs trip-count-aware totals directly from
``compiled.as_text()`` (the per-device, post-SPMD module):

  1. split the module into computations and instructions;
  2. build the computation call graph (calls= / to_apply= / while
     body=/condition=) and propagate a multiplier top-down from ENTRY,
     multiplying by each while's trip count (parsed from the s32
     constant its condition compares against);
  3. accumulate per-computation dot FLOPs (2 * prod(result_dims) *
     prod(contracting_dims), operand shapes resolved from the symbol
     table) and collective traffic, each scaled by the multiplier.

Collective traffic per op kind (ring algorithms, k = group size):
  all-reduce        2 * (k-1)/k * result_bytes
  all-gather        (k-1)/k * result_bytes       (result = gathered)
  reduce-scatter    (k-1) * result_bytes          (input = k * result)
  all-to-all        (k-1)/k * result_bytes
  collective-permute  result_bytes
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_CALLEE_RE = re.compile(r"(?:calls|to_apply|body|condition)=%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _parse_shape(expr: str) -> Tuple[Optional[Tuple[int, ...]], int]:
    """First array shape in ``expr`` -> (dims, bytes). Tuples: first leaf."""
    m = _SHAPE_RE.search(expr)
    if not m or m.group(1) not in _DTYPE_BYTES:
        return None, 0
    dims = tuple(int(d) for d in m.group(2).split(",") if d) or ()
    n = _DTYPE_BYTES[m.group(1)]
    for d in dims:
        n *= d
    return dims, n


@dataclasses.dataclass
class Instruction:
    name: str
    op: str
    expr: str
    shape: Optional[Tuple[int, ...]]
    nbytes: int
    operands: List[str]


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    symbols: Dict[str, Instruction]


_OP_RE = re.compile(r"(?:\(|\s)([a-z][\w\-]*)\(")


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{", line)
        if header:
            cur = Computation(header.group(1), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        shape, nbytes = _parse_shape(rhs)
        opm = _OP_RE.search(" " + rhs)
        op = opm.group(1) if opm else ""
        # operands: %names inside the first parens after the op
        operands = re.findall(r"%([\w.\-]+)", rhs)
        instr = Instruction(name, op, rhs, shape, nbytes, operands)
        cur.instructions.append(instr)
        cur.symbols[name] = instr
    return comps


def _constants(comps: Dict[str, Computation]) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for c in comps.values():
        for ins in c.instructions:
            m = re.search(r"constant\((\d+)\)", ins.expr)
            if m and ins.expr.startswith("s32[]"):
                out[ins.name] = int(m.group(1))
    return out


def _trip_count(cond_name: str, comps: Dict[str, Computation],
                consts: Dict[str, int]) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    # find compare direction=LT; its constant operand is the bound
    for ins in cond.instructions:
        if "direction=LT" in ins.expr or ins.op == "compare":
            for op in ins.operands:
                if op in consts:
                    return max(1, consts[op])
        # fusion-wrapped compare: operands include the constant directly
        if ins.op == "fusion" and "compare" in ins.expr:
            for op in ins.operands:
                if op in consts:
                    return max(1, consts[op])
    # fallback: any s32 constant in the cond computation
    vals = [consts[i.name] for i in cond.instructions if i.name in consts]
    return max(vals) if vals else 1


def _multipliers(comps: Dict[str, Computation], entry: str,
                 consts: Dict[str, int]) -> Dict[str, float]:
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    seen = set()
    stack = [entry]
    while stack:
        cname = stack.pop()
        if cname in seen:
            continue
        seen.add(cname)
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for ins in comp.instructions:
            callees = _CALLEE_RE.findall(ins.expr)
            if not callees:
                continue
            trip = 1.0
            if ins.op == "while" or "while(" in ins.expr:
                condm = re.search(r"condition=%([\w.\-]+)", ins.expr)
                if condm:
                    trip = float(_trip_count(condm.group(1), comps, consts))
            for callee in callees:
                mult[callee] = max(mult[callee], m * trip)
                if callee not in seen:
                    stack.append(callee)
    return mult


def _dot_bytes(ins: Instruction, comp: Computation) -> float:
    if ins.op != "dot":
        return 0.0
    total = float(ins.nbytes)
    for opnd in ins.operands[:2]:
        sym = comp.symbols.get(opnd)
        if sym is not None:
            total += sym.nbytes
    return total


def _dot_flops(ins: Instruction, comp: Computation) -> float:
    if ins.op != "dot" or ins.shape is None:
        return 0.0
    out = 1.0
    for d in ins.shape:
        out *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.expr)
    contract = 1.0
    if m and ins.operands:
        lhs = comp.symbols.get(ins.operands[0])
        if lhs is not None and lhs.shape is not None:
            for idx in (int(i) for i in m.group(1).split(",") if i):
                if idx < len(lhs.shape):
                    contract *= lhs.shape[idx]
    return 2.0 * out * contract


def _group_size(ins: Instruction, default: int) -> int:
    m = _GROUPS_RE.search(ins.expr)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_EXPL_RE.search(ins.expr)
    if m:
        return max(1, len([x for x in m.group(1).split(",") if x.strip()]))
    return default


def _collective_bytes(ins: Instruction, default_k: int) -> float:
    kind = next((c for c in COLLECTIVES if ins.op.startswith(c)), None)
    if kind is None:
        return 0.0
    k = _group_size(ins, default_k)
    b = float(ins.nbytes)
    if kind == "all-reduce":
        return 2.0 * (k - 1) / k * b
    if kind == "all-gather":
        return (k - 1) / k * b
    if kind == "reduce-scatter":
        return (k - 1) * b
    if kind == "all-to-all":
        return (k - 1) / k * b
    return b   # collective-permute


@dataclasses.dataclass
class HloStats:
    dot_flops: float            # per device, trip-count aware
    collective_bytes: float     # per device, ring-adjusted, trip-aware
    collective_counts: Dict[str, int]
    num_whiles: int
    collective_bytes_by_kind: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    top_collectives: List[Tuple[float, str]] = dataclasses.field(
        default_factory=list)
    # Σ (lhs + rhs + out bytes) over dots, trip-aware: a lower bound on
    # HBM traffic that, unlike XLA-CPU 'bytes accessed', does not count
    # the f32 conversion copies the CPU backend inserts around bf16 GEMMs
    # (TPU MXUs consume bf16 directly).
    dot_bytes: float = 0.0


def analyze(text: str, default_group: int = 1) -> HloStats:
    comps = parse_module(text)
    consts = _constants(comps)
    entry = None
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
    if m:
        entry = m.group(1)
    else:  # fall back: computation named like main
        entry = next((n for n in comps if "main" in n), next(iter(comps)))
    mult = _multipliers(comps, entry, consts)

    flops = 0.0
    coll = 0.0
    dbytes = 0.0
    counts: Dict[str, int] = defaultdict(int)
    by_kind: Dict[str, float] = defaultdict(float)
    top: List[Tuple[float, str]] = []
    whiles = 0
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        for ins in comp.instructions:
            if ins.op == "while":
                whiles += 1
            flops += m * _dot_flops(ins, comp)
            dbytes += m * _dot_bytes(ins, comp)
            cb = _collective_bytes(ins, default_group)
            if cb:
                kind = next(c for c in COLLECTIVES if ins.op.startswith(c))
                coll += m * cb
                by_kind[kind] += m * cb
                counts[kind] += int(m)
                top.append((m * cb, f"{kind} x{m:.0f} {ins.nbytes}B "
                                    f"in {cname}"))
    top.sort(reverse=True)
    return HloStats(dot_flops=flops, collective_bytes=coll,
                    collective_counts=dict(counts), num_whiles=whiles,
                    collective_bytes_by_kind=dict(by_kind),
                    top_collectives=top[:12], dot_bytes=dbytes)
