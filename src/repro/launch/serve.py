"""Batched serving driver: prefill + decode with per-layer KV/SSM caches.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
        --batch 4 --prompt-len 16 --decode-steps 24
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.models import Model


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    if not args.full:
        arch = reduced(arch, layers=args.layers)
    model = Model(arch, dtype=jnp.float32, remat=False)
    rng = jax.random.PRNGKey(args.seed)
    params = model.init(rng)

    B = args.batch
    prompts = jax.random.randint(rng, (B, args.prompt_len), 0,
                                 arch.vocab_size)
    max_len = args.prompt_len + args.decode_steps
    cache = model.init_cache(B, max_len)
    step = jax.jit(model.decode_step)

    # prefill by teacher-forcing the prompt through the decode path (the
    # SPMD prefill kernel path is exercised by the dry-run; serving here
    # demonstrates the cache machinery end to end)
    t0 = time.perf_counter()
    logits = None
    for t in range(args.prompt_len):
        logits, cache = step(params, prompts[:, t:t + 1], cache, jnp.int32(t))
    prefill_s = time.perf_counter() - t0

    out_tokens = []
    tok = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(args.decode_steps):
        out_tokens.append(np.asarray(tok[:, 0]))
        logits, cache = step(params, tok, cache,
                             jnp.int32(args.prompt_len + i))
        if args.temperature > 0:
            rng, k = jax.random.split(rng)
            tok = jax.random.categorical(
                k, logits[:, 0] / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, 0], axis=-1)[:, None].astype(jnp.int32)
    decode_s = time.perf_counter() - t0
    toks = np.stack(out_tokens, axis=1)
    print(f"[serve] batch={B} prefill={prefill_s * 1e3:.1f}ms "
          f"decode={decode_s / args.decode_steps * 1e3:.2f}ms/token")
    print(f"[serve] sample continuation (request 0): {toks[0][:16].tolist()}")
    assert np.isfinite(np.asarray(logits)).all()
    return {"tokens": toks, "ms_per_token": decode_s / args.decode_steps * 1e3}


if __name__ == "__main__":
    main()
