"""Resilient serving driver: continuous batching over slot caches with
template-based inference fault tolerance (runtime/serve_exec.py,
DESIGN.md §14).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --requests 8 --batch 4 --prompt-len 8 --decode-steps 16 \
        --temperature 0.8 --fail-at 4

Builds an OobleckEngine over a synthetic node set, registers a
ServeExecutor as its runtime, streams a request trace through the
continuous-batching scheduler, and (optionally) injects a node failure
mid-traffic through the monitor — the decode pipelines replan from the
precomputed template set and every in-flight request completes.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced
from repro.core import build_profile
from repro.core.engine import EngineConfig, OobleckEngine
from repro.models import Model
from repro.runtime.serve_exec import SamplingParams, ServeExecutor


def build_serving_engine(arch, *, nodes, fault_tolerance: int = 1,
                         n0: int = 2, nodes_per_pod: int = 2,
                         seq_len: int = 32) -> OobleckEngine:
    """Engine wired for serving: the instance set is the decode-replica
    set; templates/reconfigurator/topology work unchanged."""
    profile = build_profile(arch, microbatch=1, seq_len=seq_len)
    cfg = EngineConfig(fault_tolerance=fault_tolerance, global_batch=8,
                       microbatch=1, n0_override=n0,
                       nodes_per_pod=nodes_per_pod)
    return OobleckEngine(profile, list(nodes), cfg)


def percentile(xs, q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4,
                    help="decode slots per replica")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-steps", type=int, default=16,
                    help="generated tokens per request")
    ap.add_argument("--requests", type=int, default=0,
                    help="request count (default: one per slot)")
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--nodes", type=int, default=6)
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a node failure after this many ticks")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    if not args.full:
        arch = reduced(arch, layers=args.layers)
    model = Model(arch, dtype=jnp.float32, remat=False)
    # independent keys for params, data and sampling (a shared key would
    # correlate the prompts with the weights)
    k_init, k_data, k_sample = jax.random.split(
        jax.random.PRNGKey(args.seed), 3)
    params = model.init(k_init)

    n_req = args.requests or args.batch
    prompts = [np.asarray(jax.random.randint(
        jax.random.fold_in(k_data, i), (args.prompt_len,), 0,
        arch.vocab_size), np.int32) for i in range(n_req)]

    engine = build_serving_engine(
        arch, nodes=[f"node{i}" for i in range(args.nodes)])
    t0 = time.perf_counter()
    ex = ServeExecutor(
        model, params, engine, num_slots=args.batch,
        max_len=args.prompt_len + args.decode_steps,
        max_new_cap=args.decode_steps,
        sampling=SamplingParams(args.temperature, args.top_k),
        sample_key=k_sample)
    warm_s = time.perf_counter() - t0
    for p in prompts:
        ex.submit(p, max_new=args.decode_steps)

    t0 = time.perf_counter()
    ticks = 0
    while ex.queue or any(r.active_mask().any() for r in ex.replicas):
        if ticks == args.fail_at:
            victim = engine.instances[0].nodes[0]
            engine.monitor.inject("fail", [victim])
            engine.monitor.poll(time.perf_counter())
            print(f"[serve] killed {victim}: {ex.last_recovery}")
        ex.tick()
        ticks += 1
    wall_s = time.perf_counter() - t0

    total_tokens = sum(r.max_new for r in ex.completed)
    ttft = [r.first_token_s - r.arrival_s for r in ex.completed
            if r.first_token_s is not None]
    ms_per_token = wall_s / max(total_tokens, 1) * 1e3
    print(f"[serve] replicas={len(ex.replicas)} slots={args.batch} "
          f"requests={len(ex.completed)}/{n_req} warm={warm_s:.1f}s")
    print(f"[serve] {total_tokens} tokens in {wall_s * 1e3:.0f}ms "
          f"({total_tokens / wall_s:.1f} tok/s, {ms_per_token:.2f}"
          f"ms/token), ttft p50={percentile(ttft, 50) * 1e3:.1f}ms "
          f"p99={percentile(ttft, 99) * 1e3:.1f}ms")
    r0 = min(ex.completed, key=lambda r: r.rid)
    print(f"[serve] sample continuation (request 0): "
          f"{r0.tokens[:16].tolist()}")
    assert len(ex.completed) == n_req, "not all requests completed"
    toks = np.stack([r.tokens for r in
                     sorted(ex.completed, key=lambda r: r.rid)])
    return {"tokens": toks, "ms_per_token": ms_per_token,
            "tokens_per_s": total_tokens / wall_s,
            "ttft_p50_ms": percentile(ttft, 50) * 1e3,
            "ttft_p99_ms": percentile(ttft, 99) * 1e3,
            "recovery": ex.last_recovery}


if __name__ == "__main__":
    main()
