"""ShapeDtypeStruct stand-ins for every model input/state — the dry-run
lowers against these; nothing is ever allocated.

``input_specs(arch, shape)`` returns the step arguments for the cell's
kind: train -> (params, opt_state, batch); prefill -> (params, batch);
decode -> (params, token, cache, pos).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import Model
from repro.optim import adamw


def params_shape(model: Model) -> Any:
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def opt_shape(model: Model, pshape: Any) -> Any:
    return jax.eval_shape(adamw.init, pshape)


def batch_specs(arch: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B = shape.global_batch
    S_text = shape.seq_len - (arch.frontend_tokens if arch.frontend else 0)
    out = {
        "tokens": jax.ShapeDtypeStruct((B, S_text), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S_text), jnp.int32),
    }
    if arch.frontend:
        out["frontend_embeds"] = jax.ShapeDtypeStruct(
            (B, arch.frontend_tokens, arch.d_model), jnp.bfloat16)
    return out


def prefill_specs(arch: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    out = batch_specs(arch, shape)
    del out["labels"]
    return out


def cache_shape(model: Model, shape: ShapeConfig) -> Any:
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, shape.seq_len))


def decode_specs(arch: ArchConfig, shape: ShapeConfig, model: Model
                 ) -> Tuple[Any, Any, Any]:
    token = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    cache = cache_shape(model, shape)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return token, cache, pos
