import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import: jax locks the device
# count on first backend initialization.  512 placeholder host devices
# cover both the single-pod (16x16) and multi-pod (2x16x16) meshes.

"""Multi-pod dry-run: lower + compile EVERY assigned (arch x shape) cell
on the production meshes, prove it fits, and extract roofline terms.

For each cell:
  * the scan-over-layers program is lowered with full parameter/optimizer
    /batch shardings and compiled -> ``memory_analysis()`` proves the
    per-chip footprint fits HBM; ``cost_analysis()`` + the trip-count-
    aware HLO parser (hloparse.py) give FLOPs and collective traffic;
  * roofline terms (seconds):
        compute    = HLO_FLOPs / (peak_FLOPs_bf16 * mxu_eff ... reported
                     raw: / peak)      [per chip — the parsed module IS
                     the per-device program]
        memory     = HLO_bytes / HBM_bw   (XLA 'bytes accessed', scaled
                     by the parsed/reported FLOP ratio to undo XLA's
                     count-loop-once behavior)
        collective = ring-adjusted collective bytes / ICI_bw
  * MODEL_FLOPS = 6*N*D (train) or 2*N*D (prefill/decode), N = active
    params, D = tokens — the useful-compute yardstick.

Usage:
  python -m repro.launch.dryrun                       # full sweep, both meshes
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --mesh multi --strategy fsdp
Artifacts append to artifacts/dryrun.json (resumable; done cells skip).
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro.configs import SHAPES, all_archs, cells_for, get_arch
from repro.launch import specs as sp
from repro.launch.hloparse import analyze
from repro.launch.mesh import (cost_analysis_dict, data_axes,
                               make_production_mesh, mesh_chips)
from repro.optim import adamw
from repro.runtime.sharding import ShardingStrategy
from repro.runtime import spmd
from repro.utils.hw import V5E


def model_flops(arch, shape) -> float:
    n = arch.active_params()
    toks = shape.tokens_per_step()
    mult = 6.0 if shape.is_training else 2.0
    return mult * n * toks


def run_cell(arch_name: str, shape_name: str, mesh_kind: str,
             strategy_name: str, loss_chunk: int = 512,
             remat_policy: str = "full", moe_impl: Optional[str] = None,
             serve_bf16: bool = False, gather_dtype: Optional[str] = None,
             variant: str = "") -> Dict[str, Any]:
    arch = get_arch(arch_name)
    shape = SHAPES[shape_name]
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh_chips(mesh)
    strategy = ShardingStrategy(strategy=strategy_name,
                                data_axes=data_axes(multi),
                                gather_dtype=gather_dtype)
    t0 = time.time()
    import jax.numpy as jnp
    model = spmd.build_model(
        arch, strategy, mesh, shape.global_batch,
        # optimized serving holds bf16 weights (--serve-bf16); the
        # baseline keeps fp32 for strict comparability with training
        param_dtype=(jnp.bfloat16 if serve_bf16 and not shape.is_training
                     else jnp.float32),
        moe_impl=moe_impl or ("capacity" if shape.kind != "decode"
                              else "grouped"))
    model = dataclasses.replace(model, loss_chunk=loss_chunk,
                                remat_policy=remat_policy)
    pshape = sp.params_shape(model)
    with mesh:
        if shape.kind == "train":
            oshape = sp.opt_shape(model, pshape)
            bundle = spmd.train_bundle(model, adamw.AdamWConfig(), strategy,
                                       mesh, pshape, oshape, shape)
            # donate params+opt: outputs alias inputs (production setup)
            lowered = bundle.jit(donate=(0, 1)).lower(
                pshape, oshape, sp.batch_specs(arch, shape))
        elif shape.kind == "prefill":
            bundle = spmd.prefill_bundle(model, strategy, mesh, pshape, shape)
            lowered = bundle.jit().lower(pshape, sp.prefill_specs(arch, shape))
        else:
            tok, cache, pos = sp.decode_specs(arch, shape, model)
            bundle = spmd.decode_bundle(model, strategy, mesh, pshape, cache,
                                        shape)
            # donate the KV/SSM cache: updated in place when serving
            lowered = bundle.jit(donate=(2,)).lower(pshape, tok, cache, pos)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ca = cost_analysis_dict(compiled)
    ma = compiled.memory_analysis()
    text = compiled.as_text()
    stats = analyze(text, default_group=mesh.shape[strategy.model_axis])

    xla_flops = float(ca.get("flops", 0.0)) or 1.0
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    # undo XLA's loop-counted-once on bytes via the FLOP expansion ratio
    expansion = max(stats.dot_flops / xla_flops, 1.0)
    hbm_bytes = xla_bytes * expansion

    compute_s = stats.dot_flops / V5E.peak_flops_bf16
    memory_s = hbm_bytes / V5E.hbm_bandwidth
    collective_s = stats.collective_bytes / V5E.ici_bandwidth
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    bottleneck = max(terms, key=terms.get).replace("_s", "")
    mf = model_flops(arch, shape)
    global_flops = stats.dot_flops * chips

    per_dev_bytes = {
        "args_gb": ma.argument_size_in_bytes / 1e9,
        "temps_gb": ma.temp_size_in_bytes / 1e9,
        "output_gb": ma.output_size_in_bytes / 1e9,
        "alias_gb": ma.alias_size_in_bytes / 1e9,
    }
    # donated buffers alias outputs: count them once
    fits = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
            + ma.output_size_in_bytes
            - ma.alias_size_in_bytes) <= V5E.hbm_capacity

    suffix = f"/{variant}" if variant else ""
    return {
        "key": f"{arch_name}/{shape_name}/{mesh_kind}/{strategy_name}{suffix}",
        "arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
        "strategy": strategy_name, "variant": variant, "chips": chips,
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "compile_us": (t_lower + t_compile) * 1e6,
        "memory": per_dev_bytes, "fits_hbm": bool(fits),
        "hlo": {
            "xla_flops_per_dev": xla_flops,
            "parsed_flops_per_dev": stats.dot_flops,
            "xla_bytes_per_dev": xla_bytes,
            "dot_bytes_per_dev": stats.dot_bytes,
            "memory_s_dots": stats.dot_bytes / V5E.hbm_bandwidth,
            "collective_bytes_per_dev": stats.collective_bytes,
            "collective_counts": stats.collective_counts,
            "num_whiles": stats.num_whiles,
        },
        "roofline": {
            **{k: round(v, 6) for k, v in terms.items()},
            "bottleneck": bottleneck,
            "model_flops": mf,
            "hlo_flops_global": global_flops,
            "model_flops_ratio": mf / max(global_flops, 1.0),
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--strategy", default="fsdp", choices=["fsdp", "tp"])
    ap.add_argument("--remat-policy", default="full",
                    choices=["full", "dots"])
    ap.add_argument("--moe-impl", default=None,
                    choices=[None, "dense", "grouped", "capacity",
                             "capacity_vec"])
    ap.add_argument("--loss-chunk", type=int, default=512)
    ap.add_argument("--serve-bf16", action="store_true")
    ap.add_argument("--gather-dtype", default=None,
                    choices=[None, "bfloat16"])
    ap.add_argument("--variant", default="",
                    help="label for perf-iteration runs (artifact key suffix)")
    ap.add_argument("--out", default="artifacts/dryrun.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    cells = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            cells = json.load(f).get("cells", [])
    done = {c["key"] for c in cells if c.get("status") == "ok"}

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    work = []
    for arch in all_archs():
        if args.arch and arch.name != args.arch.replace("-", "_").replace(".", "_"):
            continue
        for shape in cells_for(arch):
            if args.shape and shape.name != args.shape:
                continue
            for mesh_kind in meshes:
                work.append((arch.name, shape.name, mesh_kind))

    suffix = f"/{args.variant}" if args.variant else ""
    for arch_name, shape_name, mesh_kind in work:
        key = f"{arch_name}/{shape_name}/{mesh_kind}/{args.strategy}{suffix}"
        if key in done and not args.force:
            print(f"SKIP {key}", flush=True)
            continue
        print(f"RUN  {key}", flush=True)
        try:
            cell = run_cell(arch_name, shape_name, mesh_kind, args.strategy,
                            loss_chunk=args.loss_chunk,
                            remat_policy=args.remat_policy,
                            moe_impl=args.moe_impl,
                            serve_bf16=args.serve_bf16,
                            gather_dtype=args.gather_dtype,
                            variant=args.variant)
            r = cell["roofline"]
            print(f"  ok: compile {cell['compile_s']}s "
                  f"mem {cell['memory']['args_gb']:.1f}+{cell['memory']['temps_gb']:.1f}GB "
                  f"fits={cell['fits_hbm']} bottleneck={r['bottleneck']} "
                  f"terms=({r['compute_s']:.4f},{r['memory_s']:.4f},"
                  f"{r['collective_s']:.4f})s useful={r['model_flops_ratio']:.2f}",
                  flush=True)
        except Exception as e:
            traceback.print_exc()
            cell = {"key": key, "arch": arch_name, "shape": shape_name,
                    "mesh": mesh_kind, "strategy": args.strategy,
                    "status": f"error: {type(e).__name__}: {e}"}
        cells = [c for c in cells if c["key"] != key] + [cell]
        with open(args.out, "w") as f:
            json.dump({"cells": cells}, f, indent=1)


if __name__ == "__main__":
    main()
