"""Render the dry-run artifact into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m repro.launch.report [artifacts/dryrun.json]
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List


def fmt_cell(c: Dict) -> str:
    r = c["roofline"]
    dom = r["bottleneck"]
    mem = c["memory"]
    return (f"| {c['arch']} | {c['shape']} | {c.get('variant') or 'baseline'} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | **{dom}** "
            f"| {r['model_flops_ratio']:.3f} "
            f"| {mem['args_gb'] + mem['temps_gb']:.1f} "
            f"| {'yes' if c['fits_hbm'] else 'NO'} |")


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun.json"
    with open(path) as f:
        cells = json.load(f)["cells"]
    ok = [c for c in cells if c.get("status") == "ok"]
    errs = [c for c in cells if c.get("status") != "ok"]

    print("### Single-pod (16x16 = 256 chips) roofline, per step\n")
    print("| arch | shape | variant | compute (s) | memory (s) | "
          "collective (s) | bottleneck | useful FLOP frac | GB/chip | fits |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for c in sorted(ok, key=lambda c: (c["arch"], c["shape"],
                                       c.get("variant") or "")):
        if c["mesh"] == "single":
            print(fmt_cell(c))

    print("\n### Multi-pod (2x16x16 = 512 chips) compile proof\n")
    print("| arch | shape | variant | compile (s) | GB/chip | fits | "
          "collective bytes/chip |")
    print("|---|---|---|---|---|---|---|")
    for c in sorted(ok, key=lambda c: (c["arch"], c["shape"],
                                       c.get("variant") or "")):
        if c["mesh"] == "multi":
            mem = c["memory"]
            print(f"| {c['arch']} | {c['shape']} "
                  f"| {c.get('variant') or 'baseline'} "
                  f"| {c['compile_s']} "
                  f"| {mem['args_gb'] + mem['temps_gb']:.1f} "
                  f"| {'yes' if c['fits_hbm'] else 'NO'} "
                  f"| {c['hlo']['collective_bytes_per_dev'] / 1e9:.2f}GB |")
    if errs:
        print("\n### Errors\n")
        for c in errs:
            print(f"- `{c['key']}`: {c['status']}")


if __name__ == "__main__":
    main()
