"""SPMD train/serve step builders — the homogeneous fast path.

With zero failures all Oobleck pipelines run the same template, and the
whole job folds into ONE SPMD program: DP over ``data`` (+ ``pod``),
parameter sharding (FSDP or TP) over ``model``, gradient mean implicit in
the sharded loss-mean backward (XLA emits the cross-replica
all-reduce/reduce-scatter).  This is the program the multi-pod dry-run
lowers and the roofline analyses; heterogeneous pipeline sets swap
between per-template programs of exactly this shape (runtime/pipeline.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import Model
from repro.optim import adamw
from repro.runtime.sharding import ShardingStrategy


def build_model(arch: ArchConfig, strategy: ShardingStrategy, mesh: Mesh,
                global_batch: int, *, dtype=jnp.bfloat16,
                param_dtype=jnp.float32, remat: bool = True,
                attn_impl: str = "blocked", moe_impl: str = "dense") -> Model:
    return Model(
        arch, dtype=dtype, param_dtype=param_dtype, remat=remat,
        attn_impl=attn_impl, moe_impl=moe_impl,
        constrain=strategy.act_constrainer(mesh, global_batch),
        unshard=strategy.unshard_blocks(mesh))


def build_train_step(model: Model, opt_cfg: adamw.AdamWConfig
                     ) -> Callable:
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return model.loss(p, batch)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params2, opt2, stats = adamw.apply(opt_cfg, params, grads, opt_state)
        return params2, opt2, {"loss": loss, **metrics, **stats}
    return train_step


def build_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch):
        fe = batch.get("frontend_embeds")
        return model.prefill(params, batch["tokens"], fe)
    return prefill_step


def build_decode_step(model: Model) -> Callable:
    def decode_step(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos)
    return decode_step


# ----------------------------------------------------------------------
# Sharding-annotated jit wrappers (used by launch/train.py and dryrun.py)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class StepBundle:
    """A jitted step with its in/out shardings, ready to lower or run."""

    fn: Callable
    in_shardings: Tuple
    out_shardings: Any

    def jit(self, donate: Tuple[int, ...] = ()):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=donate)


def train_bundle(model: Model, opt_cfg: adamw.AdamWConfig,
                 strategy: ShardingStrategy, mesh: Mesh,
                 params_shape: Any, opt_shape: Any,
                 shape: ShapeConfig) -> StepBundle:
    pspec = strategy.param_shardings(mesh, params_shape)
    ospec = strategy.opt_shardings(mesh, opt_shape, params_shape)
    bshard = NamedSharding(mesh, strategy.batch_spec(mesh, shape.global_batch))
    batch_spec: Dict[str, Any] = {"tokens": bshard, "labels": bshard}
    if model.arch.frontend:
        batch_spec["frontend_embeds"] = bshard
    scalar = NamedSharding(mesh, P())
    out_stats = {k: scalar for k in
                 ("loss", "nll", "aux", "lr", "grad_norm")}
    return StepBundle(
        fn=build_train_step(model, opt_cfg),
        in_shardings=(pspec, ospec, batch_spec),
        out_shardings=(pspec, ospec, out_stats))


def prefill_bundle(model: Model, strategy: ShardingStrategy, mesh: Mesh,
                   params_shape: Any, shape: ShapeConfig) -> StepBundle:
    pspec = strategy.param_shardings(mesh, params_shape)
    bshard = NamedSharding(mesh, strategy.batch_spec(mesh, shape.global_batch))
    batch_spec: Dict[str, Any] = {"tokens": bshard}
    if model.arch.frontend:
        batch_spec["frontend_embeds"] = bshard
    logits_out = NamedSharding(
        mesh, P(strategy.batch_spec(mesh, shape.global_batch)[0]
                if len(strategy.batch_spec(mesh, shape.global_batch)) else None))
    return StepBundle(
        fn=build_prefill_step(model),
        in_shardings=(pspec, batch_spec),
        out_shardings=logits_out)


def decode_bundle(model: Model, strategy: ShardingStrategy, mesh: Mesh,
                  params_shape: Any, cache_shape: Any,
                  shape: ShapeConfig) -> StepBundle:
    pspec = strategy.param_shardings(mesh, params_shape)
    cspec = strategy.cache_shardings(mesh, cache_shape, shape.global_batch)
    bshard = NamedSharding(mesh, strategy.batch_spec(mesh, shape.global_batch))
    scalar = NamedSharding(mesh, P())
    return StepBundle(
        fn=build_decode_step(model),
        in_shardings=(pspec, bshard, cspec, scalar),
        out_shardings=(bshard, cspec))
