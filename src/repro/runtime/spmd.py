"""SPMD train/serve step builders — the homogeneous fast path.

With zero failures all Oobleck pipelines run the same template, and the
whole job folds into ONE SPMD program: DP over ``data`` (+ ``pod``),
parameter sharding (FSDP or TP) over ``model``, gradient mean implicit in
the sharded loss-mean backward (XLA emits the cross-replica
all-reduce/reduce-scatter).  This is the program the multi-pod dry-run
lowers and the roofline analyses; heterogeneous pipeline sets swap
between per-template programs of exactly this shape (runtime/pipeline.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import Model
from repro.optim import adamw
from repro.runtime.executor import (Executor, ExecutorUnsupported,
                                    ProgramCache, avals_of as _avals_of)
from repro.runtime.sharding import ShardingStrategy


def build_model(arch: ArchConfig, strategy: ShardingStrategy, mesh: Mesh,
                global_batch: int, *, dtype=jnp.bfloat16,
                param_dtype=jnp.float32, remat: bool = True,
                attn_impl: str = "blocked", moe_impl: str = "dense") -> Model:
    return Model(
        arch, dtype=dtype, param_dtype=param_dtype, remat=remat,
        attn_impl=attn_impl, moe_impl=moe_impl,
        constrain=strategy.act_constrainer(mesh, global_batch),
        unshard=strategy.unshard_blocks(mesh))


def build_train_step(model: Model, opt_cfg: adamw.AdamWConfig
                     ) -> Callable:
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            return model.loss(p, batch)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        params2, opt2, stats = adamw.apply(opt_cfg, params, grads, opt_state)
        return params2, opt2, {"loss": loss, **metrics, **stats}
    return train_step


def build_prefill_step(model: Model) -> Callable:
    def prefill_step(params, batch):
        fe = batch.get("frontend_embeds")
        return model.prefill(params, batch["tokens"], fe)
    return prefill_step


def build_decode_step(model: Model) -> Callable:
    def decode_step(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos)
    return decode_step


# ----------------------------------------------------------------------
# Sharding-annotated jit wrappers (used by launch/train.py and dryrun.py)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class StepBundle:
    """A jitted step with its in/out shardings, ready to lower or run."""

    fn: Callable
    in_shardings: Tuple
    out_shardings: Any

    def jit(self, donate: Tuple[int, ...] = ()):
        return jax.jit(self.fn, in_shardings=self.in_shardings,
                       out_shardings=self.out_shardings,
                       donate_argnums=donate)


def train_bundle(model: Model, opt_cfg: adamw.AdamWConfig,
                 strategy: ShardingStrategy, mesh: Mesh,
                 params_shape: Any, opt_shape: Any,
                 shape: ShapeConfig) -> StepBundle:
    pspec = strategy.param_shardings(mesh, params_shape)
    ospec = strategy.opt_shardings(mesh, opt_shape, params_shape)
    bshard = NamedSharding(mesh, strategy.batch_spec(mesh, shape.global_batch))
    batch_spec: Dict[str, Any] = {"tokens": bshard, "labels": bshard}
    if model.arch.frontend:
        batch_spec["frontend_embeds"] = bshard
    scalar = NamedSharding(mesh, P())
    out_stats = {k: scalar for k in
                 ("loss", "nll", "aux", "lr", "grad_norm")}
    return StepBundle(
        fn=build_train_step(model, opt_cfg),
        in_shardings=(pspec, ospec, batch_spec),
        out_shardings=(pspec, ospec, out_stats))


def prefill_bundle(model: Model, strategy: ShardingStrategy, mesh: Mesh,
                   params_shape: Any, shape: ShapeConfig) -> StepBundle:
    pspec = strategy.param_shardings(mesh, params_shape)
    bshard = NamedSharding(mesh, strategy.batch_spec(mesh, shape.global_batch))
    batch_spec: Dict[str, Any] = {"tokens": bshard}
    if model.arch.frontend:
        batch_spec["frontend_embeds"] = bshard
    logits_out = NamedSharding(
        mesh, P(strategy.batch_spec(mesh, shape.global_batch)[0]
                if len(strategy.batch_spec(mesh, shape.global_batch)) else None))
    return StepBundle(
        fn=build_prefill_step(model),
        in_shardings=(pspec, batch_spec),
        out_shardings=logits_out)


def decode_bundle(model: Model, strategy: ShardingStrategy, mesh: Mesh,
                  params_shape: Any, cache_shape: Any,
                  shape: ShapeConfig) -> StepBundle:
    pspec = strategy.param_shardings(mesh, params_shape)
    cspec = strategy.cache_shardings(mesh, cache_shape, shape.global_batch)
    bshard = NamedSharding(mesh, strategy.batch_spec(mesh, shape.global_batch))
    scalar = NamedSharding(mesh, P())
    return StepBundle(
        fn=build_decode_step(model),
        in_shardings=(pspec, bshard, cspec, scalar),
        out_shardings=(bshard, cspec))


# ----------------------------------------------------------------------
# The homogeneous fast path behind the Executor interface
# ----------------------------------------------------------------------
class SPMDExecutor(Executor):
    """Zero-failure homogeneous fast path: the whole job is ONE donated
    SPMD train program (DESIGN.md §8).

    With all pipelines running the same template, DP folds the job into
    a single program — either the plain fused train step (no mesh), the
    sharded `train_bundle` program (mesh + strategy), or the
    shard_map-pipelined step from runtime/spmd_pipeline.py.  The program
    is AOT-compiled into a ProgramCache so steady-state stepping is a
    cache lookup and tests can assert zero recompiles.

    ``recover``/``join`` raise ExecutorUnsupported by design: a single
    SPMD program cannot re-express a heterogeneous survivor set.  The
    engine reacts by rebinding a HeteroTrainer (runtime/pipeline.py)
    from this executor's snapshot — that is the designed degradation
    path, not an error in it.
    """

    def __init__(self, model: Model, params: Dict,
                 opt_cfg: adamw.AdamWConfig,
                 mesh: Optional[Any] = None,
                 strategy: Optional[ShardingStrategy] = None,
                 shape: Optional[ShapeConfig] = None,
                 engine: Optional[Any] = None,
                 cache: Optional[ProgramCache] = None):
        self.model = model
        self.opt_cfg = opt_cfg
        self.mesh = mesh
        self.strategy = strategy
        self.shape = shape
        self.engine = engine
        self.cache = cache or ProgramCache()
        # sole ownership: the step program donates these buffers
        self.params = jax.tree.map(jnp.copy, params)
        self.opt_state = adamw.init(self.params)
        if engine is not None and hasattr(engine, "attach_executor"):
            engine.attach_executor(self)
        self.bind()

    # ------------------------------------------------------------------
    def _batch_avals(self, batch: Dict) -> Dict:
        return _avals_of(batch)

    def _program(self, batch_avals: Dict):
        from repro.kernels import ops as kops
        key = ("spmd-train", kops.backend_signature(),
               tuple(sorted((k, tuple(v.shape), str(v.dtype))
                            for k, v in batch_avals.items())))

        def build():
            p_avals = _avals_of(self.params)
            o_avals = _avals_of(self.opt_state)
            if self.mesh is not None and self.strategy is not None:
                bundle = train_bundle(self.model, self.opt_cfg,
                                      self.strategy, self.mesh,
                                      p_avals, o_avals, self.shape)
                jitted = bundle.jit(donate=(0, 1))
            else:
                jitted = jax.jit(build_train_step(self.model, self.opt_cfg),
                                 donate_argnums=(0, 1))
            return jitted.lower(p_avals, o_avals, batch_avals).compile()

        return self.cache.get_or_build(key, build)

    # Executor interface ------------------------------------------------
    def bind(self) -> None:
        """Precompile for the configured global-batch shape when known;
        otherwise the first step() compiles (and caches) lazily."""
        if self.shape is not None:
            # launch/specs.py owns the batch-aval layout (incl. the
            # frontend_embeds entry for VLM/audio models — train_bundle's
            # in_shardings expect the same pytree structure)
            from repro.launch import specs as sp
            self._program(sp.batch_specs(self.model.arch, self.shape))

    def step(self, batch: Dict) -> Dict:
        batch = {k: jnp.asarray(v).astype(jnp.int32)
                 if k in ("tokens", "labels") else jnp.asarray(v)
                 for k, v in batch.items() if not k.startswith("_")}
        prog = self._program(self._batch_avals(batch))
        self.params, self.opt_state, stats = prog(
            self.params, self.opt_state, batch)
        return stats

    def recover(self, dead, drained: bool = False) -> Dict:
        raise ExecutorUnsupported(
            "SPMD fast path is single-program: a heterogeneous survivor "
            "set needs a HeteroTrainer rebind (from snapshot())")

    def join(self, nodes) -> Dict:
        raise ExecutorUnsupported(
            "SPMD fast path cannot grow in place; rebind from snapshot()")

    def snapshot(self, data_state: Optional[Dict] = None,
                 rng_seed: int = 0):
        from repro.ckpt import TrainState
        return TrainState(step=int(self.opt_state.step),
                          params=jax.tree.map(jnp.copy, self.params),
                          opt_state=type(self.opt_state)(
                              step=self.opt_state.step,
                              m=jax.tree.map(jnp.copy, self.opt_state.m),
                              v=jax.tree.map(jnp.copy, self.opt_state.v)),
                          data_state=data_state or {}, rng_seed=rng_seed)
