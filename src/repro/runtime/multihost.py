"""Multi-process execution backend (DESIGN.md §15).

Oobleck's architecture splits cluster-wide *configuration* from
per-node *execution*: one ConfigurationEngine plans (templates,
instantiation, batch distribution, reconfiguration) while an
ExecutionEngine per node runs the compiled programs (§3).  This module
is that split for real processes:

  * ``MultiHostExecutor`` — the coordinator.  Runs in the driver
    process, owns a pure ``ConfigurationEngine`` (plans only, no device
    state beyond a canonical parameter template used to decode
    snapshots), a ``CoordinatorServer`` control channel, and the worker
    subprocesses.  Implements the same ``Executor`` interface as the
    single-process ``HeteroTrainer`` — the conformance suite runs
    against both.
  * ``ShardTrainer`` — the per-process ExecutionEngine.  A
    ``HeteroTrainer`` subclass that binds full pipeline state ONLY for
    the replicas its process *leads* (a process leads replica R iff it
    hosts ``R.nodes[0]``), runs the identical compiled per-template
    step programs, and exchanges per-bucket gradient contributions as
    raw fp32 bytes.
  * ``Worker`` + ``worker_main`` — the subprocess shell: control
    channel, heartbeats, RPC handlers, and a ``DataServer`` serving
    layer state to peers during recovery.

Bitwise parity with the single-process trainer is a design invariant,
not an accident: every process runs the SAME compiled programs on the
SAME inputs (deterministic XLA CPU), gradient combination is the
identical left-to-right chain on every process
(``BucketedSync.combine``), fp32 buffers cross the wire as raw bytes,
and the coordinator aggregates losses in replica order with the exact
expression the single-process step uses.  The multi-process acceptance
test asserts post-recovery losses are BIT-EQUAL to a single-process run
of the same failure trace.

The step protocol (per iteration):

  1. ``step_grads``   coordinator -> each worker: the microbatches of
                      the replicas it leads.  Worker replies per-replica
                      per-bucket weighted contributions + NLL sums.
  2. ``step_commit``  coordinator -> every worker: the FULL contribution
                      set.  Each worker redundantly runs the identical
                      combine + clip + donated bucket updates on its led
                      replicas; ``opt_step`` advances here and only here.
     ``step_abort``   on any failure before commit: drop everything, no
                      state mutated — the paper's lost-iteration
                      semantics (§3.3).

Reconfiguration is two-phase with an agreed epoch: PREPARE freezes a
serving view of surviving layer state and dry-runs the reconfiguration
to a plan fingerprint; the coordinator verifies every survivor computed
the SAME fingerprint as its own engine; COMMIT applies the plan
deterministically everywhere and moves layer state between processes as
actual socket transfers (the ``runtime/transfer.py`` CopyTask streams);
FINISH drops the serving view once every survivor reports the same new
epoch and post-plan fingerprint.
"""
from __future__ import annotations

import argparse
import hashlib
import os
import signal
import subprocess
import sys
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import ConfigurationEngine, EngineConfig
from repro.core.monitor import HeartbeatConfig
from repro.core.reconfigure import InsufficientReplicasError, PipelineInstance
from repro.kernels import ops as kops
from repro.optim import adamw
from repro.runtime.coordination import (CoordinatorServer, DataServer,
                                        EpochMismatch, WorkerChannel,
                                        WorkerLost, data_call, member_of,
                                        pack_batches, pack_tree,
                                        unpack_batches, unpack_tree)
from repro.runtime.executor import CompileCounter, Executor, ProgramCache
from repro.runtime.pipeline import HeteroTrainer

_RPC_TIMEOUT = float(os.environ.get("REPRO_DRYRUN_TIMEOUT", "600"))


# ----------------------------------------------------------------------
# Job spec: everything a worker needs to rebuild the IDENTICAL setup
# ----------------------------------------------------------------------
def make_job_spec(arch: str = "gpt3_medium", layers: int = 4,
                  seq_len: int = 16, microbatch: int = 2,
                  global_batch: int = 16, f: int = 1, n0: int = 2,
                  nodes: Optional[Sequence[str]] = None,
                  nodes_per_pod: int = 8,
                  hosting: Optional[Dict[str, int]] = None,
                  procs: int = 2, seed: int = 11,
                  opt: Optional[Dict[str, float]] = None) -> Dict:
    """JSON-able job description.  ``hosting`` maps node name -> worker
    rank; the default splits the node list into ``procs`` contiguous
    chunks.  Every process (coordinator included) rebuilds model,
    params, profile and engine from this spec alone — same seed, same
    arithmetic, so all replicas of the configuration agree bit-for-bit."""
    nodes = list(nodes) if nodes is not None else [f"n{i}" for i in range(5)]
    if hosting is None:
        per = -(-len(nodes) // procs)
        hosting = {n: min(i // per, procs - 1) for i, n in enumerate(nodes)}
    return {
        "arch": arch, "layers": layers, "seq_len": seq_len,
        "microbatch": microbatch, "global_batch": global_batch,
        "f": f, "n0": n0, "nodes": nodes, "nodes_per_pod": nodes_per_pod,
        "hosting": {n: int(r) for n, r in hosting.items()},
        "seed": seed,
        "opt": opt or {"lr": 1e-3, "warmup_steps": 0, "clip_norm": 1.0,
                       "weight_decay": 0.0},
    }


def build_setup(spec: Dict):
    """Deterministically rebuild (model, params, profile, opt_cfg,
    engine) from a job spec — run by the coordinator AND by every
    worker, so each process's ConfigurationEngine replica starts from
    the identical plan."""
    from repro.configs import get_arch, reduced
    from repro.core import build_profile
    from repro.models import Model

    arch = reduced(get_arch(spec["arch"]), layers=spec["layers"])
    model = Model(arch, dtype=jnp.float32, remat=False, attn_impl="naive",
                  scan_layers=False)
    params = model.init(jax.random.PRNGKey(spec["seed"]))
    profile = build_profile(arch, microbatch=spec["microbatch"],
                            seq_len=spec["seq_len"])
    opt_cfg = adamw.AdamWConfig(**spec["opt"])
    engine = ConfigurationEngine(
        profile, list(spec["nodes"]),
        EngineConfig(fault_tolerance=spec["f"],
                     global_batch=spec["global_batch"],
                     microbatch=spec["microbatch"],
                     gpus_per_node=1, n0_override=spec["n0"],
                     nodes_per_pod=spec["nodes_per_pod"]))
    return model, params, profile, opt_cfg, engine


def layer_state_hash(st: Dict[str, Any]) -> str:
    """Content hash of one layer's {p, m, v} state, leaf order fixed by
    the pytree flatten — the cross-process bitwise-equality probe."""
    h = hashlib.sha256()
    for path, leaf in jax.tree_util.tree_flatten_with_path(st)[0]:
        h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    return h.hexdigest()[:16]


# ----------------------------------------------------------------------
# Per-process execution engine
# ----------------------------------------------------------------------
class ShardTrainer(HeteroTrainer):
    """HeteroTrainer bound to the replicas this process LEADS.

    Lead rule: the process hosting a replica's first node holds the
    replica's full layer state (replica-lead execution).  All planning
    state (the engine) is replicated everywhere and mutated by the same
    deterministic calls, so every process always agrees on WHO leads
    WHAT without communicating about it.
    """

    def __init__(self, model, engine: ConfigurationEngine, params,
                 opt_cfg, hosting: Dict[str, int], rank: int,
                 cache: Optional[ProgramCache] = None):
        self.hosting = {n: int(r) for n, r in hosting.items()}
        self.rank = int(rank)
        # recovery serving state, populated between PREPARE and FINISH
        self._serve_view: Dict[Tuple[str, int], Dict] = {}
        self._old_lead: Dict[str, int] = {}
        self._old_owns: Set[Tuple[str, int]] = set()
        self._old_owners: Dict[int, Set[str]] = {}
        super().__init__(model, engine, params, opt_cfg, mode="compiled",
                         cache=cache, codec="none")

    # -- which replicas are mine ---------------------------------------
    def leads(self, inst: PipelineInstance) -> bool:
        return self.hosting.get(inst.nodes[0]) == self.rank

    def _bound_instances(self) -> List[PipelineInstance]:
        return [inst for inst in self.engine.instances if self.leads(inst)]

    def led_indices(self) -> List[int]:
        return [i for i, inst in enumerate(self.engine.instances)
                if self.leads(inst)]

    def run_of(self, replica_idx: int):
        inst = self.engine.instances[replica_idx]
        for run in self.runs:
            if run.instance is inst:
                return run
        raise KeyError(f"rank {self.rank} does not lead replica "
                       f"{replica_idx}")

    # -- step protocol -------------------------------------------------
    def grads_phase(self, replicas: Sequence[int],
                    batches: Sequence[List[Dict]]
                    ) -> Tuple[Dict[int, List[jax.Array]],
                               Dict[int, jax.Array]]:
        """Run the led replicas' pipelines and return their per-bucket
        weighted contributions + NLL sums — the bytes that go to the
        coordinator.  No state is mutated here; a failure between this
        and commit loses the iteration, nothing else."""
        weights = [float(m) for m in self.engine.batch.num_microbatches]
        grads_by: Dict[int, Dict[int, Any]] = {}
        nll_sums: Dict[int, jax.Array] = {}
        for idx, mbs in zip(replicas, batches):
            run = self.run_of(idx)
            assert len(mbs) == self.engine.batch.num_microbatches[idx], \
                (idx, len(mbs), self.engine.batch.num_microbatches)
            g, nll = self._run_pipeline(run, mbs)
            grads_by[idx] = g
            nll_sums[idx] = jnp.sum(nll)
        plan = self._bucket_plan()
        contribs, staged = self._bsync.contributions(plan, grads_by, weights)
        assert not staged, "codec residuals unsupported in multihost v1"
        return contribs, nll_sums

    def commit_phase(self, contribs_by_replica: Dict[int, Sequence[Any]]
                     ) -> jax.Array:
        """Combine the FULL contribution set (identical chain on every
        process -> identical bits), clip, and commit the donated bucket
        updates on the led replicas.  The ONLY mutating phase."""
        plan = self._bucket_plan()
        flats, sumsqs = self._bsync.combine(plan, contribs_by_replica)
        sq = jnp.zeros((), jnp.float32)
        for s in sumsqs:
            sq = sq + s
        grad_norm = jnp.sqrt(sq)
        scale = self._clip_scale(grad_norm)
        step_in = self.opt_step             # adamw.apply increments
        self.opt_step = self.opt_step + 1
        for run in self.runs:
            self._bsync.update(plan, flats, run.states, scale, step_in)
        return grad_norm

    # -- two-phase reconfiguration -------------------------------------
    def prepare_reconfig(self, dead: Set[str],
                         hosting_update: Optional[Dict[str, int]] = None,
                         kind: str = "fail") -> Optional[str]:
        """PREPARE: freeze the serving view (surviving layer state of
        led replicas, addressable by (node, layer)), record the
        pre-failure lead/ownership maps the commit's source resolution
        needs, and dry-run the reconfiguration to its plan fingerprint.
        Nothing is mutated — abort is free until COMMIT."""
        eng = self.engine
        dead = set(dead)
        self._serve_view = {}
        for run in self.runs:
            for l, st in run.states.items():
                for node in run.instance.layer_owners(l):
                    if node not in dead:
                        self._serve_view[(node, l)] = st
        self._old_lead = {}
        self._old_owns = set()
        self._old_owners = {}
        for inst in eng.instances:
            lead = self.hosting[inst.nodes[0]]
            for node in inst.nodes:
                self._old_lead[node] = lead
            for l, nodes in inst.all_layer_owners().items():
                for node in nodes:
                    if node not in dead:
                        self._old_owns.add((node, l))
                        self._old_owners.setdefault(l, set()).add(node)
        if hosting_update:
            self.hosting.update(
                {n: int(r) for n, r in hosting_update.items()})
        if kind != "fail":
            return None
        dead_active = {d for d in dead if d in set(eng.nodes)}
        if not dead_active:
            return eng.plan_fingerprint()
        spares = [n for n in eng.spare_nodes if n not in dead]
        result = eng.reconf.on_failure(eng.instances, dead_active,
                                       spares=spares)
        return eng.plan_fingerprint(result)

    def commit_reconfig(self, dead: Set[str],
                        data_addrs: Dict[int, Sequence],
                        kind: str = "fail",
                        nodes: Sequence[str] = (),
                        drained: bool = False) -> Dict:
        """COMMIT: apply the SAME deterministic replan every process
        computes, then rebind the led replicas — each layer's state
        comes from the node the transfer plan scheduled, resolved to
        the process that physically holds it (the source node's OLD
        replica lead) and pulled over the data plane when remote."""
        eng = self.engine
        dead = set(dead)
        dead_ranks = {self.hosting[n] for n in dead if n in self.hosting}
        if kind == "fail":
            result = eng.handle_failure(dead, drained=drained)
        else:
            result = eng.handle_join(list(nodes))
        plan = eng.transfer_plan(result, dead=dead)
        fetched = {"bytes": 0, "fetches": 0, "seconds": 0.0}

        def avail(node: str, l: int) -> bool:
            # a (node, layer) copy is REACHABLE iff the node survived
            # AND the process that physically held it (the node's old
            # replica lead) survived
            return ((node, l) in self._old_owns
                    and self._old_lead.get(node) is not None
                    and self._old_lead[node] not in dead_ranks)

        def state_for(node: str, l: int) -> Dict:
            if avail(node, l):
                src = node                  # state didn't move
            else:
                src = plan.source_of(node, l)
                if src is None or not avail(src, l):
                    cands = sorted(m for m in self._old_owners.get(l, ())
                                   if avail(m, l))
                    if not cands:
                        raise InsufficientReplicasError(
                            f"layer {l}: every surviving copy lived on "
                            f"a dead process")
                    src = cands[0]
            src_rank = self._old_lead[src]
            if src_rank == self.rank:
                return self._serve_view[(src, l)]
            t0 = time.perf_counter()
            reply, blobs = data_call(
                data_addrs[src_rank],
                {"type": "get_state", "node": src, "layer": l})
            st = unpack_tree(self._state_skeleton(l), reply["spec"], blobs)
            fetched["bytes"] += sum(len(b) for b in blobs)
            fetched["fetches"] += 1
            fetched["seconds"] += time.perf_counter() - t0
            return st

        self.runs = [self._bind_run(inst, layers=None, state_fn=state_for)
                     for inst in self._bound_instances()]
        self.bind()     # program swap by cache lookup (zero compiles)
        return {"copied_bytes": result.copy_bytes(),
                "fetched_bytes": fetched["bytes"],
                "fetches": fetched["fetches"],
                "transfer_s": fetched["seconds"]}

    def finish_reconfig(self) -> None:
        """FINISH: every survivor reported the agreed epoch — drop the
        frozen serving view."""
        self._serve_view = {}
        self._old_lead = {}
        self._old_owns = set()
        self._old_owners = {}

    def _state_skeleton(self, l: int) -> Dict:
        p = self._layer_avals[l]
        f32 = lambda t: jax.ShapeDtypeStruct(t.shape, jnp.float32)
        return {"p": p, "m": jax.tree.map(f32, p),
                "v": jax.tree.map(f32, p)}

    def layer_hashes(self) -> Dict[int, Dict[int, str]]:
        out: Dict[int, Dict[int, str]] = {}
        for run in self.runs:
            idx = next(i for i, inst in enumerate(self.engine.instances)
                       if inst is run.instance)
            out[idx] = {l: layer_state_hash(st)
                        for l, st in run.states.items()}
        return out


# ----------------------------------------------------------------------
# Worker process shell
# ----------------------------------------------------------------------
class Worker:
    """RPC surface of one worker process: owns the ShardTrainer, the
    control channel (heartbeats ride it), the DataServer peers pull
    state from, and a persistent CompileCounter so the coordinator can
    assert the survivors' zero-recompile property remotely."""

    def __init__(self, coordinator: Tuple[str, int], rank: int,
                 beat_interval: float = 0.2):
        self.rank = rank
        self.counter = CompileCounter()
        self.trainer: Optional[ShardTrainer] = None
        self.data_addrs: Dict[int, Sequence] = {}
        self.server = DataServer(self._serve_data)
        self.channel = WorkerChannel(
            coordinator, rank,
            hello={"data_addr": list(self.server.addr), "pid": os.getpid()},
            beat_interval=beat_interval)

    # -- data plane ----------------------------------------------------
    def _serve_data(self, header, blobs):
        assert header["type"] == "get_state", header
        st = self.trainer._serve_view[(header["node"], header["layer"])]
        spec, out = pack_tree(st)
        return {"spec": spec}, out

    # -- control handlers ----------------------------------------------
    def _h_job(self, header, blobs):
        spec = header["spec"]
        model, params, _, opt_cfg, engine = build_setup(spec)
        cache = ProgramCache(namespace=kops.process_topology())
        self.trainer = ShardTrainer(model, engine, params, opt_cfg,
                                    spec["hosting"], self.rank, cache=cache)
        return {"fingerprint": engine.plan_fingerprint(),
                "led": self.trainer.led_indices()}, ()

    def _h_start(self, header, blobs):
        self.data_addrs = {int(r): a for r, a in header["addrs"].items()}
        return {}, ()

    def _h_warm(self, header, blobs):
        stats = self.trainer.warm_templates()
        return {"cache": stats}, ()

    def _h_mark(self, header, blobs):
        self.counter.mark()
        return {}, ()

    def _h_compiles(self, header, blobs):
        return {"since_mark": self.counter.since_mark(),
                "total": self.counter.count}, ()

    def _h_step_grads(self, header, blobs):
        replicas = [int(i) for i in header["replicas"]]
        batches = unpack_batches(header["spec"], blobs)
        contribs, nll_sums = self.trainer.grads_phase(replicas, batches)
        out: List[bytes] = []
        for idx in replicas:
            for arr in contribs[idx]:
                out.append(np.ascontiguousarray(
                    np.asarray(arr, np.float32)).tobytes())
            out.append(np.asarray(nll_sums[idx], np.float32).tobytes())
        nb = len(contribs[replicas[0]]) if replicas else 0
        return {"replicas": replicas, "nbuckets": nb}, out

    def _h_step_commit(self, header, blobs):
        B = int(header["nbuckets"])
        contribs: Dict[int, List[jax.Array]] = {}
        k = 0
        for idx in header["replicas"]:
            contribs[int(idx)] = [
                jnp.asarray(np.frombuffer(blobs[k + j], np.float32))
                for j in range(B)]
            k += B
        gn = self.trainer.commit_phase(contribs)
        return {"opt_step": int(self.trainer.opt_step)}, \
            [np.asarray(gn, np.float32).tobytes()]

    def _h_step_abort(self, header, blobs):
        return {}, ()       # grads phase mutated nothing; nothing to undo

    def _h_prepare(self, header, blobs):
        fp = self.trainer.prepare_reconfig(
            set(header["dead"]),
            hosting_update=header.get("hosting_update"),
            kind=header.get("kind", "fail"))
        return {"fingerprint": fp, "epoch": self.trainer.engine.epoch}, ()

    def _h_commit(self, header, blobs):
        info = self.trainer.commit_reconfig(
            set(header["dead"]), self.data_addrs,
            kind=header.get("kind", "fail"),
            nodes=header.get("nodes", ()),
            drained=bool(header.get("drained", False)))
        eng = self.trainer.engine
        return dict(info, epoch=eng.epoch,
                    fingerprint=eng.plan_fingerprint()), ()

    def _h_finish(self, header, blobs):
        self.trainer.finish_reconfig()
        return {}, ()

    def _h_snapshot(self, header, blobs):
        st = self.trainer.snapshot(
            data_state=header.get("data_state") or {},
            rng_seed=int(header.get("rng_seed", 0)))
        spec_p, b_p = pack_tree(st.params)
        spec_m, b_m = pack_tree(st.opt_state.m)
        spec_v, b_v = pack_tree(st.opt_state.v)
        return {"step": st.step, "leaves": len(b_p), "spec_p": spec_p,
                "spec_m": spec_m, "spec_v": spec_v}, b_p + b_m + b_v

    def _h_layer_hashes(self, header, blobs):
        hashes = {str(i): {str(l): h for l, h in per.items()}
                  for i, per in self.trainer.layer_hashes().items()}
        return {"hashes": hashes}, ()

    def _h_save_ckpt(self, header, blobs):
        from repro.ckpt import CheckpointManager
        mgr = CheckpointManager(
            header["directory"], self.trainer.num_layers,
            async_mode=False, keep=int(header.get("keep", 2)),
            process_id=member_of(self.rank),
            manifest_writer=(header["writer"] == member_of(self.rank)))
        mgr.save(self.trainer.snapshot(
            data_state=header.get("data_state") or {}))
        mgr.wait()
        return {"stats": mgr.stats}, ()

    def handlers(self):
        return {
            "job": self._h_job, "start": self._h_start,
            "warm": self._h_warm, "mark_compiles": self._h_mark,
            "compile_counts": self._h_compiles,
            "step_grads": self._h_step_grads,
            "step_commit": self._h_step_commit,
            "step_abort": self._h_step_abort,
            "reconf_prepare": self._h_prepare,
            "reconf_commit": self._h_commit,
            "reconf_finish": self._h_finish,
            "snapshot": self._h_snapshot,
            "layer_hashes": self._h_layer_hashes,
            "save_ckpt": self._h_save_ckpt,
        }

    def run(self) -> None:
        try:
            self.channel.serve(self.handlers())
        finally:
            self.server.close()
            self.channel.close()


def worker_main(coordinator: str, rank: int) -> None:
    host, port = coordinator.rsplit(":", 1)
    Worker((host, int(port)), rank).run()


# ----------------------------------------------------------------------
# The coordinator-side Executor
# ----------------------------------------------------------------------
class MultiHostExecutor(Executor):
    """Executor whose execution lives in N worker subprocesses.

    The coordinator holds NO layer state: it plans (ConfigurationEngine),
    routes microbatches and contributions, arbitrates the two-phase
    reconfiguration, and watches liveness through the heartbeat channel.
    ``recover`` works from detected failures — kill -9 a worker and the
    socket EOF (or heartbeat silence) surfaces its hosted nodes as dead
    without any injected event.
    """

    def __init__(self, spec: Dict,
                 heartbeat: Optional[HeartbeatConfig] = None,
                 python: Optional[str] = None,
                 rpc_timeout: float = _RPC_TIMEOUT):
        self.spec = dict(spec)
        self.hosting = {n: int(r) for n, r in spec["hosting"].items()}
        self.rpc_timeout = rpc_timeout
        ranks = sorted(set(self.hosting.values()))
        self.server = CoordinatorServer(len(ranks), heartbeat)
        self.procs: Dict[int, subprocess.Popen] = {}
        self._spawn_workers(ranks, python)
        hellos = self.server.accept_workers(timeout=rpc_timeout)
        self.data_addrs = {r: list(h["data_addr"])
                           for r, h in hellos.items()}
        # the coordinator's CONFIGURATION side: plans only.  The params
        # template is kept host-side purely to decode snapshot pytrees.
        (self.model, self._template_params, self.profile,
         self.opt_cfg, self.engine) = build_setup(self.spec)
        replies = self.server.broadcast_call(
            {"type": "job", "spec": self.spec}, timeout=rpc_timeout)
        fp0 = self.engine.plan_fingerprint()
        for r, (h, _) in replies.items():
            if h["fingerprint"] != fp0:
                raise EpochMismatch(
                    f"rank {r} bootstrapped fingerprint "
                    f"{h['fingerprint']} != coordinator's {fp0}")
        self.server.broadcast_call(
            {"type": "start",
             "addrs": {str(r): a for r, a in self.data_addrs.items()}},
            timeout=rpc_timeout)
        self.opt_step = 0
        self.last_info: Optional[Dict] = None

    # -- process management --------------------------------------------
    def _spawn_workers(self, ranks: List[int],
                       python: Optional[str]) -> None:
        import repro
        # repro is a namespace package: __path__ holds the package dir
        src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
        host, port = self.server.addr
        for r in ranks:
            env = dict(os.environ)
            env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
            env["REPRO_PROC_COUNT"] = str(len(ranks))
            env["REPRO_PROC_INDEX"] = str(r)
            env.setdefault("JAX_PLATFORMS", "cpu")
            cmd = [python or sys.executable,
                   "-m", "repro.runtime.multihost_worker",
                   "--coordinator", f"{host}:{port}", "--rank", str(r)]
            self.procs[r] = subprocess.Popen(cmd, env=env)

    def kill_worker(self, rank: int) -> None:
        """SIGKILL a worker process — the failure-injection primitive of
        the multi-process acceptance tests.  Detection happens through
        the coordination channel (EOF/heartbeat), NOT through this call."""
        proc = self.procs[rank]
        os.kill(proc.pid, signal.SIGKILL)
        proc.wait()

    def hosted_nodes(self, ranks: Iterable[int]) -> Set[str]:
        ranks = set(ranks)
        return {n for n, r in self.hosting.items() if r in ranks}

    def detected_dead(self, timeout: float = 15.0
                      ) -> Tuple[Set[str], Set[int]]:
        """Wait for the heartbeat channel to declare worker(s) dead;
        returns (their hosted nodes, their ranks).  This is the failure
        signal the recovery path consumes — no injected events."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            ranks = self.server.poll_dead()
            if ranks:
                return self.hosted_nodes(ranks), set(ranks)
            time.sleep(0.05)
        return set(), set()

    # -- Executor interface --------------------------------------------
    def bind(self) -> None:
        pass            # workers bind internally at job/commit time

    def warm_templates(self, mb_counts=None) -> Dict[int, Dict]:
        """Broadcast warm + reset every worker's compile counter: the
        zero-recompile contract is asserted against compiles SINCE this
        point."""
        replies = self.server.broadcast_call({"type": "warm"},
                                             timeout=self.rpc_timeout)
        self.server.broadcast_call({"type": "mark_compiles"},
                                   timeout=self.rpc_timeout)
        return {r: h["cache"] for r, (h, _) in replies.items()}

    def mark_compiles(self) -> None:
        """Reset every worker's compile counter.  Call at steady state
        (after warm + one step, which traces the step's scalar glue ops
        exactly like the single-process trainer's first train_step);
        ``compile_counts`` then measures the recovery path alone."""
        self.server.broadcast_call({"type": "mark_compiles"},
                                   ranks=self.server.alive_ranks(),
                                   timeout=self.rpc_timeout)

    def compile_counts(self) -> Dict[int, int]:
        replies = self.server.broadcast_call(
            {"type": "compile_counts"}, ranks=self.server.alive_ranks(),
            timeout=self.rpc_timeout)
        return {r: h["since_mark"] for r, (h, _) in replies.items()}

    def step(self, batches: List[List[Dict]]) -> Dict:
        eng = self.engine
        assert len(batches) == len(eng.instances), \
            (len(batches), len(eng.instances))
        by_rank: Dict[int, List[int]] = {}
        for i, inst in enumerate(eng.instances):
            by_rank.setdefault(self.hosting[inst.nodes[0]], []).append(i)
        requests = {}
        for r, idxs in by_rank.items():
            spec, blobs = pack_batches([batches[i] for i in idxs])
            requests[r] = ({"type": "step_grads", "replicas": idxs,
                            "spec": spec}, blobs)
        try:
            replies = self.server.multi_call(requests,
                                             timeout=self.rpc_timeout)
        except WorkerLost:
            self._abort_step()
            raise
        contribs: Dict[int, List[bytes]] = {}
        nll: Dict[int, bytes] = {}
        B = 0
        for r, (h, bl) in replies.items():
            B = h["nbuckets"]
            k = 0
            for idx in h["replicas"]:
                contribs[idx] = bl[k:k + B]
                nll[idx] = bl[k + B]
                k += B + 1
        R = len(eng.instances)
        order = list(range(R))
        blobs = [buf for i in order for buf in contribs[i]]
        header = {"type": "step_commit", "replicas": order, "nbuckets": B}
        # commit is idempotent per-worker; workers that answered have
        # advanced opt_step.  A worker lost HERE leaves survivors
        # uniformly committed — treat the step as done and let the
        # heartbeat surface the death before the next one.
        replies = self.server.broadcast_call(
            header, blobs, timeout=self.rpc_timeout, strict=False)
        if not replies:
            raise WorkerLost(list(by_rank), "no worker survived commit")
        gn_bytes = next(iter(sorted(replies.items())))[1][1][0]
        grad_norm = jnp.asarray(
            np.frombuffer(gn_bytes, np.float32).reshape(()))
        weights = [len(b) for b in batches]
        scalars = [jnp.asarray(np.frombuffer(nll[i], np.float32).reshape(()))
                   for i in order]
        # the EXACT single-process expression, replica order preserved
        loss = sum(scalars) / float(sum(weights))
        self.opt_step += 1
        return {"loss": loss, "grad_norm": grad_norm,
                "num_pipelines": R}

    def _abort_step(self) -> None:
        alive = self.server.alive_ranks()
        try:
            self.server.broadcast_call({"type": "step_abort"}, ranks=alive,
                                       timeout=self.rpc_timeout,
                                       strict=False)
        except WorkerLost:
            pass

    # -- reconfiguration -----------------------------------------------
    def recover(self, dead: Set[str], drained: bool = False) -> Dict:
        """Two-phase agreed reconfiguration across the survivors."""
        dead = set(dead)
        alive = self.server.alive_ranks()
        # PREPARE: dry-run locally + on every survivor; fingerprints
        # must agree before anything mutates
        t0 = time.perf_counter()
        dead_active = {d for d in dead if d in set(self.engine.nodes)}
        if dead_active:
            spares = [n for n in self.engine.spare_nodes if n not in dead]
            my_fp = self.engine.plan_fingerprint(
                self.engine.reconf.on_failure(self.engine.instances,
                                              dead_active, spares=spares))
        else:
            my_fp = self.engine.plan_fingerprint()
        replies = self.server.broadcast_call(
            {"type": "reconf_prepare", "dead": sorted(dead),
             "kind": "fail"}, ranks=alive, timeout=self.rpc_timeout)
        for r, (h, _) in replies.items():
            if h["fingerprint"] != my_fp:
                raise EpochMismatch(
                    f"PREPARE: rank {r} planned {h['fingerprint']}, "
                    f"coordinator planned {my_fp}")
        replan_s = time.perf_counter() - t0
        # COMMIT: everyone applies the agreed plan; state moves between
        # processes over the data plane
        t1 = time.perf_counter()
        result = self.engine.handle_failure(dead, drained=drained)
        replies = self.server.broadcast_call(
            {"type": "reconf_commit", "dead": sorted(dead), "kind": "fail",
             "drained": drained}, ranks=alive, timeout=self.rpc_timeout)
        info = self._check_commit(replies)
        commit_s = time.perf_counter() - t1
        # FINISH: agreed epoch everywhere — drop serving views
        t2 = time.perf_counter()
        self.server.broadcast_call({"type": "reconf_finish"}, ranks=alive,
                                   timeout=self.rpc_timeout)
        barrier_s = time.perf_counter() - t2
        self.last_info = {
            "policy": "replan", "copied_bytes": result.copy_bytes(),
            "fetched_bytes": info["fetched_bytes"],
            "fetches": info["fetches"],
            "num_pipelines": len(self.engine.instances),
            "epoch": self.engine.epoch,
            "breakdown": {"replan": replan_s,
                          "transfer": info["transfer_s"],
                          "compile": 0.0,
                          "commit": commit_s,
                          "barrier": barrier_s}}
        return self.last_info

    def join(self, nodes: List[str]) -> Dict:
        """Elastic scale-up: new nodes are assigned to surviving worker
        ranks round-robin, then the same two-phase commit as recovery
        (the copy path of §5 applies to joins too)."""
        nodes = sorted(nodes)
        alive = self.server.alive_ranks()
        hosting_update = {n: alive[i % len(alive)]
                          for i, n in enumerate(nodes)}
        self.hosting.update(hosting_update)
        t0 = time.perf_counter()
        self.server.broadcast_call(
            {"type": "reconf_prepare", "dead": [], "kind": "join",
             "hosting_update": hosting_update},
            ranks=alive, timeout=self.rpc_timeout)
        replan_s = time.perf_counter() - t0
        t1 = time.perf_counter()
        result = self.engine.handle_join(list(nodes))
        replies = self.server.broadcast_call(
            {"type": "reconf_commit", "dead": [], "kind": "join",
             "nodes": nodes}, ranks=alive, timeout=self.rpc_timeout)
        info = self._check_commit(replies)
        commit_s = time.perf_counter() - t1
        self.server.broadcast_call({"type": "reconf_finish"}, ranks=alive,
                                   timeout=self.rpc_timeout)
        self.last_info = {
            "policy": "join", "copied_bytes": result.copy_bytes(),
            "fetched_bytes": info["fetched_bytes"],
            "num_pipelines": len(self.engine.instances),
            "epoch": self.engine.epoch,
            "breakdown": {"replan": replan_s,
                          "transfer": info["transfer_s"],
                          "compile": 0.0, "commit": commit_s}}
        return self.last_info

    def _check_commit(self, replies) -> Dict:
        """Every survivor must land on the coordinator's epoch AND its
        post-commit plan fingerprint — the epoch-agreement assertion."""
        fp_after = self.engine.plan_fingerprint()
        fetched, fetches, transfer_s = 0, 0, 0.0
        for r, (h, _) in replies.items():
            if h["epoch"] != self.engine.epoch:
                raise EpochMismatch(
                    f"COMMIT: rank {r} at epoch {h['epoch']}, "
                    f"coordinator at {self.engine.epoch}")
            if h["fingerprint"] != fp_after:
                raise EpochMismatch(
                    f"COMMIT: rank {r} landed on {h['fingerprint']}, "
                    f"coordinator on {fp_after}")
            fetched += h["fetched_bytes"]
            fetches += h["fetches"]
            transfer_s = max(transfer_s, h["transfer_s"])
        return {"fetched_bytes": fetched, "fetches": fetches,
                "transfer_s": transfer_s}

    # -- state access --------------------------------------------------
    def snapshot(self, data_state: Optional[Dict] = None,
                 rng_seed: int = 0):
        from repro.ckpt import TrainState
        lead = self.hosting[self.engine.instances[0].nodes[0]]
        h, blobs = self.server.call(
            lead, {"type": "snapshot", "data_state": data_state or {},
                   "rng_seed": rng_seed}, timeout=self.rpc_timeout)
        n = h["leaves"]
        params = unpack_tree(self._template_params, h["spec_p"], blobs[:n])
        m = unpack_tree(self._template_params, h["spec_m"],
                        blobs[n:2 * n])
        v = unpack_tree(self._template_params, h["spec_v"],
                        blobs[2 * n:3 * n])
        opt = adamw.AdamWState(jnp.asarray(h["step"], jnp.int32), m, v)
        return TrainState(step=h["step"], params=params, opt_state=opt,
                          data_state=data_state or {}, rng_seed=rng_seed)

    def full_params(self) -> Dict:
        return self.snapshot().params

    def layer_hashes(self) -> Dict[int, Dict[int, str]]:
        """replica -> layer -> content hash, gathered across workers —
        the bitwise cross-process divergence probe."""
        replies = self.server.broadcast_call(
            {"type": "layer_hashes"}, ranks=self.server.alive_ranks(),
            timeout=self.rpc_timeout)
        out: Dict[int, Dict[int, str]] = {}
        for r, (h, _) in replies.items():
            for idx, per in h["hashes"].items():
                out[int(idx)] = {int(l): hh for l, hh in per.items()}
        return out

    def replica_divergence(self) -> int:
        """Number of (layer, replica-pair) hash mismatches — must be 0."""
        hashes = self.layer_hashes()
        bad = 0
        per_layer: Dict[int, Set[str]] = {}
        for per in hashes.values():
            for l, h in per.items():
                per_layer.setdefault(l, set()).add(h)
        for l, hs in per_layer.items():
            bad += len(hs) - 1
        return bad

    def save_checkpoint(self, directory: str,
                        data_state: Optional[Dict] = None) -> Dict[int, Dict]:
        """Every lead rank writes its shards; the elected writer commits
        the manifest (ckpt/checkpoint.py multi-writer safety)."""
        from repro.ckpt import elect_writer
        alive = set(self.server.alive_ranks())
        lead_ranks = sorted({self.hosting[i.nodes[0]]
                             for i in self.engine.instances} & alive)
        writer = elect_writer([member_of(r) for r in lead_ranks])
        replies = self.server.broadcast_call(
            {"type": "save_ckpt", "directory": directory, "writer": writer,
             "data_state": data_state or {}},
            ranks=lead_ranks, timeout=self.rpc_timeout)
        return {r: h["stats"] for r, (h, _) in replies.items()}

    # -- lifecycle -----------------------------------------------------
    def shutdown(self) -> None:
        for r in self.server.alive_ranks():
            self.server.notify(r, {"type": "shutdown"})
        for r, p in self.procs.items():
            try:
                p.wait(timeout=15)
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait()
        self.server.close()

    def __enter__(self) -> "MultiHostExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()


def worker_cli(argv: Optional[Sequence[str]] = None) -> None:
    """Entry point of a worker process — ``python -m
    repro.runtime.multihost_worker --coordinator HOST:PORT --rank R``."""
    ap = argparse.ArgumentParser(
        description="multi-process training worker (spawned by "
                    "MultiHostExecutor or launched manually against a "
                    "coordinator)")
    ap.add_argument("--coordinator", required=True,
                    help="host:port of the coordinator's control channel")
    ap.add_argument("--rank", type=int, required=True)
    ap.add_argument("--procs", type=int, default=None,
                    help="world size (for manual launches; the spawner "
                         "sets REPRO_PROC_COUNT itself)")
    args = ap.parse_args(argv)
    if args.procs is not None:
        os.environ.setdefault("REPRO_PROC_COUNT", str(args.procs))
    os.environ.setdefault("REPRO_PROC_INDEX", str(args.rank))
    worker_main(args.coordinator, args.rank)


if __name__ == "__main__":
    worker_cli()
