"""Compiled bucketed gradient-sync data plane (DESIGN.md §10).

The planner's sync plan (core/sync.py) says WHAT synchronizes — layer
buckets with identical peer structure, deepest-first.  Until this module
the runtime ignored it and walked an eager per-layer ``jax.tree.map``
chain: O(layers x replicas) tiny dispatches per step for the weighted
average, plus a second O(layers x leaves) chain for the global-norm
clip, plus one update-program call per layer per replica.  This module
executes the plan instead:

  * each ``SyncBucket``'s layers are FLATTENED into one contiguous fp32
    buffer (``pack``), and sync + norm + clip + AdamW run as a small
    family of cached, donated programs keyed by (bucket structure,
    codec) — one collective-equivalent weighted reduction per bucket;
  * buckets are issued deepest-first (the plan's order), so on real
    hardware the reduction of deep buckets overlaps the remaining
    backward — the same schedule `core.sync.SyncCostModel` prices;
  * when a bucket's peer group spans pods, the reduction runs the
    two-level hierarchical path: partial sums within each pod (ICI),
    one exchange across pod leads (DCN), broadcast back.  Numerically
    this only reassociates the sum; every replica still consumes the
    SAME reduced buffer, so replicas stay bit-identical;
  * the wire codec (runtime/compression.py) encodes each replica's
    weighted contribution per bucket — one int8 scale per bucket — with
    per-(bucket, replica) error-feedback residuals.  Residuals are keyed
    by bucket signature and dropped on reconfiguration (a stale residual
    would shape-mismatch the new layout);
  * program identity depends only on the bucket's LAYER STRUCTURE (the
    per-layer leaf specs), not its depth, node placement, or replica
    count — all block layers look alike, so ``warm()`` covers every
    bucket layout any reachable instance set can produce by cap-splitting
    every span between template stage boundaries (`core.sync.split_span`
    is shared with ``build_sync_plan``), keeping reconfiguration
    zero-compile for bucket programs too.

``perlayer_sync`` keeps the original eager per-layer path verbatim: it
is the parity oracle — bitwise-equal synced gradients for codec="none"
(same multiply/add order per element), bounded error for bf16/int8.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Hashable, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.sync import SyncBucket, split_span
from repro.optim import adamw
from repro.runtime.compression import (CODEC_WIRE, ErrorFeedback,
                                       decode_flat, encode_flat)
from repro.runtime.executor import ProgramCache, tree_spec

LayerState = Dict[str, Any]


# ----------------------------------------------------------------------
# The eager per-layer oracle (the pre-data-plane runtime path, verbatim)
# ----------------------------------------------------------------------
def perlayer_sync(all_grads: Sequence[Dict[int, Any]],
                  weights: Sequence[float], num_layers: int
                  ) -> Dict[int, Any]:
    """Layer-granular cross-replica weighted average (Figure 9): the
    readable spec of what the bucketed plane fuses.  Weights are
    minibatch sizes, so the result is the global-batch mean gradient."""
    wsum = float(sum(weights))
    synced: Dict[int, Any] = {}
    for l in range(num_layers):
        contribs = [(w / wsum, g[l]) for w, g in zip(weights, all_grads)
                    if l in g]
        acc = jax.tree.map(lambda t: t * contribs[0][0], contribs[0][1])
        for w, g in contribs[1:]:
            acc = jax.tree.map(lambda a, t: a + t * w, acc, g)
        synced[l] = acc
    return synced


def perlayer_global_sumsq(synced: Dict[int, Any], num_layers: int
                          ) -> jax.Array:
    """Sum of squared gradient elements across the WHOLE model, per-leaf
    accumulation order (the global-norm-clip input)."""
    sq = jnp.zeros((), jnp.float32)
    for l in range(num_layers):
        for t in jax.tree.leaves(synced[l]):
            sq = sq + jnp.sum(jnp.square(t.astype(jnp.float32)))
    return sq


# ----------------------------------------------------------------------
# Bucket execution plan
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class BucketExec:
    """One sync bucket bound for execution."""

    lids: Tuple[int, ...]                       # ascending layer ids
    specs: Tuple                                # program identity (structure)
    n: int                                      # flat fp32 element count
    pod_groups: Tuple[Tuple[int, ...], ...]     # replica indices per pod

    @property
    def signature(self) -> Tuple:
        """Bucket signature: the residual/staging key component — the
        layer span AND its structure (a reconfiguration that changes
        either invalidates carried error-feedback residuals)."""
        return (self.lids, self.n)

    @property
    def hierarchical(self) -> bool:
        return len(self.pod_groups) > 1


@dataclasses.dataclass
class SyncReduceResult:
    """Everything the reduce phase produced, with NO state mutated:
    the optimizer commit (and the residual commit that rides with it)
    happens only after the caller's sync-phase fault seam passes."""

    flats: List[jax.Array]                      # per bucket, reduced
    sumsqs: List[jax.Array]                     # per bucket, scalar
    staged_residuals: Dict[Hashable, jax.Array]


def _aval_size(aval) -> int:
    return int(math.prod(aval.shape)) if aval.shape else 1


class BucketedSync:
    """The compiled bucketed sync/clip/update tail.

    Owns no layer state — it reads per-replica gradient dicts and writes
    ``run.states`` through donated update programs.  All executables
    live in the trainer's ProgramCache, so the §8 zero-recompilation
    contract extends to the sync tail.
    """

    def __init__(self, cache: ProgramCache, opt_cfg: adamw.AdamWConfig,
                 layer_avals: Sequence[Any], codec: str = "none"):
        if codec not in CODEC_WIRE:
            raise ValueError(f"unknown codec {codec!r}")
        self.cache = cache
        self.opt_cfg = opt_cfg
        self.layer_avals = list(layer_avals)
        self.codec = codec
        self.ef = ErrorFeedback(codec)

    # ------------------------------------------------------------------
    # Plan construction
    # ------------------------------------------------------------------
    def exec_plan(self, sync_plan: Sequence[SyncBucket],
                  replica_pods: Optional[Sequence[Sequence[Hashable]]] = None
                  ) -> List[BucketExec]:
        """Bind the planner's buckets for execution.  ``replica_pods[b]``
        gives, per bucket, the pod of each replica's lead owner — the
        grouping for the hierarchical ICI/DCN path; None means one pod
        (flat chain)."""
        out: List[BucketExec] = []
        for i, b in enumerate(sync_plan):
            lids = tuple(range(b.layer_start, b.layer_end))
            specs = tuple(tree_spec(self.layer_avals[l]) for l in lids)
            n = sum(_aval_size(a) for l in lids
                    for a in jax.tree.leaves(self.layer_avals[l]))
            pods = (replica_pods[i] if replica_pods is not None else None)
            out.append(BucketExec(lids=lids, specs=specs, n=n,
                                  pod_groups=self._group(pods)))
        return out

    @staticmethod
    def _group(pods: Optional[Sequence[Hashable]]
               ) -> Tuple[Tuple[int, ...], ...]:
        if not pods:
            return ((),)        # filled lazily per replica count at reduce
        groups: List[List[int]] = []
        index: Dict[Hashable, int] = {}
        for r, pod in enumerate(pods):
            if pod not in index:
                index[pod] = len(groups)
                groups.append([])
            groups[index[pod]].append(r)
        return tuple(tuple(g) for g in groups)

    # ------------------------------------------------------------------
    # Program family (all cached; keys carry structure, never placement)
    # ------------------------------------------------------------------
    def _layer_state_aval(self, l: int):
        aval = self.layer_avals[l]
        f32 = lambda t: jax.ShapeDtypeStruct(t.shape, jnp.float32)  # noqa: E731
        return {"p": aval, "m": jax.tree.map(f32, aval),
                "v": jax.tree.map(f32, aval)}

    def _pack_prog(self, b: BucketExec) -> Callable:
        key = ("bpack", b.specs)

        def build() -> Callable:
            def pack(layers):
                parts = [jnp.ravel(leaf).astype(jnp.float32)
                         for lt in layers for leaf in jax.tree.leaves(lt)]
                return jnp.concatenate(parts)
            avals = [self.layer_avals[l] for l in b.lids]
            return jax.jit(pack).lower(avals).compile()

        return self.cache.get_or_build(key, build)

    def _scale_prog(self, n: int) -> Callable:
        key = ("bscale", n)

        def build() -> Callable:
            flat = jax.ShapeDtypeStruct((n,), jnp.float32)
            w = jax.ShapeDtypeStruct((), jnp.float32)
            return jax.jit(lambda x, w: x * w).lower(flat, w).compile()

        return self.cache.get_or_build(key, build)

    def _add_prog(self, n: int) -> Callable:
        key = ("badd", n)

        def build() -> Callable:
            flat = jax.ShapeDtypeStruct((n,), jnp.float32)
            return jax.jit(lambda acc, x: acc + x,
                           donate_argnums=(0,)).lower(flat, flat).compile()

        return self.cache.get_or_build(key, build)

    def _sumsq_prog(self, n: int) -> Callable:
        key = ("bsumsq", n)

        def build() -> Callable:
            flat = jax.ShapeDtypeStruct((n,), jnp.float32)
            return jax.jit(
                lambda x: jnp.sum(jnp.square(x))).lower(flat).compile()

        return self.cache.get_or_build(key, build)

    def _ef_prog(self, n: int) -> Callable:
        """codec roundtrip + error feedback for one replica's weighted
        bucket contribution: what goes on the wire, and what the codec
        lost (carried into the next step)."""
        key = ("bef", self.codec, n)
        codec = self.codec

        def build() -> Callable:
            def ef(c, res):
                c = c + res
                sent = decode_flat(encode_flat(c, codec), codec)
                return sent, c - sent
            flat = jax.ShapeDtypeStruct((n,), jnp.float32)
            return jax.jit(ef, donate_argnums=(0,)).lower(flat, flat).compile()

        return self.cache.get_or_build(key, build)

    def _zeros(self, n: int) -> jax.Array:
        return jnp.zeros((n,), jnp.float32)

    def _update_prog(self, b: BucketExec) -> Callable:
        """Donated per-bucket AdamW: unflatten the reduced buffer back
        into the bucket's layers and update them all in ONE program —
        the bucketed replacement for per-layer update calls."""
        key = ("bupdate", b.specs)

        def build() -> Callable:
            layer_cfg = dataclasses.replace(self.opt_cfg, clip_norm=0.0)

            def upd(states, flat, scale, step):
                out, off = [], 0
                for st in states:
                    leaves, treedef = jax.tree_util.tree_flatten(st["p"])
                    gl = []
                    for leaf in leaves:
                        sz = int(math.prod(leaf.shape)) if leaf.shape else 1
                        gl.append(flat[off:off + sz].reshape(leaf.shape)
                                  * scale)
                        off += sz
                    g = jax.tree_util.tree_unflatten(treedef, gl)
                    new_p, new_opt, _ = adamw.apply(
                        layer_cfg, st["p"], g,
                        adamw.AdamWState(step, st["m"], st["v"]))
                    out.append({"p": new_p, "m": new_opt.m, "v": new_opt.v})
                return out

            states_aval = [self._layer_state_aval(l) for l in b.lids]
            flat_aval = jax.ShapeDtypeStruct((b.n,), jnp.float32)
            scalar = jax.ShapeDtypeStruct((), jnp.float32)
            step_aval = jax.ShapeDtypeStruct((), jnp.int32)
            return jax.jit(upd, donate_argnums=(0,)).lower(
                states_aval, flat_aval, scalar, step_aval).compile()

        return self.cache.get_or_build(key, build)

    # ------------------------------------------------------------------
    # Warming
    # ------------------------------------------------------------------
    def bind_plan(self, plan: Sequence[BucketExec]) -> None:
        """Ensure every program the CURRENT plan needs is cached."""
        for b in plan:
            self._pack_prog(b)
            self._scale_prog(b.n)
            self._add_prog(b.n)
            self._sumsq_prog(b.n)
            self._update_prog(b)
            if self.codec != "none":
                self._ef_prog(b.n)
                self._zeros(b.n)        # residual-init fill, shape-keyed

    def warm(self, templates: Iterable[Any], layer_bytes: Sequence[int],
             bucket_cap_bytes: int) -> None:
        """Precompile bucket programs for EVERY layout any reachable
        instance set can produce: bucket spans are cap-splits of runs
        between peer-structure change points, and every change point is
        a stage boundary of some template — so cap-splitting every span
        between template boundary pairs (same `split_span` the planner
        uses) over-covers the reachable set.  Structure-keyed programs
        collapse the span count to a handful of distinct compiles."""
        num_layers = len(self.layer_avals)
        bounds = {0, num_layers}
        for t in templates:
            for st in t.stages:
                bounds.add(int(st.layer_start))
                bounds.add(int(st.layer_end))
        pts = sorted(p for p in bounds if 0 <= p <= num_layers)
        seen: set = set()
        for i, s in enumerate(pts):
            for e in pts[i + 1:]:
                for (lo, hi) in split_span(s, e, layer_bytes,
                                           bucket_cap_bytes):
                    if (lo, hi) in seen:
                        continue
                    seen.add((lo, hi))
        for (lo, hi) in sorted(seen):
            fake = SyncBucket(lo, hi, ((),), 0)
            self.bind_plan(self.exec_plan([fake]))

    # ------------------------------------------------------------------
    # Execution
    # ------------------------------------------------------------------
    def contributions(self, plan: Sequence[BucketExec],
                      grads_by_replica: Dict[int, Dict[int, Any]],
                      weights: Sequence[float]
                      ) -> Tuple[Dict[int, List[jax.Array]],
                                 Dict[Hashable, jax.Array]]:
        """Per-replica weighted bucket contributions: pack each bucket's
        layer grads into one flat fp32 buffer, scale by the replica's
        batch weight, and (if a codec is configured) run the error-
        feedback roundtrip.  ``grads_by_replica`` maps GLOBAL replica
        index -> that replica's per-layer grads — a multi-process worker
        passes only the replicas it executes; single-process passes all.
        Returns ({replica: [flat per bucket]}, staged residuals).  These
        buffers are exactly what crosses the wire between processes."""
        wsum = float(sum(weights))
        w_dev = {r: jnp.asarray(weights[r] / wsum, jnp.float32)
                 for r in grads_by_replica}
        out: Dict[int, List[jax.Array]] = {r: [] for r in grads_by_replica}
        staged: Dict[Hashable, jax.Array] = {}
        for b in plan:
            pack = self._pack_prog(b)
            for r, g in grads_by_replica.items():
                missing = [l for l in b.lids if l not in g]
                assert not missing, \
                    f"replica {r} lacks grads for layers {missing}"
                flat = pack([g[l] for l in b.lids])
                c = self._scale_prog(b.n)(flat, w_dev[r])
                if self.codec != "none":
                    res_key = ("ef", b.signature, self.codec, r)
                    res = self.ef.get(res_key)
                    if res is None:
                        res = self._zeros(b.n)
                    c, new_res = self._ef_prog(b.n)(c, res)
                    staged[res_key] = new_res
                out[r].append(c)
        return out, staged

    def combine(self, plan: Sequence[BucketExec],
                contribs_by_replica: Dict[int, Sequence[Any]]
                ) -> Tuple[List[jax.Array], List[jax.Array]]:
        """Reduce the full contribution set: per bucket, partial sums
        within each pod group (ICI legs) then one exchange across pods
        (DCN leg), plus the per-bucket sumsq.  Deterministic left-to-
        right chains — every caller holding the same contributions
        computes the SAME bits, which is what lets every process in a
        multi-host run execute this redundantly and stay bit-identical
        (and what makes codec="none" bitwise-equal to the per-layer
        oracle on a single pod)."""
        R = len(contribs_by_replica)
        assert sorted(contribs_by_replica) == list(range(R)), \
            f"combine needs contributions from ALL replicas, got " \
            f"{sorted(contribs_by_replica)}"
        flats: List[jax.Array] = []
        sumsqs: List[jax.Array] = []
        for i, b in enumerate(plan):
            groups = (b.pod_groups if b.pod_groups != ((),)
                      else (tuple(range(R)),))
            contribs = [contribs_by_replica[r][i] for r in range(R)]
            partials: List[jax.Array] = []
            for grp in groups:
                acc = contribs[grp[0]]
                for r in grp[1:]:
                    acc = self._add_prog(b.n)(acc, contribs[r])
                partials.append(acc)
            total = partials[0]
            for p in partials[1:]:
                total = self._add_prog(b.n)(total, p)
            flats.append(total)
            sumsqs.append(self._sumsq_prog(b.n)(total))
        return flats, sumsqs

    def reduce(self, plan: Sequence[BucketExec],
               all_grads: Sequence[Dict[int, Any]],
               weights: Sequence[float]) -> SyncReduceResult:
        """Weighted cross-replica reduction of every bucket, issued
        deepest-first (the plan's order): contributions + combine in one
        process.  Pure with respect to trainer state: residual updates
        are STAGED, committed by the caller only after the sync-phase
        fault seam passes — an aborted iteration leaves residuals
        exactly as they were (§3.3 lost-iteration semantics)."""
        contribs, staged = self.contributions(
            plan, {r: g for r, g in enumerate(all_grads)}, weights)
        flats, sumsqs = self.combine(plan, contribs)
        return SyncReduceResult(flats=flats, sumsqs=sumsqs,
                                staged_residuals=staged)

    def commit_residuals(self, result: SyncReduceResult) -> None:
        for k, v in result.staged_residuals.items():
            self.ef.put(k, v)

    def retain_residuals(self, plan: Sequence[BucketExec],
                         num_replicas: int) -> int:
        """Drop error-feedback residuals the current bucket layout can
        no longer use (recover/join changed spans or replica count)."""
        valid = {("ef", b.signature, self.codec, r)
                 for b in plan for r in range(num_replicas)}
        return self.ef.retain(valid)

    def update(self, plan: Sequence[BucketExec], flats: Sequence[jax.Array],
               states: Dict[int, LayerState], scale: jax.Array,
               step: jax.Array) -> None:
        """Apply the donated per-bucket AdamW programs to ONE replica's
        layer states, in place (dict entries are replaced)."""
        for b, flat in zip(plan, flats):
            new_states = self._update_prog(b)(
                [states[l] for l in b.lids], flat, scale, step)
            for l, st in zip(b.lids, new_states):
                states[l] = st
