"""Recovery data plane: topology-aware state-transfer scheduling (DESIGN.md §9).

The reconfigurator (core/reconfigure.py) emits a layer-granular list of
``CopyTask``s — *what* has to move after a failure.  This module decides
*how* it moves:

  * **source selection** — every task carries the full set of surviving
    replicas that hold the layer; the scheduler picks a source that is
    pod-local to the destination (ICI, 50 GB/s/link) before falling back
    to a cross-pod replica (DCN, 25 GB/s/host), breaking ties by the
    bytes already assigned to each sender (least-loaded);
  * **parallel streams** — tasks sharing a (src, dst) pair coalesce into
    one ordered stream; all streams start together, so recovery time is
    the *makespan over streams under link contention*, not the serial
    sum of bytes the simulator used to charge;
  * **contention** — stream rates come from a progressive-filling model
    against the `utils/hw.py` constants: an ICI stream is capped by one
    ICI link and by its endpoints' NIC aggregate (links x per-link
    bandwidth) shared across that node's active streams; DCN streams
    share each host's single DCN allotment;
  * **chunking** — streams are cut into fixed-size chunks so the runtime
    can interleave copies with the first post-recovery steps (the warm
    program cache means compute is ready before state is, ReCycle's
    observation in arXiv:2405.14009).

Nothing here touches arrays: the plan is pure metadata.  The
heterogeneous runtime (runtime/pipeline.py) executes it against real
layer states; the simulator (sim/policies.py) charges its makespan as
downtime; the benchmark (benchmarks/recovery_latency.py) decomposes it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from repro.core.reconfigure import CopyTask
from repro.utils.hw import HardwareSpec, V5E

ICI = "ici"
DCN = "dcn"


class TransferPlanError(RuntimeError):
    """The scheduled plan violates the data-plane contract (reads a dead
    node, routes inconsistently with pod placement, drops bytes)."""


# ----------------------------------------------------------------------
# Topology
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Topology:
    """Node -> pod placement plus the fabric constants.

    Nodes inside one pod talk over ICI; pods talk over DCN (DESIGN.md
    §5).  Nodes the map has never seen (late joins, hot spares) are
    conservatively placed in their own singleton pod, so every path to
    them is priced as DCN until a replan assigns them properly.
    """

    pods: Mapping[str, int]
    hw: HardwareSpec = V5E

    @classmethod
    def regular(cls, nodes: Sequence[str], nodes_per_pod: int = 8,
                hw: HardwareSpec = V5E) -> "Topology":
        """Pods of ``nodes_per_pod`` consecutive nodes, in given order —
        mirrors how launch/mesh.py lays pipeline replicas out per pod."""
        per = max(1, nodes_per_pod)
        return cls(pods={n: i // per for i, n in enumerate(nodes)}, hw=hw)

    def pod_of(self, node: str):
        pod = self.pods.get(node)
        return pod if pod is not None else ("solo", node)

    def same_pod(self, a: str, b: str) -> bool:
        return self.pod_of(a) == self.pod_of(b)

    def link_kind(self, src: str, dst: str) -> str:
        return ICI if self.same_pod(src, dst) else DCN

    def link_bandwidth(self, kind: str) -> float:
        return self.hw.ici_bandwidth if kind == ICI else self.hw.dcn_bandwidth

    def nic_capacity(self, node: str) -> float:
        """Aggregate ICI egress/ingress of one node (all links)."""
        return self.hw.ici_bandwidth * self.hw.ici_links_per_chip


# ----------------------------------------------------------------------
# Streams
# ----------------------------------------------------------------------
@dataclasses.dataclass
class TransferStream:
    """All bytes moving src -> dst, sent as one ordered chunked stream."""

    src: str
    dst: str
    link: str                       # ICI | DCN
    tasks: List[CopyTask]

    @property
    def nbytes(self) -> int:
        return sum(t.nbytes for t in self.tasks)

    @property
    def layers(self) -> List[int]:
        return [t.layer for t in self.tasks]

    def chunks(self, chunk_bytes: int) -> List[Tuple[int, int]]:
        """(layer, nbytes) pieces in send order, each <= chunk_bytes.
        Layer boundaries are preserved: a chunk never mixes layers, so
        the receiver can install a layer as soon as its last chunk
        lands (that is what overlap with the first steps needs)."""
        out: List[Tuple[int, int]] = []
        for t in self.tasks:
            n_parts = max(1, math.ceil(t.nbytes / max(chunk_bytes, 1)))
            base, rem = divmod(t.nbytes, n_parts)
            for i in range(n_parts):
                out.append((t.layer, base + (1 if i < rem else 0)))
        return out


@dataclasses.dataclass
class TransferPlan:
    streams: List[TransferStream]
    topology: Topology
    chunk_bytes: int = 64 * 1024 * 1024

    @property
    def total_bytes(self) -> int:
        return sum(s.nbytes for s in self.streams)

    @property
    def pod_local_bytes(self) -> int:
        return sum(s.nbytes for s in self.streams if s.link == ICI)

    def pod_local_fraction(self) -> float:
        total = self.total_bytes
        return self.pod_local_bytes / total if total else 1.0

    def source_of(self, dst: str, layer: int) -> Optional[str]:
        for s in self.streams:
            if s.dst == dst and layer in s.layers:
                return s.src
        return None

    def incoming(self, dst: str) -> List[Tuple[int, str]]:
        """Every (layer, src) this destination receives, in stream then
        task order — what a multi-host worker must actually FETCH over
        the wire for the node it hosts."""
        out: List[Tuple[int, str]] = []
        for s in self.streams:
            if s.dst == dst:
                out.extend((t.layer, s.src) for t in s.tasks)
        return out

    # ------------------------------------------------------------------
    # Timing: progressive filling over shared links
    # ------------------------------------------------------------------
    def _rates(self, active: List[int]) -> Dict[int, float]:
        """Instantaneous per-stream rate with the current active set.

        Each node's NIC aggregate is split evenly over its active
        streams; an ICI stream is additionally capped by one ICI link;
        DCN streams split each endpoint host's DCN allotment.
        """
        topo = self.topology
        at_node: Dict[str, int] = {}
        dcn_at: Dict[str, int] = {}
        for i in active:
            s = self.streams[i]
            at_node[s.src] = at_node.get(s.src, 0) + 1
            at_node[s.dst] = at_node.get(s.dst, 0) + 1
            if s.link == DCN:
                dcn_at[s.src] = dcn_at.get(s.src, 0) + 1
                dcn_at[s.dst] = dcn_at.get(s.dst, 0) + 1
        rates: Dict[int, float] = {}
        for i in active:
            s = self.streams[i]
            rate = min(topo.nic_capacity(s.src) / at_node[s.src],
                       topo.nic_capacity(s.dst) / at_node[s.dst])
            if s.link == ICI:
                rate = min(rate, topo.hw.ici_bandwidth)
            else:
                rate = min(rate,
                           topo.hw.dcn_bandwidth / dcn_at[s.src],
                           topo.hw.dcn_bandwidth / dcn_at[s.dst])
            rates[i] = rate
        return rates

    def finish_times(self) -> List[float]:
        """Per-stream completion time; all streams start at t=0 and
        share links per _rates (streams speed up as peers drain)."""
        remaining = {i: float(s.nbytes) for i, s in enumerate(self.streams)
                     if s.nbytes > 0}
        finish = [0.0] * len(self.streams)
        t = 0.0
        while remaining:
            active = sorted(remaining)
            rates = self._rates(active)
            dt = min(remaining[i] / rates[i] for i in active)
            t += dt
            for i in active:
                remaining[i] -= dt * rates[i]
                if remaining[i] <= 1e-6 * max(self.streams[i].nbytes, 1):
                    finish[i] = t
                    del remaining[i]
        return finish

    def makespan(self) -> float:
        """Recovery transfer time: MAX over parallel streams (the
        acceptance metric), not the serial sum of bytes."""
        times = self.finish_times()
        return max(times) if times else 0.0

    def exposed_seconds(self, overlap_seconds: float = 0.0) -> float:
        """Transfer time not hidden behind post-recovery compute: chunked
        streams overlap with the first steps the warm program cache can
        already run (DESIGN.md §9)."""
        return max(0.0, self.makespan() - max(overlap_seconds, 0.0))

    def serial_seconds(self) -> float:
        """The pre-data-plane accounting (sum of bytes over one link) —
        kept for the benchmark's before/after comparison."""
        return sum(s.nbytes / self.topology.link_bandwidth(s.link)
                   for s in self.streams)

    # ------------------------------------------------------------------
    def validate(self, dead: Iterable[str] = (),
                 expected_bytes: Optional[int] = None) -> None:
        """Raise TransferPlanError unless the plan honours the contract:
        no stream reads a failed node, no stream loops back to its
        source, every route's link matches pod placement, and no bytes
        were dropped relative to the copy plan."""
        dead = set(dead)
        for s in self.streams:
            if s.src in dead:
                raise TransferPlanError(
                    f"stream {s.src}->{s.dst} reads failed node {s.src}")
            if s.src == s.dst:
                raise TransferPlanError(f"self-copy at {s.src}")
            if s.link != self.topology.link_kind(s.src, s.dst):
                raise TransferPlanError(
                    f"stream {s.src}->{s.dst} labelled {s.link} but pods "
                    f"say {self.topology.link_kind(s.src, s.dst)}")
            for t in s.tasks:
                if t.dst_node != s.dst:
                    raise TransferPlanError(
                        f"task for {t.dst_node} routed into stream to {s.dst}")
        if expected_bytes is not None and self.total_bytes != expected_bytes:
            raise TransferPlanError(
                f"plan moves {self.total_bytes} bytes, copy plan asked for "
                f"{expected_bytes}")

    def stats(self) -> Dict[str, float]:
        return {"streams": len(self.streams),
                "bytes": self.total_bytes,
                "pod_local_fraction": self.pod_local_fraction(),
                "seconds": self.makespan(),
                "serial_seconds": self.serial_seconds()}


# ----------------------------------------------------------------------
# Scheduling
# ----------------------------------------------------------------------
def schedule_transfers(copy_plan: Sequence[CopyTask], topology: Topology,
                       dead: Iterable[str] = (),
                       chunk_bytes: int = 64 * 1024 * 1024) -> TransferPlan:
    """Turn the reconfigurator's copy plan into parallel streams.

    For every task the final source is re-chosen among the surviving
    replicas the task carries (``task.sources``; falls back to the
    reconfigurator's pick): pod-local replicas beat cross-pod ones, and
    within a tier the sender with the fewest bytes already assigned
    wins, so no single replica becomes the copy hot-spot.
    """
    dead = set(dead)
    load: Dict[str, int] = {}
    by_pair: Dict[Tuple[str, str], List[CopyTask]] = {}
    for task in copy_plan:
        candidates = [n for n in (task.sources or (task.src_node,))
                      if n not in dead and n != task.dst_node]
        if not candidates:
            raise TransferPlanError(
                f"layer {task.layer}: no surviving source for "
                f"{task.dst_node} (candidates all dead)")
        src = min(candidates, key=lambda n: (
            0 if topology.same_pod(n, task.dst_node) else 1,
            load.get(n, 0), n))
        load[src] = load.get(src, 0) + task.nbytes
        routed = (task if src == task.src_node
                  else dataclasses.replace(task, src_node=src))
        by_pair.setdefault((src, task.dst_node), []).append(routed)
    streams = [TransferStream(src=src, dst=dst,
                              link=topology.link_kind(src, dst),
                              tasks=sorted(tasks, key=lambda t: t.layer))
               for (src, dst), tasks in sorted(by_pair.items())]
    plan = TransferPlan(streams=streams, topology=topology,
                        chunk_bytes=chunk_bytes)
    plan.validate(dead, expected_bytes=sum(t.nbytes for t in copy_plan))
    return plan
