"""1F1B pipeline schedule (Figure 5): construction + makespan simulation.

``one_f_one_b(S, M)`` produces each stage's op sequence: a warmup of
(S - 1 - s) forwards, then alternating B/F in the steady phase, then a
drain of backwards.  ``simulate_makespan`` runs the dependency-driven
event simulation for arbitrary per-stage F/B times — used (a) to check
the planner's T1+T2+T3 critical-path estimate, (b) by the discrete-event
simulator to time heterogeneous pipelines.

The *adapted* mode (ReCycle, arXiv:2405.14009) re-routes a damaged
pipeline's microbatches to surviving peer data-parallel pipelines:
every pipeline replica holds the full model, so a guest microbatch is
just an extra (F, B) pair filling the host's decoupled-1F1B bubbles.
``adapt_reroute`` picks the hosts, ``adapted_per_stage`` builds the
per-host op sequences over (pipeline, mb) tagged microbatches, and
``adapted_flat_schedule`` serializes them through the same
dependency validator as ``flat_schedule``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Set, Tuple

Op = Tuple[str, int]          # ("F"|"B", microbatch index)
# Adapted-mode ops tag each microbatch with its source pipeline so a
# host can interleave native and guest work: ("F"|"B", (src_pipe, mb)).
TaggedOp = Tuple[str, Tuple[int, int]]


class ScheduleError(RuntimeError):
    """The per-stage op sequences deadlocked: no stage's head op has its
    dependencies satisfied.  Raised (never spun on) by flat_schedule."""


def one_f_one_b(num_stages: int, num_microbatches: int) -> List[List[Op]]:
    """Per-stage op sequences implementing 1F1B."""
    S, M = num_stages, num_microbatches
    assert M >= 1
    out: List[List[Op]] = []
    for s in range(S):
        warmup = min(S - 1 - s, M)
        ops: List[Op] = [("F", i) for i in range(warmup)]
        f_next, b_next = warmup, 0
        while b_next < M:
            if f_next < M:
                ops.append(("F", f_next)); f_next += 1
            ops.append(("B", b_next)); b_next += 1
        out.append(ops)
    return out


def flat_schedule(num_stages: int, num_microbatches: int,
                  per_stage: Optional[List[List[Op]]] = None
                  ) -> List[Tuple[int, str, int]]:
    """Dependency-respecting serialization: (stage, op, mb) triples in an
    order a single controller can execute.

    ``per_stage`` overrides the generated 1F1B sequences (used by tests
    and by callers with custom schedules).  A malformed sequence — an op
    whose dependency can never be produced — raises ``ScheduleError``
    naming every stuck (stage, op, mb) head instead of spinning: the
    ``while len(out) < total`` loop would otherwise never terminate once
    ``progressed`` stays False.
    """
    if per_stage is None:
        per_stage = one_f_one_b(num_stages, num_microbatches)
    else:
        num_stages = len(per_stage)     # the sequences define the stages
    ptr = [0] * num_stages
    done_f = [set() for _ in range(num_stages)]
    done_b = [set() for _ in range(num_stages)]
    out: List[Tuple[int, str, int]] = []
    total = sum(len(ops) for ops in per_stage)
    while len(out) < total:
        progressed = False
        # favor deeper stages first (drain backwards early, 1F1B spirit)
        for s in reversed(range(num_stages)):
            if ptr[s] >= len(per_stage[s]):
                continue
            op, mb = per_stage[s][ptr[s]]
            ready = ((op == "F" and (s == 0 or mb in done_f[s - 1])) or
                     (op == "B" and (s == num_stages - 1 or mb in done_b[s + 1])
                      and mb in done_f[s]))
            if ready:
                out.append((s, op, mb))
                (done_f if op == "F" else done_b)[s].add(mb)
                ptr[s] += 1
                progressed = True
        if not progressed:
            stuck = [(s, *per_stage[s][ptr[s]]) for s in range(num_stages)
                     if ptr[s] < len(per_stage[s])]
            raise ScheduleError(
                f"schedule cannot progress after {len(out)}/{total} ops; "
                f"stuck head ops (stage, op, mb): {stuck}")
    return out


def adapt_reroute(mb_counts: Sequence[int],
                  dead_pipelines: Set[int]) -> Dict[int, List[Tuple[int, int]]]:
    """Assign every microbatch of each dead pipeline to a surviving host.

    Returns {host_pipeline: [(src_pipeline, mb), ...]} covering exactly
    the dead pipelines' microbatches.  Assignment is deterministic and
    balanced: each guest microbatch goes to the survivor with the least
    total load (native + already-assigned guests), ties broken by
    pipeline index, so replayed failures re-route identically.
    """
    for p in dead_pipelines:
        if not 0 <= p < len(mb_counts):
            raise ScheduleError(f"dead pipeline {p} out of range "
                                f"(have {len(mb_counts)} pipelines)")
    survivors = [p for p in range(len(mb_counts)) if p not in dead_pipelines]
    if not survivors:
        raise ScheduleError("adaptation infeasible: no surviving pipeline "
                            f"to host re-routed microbatches (dead="
                            f"{sorted(dead_pipelines)})")
    load = {p: mb_counts[p] for p in survivors}
    routes: Dict[int, List[Tuple[int, int]]] = {p: [] for p in survivors}
    for src in sorted(dead_pipelines):
        for mb in range(mb_counts[src]):
            host = min(survivors, key=lambda p: (load[p], p))
            routes[host].append((src, mb))
            load[host] += 1
    return {p: r for p, r in routes.items() if r}


def adapted_per_stage(num_stages: int, mb_counts: Sequence[int],
                      dead_pipelines: Set[int]
                      ) -> Dict[int, List[List[TaggedOp]]]:
    """Per-stage op sequences for every surviving pipeline after
    re-routing dead pipelines' microbatches (decoupled 1F1B
    bubble-filling: guests are appended to the host's microbatch stream,
    so they fill the drain-phase bubbles of the host's own schedule).

    Returns {host_pipeline: per_stage ops} where each op is
    ("F"|"B", (src_pipeline, mb)).  Native microbatches keep their own
    pipeline tag; a host with G guests runs one_f_one_b(S, M_host + G)
    with the tail G slots relabeled to the guests in route order.
    """
    routes = adapt_reroute(mb_counts, dead_pipelines)
    out: Dict[int, List[List[TaggedOp]]] = {}
    for host in range(len(mb_counts)):
        if host in dead_pipelines:
            continue
        guests = routes.get(host, [])
        native = mb_counts[host]
        # slot i < native → native mb i; slot native+j → guest j
        tags = ([(host, i) for i in range(native)] + list(guests))
        base = one_f_one_b(num_stages, native + len(guests))
        out[host] = [[(op, tags[mb]) for op, mb in ops] for ops in base]
    return out


def adapted_flat_schedule(num_stages: int, mb_counts: Sequence[int],
                          dead_pipelines: Set[int]
                          ) -> Dict[int, List[Tuple[int, str, Tuple[int, int]]]]:
    """Serialized adapted schedule per surviving pipeline:
    {host: [(stage, op, (src_pipeline, mb)), ...]}.

    Each host is serialized through ``flat_schedule``'s dependency
    validator (guest microbatches obey the same F-before-B,
    upstream-before-downstream rules as native ones), so a malformed
    adaptation raises ``ScheduleError`` instead of hanging.
    """
    per_host = adapted_per_stage(num_stages, mb_counts, dead_pipelines)
    out: Dict[int, List[Tuple[int, str, Tuple[int, int]]]] = {}
    for host, tagged in per_host.items():
        # flat_schedule validates over dense int mb ids; map tags to ids
        # and back so host-level dependency checking is reused verbatim.
        ids: Dict[Tuple[int, int], int] = {}
        for ops in tagged:
            for _, tag in ops:
                ids.setdefault(tag, len(ids))
        dense = [[(op, ids[tag]) for op, tag in ops] for ops in tagged]
        rev = {i: tag for tag, i in ids.items()}
        flat = flat_schedule(num_stages, len(ids), per_stage=dense)
        out[host] = [(s, op, rev[i]) for s, op, i in flat]
    return out


def simulate_makespan(stage_fwd: Sequence[float], stage_bwd: Sequence[float],
                      num_microbatches: int,
                      hop_time: float = 0.0) -> float:
    """Event-driven makespan of 1F1B with given per-stage F/B times."""
    S = len(stage_fwd)
    per_stage = one_f_one_b(S, num_microbatches)
    ptr = [0] * S
    free_at = [0.0] * S
    f_done: Dict[Tuple[int, int], float] = {}
    b_done: Dict[Tuple[int, int], float] = {}
    finish = 0.0
    remaining = sum(len(o) for o in per_stage)
    while remaining:
        progressed = False
        for s in range(S):
            while ptr[s] < len(per_stage[s]):
                op, mb = per_stage[s][ptr[s]]
                if op == "F":
                    dep = 0.0 if s == 0 else f_done.get((s - 1, mb))
                    if dep is None:
                        break
                    start = max(free_at[s], dep + (hop_time if s else 0.0))
                    end = start + stage_fwd[s]
                    f_done[(s, mb)] = end
                else:
                    if (s, mb) not in f_done:
                        break
                    dep = 0.0 if s == S - 1 else b_done.get((s + 1, mb))
                    if dep is None:
                        break
                    start = max(free_at[s], f_done[(s, mb)],
                                dep + (hop_time if s != S - 1 else 0.0))
                    end = start + stage_bwd[s]
                    b_done[(s, mb)] = end
                free_at[s] = end
                finish = max(finish, end)
                ptr[s] += 1
                remaining -= 1
                progressed = True
        if not progressed:
            raise RuntimeError("deadlock in makespan simulation")
    return finish
