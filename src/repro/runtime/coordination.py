"""Out-of-band coordination channel for multi-process training
(DESIGN.md §15).

Oobleck separates the *coordination* plane from the *collective* plane:
per-node agents hold plain TCP connections to a central coordinator, so
a process death is observed as a socket disconnect (instantly) or a
heartbeat timeout (bounded), never as a collective hanging until its own
timeout (§3.3).  This module is that channel for the multi-process
executor (runtime/multihost.py):

  * ``send_msg``/``recv_msg`` — a framed wire format: one length-
    prefixed JSON header plus N length-prefixed binary blobs.  Control
    traffic is all-JSON; tensor payloads ride the blobs untouched (raw
    row-major bytes, so fp32 state crosses the wire bit-exactly);
  * ``CoordinatorServer`` — the coordinator's side: accepts one control
    connection per worker, runs a reader thread per socket that feeds
    heartbeats into a ``core.monitor.HeartbeatTracker`` and routes
    request replies by ``req_id``; socket EOF fences the worker
    immediately (the disconnect-as-failure signal);
  * ``WorkerChannel`` — the worker's side: one control socket, a beat
    thread, and a blocking serve loop dispatching coordinator requests
    to registered handlers;
  * ``DataServer``/``data_call`` — a one-request-per-connection bulk
    channel between workers, used by recovery to pull layer states from
    surviving replicas (runtime/transfer.py CopyTask streams become
    actual cross-process transfers through this).

Everything here is pure stdlib + numpy on the wire; jax appears only to
flatten/unflatten pytrees at the edges.
"""
from __future__ import annotations

import itertools
import json
import queue
import socket
import struct
import threading
import traceback
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.monitor import HeartbeatConfig, HeartbeatTracker

Header = Dict[str, Any]
Blobs = Sequence[bytes]

_LEN = struct.Struct(">Q")
_MAX_FRAME = 1 << 34        # 16 GiB sanity bound on any one length field


class WorkerLost(RuntimeError):
    """A control-plane peer died (socket EOF or heartbeat timeout) while
    we were waiting on it.  Carries the ranks involved."""

    def __init__(self, ranks: Iterable[int], why: str = ""):
        self.ranks = sorted(set(ranks))
        super().__init__(f"worker(s) {self.ranks} lost"
                         + (f": {why}" if why else ""))


class EpochMismatch(RuntimeError):
    """Two sides of the reconfiguration protocol disagree on the
    reconfiguration epoch or its plan fingerprint — the agreed-epoch
    invariant would be violated by proceeding."""


# ----------------------------------------------------------------------
# Wire format
# ----------------------------------------------------------------------
def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(min(n - len(buf), 1 << 20))
        if not chunk:
            raise ConnectionError("peer closed mid-message")
        buf.extend(chunk)
    return bytes(buf)


def send_msg(sock: socket.socket, header: Header, blobs: Blobs = (),
             lock: Optional[threading.Lock] = None) -> None:
    """One framed message: [len][json header][nblobs]([len][bytes])*.
    The whole frame goes out as a single ``sendall`` under ``lock`` so
    concurrent senders on a shared socket (beat thread vs. reply path)
    never interleave frames."""
    payload = json.dumps(header, sort_keys=True).encode()
    parts = [_LEN.pack(len(payload)), payload, _LEN.pack(len(blobs))]
    for b in blobs:
        parts.append(_LEN.pack(len(b)))
        parts.append(bytes(b))
    frame = b"".join(parts)
    if lock is not None:
        with lock:
            sock.sendall(frame)
    else:
        sock.sendall(frame)


def recv_msg(sock: socket.socket) -> Tuple[Header, List[bytes]]:
    n = _LEN.unpack(_recv_exact(sock, _LEN.size))[0]
    if n > _MAX_FRAME:
        raise ConnectionError(f"oversized header ({n} bytes)")
    header = json.loads(_recv_exact(sock, n))
    k = _LEN.unpack(_recv_exact(sock, _LEN.size))[0]
    if k > 1 << 20:
        raise ConnectionError(f"implausible blob count ({k})")
    blobs = []
    for _ in range(k):
        m = _LEN.unpack(_recv_exact(sock, _LEN.size))[0]
        if m > _MAX_FRAME:
            raise ConnectionError(f"oversized blob ({m} bytes)")
        blobs.append(_recv_exact(sock, m))
    return header, blobs


# ----------------------------------------------------------------------
# Pytree <-> (spec, blobs): raw bytes on the wire, bit-exact round trip
# ----------------------------------------------------------------------
def pack_tree(tree: Any) -> Tuple[List[List], List[bytes]]:
    """Flatten a pytree of arrays to ([(keypath, shape, dtype)], [raw
    bytes]) in canonical flatten order.  The receiving side unpacks
    against a structurally identical skeleton; the spec is carried for
    verification, not reconstruction."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    spec: List[List] = []
    blobs: List[bytes] = []
    for path, leaf in flat:
        a = np.asarray(leaf)
        spec.append([jax.tree_util.keystr(path), list(a.shape),
                     a.dtype.name])
        blobs.append(np.ascontiguousarray(a).tobytes())
    return spec, blobs


def unpack_tree(skeleton: Any, spec: Sequence[Sequence],
                blobs: Sequence[bytes]) -> Any:
    """Rebuild a pytree from ``pack_tree`` output.  ``skeleton`` is any
    pytree with the same structure (avals or arrays); each leaf's shape
    and dtype come from the wire spec and are cross-checked against the
    skeleton's key paths."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(skeleton)
    if len(flat) != len(blobs):
        raise ValueError(f"skeleton has {len(flat)} leaves, "
                         f"wire message has {len(blobs)}")
    leaves = []
    for (path, _), (key, shape, dtype), raw in zip(flat, spec, blobs):
        if jax.tree_util.keystr(path) != key:
            raise ValueError(f"tree structure mismatch at {key!r} vs "
                             f"{jax.tree_util.keystr(path)!r}")
        leaves.append(jnp.asarray(
            np.frombuffer(raw, dtype=dtype).reshape(shape)))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def pack_batches(per_pipeline: Sequence[Sequence[Dict[str, Any]]]
                 ) -> Tuple[List[List[List]], List[bytes]]:
    """Serialize per-pipeline microbatch lists (the coordinator->worker
    data feed).  Structure rides in the spec — the receiver has no
    skeleton because microbatch counts change every reconfiguration."""
    spec: List[List[List]] = []
    blobs: List[bytes] = []
    for mbs in per_pipeline:
        mspec = []
        for mb in mbs:
            keys = sorted(mb)
            entry = []
            for k in keys:
                a = np.asarray(mb[k])
                entry.append([k, list(a.shape), a.dtype.name])
                blobs.append(np.ascontiguousarray(a).tobytes())
            mspec.append(entry)
        spec.append(mspec)
    return spec, blobs


def unpack_batches(spec: Sequence[Sequence[Sequence]],
                   blobs: Sequence[bytes]
                   ) -> List[List[Dict[str, np.ndarray]]]:
    it = iter(blobs)
    out: List[List[Dict[str, np.ndarray]]] = []
    for mspec in spec:
        mbs = []
        for entry in mspec:
            mb = {}
            for k, shape, dtype in entry:
                mb[k] = np.frombuffer(next(it), dtype=dtype).reshape(shape)
            mbs.append(mb)
        out.append(mbs)
    return out


# ----------------------------------------------------------------------
# Coordinator side
# ----------------------------------------------------------------------
def member_of(rank: int) -> str:
    return f"proc{rank}"


def rank_of(member: str) -> int:
    assert member.startswith("proc"), member
    return int(member[4:])


class CoordinatorServer:
    """The coordinator's half of the control plane.

    One listening socket; each worker connects once and sends a HELLO.
    Per-worker reader threads then: (a) feed ``beat`` messages into the
    heartbeat tracker, (b) route replies to the ``call`` that issued the
    matching ``req_id``, and (c) on socket EOF immediately fence the
    worker via ``mark_dead`` — Oobleck's disconnect-as-failure signal,
    no timeout needed for a SIGKILL.  ``call``/``broadcast_call`` raise
    ``WorkerLost`` the moment a waited-on worker is declared dead, so
    the training loop never hangs on a corpse.
    """

    def __init__(self, nprocs: int,
                 heartbeat: Optional[HeartbeatConfig] = None,
                 host: str = "127.0.0.1"):
        self.nprocs = nprocs
        self.tracker = HeartbeatTracker(heartbeat or HeartbeatConfig())
        self._listener = socket.create_server((host, 0))
        self.addr: Tuple[str, int] = self._listener.getsockname()[:2]
        self._socks: Dict[int, socket.socket] = {}
        self._send_locks: Dict[int, threading.Lock] = {}
        self._hello: Dict[int, Header] = {}
        self._pending: Dict[str, "queue.Queue"] = {}
        self._req_ids = itertools.count()
        self._closed = False

    # -- bootstrap -----------------------------------------------------
    def accept_workers(self, timeout: float = 120.0) -> Dict[int, Header]:
        """Block until every expected worker has connected and said
        HELLO; returns rank -> hello header (which carries the worker's
        data-server address)."""
        self._listener.settimeout(timeout)
        for _ in range(self.nprocs):
            sock, _ = self._listener.accept()
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            header, _ = recv_msg(sock)
            if header.get("type") != "hello":
                raise ConnectionError(f"expected hello, got {header}")
            rank = int(header["rank"])
            self._socks[rank] = sock
            self._send_locks[rank] = threading.Lock()
            self._hello[rank] = header
            self.tracker.register(member_of(rank))
            threading.Thread(target=self._reader, args=(rank, sock),
                             daemon=True).start()
        return dict(self._hello)

    # -- per-worker reader ---------------------------------------------
    def _reader(self, rank: int, sock: socket.socket) -> None:
        try:
            while True:
                header, blobs = recv_msg(sock)
                if header.get("type") == "beat":
                    self.tracker.beat(member_of(rank))
                    continue
                q = self._pending.get(header.get("req_id"))
                if q is not None:
                    q.put((header, blobs))
        except (ConnectionError, OSError):
            if not self._closed:
                self.tracker.mark_dead(member_of(rank))

    # -- request/response ----------------------------------------------
    def _new_pending(self) -> Tuple[str, "queue.Queue"]:
        rid = f"c{next(self._req_ids)}"
        q: "queue.Queue" = queue.Queue()
        self._pending[rid] = q
        return rid, q

    def _send(self, rank: int, header: Header, blobs: Blobs) -> None:
        try:
            send_msg(self._socks[rank], header, blobs,
                     lock=self._send_locks[rank])
        except OSError:
            self.tracker.mark_dead(member_of(rank))
            raise WorkerLost([rank], "send failed")

    def _wait(self, rank: int, rid: str, q: "queue.Queue",
              timeout: Optional[float]) -> Tuple[Header, List[bytes]]:
        waited = 0.0
        while True:
            try:
                header, blobs = q.get(timeout=0.1)
                break
            except queue.Empty:
                if self.tracker.status(member_of(rank)) == \
                        HeartbeatTracker.DEAD:
                    raise WorkerLost([rank], "died during call")
                waited += 0.1
                if timeout is not None and waited >= timeout:
                    raise TimeoutError(
                        f"rank {rank} did not answer {rid} "
                        f"within {timeout}s")
        if header.get("status") == "error":
            raise RuntimeError(
                f"rank {rank} raised:\n{header.get('error')}")
        return header, blobs

    def call(self, rank: int, header: Header, blobs: Blobs = (),
             timeout: Optional[float] = None) -> Tuple[Header, List[bytes]]:
        rid, q = self._new_pending()
        try:
            self._send(rank, dict(header, req_id=rid), blobs)
            return self._wait(rank, rid, q, timeout)
        finally:
            self._pending.pop(rid, None)

    def broadcast_call(self, header: Header, blobs: Blobs = (),
                       ranks: Optional[Iterable[int]] = None,
                       timeout: Optional[float] = None,
                       strict: bool = True
                       ) -> Dict[int, Tuple[Header, List[bytes]]]:
        """Issue the same request to many workers CONCURRENTLY (all
        sends first, then all waits) — a step's grads phase runs on
        every worker in parallel.  Raises WorkerLost naming every rank
        that died, after collecting all live replies.  With
        ``strict=False`` the live replies are returned instead — the
        step-commit path uses this: survivors that answered HAVE
        committed, so a death mid-commit must not fail the step."""
        ranks = sorted(self._socks) if ranks is None else sorted(ranks)
        issued: Dict[int, Tuple[str, "queue.Queue"]] = {}
        lost: List[int] = []
        for r in ranks:
            rid, q = self._new_pending()
            issued[r] = (rid, q)
            try:
                self._send(r, dict(header, req_id=rid), blobs)
            except WorkerLost:
                lost.append(r)
        results: Dict[int, Tuple[Header, List[bytes]]] = {}
        try:
            for r, (rid, q) in issued.items():
                if r in lost:
                    continue
                try:
                    results[r] = self._wait(r, rid, q, timeout)
                except WorkerLost:
                    lost.append(r)
        finally:
            for rid, _ in issued.values():
                self._pending.pop(rid, None)
        if lost and strict:
            raise WorkerLost(lost, f"during {header.get('type')}")
        return results

    def multi_call(self, requests: Dict[int, Tuple[Header, Blobs]],
                   timeout: Optional[float] = None
                   ) -> Dict[int, Tuple[Header, List[bytes]]]:
        """Like broadcast_call but with a DIFFERENT payload per rank —
        the step's grads phase sends each worker only the microbatches
        of the replicas it leads."""
        issued: Dict[int, Tuple[str, "queue.Queue"]] = {}
        lost: List[int] = []
        for r, (header, blobs) in sorted(requests.items()):
            rid, q = self._new_pending()
            issued[r] = (rid, q)
            try:
                self._send(r, dict(header, req_id=rid), blobs)
            except WorkerLost:
                lost.append(r)
        results: Dict[int, Tuple[Header, List[bytes]]] = {}
        try:
            for r, (rid, q) in issued.items():
                if r in lost:
                    continue
                try:
                    results[r] = self._wait(r, rid, q, timeout)
                except WorkerLost:
                    lost.append(r)
        finally:
            for rid, _ in issued.values():
                self._pending.pop(rid, None)
        if lost:
            raise WorkerLost(lost, "during multi_call")
        return results

    def notify(self, rank: int, header: Header, blobs: Blobs = ()) -> None:
        """Fire-and-forget (shutdown etc.); send errors are swallowed —
        a dead worker doesn't need the message."""
        try:
            self._send(rank, header, blobs)
        except WorkerLost:
            pass

    # -- liveness ------------------------------------------------------
    def alive_ranks(self) -> List[int]:
        return sorted(r for r in self._socks
                      if self.tracker.status(member_of(r))
                      != HeartbeatTracker.DEAD)

    def poll_dead(self) -> List[int]:
        """Ranks NEWLY declared dead since the last poll (socket EOF or
        heartbeat silence past the dead_after window)."""
        return sorted(rank_of(m) for m in self.tracker.poll())

    def close(self) -> None:
        self._closed = True
        for sock in self._socks.values():
            try:
                sock.close()
            except OSError:
                pass
        try:
            self._listener.close()
        except OSError:
            pass


# ----------------------------------------------------------------------
# Worker side
# ----------------------------------------------------------------------
class WorkerChannel:
    """The worker's half: one control socket to the coordinator, a beat
    thread (every ``interval`` seconds, under the shared send lock), and
    a blocking ``serve`` loop dispatching coordinator requests to
    handlers.  The serve loop exits on a ``shutdown`` message or socket
    EOF — a worker outliving its coordinator exits instead of spinning."""

    def __init__(self, coordinator: Tuple[str, int], rank: int,
                 hello: Optional[Header] = None,
                 beat_interval: float = 0.5):
        self.rank = rank
        self.sock = socket.create_connection(tuple(coordinator),
                                             timeout=120.0)
        self.sock.settimeout(None)
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._send_lock = threading.Lock()
        send_msg(self.sock, dict(hello or {}, type="hello", rank=rank),
                 lock=self._send_lock)
        self._stop = threading.Event()
        threading.Thread(target=self._beat_loop, args=(beat_interval,),
                         daemon=True).start()

    def _beat_loop(self, interval: float) -> None:
        while not self._stop.wait(interval):
            try:
                send_msg(self.sock, {"type": "beat"},
                         lock=self._send_lock)
            except OSError:
                return

    def serve(self, handlers: Dict[str, Callable[[Header, List[bytes]],
                                                 Tuple[Header, Blobs]]]
              ) -> None:
        while True:
            try:
                header, blobs = recv_msg(self.sock)
            except (ConnectionError, OSError):
                return
            kind = header.get("type")
            if kind == "shutdown":
                return
            rid = header.get("req_id")
            try:
                fn = handlers[kind]
                reply, rblobs = fn(header, blobs)
            except Exception:
                reply, rblobs = ({"status": "error",
                                  "error": traceback.format_exc()}, ())
            try:
                send_msg(self.sock, dict(reply, req_id=rid), rblobs,
                         lock=self._send_lock)
            except OSError:
                return

    def close(self) -> None:
        self._stop.set()
        try:
            self.sock.close()
        except OSError:
            pass


# ----------------------------------------------------------------------
# Worker <-> worker bulk data plane (recovery state pulls)
# ----------------------------------------------------------------------
class DataServer:
    """Threaded one-request-per-connection TCP server.  Recovery's
    CopyTask streams execute against this: the destination worker
    connects to the source worker's DataServer and pulls the layer
    state as raw bytes.  Runs on its own threads so a worker can SERVE
    state while its control thread is simultaneously PULLING state from
    a peer — the two-phase commit would deadlock otherwise."""

    def __init__(self, handler: Callable[[Header, List[bytes]],
                                         Tuple[Header, Blobs]],
                 host: str = "127.0.0.1"):
        self._handler = handler
        self._listener = socket.create_server((host, 0))
        self.addr: Tuple[str, int] = self._listener.getsockname()[:2]
        self._closed = False
        threading.Thread(target=self._accept_loop, daemon=True).start()

    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                sock, _ = self._listener.accept()
            except OSError:
                return
            threading.Thread(target=self._serve_one, args=(sock,),
                             daemon=True).start()

    def _serve_one(self, sock: socket.socket) -> None:
        try:
            with sock:
                header, blobs = recv_msg(sock)
                try:
                    reply, rblobs = self._handler(header, blobs)
                except Exception:
                    reply, rblobs = ({"status": "error",
                                      "error": traceback.format_exc()}, ())
                send_msg(sock, reply, rblobs)
        except (ConnectionError, OSError):
            pass

    def close(self) -> None:
        self._closed = True
        try:
            self._listener.close()
        except OSError:
            pass


def data_call(addr: Sequence, header: Header, blobs: Blobs = (),
              timeout: float = 60.0) -> Tuple[Header, List[bytes]]:
    """One request against a peer's DataServer."""
    host, port = addr[0], int(addr[1])
    with socket.create_connection((host, port), timeout=timeout) as sock:
        send_msg(sock, header, blobs)
        reply, rblobs = recv_msg(sock)
    if reply.get("status") == "error":
        raise RuntimeError(f"data server {host}:{port} raised:\n"
                           f"{reply.get('error')}")
    return reply, rblobs
