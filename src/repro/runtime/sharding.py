"""Sharding strategies: parameter/optimizer/activation PartitionSpecs.

Oobleck-on-GPU uses FSDP inside each pipeline stage (§6 of the paper) —
on TPU that is parameters sharded over the ``model`` axis with
all-gather-at-use (ZeRO-3 semantics under GSPMD).  We additionally
implement Megatron-style tensor parallelism ("tp") as a beyond-paper
alternative (column/row-parallel projections; activations stay sharded
over heads inside a block), plus ZeRO-1 optimizer-state sharding over the
data axes for either strategy.

Specs are derived by pattern-matching parameter tree paths, with
divisibility guards: a dimension is only sharded if the mesh axis divides
it (GQA models with few KV heads etc. fall back to replication for that
tensor).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShardingStrategy:
    """How to lay a model out on a ("pod",)? + ("data", "model") mesh."""

    strategy: str = "fsdp"        # fsdp | tp
    zero1: bool = True            # shard optimizer moments over data axes
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        """Axes the batch shards over.  Pure FSDP (ZeRO-3) compute is
        data-parallel across EVERY chip — the ``model`` axis only shards
        parameter storage — so the batch spans it too.  TP keeps compute
        partitioned over ``model`` and shards the batch over data axes
        only."""
        if self.strategy == "fsdp":
            return self.data_axes + (self.model_axis,)
        return self.data_axes

    # ------------------------------------------------------------------
    def _axis_size(self, mesh: Mesh, axis) -> int:
        if isinstance(axis, tuple):
            out = 1
            for a in axis:
                out *= mesh.shape[a]
            return out
        return mesh.shape[axis]

    def _maybe(self, mesh: Mesh, dim_size: int, axis):
        """Return axis if it divides dim_size, else None (replicate)."""
        return axis if dim_size % self._axis_size(mesh, axis) == 0 else None

    # ------------------------------------------------------------------
    def param_spec(self, mesh: Mesh, path: str, shape: Tuple[int, ...]) -> P:
        """PartitionSpec for one parameter.  ``path`` like
        'blocks/attn/wq' (leading 'blocks' means a stacked [L, ...] dim)."""
        m = self.model_axis
        stacked = path.startswith("blocks/")
        lead = (None,) if stacked else ()
        body = shape[1:] if stacked else shape

        def col(i):  # shard output dim i of the body
            specs = [None] * len(body)
            specs[i] = self._maybe(mesh, body[i], m)
            return P(*lead, *specs)

        name = path.split("/")[-1]
        parent = path.split("/")[-2] if "/" in path else ""

        if self.strategy == "fsdp":
            # shard the largest dim of every >=2D tensor over `model`.
            if len(body) >= 2:
                i = int(np.argmax(body))
                return col(i)
            return P(*lead, *([None] * len(body)))

        # ---- Megatron TP ------------------------------------------------
        if parent == "moe" and name in ("gate", "up", "down"):
            return col(0)                       # expert parallelism over E
        if name in ("wq", "wk", "wv", "gate", "up", "in_proj"):
            return col(len(body) - 1)           # column parallel
        if name in ("wo", "down", "out_proj"):
            return col(len(body) - 2) if len(body) >= 2 else col(0)
        if name in ("bq", "bk", "bv"):
            return col(0)
        if name == "table":
            return col(0)                       # vocab-sharded embedding
        if name == "router":
            return P(*lead, None, None)
        if name in ("conv_w", "conv_b"):
            return col(len(body) - 1)
        if name in ("A_log", "dt_bias", "D", "norm_w"):
            return col(0)
        return P(*lead, *([None] * len(body)))

    def param_shardings(self, mesh: Mesh, params: Any) -> Any:
        def spec_for(path, leaf):
            pstr = "/".join(_key_name(k) for k in path)
            return NamedSharding(mesh, self.param_spec(mesh, pstr, leaf.shape))
        return jax.tree_util.tree_map_with_path(spec_for, params)

    def opt_shardings(self, mesh: Mesh, opt_state: Any, params: Any) -> Any:
        """Moments: like params; with ZeRO-1 additionally shard the first
        unsharded dim over the data axes."""
        pspecs = self.param_shardings(mesh, params)

        def zero1_spec(ns: NamedSharding, leaf) -> NamedSharding:
            if not self.zero1:
                return ns
            spec = list(ns.spec) + [None] * (leaf.ndim - len(ns.spec))
            daxis = self.data_axes if len(self.data_axes) > 1 else self.data_axes[0]
            for i, (s, dim) in enumerate(zip(spec, leaf.shape)):
                if s is None and dim % self._axis_size(ns.mesh, daxis) == 0 \
                        and dim >= 2 * self._axis_size(ns.mesh, daxis):
                    spec[i] = daxis
                    return NamedSharding(ns.mesh, P(*spec))
            return ns

        m = jax.tree.map(zero1_spec, pspecs, params)
        v = jax.tree.map(zero1_spec, pspecs, params)
        step = NamedSharding(mesh, P())
        return type(opt_state)(step=step, m=m, v=v)

    # ------------------------------------------------------------------
    def batch_spec(self, mesh: Mesh, global_batch: int) -> P:
        """Shard the batch over the longest prefix of batch_axes that
        divides it (small serving batches drop the model axis first,
        then pods; batch=1 replicates)."""
        axes = list(self.batch_axes)
        while axes:
            axis = tuple(axes) if len(axes) > 1 else axes[0]
            if global_batch % self._axis_size(mesh, axis) == 0:
                return P(axis)
            axes.pop()
        return P()

    def act_constrainer(self, mesh: Mesh, global_batch: int):
        bspec = self.batch_spec(mesh, global_batch)
        batch_axis = bspec[0] if len(bspec) else None
        # sequence parallelism over whatever batch axes the (small) batch
        # could not cover: activations [b, s, d] shard s over the leftover
        # axes so compute still spans every chip (GSPMD inserts the
        # gathers sequence-dependent ops need, e.g. K/V for attention).
        used = set()
        if batch_axis is not None:
            used = set(batch_axis) if isinstance(batch_axis, tuple) else {batch_axis}
        leftover = tuple(a for a in self.batch_axes if a not in used)
        seq_axis = (leftover if len(leftover) > 1 else leftover[0]) if leftover else None

        model_free = self.model_axis not in used

        def constrain(x, name):
            if x.ndim < 2:
                return x
            if name == "logits":
                vocab = (self._maybe(mesh, x.shape[-1], self.model_axis)
                         if model_free else None)
                spec = P(batch_axis, *([None] * (x.ndim - 2)), vocab)
            elif name == "heads4d" and x.ndim == 4:
                # decode q/k/v: head_dim-sharded to match the KV cache —
                # uniform across GQA configs (KV heads rarely divide a
                # 16-wide model axis; head_dim 64/128 always does).  The
                # price is a small partial-sum all-reduce on the scores.
                if not model_free:
                    return x
                d_ax = self._maybe(mesh, x.shape[3], self.model_axis)
                spec = P(batch_axis, None, None, d_ax)
            elif x.ndim >= 3 and seq_axis is not None \
                    and x.shape[1] % self._axis_size(mesh, seq_axis) == 0 \
                    and x.shape[1] > 1:
                spec = P(batch_axis, seq_axis, *([None] * (x.ndim - 2)))
            else:
                spec = P(batch_axis, *([None] * (x.ndim - 1)))
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
        return constrain

    #: gather weights in this dtype (None keeps the storage dtype).
    #: bf16 halves both the all-gather bytes and the gathered buffers vs
    #: gathering the fp32 master copy; gradients then reduce-scatter in
    #: bf16 too (fp32 accumulation happens in the optimizer) — standard
    #: mixed-precision FSDP practice.  §Perf iteration A3.
    gather_dtype: Optional[str] = None

    def unshard_blocks(self, mesh: Mesh):
        """FSDP/ZeRO-3 semantics: all-gather a block's weights right
        before use so compute is purely data-parallel (backward of the
        gather is the gradient reduce-scatter).  Without this, GSPMD
        propagation turns dim-sharded weights into Megatron-TP with an
        activation all-reduce per projection — a different (and for FSDP,
        worse) collective pattern.  TP strategy: identity."""
        if self.strategy != "fsdp":
            return lambda tree: tree
        import jax.numpy as jnp
        cast = (jnp.dtype(self.gather_dtype) if self.gather_dtype else None)

        def unshard(tree):
            def one(t):
                if cast is not None and t.dtype == jnp.float32:
                    t = t.astype(cast)
                return jax.lax.with_sharding_constraint(
                    t, NamedSharding(mesh, P(*([None] * t.ndim))))
            return jax.tree.map(one, tree)
        return unshard

    def cache_shardings(self, mesh: Mesh, cache: Any, batch: int) -> Any:
        """KV/SSM caches: shard batch if divisible, else heads over model."""
        bspec = self.batch_spec(mesh, batch)
        batch_axis = bspec[0] if len(bspec) else None
        used = (set(batch_axis) if isinstance(batch_axis, tuple)
                else {batch_axis} if batch_axis else set())
        model_free = self.model_axis not in used

        def spec_for(path, leaf):
            # layouts: attn k/v [L, B, S, KV, D]; mamba conv [L, B, W, dim];
            # mamba ssm [L, B, H, P, N]
            pstr = "/".join(_key_name(k) for k in path)
            dims = [None] * leaf.ndim
            if leaf.ndim >= 2:
                dims[1] = batch_axis
            if model_free:
                if "attn" in pstr and leaf.ndim == 5:
                    # head_dim-sharded (matches the decode heads4d rule)
                    dims[4] = self._maybe(mesh, leaf.shape[4],
                                          self.model_axis)
                    if dims[4] is None:
                        dims[3] = self._maybe(mesh, leaf.shape[3],
                                              self.model_axis)
                elif "ssm" in pstr and leaf.ndim == 5:
                    dims[2] = self._maybe(mesh, leaf.shape[2], self.model_axis)
                    if dims[2] is None:
                        dims[3] = self._maybe(mesh, leaf.shape[3],
                                              self.model_axis)
                elif "conv" in pstr and leaf.ndim == 4:
                    dims[3] = self._maybe(mesh, leaf.shape[3], self.model_axis)
            return NamedSharding(mesh, P(*dims))
        return jax.tree_util.tree_map_with_path(spec_for, cache)


def _key_name(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def strategy_for(arch: ArchConfig, name: str = "fsdp",
                 data_axes: Tuple[str, ...] = ("data",)) -> ShardingStrategy:
    return ShardingStrategy(strategy=name, data_axes=data_axes)
