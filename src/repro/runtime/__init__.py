from repro.runtime.coordination import (CoordinatorServer, DataServer,
                                        EpochMismatch, WorkerChannel,
                                        WorkerLost, data_call, pack_batches,
                                        pack_tree, recv_msg, send_msg,
                                        unpack_batches, unpack_tree)
from repro.runtime.executor import (CompileCounter, Executor,
                                    ExecutorUnsupported, ProgramCache,
                                    template_signature, track_compiles,
                                    track_host_transfers, tree_spec)
from repro.runtime.pipeline import HeteroTrainer, split_into_layers
from repro.runtime.multihost import (MultiHostExecutor, ShardTrainer,
                                     build_setup, layer_state_hash,
                                     make_job_spec)
from repro.runtime.schedule import (ScheduleError, adapt_reroute,
                                    adapted_flat_schedule, adapted_per_stage,
                                    flat_schedule, one_f_one_b,
                                    simulate_makespan)
from repro.runtime.serve_exec import (SamplingParams, ServeExecutor,
                                      ServeRequest)
from repro.runtime.sharding import ShardingStrategy
from repro.runtime import spmd
from repro.runtime.spmd import SPMDExecutor
from repro.runtime.sync_exec import (BucketedSync, BucketExec,
                                     perlayer_global_sumsq, perlayer_sync)
from repro.runtime.transfer import (Topology, TransferPlan, TransferPlanError,
                                    TransferStream, schedule_transfers)

__all__ = ["CoordinatorServer", "DataServer", "EpochMismatch",
           "WorkerChannel", "WorkerLost", "data_call", "pack_batches",
           "pack_tree", "recv_msg", "send_msg", "unpack_batches",
           "unpack_tree",
           "CompileCounter", "Executor", "ExecutorUnsupported",
           "ProgramCache", "template_signature", "track_compiles",
           "track_host_transfers", "tree_spec",
           "HeteroTrainer", "split_into_layers",
           "MultiHostExecutor", "ShardTrainer", "build_setup",
           "layer_state_hash", "make_job_spec",
           "ScheduleError", "adapt_reroute", "adapted_flat_schedule",
           "adapted_per_stage", "flat_schedule", "one_f_one_b",
           "simulate_makespan",
           "SamplingParams", "ServeExecutor", "ServeRequest",
           "ShardingStrategy", "spmd", "SPMDExecutor", "BucketedSync",
           "BucketExec", "perlayer_global_sumsq", "perlayer_sync",
           "Topology", "TransferPlan", "TransferPlanError",
           "TransferStream", "schedule_transfers"]
