from repro.runtime.pipeline import HeteroTrainer, split_into_layers
from repro.runtime.schedule import (flat_schedule, one_f_one_b,
                                    simulate_makespan)
from repro.runtime.sharding import ShardingStrategy
from repro.runtime import spmd

__all__ = ["HeteroTrainer", "split_into_layers", "flat_schedule",
           "one_f_one_b", "simulate_makespan", "ShardingStrategy", "spmd"]
