"""Resilient serving data plane: continuous batching + template-based
inference fault tolerance (DESIGN.md §14).

Training got the paper's property in PR 2/3: recovery is a TABLE LOOKUP
because templates are precomputed (§4) and programs are precompiled
(§8).  This module gives SERVING the same property.  A ``ServeExecutor``
registers with the engine/monitor exactly like the trainers do
(Executor interface: bind / step / recover / join / snapshot), and every
``engine.instances`` entry becomes a decode-pipeline REPLICA with a
fixed-shape slot state:

    cache   model.init_cache(num_slots, max_len)   [L, B, ...] per leaf
    tok     [B] int32    last token per slot (next decode input)
    pos     [B] int32    absolute position per slot
    ngen    [B] int32    generated-token count per slot
    keys    [B, 2] u32   per-request PRNG base key per slot
    out     [B, cap] i32 generated-token ring (host harvests on finish)

Continuous batching (Orca-style) then NEVER changes a program's shapes:
admission teacher-forces a prompt into ONE slot (a scan of the very same
full-batch decode tick, other rows masked frozen), eviction is pure host
bookkeeping, and the decode tick is one donated compiled program with
in-program sampling — temperature/top-k, per-slot key folding — so the
steady-state loop does ZERO device->host syncs (the
``track_host_transfers`` contract) and ZERO recompiles (ProgramCache
keys are (kind, backend_signature, shapes) — DESIGN.md §8 discipline:
admit/evict mutate buffer CONTENTS only).

Sampling determinism is the recovery keystone: the token at generated
index ``n`` of a request with base key ``k`` is sampled with
``fold_in(k, P + n - 1)`` (P = prompt length) — a pure function of the
request and the position, never of batch composition or wall clock.  A
mid-decode failure therefore resumes bitwise-identically:

  fail event -> engine.handle_failure() replans instances from the
  precomputed template set (table lookup) -> surviving replicas inherit
  their slot state (max node-overlap matching) -> requests on dissolved
  replicas MIGRATE their cache rows to free slots (extract/install
  programs + CopyTasks scheduled through runtime/transfer.py's
  topology-aware streams, exactly like training state copies) ->
  requests whose layers lost every owner REPLAY by teacher-forcing the
  host-known prefix (prompt + already-streamed tokens) -> decode
  continues.  All through programs warmed at bootstrap:
  ``track_compiles`` asserts backend_compiles == 0 across the whole
  fail -> recover -> drain cycle.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.reconfigure import CopyTask, PipelineInstance
from repro.kernels import ops as kops
from repro.models import Model
from repro.runtime.executor import (Executor, ProgramCache, avals_of,
                                    tree_spec)
from repro.runtime.transfer import schedule_transfers


# ----------------------------------------------------------------------
# Requests + sampling
# ----------------------------------------------------------------------
@dataclasses.dataclass
class SamplingParams:
    temperature: float = 0.0
    top_k: int = 0                   # 0 = full vocab


@dataclasses.dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray               # [P] int32
    max_new: int                     # TOTAL generated tokens requested
    arrival_s: float = 0.0
    first_token_s: Optional[float] = None
    done_s: Optional[float] = None
    tokens: Optional[np.ndarray] = None     # filled on completion
    # tokens already emitted before a replay (streamed to the client;
    # teacher-forced back in, never regenerated)
    prior: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros((0,), np.int32))
    replays: int = 0
    migrations: int = 0

    @property
    def remaining(self) -> int:
        return self.max_new - len(self.prior)


def _sample_tokens(logits, keys, pos, temp, top_k: int):
    """In-program sampling: [B, V] fp32 logits -> [B] int32 tokens.

    Per-row key = fold_in(row base key, row position): a pure function
    of (request, position), so replay/migration reproduce the stream at
    ANY temperature.  vmapped per row so the math of one row is
    identical whether it runs in a [1]- or [B]-shaped program.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if top_k:
        kth = jax.lax.top_k(logits, top_k)[0][..., -1:]
        logits = jnp.where(logits < kth, -jnp.inf, logits)

    def one(lg, key, p):
        k = jax.random.fold_in(key, p)
        return jax.random.categorical(k, lg / jnp.maximum(temp, 1e-6))

    sampled = jax.vmap(one)(logits, keys, pos).astype(jnp.int32)
    return jnp.where(temp > 0, sampled, greedy)


# ----------------------------------------------------------------------
# Replica: one engine instance + its slot state
# ----------------------------------------------------------------------
class _Replica:
    def __init__(self, instance: PipelineInstance, num_slots: int, state):
        self.instance = instance
        self.cache, self.tok, self.pos, self.ngen, self.keys, self.out = state
        self.requests: List[Optional[ServeRequest]] = [None] * num_slots
        self.ngen_h = np.zeros(num_slots, np.int64)   # host shadow

    def active_mask(self) -> np.ndarray:
        return np.array([r is not None for r in self.requests], bool)

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.requests) if r is None]

    def state(self):
        return (self.cache, self.tok, self.pos, self.ngen, self.keys,
                self.out)

    def lost_layers(self, dead: Set[str]) -> List[int]:
        """Layers whose every serving owner died (cache unrecoverable)."""
        return [l for l in range(self.instance.template.num_layers)
                if set(self.instance.layer_owners(l)) <= dead]


# ----------------------------------------------------------------------
# The executor
# ----------------------------------------------------------------------
class ServeExecutor(Executor):
    """Continuous-batching serving runtime behind the Executor seam.

    ``engine.instances`` are the decode-pipeline replicas; the template
    describes stage placement/ownership for fault tolerance while the
    compiled programs are keyed ONLY by (kind, backend, shapes) — a
    replan swaps bookkeeping, never programs.
    """

    def __init__(self, model: Model, params: Dict, engine, *,
                 num_slots: int = 4, max_len: int = 64,
                 max_new_cap: int = 32,
                 sampling: Optional[SamplingParams] = None,
                 prompt_buckets: Optional[Sequence[int]] = None,
                 sample_key: Optional[jax.Array] = None,
                 admission: str = "continuous",
                 cache: Optional[ProgramCache] = None,
                 clock: Callable[[], float] = time.perf_counter):
        assert admission in ("continuous", "static")
        self.model = model
        self.params = params
        self.engine = engine
        self.num_slots = num_slots
        self.max_len = max_len
        self.cap = max_new_cap
        self.sampling = sampling or SamplingParams()
        self.admission = admission
        self.cache = cache or ProgramCache()
        self.clock = clock
        self.sample_key = (sample_key if sample_key is not None
                           else jax.random.PRNGKey(0))
        if prompt_buckets is None:
            prompt_buckets, b = [], 8
            while b < max_len:
                prompt_buckets.append(b)
                b *= 2
            prompt_buckets.append(max_len)
        self.buckets = sorted(set(prompt_buckets))
        assert self.buckets[-1] >= max_len, "buckets must cover max_len"

        self.queue: "deque[ServeRequest]" = deque()
        self.completed: List[ServeRequest] = []
        self.replicas: List[_Replica] = []
        self.ticks = 0
        self._next_rid = 0
        self.last_recovery: Optional[Dict] = None
        engine.attach_executor(self)
        self.bind()

    # ------------------------------------------------------------------
    # Executor interface
    # ------------------------------------------------------------------
    def bind(self) -> None:
        """Fresh replicas for the current instance set + warm every
        program the serving plane can ever need (§8: compile at
        bootstrap so recovery never compiles)."""
        self.replicas = [
            _Replica(inst, self.num_slots, self._fresh_state())
            for inst in self.engine.instances]
        self.warm()

    def step(self, batches=None) -> Dict:
        return self.tick()

    def snapshot(self, data_state: Optional[Dict] = None,
                 rng_seed: int = 0):
        return {
            "ticks": self.ticks,
            "completed": [r.rid for r in self.completed],
            "in_flight": [r.rid for rep in self.replicas
                          for r in rep.requests if r is not None],
            "queued": [r.rid for r in self.queue],
            "cache": self.cache.stats.as_dict(),
        }

    # ------------------------------------------------------------------
    # Request lifecycle
    # ------------------------------------------------------------------
    def submit(self, prompt: np.ndarray, max_new: int,
               rid: Optional[int] = None) -> ServeRequest:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if len(prompt) + max_new > self.max_len:
            raise ValueError(
                f"prompt({len(prompt)}) + max_new({max_new}) exceeds "
                f"max_len({self.max_len})")
        if max_new > self.cap:
            raise ValueError(f"max_new({max_new}) > out cap({self.cap})")
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        req = ServeRequest(rid=rid, prompt=prompt, max_new=max_new,
                           arrival_s=self.clock())
        self.queue.append(req)
        return req

    def tick(self) -> Dict:
        """One scheduler round: admit, one batched decode step per
        replica, harvest finished slots.  The decode inner loop does no
        device->host transfer; completions are detected from host
        shadows and only then is the finished row fetched."""
        admitted = 0
        for rep in self.replicas:
            free = rep.free_slots()
            if self.admission == "static" and len(free) < self.num_slots:
                free = []           # static baseline: drain, then refill
            for slot in free:
                if not self.queue:
                    break
                self._admit(rep, slot, self.queue.popleft())
                admitted += 1
        decoded = 0
        for rep in self.replicas:
            active = rep.active_mask()
            if not active.any():
                continue
            prog = self._decode_program()
            rep.cache, rep.tok, rep.pos, rep.ngen, rep.out = prog(
                self.params, rep.cache, rep.tok, rep.pos, rep.ngen,
                rep.keys, jnp.asarray(active),
                jnp.asarray(self.sampling.temperature, jnp.float32),
                rep.out)
            rep.ngen_h[active] += 1
            decoded += int(active.sum())
        finished = 0
        for rep in self.replicas:
            for slot, req in enumerate(rep.requests):
                if req is not None and rep.ngen_h[slot] >= req.remaining:
                    self._harvest(rep, slot)
                    finished += 1
        self.ticks += 1
        return {"admitted": admitted, "decoded": decoded,
                "finished": finished}

    def drain(self, max_ticks: int = 10_000) -> None:
        for _ in range(max_ticks):
            if not self.queue and not any(r.active_mask().any()
                                          for r in self.replicas):
                return
            self.tick()
        raise RuntimeError(f"not drained after {max_ticks} ticks")

    def _base_key(self, rid: int) -> jax.Array:
        return jax.random.fold_in(self.sample_key, rid & 0xFFFFFFFF)

    def _admit(self, rep: _Replica, slot: int, req: ServeRequest) -> None:
        """Teacher-force prompt + any replay prefix into ``slot`` via the
        bucketed admit program (the same full-batch decode tick, other
        rows frozen), then sample the first new token in-program."""
        if req.remaining <= 0:      # replayed request already had all
            req.tokens = req.prior  # its tokens streamed pre-failure
            req.done_s = req.done_s or self.clock()
            self.completed.append(req)
            return
        prefix = np.concatenate([req.prompt, req.prior]).astype(np.int32)
        plen = len(prefix)
        bucket = next(b for b in self.buckets if b >= plen)
        padded = np.zeros(bucket, np.int32)
        padded[:plen] = prefix
        prog = self._admit_program(bucket)
        state = prog(self.params, *rep.state(),
                     jnp.asarray(slot, jnp.int32), jnp.asarray(padded),
                     jnp.asarray(plen, jnp.int32), self._base_key(req.rid),
                     jnp.asarray(self.sampling.temperature, jnp.float32))
        (rep.cache, rep.tok, rep.pos, rep.ngen, rep.keys,
         rep.out) = state
        rep.requests[slot] = req
        rep.ngen_h[slot] = 1
        rep.tok.block_until_ready()          # TTFT is an honest wall time
        if req.first_token_s is None:
            req.first_token_s = self.clock()

    def _harvest(self, rep: _Replica, slot: int) -> None:
        req = rep.requests[slot]
        # admission + the same tick's decode can overshoot remaining by
        # one row entry; the client asked for max_new, slice to it
        n = min(int(rep.ngen_h[slot]), req.remaining)
        row = np.asarray(rep.out[slot])      # the ONLY steady-state D2H
        req.tokens = np.concatenate([req.prior, row[:n]])
        req.done_s = self.clock()
        self.completed.append(req)
        rep.requests[slot] = None
        rep.ngen_h[slot] = 0

    # ------------------------------------------------------------------
    # Fault tolerance
    # ------------------------------------------------------------------
    def recover(self, dead: Set[str], drained: bool = False) -> Dict:
        """Fail event mid-traffic: replan decode pipelines from the
        template set, migrate live cache rows, replay what died —
        zero recompilation end to end."""
        t0 = self.clock()
        dead = set(dead)
        old = self.replicas
        self.engine.handle_failure(dead, drained=drained)
        info = self._rebind(old, dead)
        info.update(policy="replan", downtime_s=self.clock() - t0,
                    cache=self.cache.stats.as_dict())
        self.last_recovery = info
        return info

    def join(self, nodes: List[str]) -> Dict:
        t0 = self.clock()
        old = self.replicas
        self.engine.handle_join(list(nodes))
        info = self._rebind(old, set())
        info.update(policy="join", downtime_s=self.clock() - t0)
        self.last_recovery = info
        return info

    def _rebind(self, old: List[_Replica], dead: Set[str]) -> Dict:
        """Map the engine's NEW instance set onto the old replicas by
        max node overlap; inherited replicas keep their slot state
        (shapes never changed, so the programs are the same cache
        entries), dissolved replicas migrate or replay their requests."""
        pairs = sorted(
            ((len(set(inst.nodes) & (set(r.instance.nodes) - dead)), ni, oi)
             for ni, inst in enumerate(self.engine.instances)
             for oi, r in enumerate(old)),
            key=lambda t: (-t[0], t[1], t[2]))
        match: Dict[int, int] = {}
        used: Set[int] = set()
        for score, ni, oi in pairs:
            if score <= 0 or ni in match or oi in used:
                continue
            match[ni] = oi
            used.add(oi)

        copy_tasks: List[CopyTask] = []
        replay: List[ServeRequest] = []
        migrate: List[Tuple[_Replica, int, ServeRequest]] = []
        new_replicas: List[_Replica] = []
        row_bytes = self._row_bytes_per_layer()

        for ni, inst in enumerate(self.engine.instances):
            if ni not in match:
                new_replicas.append(
                    _Replica(inst, self.num_slots, self._fresh_state()))
                continue
            src = old[match[ni]]
            rep = _Replica(inst, self.num_slots, src.state())
            rep.requests = list(src.requests)
            rep.ngen_h = src.ngen_h.copy()
            lost = set(src.lost_layers(dead))
            if lost:
                # some layer's cache has no surviving owner: every
                # in-flight request on this replica must replay
                for slot, req in enumerate(rep.requests):
                    if req is not None:
                        replay.append(self._prepare_replay(src, slot, req))
                rep.requests = [None] * self.num_slots
                rep.ngen_h[:] = 0
            else:
                active = int(rep.active_mask().sum())
                for layer in range(inst.template.num_layers):
                    prev = set(src.instance.layer_owners(layer)) - dead
                    for dst in inst.layer_owners(layer):
                        if dst in prev or not active:
                            continue
                        copy_tasks.append(CopyTask(
                            layer, min(prev), dst, row_bytes * active,
                            sources=tuple(sorted(prev))))
            new_replicas.append(rep)

        for oi, src in enumerate(old):
            if oi in used:
                continue
            # dissolved replica: rows migrate if every layer survives
            # somewhere, else the requests replay from the host prefix
            lost = set(src.lost_layers(dead))
            for slot, req in enumerate(src.requests):
                if req is None:
                    continue
                if lost:
                    replay.append(self._prepare_replay(src, slot, req))
                else:
                    migrate.append((src, slot, req))

        self.replicas = new_replicas
        migrated = 0
        for src, slot, req in migrate:
            target = next(((rep, s) for rep in self.replicas
                           for s in rep.free_slots()), None)
            if target is None:
                replay.append(self._prepare_replay(src, slot, req))
                continue
            rep, dst_slot = target
            self._migrate_row(src, slot, rep, dst_slot, req)
            for layer in range(rep.instance.template.num_layers):
                srcs = tuple(sorted(
                    set(src.instance.layer_owners(layer)) - dead))
                for dst in rep.instance.layer_owners(layer):
                    copy_tasks.append(CopyTask(layer, srcs[0], dst,
                                               row_bytes, sources=srcs))
            req.migrations += 1
            migrated += 1

        # the modeled data plane: same topology-aware streams training
        # state copies ride (validated, makespan = max over streams)
        plan = (schedule_transfers(copy_tasks, self.engine.topology,
                                   dead=dead) if copy_tasks else None)
        for req in reversed(replay):        # preserve original order
            req.replays += 1
            self.queue.appendleft(req)
        return {
            "migrated": migrated, "replayed": len(replay),
            "copy_bytes": sum(t.nbytes for t in copy_tasks),
            "transfer_makespan_s": plan.makespan() if plan else 0.0,
            "replicas": len(self.replicas),
        }

    def _prepare_replay(self, rep: _Replica, slot: int,
                        req: ServeRequest) -> ServeRequest:
        """Fold the already-streamed tokens (host-known: they went to the
        client) into the replay prefix; they are teacher-forced back and
        never regenerated, so the stream stays bitwise-identical."""
        n = int(rep.ngen_h[slot])
        if n:
            row = np.asarray(rep.out[slot])
            req.prior = np.concatenate([req.prior, row[:n]])
        return req

    def _migrate_row(self, src: _Replica, src_slot: int, dst: _Replica,
                     dst_slot: int, req: ServeRequest) -> None:
        ext = self._extract_program()
        row, orow, tok, pos, ngen, key = ext(
            src.cache, src.tok, src.pos, src.ngen, src.keys, src.out,
            jnp.asarray(src_slot, jnp.int32))
        ins = self._install_program()
        state = ins(*dst.state(), row, orow,
                    jnp.asarray(dst_slot, jnp.int32), tok, pos, ngen, key)
        (dst.cache, dst.tok, dst.pos, dst.ngen, dst.keys, dst.out) = state
        dst.requests[dst_slot] = req
        dst.ngen_h[dst_slot] = src.ngen_h[src_slot]

    # ------------------------------------------------------------------
    # Programs (all AOT through the ProgramCache; §8 key discipline)
    # ------------------------------------------------------------------
    def _fresh_state(self):
        B, cap = self.num_slots, self.cap
        return (self.model.init_cache(B, self.max_len),
                jnp.zeros((B,), jnp.int32), jnp.zeros((B,), jnp.int32),
                jnp.zeros((B,), jnp.int32), jnp.zeros((B, 2), jnp.uint32),
                jnp.zeros((B, cap), jnp.int32))

    def _state_avals(self):
        return self._state_template()

    def _state_template(self):
        # shapes only — computed once (static config)
        if getattr(self, "_state_tpl", None) is not None:
            return self._state_tpl
        B, cap = self.num_slots, self.cap
        cache = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
            avals_of(self.model.init_cache(1, self.max_len)))
        cache = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((s.shape[0], B) + s.shape[2:],
                                           s.dtype), cache)
        self._state_tpl = (
            cache,
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B,), jnp.int32),
            jax.ShapeDtypeStruct((B, 2), jnp.uint32),
            jax.ShapeDtypeStruct((B, cap), jnp.int32))
        return self._state_tpl

    def _key_base(self) -> Tuple:
        if getattr(self, "_kb", None) is None:
            self._kb = (kops.backend_signature(),
                        tree_spec(avals_of(self.params)),
                        tree_spec(self._state_avals()[0]), self.num_slots,
                        self.cap, self.sampling.top_k)
        return self._kb

    def _decode_program(self):
        key = ("serve_decode",) + self._key_base()

        def build():
            cap = self.cap

            def fn(params, cache, tok, pos, ngen, keys, active, temp, out):
                logits, cache2 = self.model.decode_step(
                    params, tok[:, None], cache, pos)
                nxt = _sample_tokens(logits[:, 0], keys, pos, temp,
                                     self.sampling.top_k)
                nxt = jnp.where(active, nxt, tok)
                hit = active[:, None] & (jnp.arange(cap)[None, :]
                                         == ngen[:, None])
                out2 = jnp.where(hit, nxt[:, None], out)
                inc = active.astype(jnp.int32)
                return cache2, nxt, pos + inc, ngen + inc, out2

            cache_s, tok_s, pos_s, ngen_s, keys_s, out_s = \
                self._state_avals()
            return jax.jit(fn, donate_argnums=(1, 2, 3, 4, 8)).lower(
                avals_of(self.params), cache_s, tok_s, pos_s, ngen_s,
                keys_s, jax.ShapeDtypeStruct((self.num_slots,), jnp.bool_),
                jax.ShapeDtypeStruct((), jnp.float32), out_s).compile()

        return self.cache.get_or_build(key, build)

    def _admit_program(self, bucket: int):
        key = ("serve_admit", bucket) + self._key_base()

        def build():
            B, cap = self.num_slots, self.cap
            V = self.model.arch.vocab_size

            def fn(params, cache, tok, pos, ngen, keys, out, slot,
                   prompt, plen, base_key, temp):
                rows = jnp.arange(B)
                # evict the previous occupant: zero the slot's row so
                # stale SSM/conv running state cannot leak into the new
                # request (attention is position-masked, SSM is not)
                cache = jax.tree.map(lambda c: c * (rows != slot).reshape(
                    (1, B) + (1,) * (c.ndim - 2)).astype(c.dtype), cache)

                def body(carry, t):
                    cache, last = carry
                    tok2 = tok.at[slot].set(prompt[t])
                    pos2 = pos.at[slot].set(t)
                    lg, nc = self.model.decode_step(
                        params, tok2[:, None], cache, pos2)
                    keep = ((rows == slot) & (t < plen))
                    cache = jax.tree.map(
                        lambda a, b: jnp.where(
                            keep.reshape((1, B) + (1,) * (a.ndim - 2)),
                            a, b), nc, cache)
                    last = jnp.where(t == plen - 1, lg[slot, 0], last)
                    return (cache, last), None

                (cache, last), _ = jax.lax.scan(
                    body, (cache, jnp.zeros((V,), jnp.float32)),
                    jnp.arange(bucket, dtype=jnp.int32))
                first = _sample_tokens(last[None], base_key[None],
                                       (plen - 1)[None], temp,
                                       self.sampling.top_k)[0]
                return (cache, tok.at[slot].set(first),
                        pos.at[slot].set(plen), ngen.at[slot].set(1),
                        keys.at[slot].set(base_key),
                        out.at[slot].set(
                            jnp.zeros((cap,), jnp.int32).at[0].set(first)))

            cache_s, tok_s, pos_s, ngen_s, keys_s, out_s = \
                self._state_avals()
            return jax.jit(fn, donate_argnums=(1, 2, 3, 4, 5, 6)).lower(
                avals_of(self.params), cache_s, tok_s, pos_s, ngen_s,
                keys_s, out_s, jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((bucket,), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((2,), jnp.uint32),
                jax.ShapeDtypeStruct((), jnp.float32)).compile()

        return self.cache.get_or_build(key, build)

    def _extract_program(self):
        key = ("serve_extract",) + self._key_base()

        def build():
            def fn(cache, tok, pos, ngen, keys, out, slot):
                row = jax.tree.map(
                    lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1,
                                                           axis=1), cache)
                return (row, out[slot], tok[slot], pos[slot], ngen[slot],
                        keys[slot])

            cache_s, tok_s, pos_s, ngen_s, keys_s, out_s = \
                self._state_avals()
            return jax.jit(fn).lower(
                cache_s, tok_s, pos_s, ngen_s, keys_s, out_s,
                jax.ShapeDtypeStruct((), jnp.int32)).compile()

        return self.cache.get_or_build(key, build)

    def _install_program(self):
        key = ("serve_install",) + self._key_base()

        def build():
            def fn(cache, tok, pos, ngen, keys, out, row, orow, slot,
                   tok_s, pos_s, ngen_s, key_s):
                cache2 = jax.tree.map(
                    lambda c, r: jax.lax.dynamic_update_slice_in_dim(
                        c, r, slot, axis=1), cache, row)
                return (cache2, tok.at[slot].set(tok_s),
                        pos.at[slot].set(pos_s), ngen.at[slot].set(ngen_s),
                        keys.at[slot].set(key_s), out.at[slot].set(orow))

            cache_s, tok_s, pos_s, ngen_s, keys_s, out_s = \
                self._state_avals()
            row_s = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(
                    (s.shape[0], 1) + s.shape[2:], s.dtype), cache_s)
            return jax.jit(fn, donate_argnums=(0, 1, 2, 3, 4, 5)).lower(
                cache_s, tok_s, pos_s, ngen_s, keys_s, out_s, row_s,
                jax.ShapeDtypeStruct((self.cap,), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((), jnp.int32),
                jax.ShapeDtypeStruct((2,), jnp.uint32)).compile()

        return self.cache.get_or_build(key, build)

    def _row_bytes_per_layer(self) -> int:
        cache_s, *_ = self._state_avals()
        return sum(int(np.prod(s.shape[2:])) * np.dtype(s.dtype).itemsize
                   for s in jax.tree.leaves(cache_s))

    # ------------------------------------------------------------------
    def warm(self) -> None:
        """Compile every program AND exercise every host-side glue
        dispatch (zeros init, key folding, mask upload, row fetch) with
        one synthetic request on a scratch replica, so a later failure
        -> recover -> drain cycle triggers ZERO backend compiles."""
        self._decode_program()
        for b in self.buckets:
            self._admit_program(b)
        self._extract_program()
        self._install_program()
        if not self.replicas:
            return
        rep = _Replica(self.replicas[0].instance, self.num_slots,
                       self._fresh_state())
        req = ServeRequest(rid=-1, prompt=np.zeros(1, np.int32), max_new=1)
        clock, self.clock = self.clock, lambda: 0.0
        try:
            self._admit(rep, 0, req)
            prog = self._decode_program()
            rep.cache, rep.tok, rep.pos, rep.ngen, rep.out = prog(
                self.params, rep.cache, rep.tok, rep.pos, rep.ngen,
                rep.keys, jnp.asarray(rep.active_mask()),
                jnp.asarray(0.0, jnp.float32), rep.out)
            rep.ngen_h[0] += 1
            self._prepare_replay(rep, 0, req)       # warm the row fetch
            self._harvest(rep, 0)
            self._migrate_row(rep, 0, rep, 1, req)  # warm extract/install
            self._base_key(0)
        finally:
            self.clock = clock
            self.completed = [r for r in self.completed if r.rid != -1]
