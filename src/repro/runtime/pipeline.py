"""Heterogeneous pipeline execution engine (paper §6) at array level.

Each PipelineInstance from the core engine is bound to concrete arrays:
every stage holds ONLY its layers' params + Adam moments (layer-indexed,
the paper's unit of state).  A training step:

  1. per pipeline: ONE compiled, cached step program — a
     ``lax.scan`` over the microbatch axis with in-program 1F1B
     gradient accumulation — returns per-layer gradient sums and the
     per-microbatch NLL as an ARRAY (no host sync inside the schedule).
     Programs live in a template-keyed ProgramCache
     (runtime/executor.py, DESIGN.md §8): key = (template signature,
     microbatch count, shapes), warmed at bootstrap for the whole
     template set so reconfiguration swaps programs by lookup — the
     execution-side mirror of the planner's precompute-everything
     design;
  2. cross-pipeline sync at LAYER granularity (Figure 9): a weighted
     average over replicas, weights = minibatch sizes, so the result is
     exactly the global-batch mean gradient.  Compiled mode executes
     the engine's BUCKET plan through the sync data plane
     (runtime/sync_exec.py, DESIGN.md §10): each bucket flattened to
     one buffer, reduced deepest-first, hierarchically across pods,
     optionally codec-compressed with error feedback;
  3. identical AdamW update on every replica through compiled, DONATED
     update programs (per BUCKET in compiled mode, per layer on the
     eager oracle path) — replicas stay bit-identical, which is what
     makes step 4 sound;
  4. on failure: the core engine reinstantiates pipelines from templates
     and emits a copy plan; we rebuild stage arrays by copying layer
     states (params AND moments) from surviving replicas — recovery
     without any checkpoint, the paper's headline mechanism — and the
     new pipeline set's programs come straight from the cache.

``mode="eager"`` keeps the original per-microbatch ``jax.vjp``-chain
schedule walker as the parity reference (it shares the sync/update path
and, per the compiled contract, never syncs the host mid-schedule).

This path runs real heterogeneous sets (different stage counts per
pipeline) — the thing single-program SPMD cannot express; the SPMD fast
path (runtime/spmd.py) covers the homogeneous zero-failure case.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.adapt import AdaptationError
from repro.core.engine import OobleckEngine
from repro.kernels import ops as kops
from repro.core.reconfigure import PipelineInstance
from repro.models import Model
from repro.models.layers import cross_entropy, embed, unembed
from repro.optim import adamw
from repro.runtime.executor import (Executor, ProgramCache,
                                    avals_of as _avals_of,
                                    template_signature, tree_spec)
from repro.runtime.schedule import flat_schedule
from repro.runtime.sync_exec import (BucketedSync, perlayer_global_sumsq,
                                     perlayer_sync)

LayerState = Dict[str, Any]     # {"p": params, "m": moment1, "v": moment2}


# ----------------------------------------------------------------------
# Canonical layer-indexed parameter view
# ----------------------------------------------------------------------
def split_into_layers(model: Model, params: Dict) -> List[Dict]:
    """Full param tree -> [embed, block_0..block_{L-1}, head] per the
    cost-model layer indexing (embed = layer 0, head = layer L+1).

    Tied-embedding models are AUTO-UNTIED here: pipeline stages own
    disjoint layer sets, so the head stage gets its own copy of the
    table (trained independently thereafter).  This is the standard
    pipeline-parallel treatment when first/last stages differ.
    """
    L = model.arch.num_layers
    layers: List[Dict] = [{"embed": params["embed"]}]
    for i in range(L):
        layers.append(jax.tree.map(lambda t: t[i], params["blocks"]))
    tail = {"final_norm": params["final_norm"]}
    tail["head"] = params.get("head", jax.tree.map(jnp.copy, params["embed"]))
    layers.append(tail)
    return layers


def zeros_like_tree(tree):
    return jax.tree.map(lambda t: jnp.zeros_like(t, dtype=jnp.float32), tree)


# shared with the sync data plane's program keys (runtime/executor.py)
_tree_spec = tree_spec


# ----------------------------------------------------------------------
# Stage program
# ----------------------------------------------------------------------
def make_stage_fn(model: Model, kinds: Sequence[str]) -> Callable:
    """Stage program over its layer list.  Signature:
    fn(layer_params, carry, labels, fe) -> carry' | (loss, metrics)
    carry = (x, aux) with x = tokens for the first stage."""
    arch = model.arch

    def fn(layer_params: List[Dict], carry, labels, fe):
        x, aux = carry
        for kind, lp in zip(kinds, layer_params):
            if kind == "embed":
                x = embed(lp["embed"], x, model.dtype)
                if fe is not None:
                    x = jnp.concatenate([fe.astype(model.dtype), x], axis=1)
            elif kind == "block":
                x, aux = model.block(lp, x, aux)
            else:  # head
                x = model._norm(lp["final_norm"], x)
                logits = unembed(lp["head"], x)
                ft = logits.shape[1] - labels.shape[1]
                if ft:
                    logits = logits[:, ft:]
                # labels are pre-shifted next-token targets; the final
                # position is excluded from the mean (S-1 reduction,
                # bit-exact compiled/eager parity)
                nll = cross_entropy(logits[:, :-1], labels[:, :-1])
                coef = (arch.moe.router_aux_loss_coef
                        if arch.moe is not None else 0.0)
                return nll + coef * aux, nll
        return x, aux
    return fn


# ----------------------------------------------------------------------
# One bound pipeline
# ----------------------------------------------------------------------
@dataclasses.dataclass
class PipelineRun:
    instance: PipelineInstance
    # per stage: list of layer ids and their states
    stage_layers: List[List[int]]
    states: Dict[int, LayerState]          # layer id -> state (this replica)
    stage_fns: List[Callable]

    @property
    def num_stages(self) -> int:
        return len(self.stage_layers)

    @property
    def signature(self) -> Tuple[Tuple[int, int], ...]:
        return template_signature(self.instance.template)

    def stage_params(self, s: int) -> List[Dict]:
        return [self.states[l]["p"] for l in self.stage_layers[s]]

    def all_stage_params(self) -> List[List[Dict]]:
        return [[self.states[l]["p"] for l in lids]
                for lids in self.stage_layers]


class HeteroTrainer(Executor):
    """Drives N heterogeneous pipeline replicas through train steps and
    failure recovery, using the core engine for all planning and a
    template-keyed ProgramCache for all execution."""

    def __init__(self, model: Model, engine: OobleckEngine,
                 params: Dict, opt_cfg: adamw.AdamWConfig,
                 mode: str = "compiled",
                 cache: Optional[ProgramCache] = None,
                 codec: str = "none",
                 sync_mode: Optional[str] = None):
        assert mode in ("compiled", "eager"), mode
        self.model = model
        self.engine = engine
        self.opt_cfg = opt_cfg
        self.mode = mode
        self.cache = cache or ProgramCache()
        # Sync tail implementation (DESIGN.md §10): "bucketed" executes
        # the engine's sync plan through compiled per-bucket programs;
        # "perlayer" keeps the eager jax.tree.map chain as the parity
        # oracle.  Compiled mode defaults to bucketed; eager mode stays
        # the end-to-end reference on the per-layer path.
        self.sync_mode = sync_mode or (
            "bucketed" if mode == "compiled" else "perlayer")
        assert self.sync_mode in ("bucketed", "perlayer"), self.sync_mode
        assert codec == "none" or self.sync_mode == "bucketed", \
            "wire codecs ride the bucketed data plane only"
        self.codec = codec
        # fault-injection seam (tests/test_fault_injection.py): called at
        # the step's phase boundaries — "grads" after each pipeline's
        # forward/backward, "sync" after the cross-replica gradient
        # average, BEFORE any state mutation.  A failure raised from
        # either phase therefore aborts the iteration with every layer
        # state untouched (the lost-iteration semantics of §3.3); the
        # optimizer commit is the only mutating phase and runs last.
        self.on_phase: Optional[Callable[[str], None]] = None
        self.opt_step = jnp.zeros((), jnp.int32)
        layers = split_into_layers(model, params)
        self.num_layers = len(layers)
        self._kind = (["embed"] + ["block"] * model.arch.num_layers
                      + ["head"])
        # shape/dtype skeleton of every layer: lets warm() compile
        # programs for templates that are not currently instantiated
        self._layer_avals = [_avals_of(l) for l in layers]
        self._bsync = BucketedSync(self.cache, opt_cfg, self._layer_avals,
                                   codec=codec)
        self._bucket_plan_cache = None   # rebuilt whenever bind() runs
        self.runs: List[PipelineRun] = [
            self._bind_run(inst, layers) for inst in self._bound_instances()]
        if hasattr(engine, "attach_executor"):
            engine.attach_executor(self)
        self.bind()

    def _bound_instances(self) -> List[PipelineInstance]:
        """Which pipeline instances THIS process binds full state for.
        The single-controller trainer binds all of them; the multi-host
        shard trainer (runtime/multihost.py) overrides this to bind only
        the replicas its process leads."""
        return list(self.engine.instances)

    # ------------------------------------------------------------------
    def _bind_run(self, inst: PipelineInstance, layers: Optional[List[Dict]],
                  source_states: Optional[Dict[int, LayerState]] = None,
                  state_fn: Optional[Callable[[str, int], LayerState]] = None
                  ) -> PipelineRun:
        stage_layers = [list(range(st.layer_start, st.layer_end))
                        for st in inst.template.stages]
        states: Dict[int, LayerState] = {}
        for lids in stage_layers:
            for l in lids:
                # ALWAYS copy: update programs donate their input
                # buffers, so replicas must never alias layer state
                if state_fn is not None:
                    # data-plane path: the state a layer's owning node
                    # receives comes from the SCHEDULED source replica
                    src = state_fn(inst.layer_owners(l)[0], l)
                    states[l] = {"p": jax.tree.map(jnp.copy, src["p"]),
                                 "m": jax.tree.map(jnp.copy, src["m"]),
                                 "v": jax.tree.map(jnp.copy, src["v"])}
                elif source_states is not None and l in source_states:
                    src = source_states[l]
                    states[l] = {"p": jax.tree.map(jnp.copy, src["p"]),
                                 "m": jax.tree.map(jnp.copy, src["m"]),
                                 "v": jax.tree.map(jnp.copy, src["v"])}
                else:
                    p = layers[l]
                    states[l] = {"p": jax.tree.map(jnp.copy, p),
                                 "m": zeros_like_tree(p),
                                 "v": zeros_like_tree(p)}
        fns = [make_stage_fn(self.model, [self._kind[l] for l in lids])
               for lids in stage_layers]
        return PipelineRun(inst, stage_layers, states, fns)

    # keep the historical name for callers/tests
    _bind = _bind_run

    # ------------------------------------------------------------------
    # Program cache plumbing
    # ------------------------------------------------------------------
    def _stage_avals(self, sig: Tuple[Tuple[int, int], ...]) -> List[List]:
        return [[self._layer_avals[l] for l in range(u, v)]
                for (u, v) in sig]

    def _batch_avals(self, M: int) -> Tuple:
        b = self.engine.config.microbatch
        s = self.engine.profile.seq_len
        tok = jax.ShapeDtypeStruct((M, b, s), jnp.int32)
        return tok, tok

    def _grads_program(self, sig: Tuple[Tuple[int, int], ...],
                       tok_aval, lab_aval, fe_aval=None) -> Callable:
        """Compiled per-(template-signature, microbatch-count) step
        program: scan over microbatches, in-program 1F1B gradient
        accumulation, per-microbatch NLL returned as an array."""
        # backend_signature: a stage program may contain Pallas kernels
        # whose interpret-vs-compiled lowering is resolved at TRACE time;
        # without it a program traced under the CPU default would be
        # silently reused (interpreted!) on an accelerator mesh.
        key = ("grads", kops.backend_signature(), sig,
               _tree_spec(tok_aval), _tree_spec(lab_aval),
               _tree_spec(fe_aval) if fe_aval is not None else None)

        def build() -> Callable:
            kinds = [[self._kind[l] for l in range(u, v)] for (u, v) in sig]
            fns = [make_stage_fn(self.model, k) for k in kinds]
            M = tok_aval.shape[0]

            def loss_of(stage_params, tok, lab, fe):
                carry = (tok, jnp.zeros((), jnp.float32))
                for fn, sp in zip(fns, stage_params):
                    carry = fn(sp, carry, lab, fe)
                loss, nll = carry
                return loss, nll

            def grads_fn(stage_params, tokens, labels, *fe_args):
                def body(gsum, xs):
                    tok, lab = xs[0], xs[1]
                    fe = xs[2] if len(xs) > 2 else None
                    (_, nll), g = jax.value_and_grad(
                        loss_of, has_aux=True)(stage_params, tok, lab, fe)
                    return jax.tree.map(jnp.add, gsum, g), nll

                zeros = jax.tree.map(lambda t: jnp.zeros(t.shape, t.dtype),
                                     stage_params)
                xs = (tokens, labels) + tuple(fe_args)
                gsum, nlls = jax.lax.scan(body, zeros, xs)
                gsum = jax.tree.map(lambda g: g / M, gsum)
                return gsum, nlls

            avals = (self._stage_avals(sig), tok_aval, lab_aval)
            if fe_aval is not None:
                avals = avals + (fe_aval,)
            return jax.jit(grads_fn).lower(*avals).compile()

        return self.cache.get_or_build(key, build)

    def _update_program(self, state: LayerState, grad) -> Callable:
        """Compiled per-layer-structure AdamW update with the state
        buffers DONATED — the optimizer writes in place."""
        s_aval, g_aval = _avals_of(state), _avals_of(grad)
        key = ("update", _tree_spec(s_aval), _tree_spec(g_aval))

        def build() -> Callable:
            layer_cfg = dataclasses.replace(self.opt_cfg, clip_norm=0.0)

            def upd(st, g, scale, step):
                g = jax.tree.map(lambda t: t * scale, g)
                new_p, new_opt, _ = adamw.apply(
                    layer_cfg, st["p"], g,
                    adamw.AdamWState(step, st["m"], st["v"]))
                return {"p": new_p, "m": new_opt.m, "v": new_opt.v}

            scale_aval = jax.ShapeDtypeStruct((), jnp.float32)
            step_aval = jax.ShapeDtypeStruct((), jnp.int32)
            return jax.jit(upd, donate_argnums=(0,)).lower(
                s_aval, g_aval, scale_aval, step_aval).compile()

        return self.cache.get_or_build(key, build)

    # ------------------------------------------------------------------
    # Warming: precompute-everything, execution edition
    # ------------------------------------------------------------------
    def _bucket_plan(self):
        """The engine's sync plan bound for execution (cached until the
        next bind): per bucket, the replica lead owners' pods drive the
        hierarchical ICI/DCN reduction path."""
        if self._bucket_plan_cache is None:
            sync_plan = self.engine.sync_plan()
            topo = self.engine.topology
            pods = [[topo.pod_of(inst.layer_owners(b.layer_start)[0])
                     for inst in self.engine.instances]
                    for b in sync_plan]
            self._bucket_plan_cache = self._bsync.exec_plan(sync_plan, pods)
        return self._bucket_plan_cache

    def bind(self) -> None:
        """Ensure programs for the CURRENT pipeline set + batch plan are
        cached (cheap after warm_templates(): pure lookups)."""
        self._bucket_plan_cache = None
        if self.mode != "compiled":
            return
        mb_of = {id(inst): M for inst, M in zip(
            self.engine.instances, self.engine.batch.num_microbatches)}
        for run in self.runs:
            tok, lab = self._batch_avals(mb_of[id(run.instance)])
            self._grads_program(run.signature, tok, lab)
        if self.sync_mode == "bucketed":
            plan = self._bucket_plan()
            self._bsync.bind_plan(plan)
            # a reconfiguration may have changed the bucket layout or
            # replica count: stale error-feedback residuals would
            # shape-mismatch the new buckets — drop them
            self._bsync.retain_residuals(plan, len(self.engine.instances))
            return
        # per-layer update path: seed every distinct layer structure
        # (embed / block / head)
        for l, aval in enumerate(self._layer_avals):
            state_aval = {"p": aval,
                          "m": jax.tree.map(
                              lambda t: jax.ShapeDtypeStruct(
                                  t.shape, jnp.float32), aval),
                          "v": jax.tree.map(
                              lambda t: jax.ShapeDtypeStruct(
                                  t.shape, jnp.float32), aval)}
            self._update_program(state_aval, aval)

    def warm_templates(self, mb_counts: Optional[Iterable[int]] = None
                       ) -> Dict[str, int]:
        """Precompile step programs for EVERY template in the engine's
        set x every reachable microbatch count, so any reconfiguration
        the reconfigurator can emit swaps programs by cache lookup with
        ZERO compilation.  Counts default to 1..total_mb — the exact
        reachable set, since batch distribution gives every pipeline at
        least one of the total_mb microbatches."""
        if self.mode != "compiled":
            return self.cache.stats.as_dict()
        if mb_counts is None:
            total_mb = (self.engine.config.global_batch
                        // self.engine.config.microbatch)
            mb_counts = range(1, total_mb + 1)
        mb_counts = list(mb_counts)
        for tpl in self.engine.templates.values():
            sig = template_signature(tpl)
            for M in mb_counts:
                tok, lab = self._batch_avals(M)
                self._grads_program(sig, tok, lab)
        # Warm the eager GLUE around the cached programs too: stacking M
        # microbatches and reducing the M-length NLL are shape-keyed op
        # dispatches that would otherwise compile on the first step after
        # a reconfiguration lands on a previously-unseen microbatch
        # count — exactly the moment the zero-recompilation contract is
        # supposed to protect.
        b = self.engine.config.microbatch
        s = self.engine.profile.seq_len
        host = np.zeros((b, s), np.int32)
        for M in mb_counts:
            stacked = jnp.stack([jnp.asarray(host)] * M).astype(jnp.int32)
            nll = jnp.zeros((M,), jnp.float32)
            (jnp.sum(nll) / float(M)).block_until_ready()
            del stacked
        if self.sync_mode == "bucketed":
            # bucket programs for EVERY layout any reachable instance
            # set can produce (structure-keyed, so this is a handful of
            # distinct compiles) + the scalar glue around them — a
            # reconfiguration must not compile in the sync tail either
            self._bsync.warm(
                self.engine.templates.values(),
                [l.param_bytes for l in self.engine.profile.layers],
                self.engine.config.bucket_cap_bytes)
            self._warm_clip_glue()
        self.bind()
        return self.cache.stats.as_dict()

    def _warm_clip_glue(self) -> None:
        """Dispatch the scalar ops of the norm/clip glue once (sqrt,
        min/max, division on () arrays are shape-keyed op dispatches)."""
        sq = jnp.zeros((), jnp.float32)
        sq = sq + jnp.zeros((), jnp.float32)
        norm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, 1.0 / jnp.maximum(norm, 1e-12))
        scale.astype(jnp.float32).block_until_ready()
        jnp.ones(()).astype(jnp.float32).block_until_ready()

    # ------------------------------------------------------------------
    # One pipeline's iteration -> per-layer grad means + per-mb NLL
    # ------------------------------------------------------------------
    def _run_compiled(self, run: PipelineRun, microbatches: List[Dict]
                      ) -> Tuple[Dict[int, Any], jax.Array]:
        tokens = jnp.stack([jnp.asarray(b["tokens"])
                            for b in microbatches]).astype(jnp.int32)
        labels = jnp.stack([jnp.asarray(b["labels"])
                            for b in microbatches]).astype(jnp.int32)
        fes = [b.get("frontend_embeds") for b in microbatches]
        fe = (jnp.stack([jnp.asarray(f) for f in fes])
              if fes[0] is not None else None)
        prog = self._grads_program(
            run.signature, _avals_of(tokens), _avals_of(labels),
            _avals_of(fe) if fe is not None else None)
        args = (run.all_stage_params(), tokens, labels)
        if fe is not None:
            args = args + (fe,)
        gstages, nll = prog(*args)
        grads: Dict[int, Any] = {}
        for s, lids in enumerate(run.stage_layers):
            for j, l in enumerate(lids):
                grads[l] = gstages[s][j]
        return grads, nll

    def _run_eager(self, run: PipelineRun, microbatches: List[Dict]
                   ) -> Tuple[Dict[int, Any], jax.Array]:
        """Reference path: walks the explicit 1F1B schedule with
        per-microbatch jax.vjp chains.  Kept for parity testing and as
        the readable spec of what the compiled program fuses; it must
        never force a host sync mid-schedule (losses stay on device)."""
        S = run.num_stages
        M = len(microbatches)
        sched = flat_schedule(S, M)
        acts: Dict[Tuple[int, int], Any] = {}
        cots: Dict[Tuple[int, int], Any] = {}
        vjps: Dict[Tuple[int, int], Any] = {}
        gsum: List[Any] = [None] * S
        losses: List[jax.Array] = []

        for (s, op, mb) in sched:
            batch = microbatches[mb]
            labels = jnp.asarray(batch["labels"])
            fe = batch.get("frontend_embeds")
            fe = jnp.asarray(fe) if fe is not None else None
            if op == "F":
                if s == 0:
                    carry = (jnp.asarray(batch["tokens"]),
                             jnp.zeros((), jnp.float32))
                else:
                    carry = acts[(s - 1, mb)]
                out, vjp = jax.vjp(
                    lambda lp, c: run.stage_fns[s](lp, c, labels, fe),
                    run.stage_params(s), carry)
                vjps[(s, mb)] = vjp
                if s == S - 1:
                    loss, nll = out
                    losses.append(nll)          # device array, no sync
                    cots[(s, mb)] = (jnp.ones(()), jnp.zeros(()))
                else:
                    acts[(s, mb)] = out
            else:  # backward
                ct = cots.pop((s, mb))
                gparams, gcarry = vjps.pop((s, mb))(ct)
                if s > 0:
                    cots[(s - 1, mb)] = gcarry
                    acts.pop((s - 1, mb), None)
                gsum[s] = (gparams if gsum[s] is None else
                           jax.tree.map(jnp.add, gsum[s], gparams))

        grads: Dict[int, Any] = {}
        for s, lids in enumerate(run.stage_layers):
            for j, l in enumerate(lids):
                grads[l] = jax.tree.map(lambda g: g / M, gsum[s][j])
        return grads, jnp.stack(losses)

    def _run_pipeline(self, run: PipelineRun, microbatches: List[Dict]
                      ) -> Tuple[Dict[int, Any], jax.Array]:
        if self.mode == "compiled":
            return self._run_compiled(run, microbatches)
        return self._run_eager(run, microbatches)

    # ------------------------------------------------------------------
    def train_step(self, per_pipeline_batches: List[List[Dict]]) -> Dict:
        """per_pipeline_batches[i] = list of N_b,i microbatch dicts.
        Returns metrics as DEVICE ARRAYS — nothing here blocks on the
        device; callers convert when they want to look."""
        assert len(per_pipeline_batches) == len(self.runs)
        all_grads: List[Dict[int, Any]] = []
        nlls, weights = [], []
        for run, mbs in zip(self.runs, per_pipeline_batches):
            g, nll = self._run_pipeline(run, mbs)
            all_grads.append(g)
            nlls.append(nll)
            weights.append(len(mbs))
            if self.on_phase is not None:
                self.on_phase("grads")

        grad_norm = self._sync_and_update(all_grads, weights)
        loss = sum(jnp.sum(n) for n in nlls) / float(sum(weights))
        return {"loss": loss, "grad_norm": grad_norm,
                "num_pipelines": len(self.runs)}

    # ------------------------------------------------------------------
    # The sync tail: cross-replica sync + global-norm clip + AdamW
    # (runtime/sync_exec.py, DESIGN.md §10)
    # ------------------------------------------------------------------
    def _sync_and_update(self, all_grads: List[Dict[int, Any]],
                         weights: List[int]) -> jax.Array:
        """Route the step's tail through the sync data plane and commit
        the optimizer update on every replica; returns the global grad
        norm as a device array.  ``sync_mode="bucketed"`` executes the
        engine's bucket plan as compiled per-bucket programs (deepest
        first, hierarchical across pods, optional wire codec);
        ``"perlayer"`` is the eager per-layer oracle."""
        if self.sync_mode == "bucketed":
            plan = self._bucket_plan()
            red = self._bsync.reduce(plan, all_grads, weights)
            sq = jnp.zeros((), jnp.float32)
            for s in red.sumsqs:
                sq = sq + s
            grad_norm = jnp.sqrt(sq)
            scale = self._clip_scale(grad_norm)
            if self.on_phase is not None:
                self.on_phase("sync")
            # ---- commit phase: the ONLY mutating part of the step ----
            self._bsync.commit_residuals(red)
            step_in = self.opt_step             # adamw.apply increments
            self.opt_step = self.opt_step + 1
            for run in self.runs:
                self._bsync.update(plan, red.flats, run.states, scale,
                                   step_in)
            return grad_norm

        # ---- per-layer oracle (Figure 9, the pre-§10 runtime path) ----
        synced = perlayer_sync(all_grads, weights, self.num_layers)
        if self.on_phase is not None:
            self.on_phase("sync")
        # global-norm clip across the WHOLE model (clipping per layer
        # would diverge from the SPMD fast path); all-device arithmetic:
        # the scale is folded into the compiled update, never forced to
        # the host
        grad_norm = jnp.sqrt(perlayer_global_sumsq(synced, self.num_layers))
        scale = self._clip_scale(grad_norm)
        step_in = self.opt_step                 # adamw.apply increments
        self.opt_step = self.opt_step + 1
        for run in self.runs:
            for l in sorted(run.states):
                st = run.states[l]
                prog = self._update_program(st, synced[l])
                run.states[l] = prog(st, synced[l], scale, step_in)
        return grad_norm

    def _clip_scale(self, grad_norm: jax.Array) -> jax.Array:
        if self.opt_cfg.clip_norm:
            scale = jnp.minimum(
                1.0, self.opt_cfg.clip_norm / jnp.maximum(grad_norm, 1e-12))
        else:
            scale = jnp.ones(())
        return scale.astype(jnp.float32)

    # Executor interface --------------------------------------------------
    def step(self, batches: List[List[Dict]]) -> Dict:
        return self.train_step(batches)

    # ------------------------------------------------------------------
    # Failure recovery: the data plane copies layer states from the
    # SCHEDULED surviving replicas (runtime/transfer.py, DESIGN.md §9)
    # ------------------------------------------------------------------
    def _states_by_node(self, exclude: Set[str] = frozenset()
                        ) -> Dict[str, Dict[int, LayerState]]:
        """node -> layer -> state, for every surviving owner.  A node's
        layer states survive iff the node survives; every node of a
        multi-node stage holds the stage's states."""
        by_node: Dict[str, Dict[int, LayerState]] = {}
        for run in self.runs:
            for l, st in run.states.items():
                for node in run.instance.layer_owners(l):
                    if node not in exclude:
                        by_node.setdefault(node, {})[l] = st
        return by_node

    def _apply_transfer_plan(self, result, by_node: Dict[str, Dict[int, LayerState]],
                             dead: Set[str]) -> Dict:
        """Rebind every pipeline, sourcing each moved layer from the
        replica the transfer scheduler routed it from (pod-local first,
        least-loaded sender), then swap programs by cache lookup."""
        # (schedule_transfers already validated the plan against ``dead``
        # and the copy plan's byte total)
        plan = self.engine.transfer_plan(result, dead=dead)
        fallback: Dict[int, LayerState] = {}
        for node_states in by_node.values():
            for l, st in node_states.items():
                fallback.setdefault(l, st)
        missing = [l for l in range(self.num_layers) if l not in fallback]
        assert not missing, f"layers {missing} lost (>f failures in a stage)"

        def state_for(node: str, layer: int) -> LayerState:
            held = by_node.get(node, {})
            if layer in held:          # the node already owns this layer
                return held[layer]
            src = plan.source_of(node, layer)
            if src is not None and layer in by_node.get(src, {}):
                return by_node[src][layer]
            return fallback[layer]

        self.runs = [self._bind_run(inst, layers=None, state_fn=state_for)
                     for inst in self._bound_instances()]
        self.bind()        # swap programs by lookup (zero compiles if warm)
        stats = plan.stats()      # prices the makespan once
        return {"copied_bytes": result.copy_bytes(),
                "num_pipelines": len(self.runs),
                "cache": self.cache.stats.as_dict(),
                "transfer": stats,
                "breakdown": {"replan": result.replan_seconds,
                              "transfer": stats["seconds"],
                              "compile": 0.0}}

    def _apply_adaptation(self, plan, dead: Set[str],
                          drained: bool = False) -> Dict:
        """Commit a ReCycle adaptation: drop the damaged replicas' runs,
        keep the survivors' layer states untouched (every replica holds
        the full model, so re-routed microbatches compute the same math
        on the host), and rebind — programs for the survivors' new
        microbatch counts are already warm, so this is copy-free AND
        compile-free."""
        # price the reroute exposure against the replan alternative
        ref_iter = self.engine.adaptation_reference_iteration(dead)
        breakdown = self.engine.adapt_cost_model().breakdown(plan, ref_iter)
        kept = {id(inst) for inst in plan.instances}
        self.engine.apply_adaptation(plan, dead=dead, drained=drained)
        self.runs = [run for run in self.runs if id(run.instance) in kept]
        self.bind()        # pure cache lookups after warm_templates()
        return {"policy": "adapt", "copied_bytes": 0,
                "num_pipelines": len(self.runs),
                "parked_nodes": list(plan.parked_nodes),
                "cache": self.cache.stats.as_dict(),
                "breakdown": breakdown}

    def handle_failure(self, dead_nodes: set, drained: bool = False,
                       policy: Optional[str] = None) -> Dict:
        """Route a failure event through the configured recovery policy
        (engine config's ``recovery_policy`` unless overridden).  "auto"
        selects per event from predicted downtime; "adapt"/"spare" fall
        back to the full replan path when infeasible."""
        dead = set(dead_nodes)
        policy = policy or getattr(self.engine.config,
                                   "recovery_policy", "replan")
        decision = None
        if policy == "auto":
            decision = self.engine.select_recovery_policy(dead)
            policy = decision["policy"]
        if policy == "adapt":
            try:
                plan = self.engine.plan_adaptation(dead)
                info = self._apply_adaptation(plan, dead, drained=drained)
                if decision is not None:
                    info["decision"] = decision["policy"]
                return info
            except AdaptationError:
                policy = "replan"
        if policy == "spare":
            try:
                result = self.engine.plan_spare_promotion(dead)
                by_node = self._states_by_node(exclude=dead)
                self.engine.apply_spare_promotion(result, dead=dead,
                                                  drained=drained)
                info = self._apply_transfer_plan(result, by_node, dead)
                info["policy"] = "spare"
                if decision is not None:
                    info["decision"] = decision["policy"]
                return info
            except AdaptationError:
                policy = "replan"
        by_node = self._states_by_node(exclude=dead)
        result = self.engine.handle_failure(dead, drained=drained)
        info = self._apply_transfer_plan(result, by_node, dead)
        info["policy"] = "replan"
        if decision is not None:
            info["decision"] = decision["policy"]
        return info

    def handle_join(self, new_nodes: list) -> Dict:
        """Elastic scale-up: re-plan globally over the larger cluster and
        seed every new pipeline's layer states from existing replicas
        (the same copy path as failure recovery — §5 applies to joins)."""
        by_node = self._states_by_node()
        result = self.engine.handle_join(list(new_nodes))
        return self._apply_transfer_plan(result, by_node, set())

    def recover(self, dead: Set[str], drained: bool = False) -> Dict:
        return self.handle_failure(set(dead), drained=drained)

    def join(self, nodes: List[str]) -> Dict:
        return self.handle_join(list(nodes))

    # ------------------------------------------------------------------
    def replica_divergence(self) -> float:
        """Max abs param difference across replicas (must be ~0)."""
        worst = 0.0
        for l in range(self.num_layers):
            reps = [r.states[l]["p"] for r in self.runs if l in r.states]
            base = reps[0]
            for other in reps[1:]:
                d = jax.tree.map(
                    lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                                       - b.astype(jnp.float32)))),
                    base, other)
                worst = max(worst, max(jax.tree.leaves(d), default=0.0))
        return worst

    def _assemble(self, field: str) -> Dict:
        """Reassemble a canonical full tree ('p'/'m'/'v') from replica-0
        layer states.  Leaves are COPIES: later (donating) train steps
        must not invalidate what we hand out."""
        states: Dict[int, LayerState] = {}
        for run in self.runs:
            for l, st in run.states.items():
                states.setdefault(l, st)
        blocks = [states[1 + i][field]
                  for i in range(self.model.arch.num_layers)]
        tree = {
            "embed": jax.tree.map(jnp.copy, states[0][field]["embed"]),
            "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
            "final_norm": jax.tree.map(
                jnp.copy, states[self.num_layers - 1][field]["final_norm"]),
        }
        if "head" in states[self.num_layers - 1][field]:
            tree["head"] = jax.tree.map(
                jnp.copy, states[self.num_layers - 1][field]["head"])
        return tree

    def full_params(self) -> Dict:
        """Canonical full param tree from replica 0's layers (for
        checkpointing / evaluation)."""
        return self._assemble("p")

    def snapshot(self, data_state: Optional[Dict] = None,
                 rng_seed: int = 0):
        """Host-side TrainState (ckpt/checkpoint.py format): params and
        both Adam moments reassembled into the canonical stacked-block
        layout.  The one place a host sync is the point."""
        from repro.ckpt import TrainState
        params = self._assemble("p")
        opt = adamw.AdamWState(self.opt_step, self._assemble("m"),
                               self._assemble("v"))
        return TrainState(step=int(self.opt_step), params=params,
                          opt_state=opt, data_state=data_state or {},
                          rng_seed=rng_seed)
