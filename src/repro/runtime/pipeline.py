"""Heterogeneous pipeline execution engine (paper §6) at array level.

Each PipelineInstance from the core engine is bound to concrete arrays:
every stage holds ONLY its layers' params + Adam moments (layer-indexed,
the paper's unit of state).  A training step:

  1. per pipeline: run the 1F1B schedule with per-microbatch jax.vjp
     chains (forward activations / backward cotangents hop between
     stages), accumulating per-layer gradients;
  2. cross-pipeline sync at LAYER granularity (Figure 9): a weighted
     average over replicas, weights = minibatch sizes, so the result is
     exactly the global-batch mean gradient;
  3. identical AdamW update on every replica of every layer — replicas
     stay bit-identical, which is what makes step 4 sound;
  4. on failure: the core engine reinstantiates pipelines from templates
     and emits a copy plan; we rebuild stage arrays by copying layer
     states (params AND moments) from surviving replicas — recovery
     without any checkpoint, the paper's headline mechanism.

This path runs real heterogeneous sets (different stage counts per
pipeline) — the thing single-program SPMD cannot express; the SPMD fast
path (runtime/spmd.py) covers the homogeneous zero-failure case.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.engine import OobleckEngine
from repro.core.reconfigure import PipelineInstance
from repro.models import Model
from repro.models.layers import cross_entropy, embed, unembed
from repro.optim import adamw
from repro.runtime.schedule import flat_schedule

LayerState = Dict[str, Any]     # {"p": params, "m": moment1, "v": moment2}


# ----------------------------------------------------------------------
# Canonical layer-indexed parameter view
# ----------------------------------------------------------------------
def split_into_layers(model: Model, params: Dict) -> List[Dict]:
    """Full param tree -> [embed, block_0..block_{L-1}, head] per the
    cost-model layer indexing (embed = layer 0, head = layer L+1).

    Tied-embedding models are AUTO-UNTIED here: pipeline stages own
    disjoint layer sets, so the head stage gets its own copy of the
    table (trained independently thereafter).  This is the standard
    pipeline-parallel treatment when first/last stages differ.
    """
    L = model.arch.num_layers
    layers: List[Dict] = [{"embed": params["embed"]}]
    for i in range(L):
        layers.append(jax.tree.map(lambda t: t[i], params["blocks"]))
    tail = {"final_norm": params["final_norm"]}
    tail["head"] = params.get("head", jax.tree.map(jnp.copy, params["embed"]))
    layers.append(tail)
    return layers


def zeros_like_tree(tree):
    return jax.tree.map(lambda t: jnp.zeros_like(t, dtype=jnp.float32), tree)


# ----------------------------------------------------------------------
# Stage program
# ----------------------------------------------------------------------
def make_stage_fn(model: Model, kinds: Sequence[str]) -> Callable:
    """Stage program over its layer list.  Signature:
    fn(layer_params, carry, labels, fe) -> carry' | (loss, metrics)
    carry = (x, aux) with x = tokens for the first stage."""
    arch = model.arch

    def fn(layer_params: List[Dict], carry, labels, fe):
        x, aux = carry
        for kind, lp in zip(kinds, layer_params):
            if kind == "embed":
                x = embed(lp["embed"], x, model.dtype)
                if fe is not None:
                    x = jnp.concatenate([fe.astype(model.dtype), x], axis=1)
            elif kind == "block":
                x, aux = model.block(lp, x, aux)
            else:  # head
                x = model._norm(lp["final_norm"], x)
                logits = unembed(lp["head"], x)
                ft = logits.shape[1] - labels.shape[1]
                if ft:
                    logits = logits[:, ft:]
                nll = cross_entropy(logits[:, :-1], labels[:, 1:])
                coef = (arch.moe.router_aux_loss_coef
                        if arch.moe is not None else 0.0)
                return nll + coef * aux, nll
        return x, aux
    return fn


# ----------------------------------------------------------------------
# One bound pipeline
# ----------------------------------------------------------------------
@dataclasses.dataclass
class PipelineRun:
    instance: PipelineInstance
    # per stage: list of layer ids and their states
    stage_layers: List[List[int]]
    states: Dict[int, LayerState]          # layer id -> state (this replica)
    stage_fns: List[Callable]

    @property
    def num_stages(self) -> int:
        return len(self.stage_layers)

    def stage_params(self, s: int) -> List[Dict]:
        return [self.states[l]["p"] for l in self.stage_layers[s]]


class HeteroTrainer:
    """Drives N heterogeneous pipeline replicas through train steps and
    failure recovery, using the core engine for all planning."""

    def __init__(self, model: Model, engine: OobleckEngine,
                 params: Dict, opt_cfg: adamw.AdamWConfig):
        self.model = model
        self.engine = engine
        self.opt_cfg = opt_cfg
        self.opt_step = jnp.zeros((), jnp.int32)
        layers = split_into_layers(model, params)
        self.num_layers = len(layers)
        self._kind = (["embed"] + ["block"] * model.arch.num_layers
                      + ["head"])
        self.runs: List[PipelineRun] = [
            self._bind(inst, layers) for inst in engine.instances]

    # ------------------------------------------------------------------
    def _bind(self, inst: PipelineInstance, layers: List[Dict],
              source_states: Optional[Dict[int, LayerState]] = None
              ) -> PipelineRun:
        stage_layers = [list(range(st.layer_start, st.layer_end))
                        for st in inst.template.stages]
        states: Dict[int, LayerState] = {}
        for lids in stage_layers:
            for l in lids:
                if source_states is not None and l in source_states:
                    src = source_states[l]
                    states[l] = {"p": jax.tree.map(jnp.copy, src["p"]),
                                 "m": jax.tree.map(jnp.copy, src["m"]),
                                 "v": jax.tree.map(jnp.copy, src["v"])}
                else:
                    p = layers[l]
                    states[l] = {"p": jax.tree.map(jnp.asarray, p),
                                 "m": zeros_like_tree(p),
                                 "v": zeros_like_tree(p)}
        fns = [make_stage_fn(self.model, [self._kind[l] for l in lids])
               for lids in stage_layers]
        return PipelineRun(inst, stage_layers, states, fns)

    # ------------------------------------------------------------------
    # One pipeline's 1F1B iteration -> per-layer grads + mean loss
    # ------------------------------------------------------------------
    def _run_pipeline(self, run: PipelineRun, microbatches: List[Dict]
                      ) -> Tuple[Dict[int, Any], float]:
        S = run.num_stages
        M = len(microbatches)
        sched = flat_schedule(S, M)
        acts: Dict[Tuple[int, int], Any] = {}
        cots: Dict[Tuple[int, int], Any] = {}
        vjps: Dict[Tuple[int, int], Any] = {}
        gsum: List[Any] = [None] * S
        losses: List[float] = []

        for (s, op, mb) in sched:
            batch = microbatches[mb]
            labels = jnp.asarray(batch["labels"])
            fe = batch.get("frontend_embeds")
            fe = jnp.asarray(fe) if fe is not None else None
            if op == "F":
                if s == 0:
                    carry = (jnp.asarray(batch["tokens"]),
                             jnp.zeros((), jnp.float32))
                else:
                    carry = acts[(s - 1, mb)]
                out, vjp = jax.vjp(
                    lambda lp, c: run.stage_fns[s](lp, c, labels, fe),
                    run.stage_params(s), carry)
                vjps[(s, mb)] = vjp
                if s == S - 1:
                    loss, nll = out
                    losses.append(float(nll))
                    cots[(s, mb)] = (jnp.ones(()), jnp.zeros(()))
                else:
                    acts[(s, mb)] = out
            else:  # backward
                ct = cots.pop((s, mb))
                gparams, gcarry = vjps.pop((s, mb))(ct)
                if s > 0:
                    cots[(s - 1, mb)] = gcarry
                    acts.pop((s - 1, mb), None)
                gsum[s] = (gparams if gsum[s] is None else
                           jax.tree.map(jnp.add, gsum[s], gparams))

        grads: Dict[int, Any] = {}
        for s, lids in enumerate(run.stage_layers):
            for j, l in enumerate(lids):
                grads[l] = jax.tree.map(lambda g: g / M, gsum[s][j])
        return grads, float(np.mean(losses))

    # ------------------------------------------------------------------
    def train_step(self, per_pipeline_batches: List[List[Dict]]) -> Dict:
        """per_pipeline_batches[i] = list of N_b,i microbatch dicts."""
        assert len(per_pipeline_batches) == len(self.runs)
        all_grads: List[Dict[int, Any]] = []
        losses, weights = [], []
        for run, mbs in zip(self.runs, per_pipeline_batches):
            g, loss = self._run_pipeline(run, mbs)
            all_grads.append(g)
            losses.append(loss)
            weights.append(len(mbs))

        # ---- layer-granular cross-replica sync (Figure 9) -------------
        wsum = float(sum(weights))
        synced: Dict[int, Any] = {}
        for l in range(self.num_layers):
            contribs = [(w / wsum, g[l]) for w, g in zip(weights, all_grads)
                        if l in g]
            acc = jax.tree.map(lambda t: t * contribs[0][0], contribs[0][1])
            for w, g in contribs[1:]:
                acc = jax.tree.map(lambda a, t: a + t * w, acc, g)
            synced[l] = acc

        # ---- global-norm clip across the WHOLE model -------------------
        # (clipping per layer would diverge from the SPMD fast path)
        if self.opt_cfg.clip_norm:
            sq = sum(float(jnp.sum(jnp.square(t.astype(jnp.float32))))
                     for l in range(self.num_layers)
                     for t in jax.tree.leaves(synced[l]))
            norm = float(np.sqrt(sq))
            scale = min(1.0, self.opt_cfg.clip_norm / max(norm, 1e-12))
            if scale < 1.0:
                synced = {l: jax.tree.map(lambda g: g * scale, g_)
                          for l, g_ in synced.items()}
        layer_cfg = dataclasses.replace(self.opt_cfg, clip_norm=0.0)

        # ---- identical AdamW update on every replica -------------------
        self.opt_step = self.opt_step + 1
        for run in self.runs:
            for l, st in run.states.items():
                new_p, new_opt, _ = adamw.apply(
                    layer_cfg, st["p"], synced[l],
                    adamw.AdamWState(self.opt_step - 1, st["m"], st["v"]))
                st["p"], st["m"], st["v"] = new_p, new_opt.m, new_opt.v
        loss = float(np.average(losses, weights=weights))
        return {"loss": loss, "num_pipelines": len(self.runs)}

    # ------------------------------------------------------------------
    # Failure recovery: copy layer states from surviving replicas
    # ------------------------------------------------------------------
    def handle_failure(self, dead_nodes: set) -> Dict:
        # Surviving replicas' states, BEFORE reconfiguration: a node's
        # layer states survive iff the node survives.
        survivors: Dict[int, LayerState] = {}
        for run in self.runs:
            for st_spec, lids in zip(run.instance.template.stages,
                                     run.stage_layers):
                node = run.instance.nodes[st_spec.node_offset]
                if node in dead_nodes:
                    continue
                for l in lids:
                    survivors.setdefault(l, run.states[l])
        result = self.engine.handle_failure(dead_nodes)
        missing = [l for l in range(self.num_layers) if l not in survivors]
        assert not missing, f"layers {missing} lost (>f failures in a stage)"
        self.runs = [self._bind(inst, layers=None, source_states=survivors)  # type: ignore
                     for inst in self.engine.instances]
        return {"copied_bytes": result.copy_bytes(),
                "num_pipelines": len(self.runs)}

    def handle_join(self, new_nodes: list) -> Dict:
        """Elastic scale-up: re-plan globally over the larger cluster and
        seed every new pipeline's layer states from existing replicas
        (the same copy path as failure recovery — §5 applies to joins)."""
        survivors: Dict[int, LayerState] = {}
        for run in self.runs:
            for l, st in run.states.items():
                survivors.setdefault(l, st)
        result = self.engine.handle_join(list(new_nodes))
        self.runs = [self._bind(inst, layers=None, source_states=survivors)  # type: ignore
                     for inst in self.engine.instances]
        return {"copied_bytes": result.copy_bytes(),
                "num_pipelines": len(self.runs)}

    # ------------------------------------------------------------------
    def replica_divergence(self) -> float:
        """Max abs param difference across replicas (must be ~0)."""
        worst = 0.0
        for l in range(self.num_layers):
            reps = [r.states[l]["p"] for r in self.runs if l in r.states]
            base = reps[0]
            for other in reps[1:]:
                d = jax.tree.map(
                    lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                                       - b.astype(jnp.float32)))),
                    base, other)
                worst = max(worst, max(jax.tree.leaves(d), default=0.0))
        return worst

    def full_params(self) -> Dict:
        """Reassemble the canonical full tree from replica 0's layers
        (for checkpointing / evaluation)."""
        states = {}
        for run in self.runs:
            for l, st in run.states.items():
                states.setdefault(l, st)
        blocks = [states[1 + i]["p"] for i in range(self.model.arch.num_layers)]
        params = {
            "embed": states[0]["p"]["embed"],
            "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
            "final_norm": states[self.num_layers - 1]["p"]["final_norm"],
        }
        if "head" in states[self.num_layers - 1]["p"]:
            params["head"] = states[self.num_layers - 1]["p"]["head"]
        return params
