"""Gradient compression for the cross-pipeline sync path (optional).

Heterogeneous-pipeline sync rides layer buckets (core/sync.py); when the
sync peers span pods the traffic crosses DCN (25 GB/s vs 50 GB/s ICI), so
Oobleck-at-scale benefits from compressing buckets before the all-reduce.
Two codecs:

  * ``bf16``  — cast fp32 grads to bf16 (2x, error ~1e-3 relative);
  * ``int8``  — per-bucket symmetric quantization with an fp32 scale
    (4x, stochastic-rounding-free deterministic variant).

Both are used with error feedback (the residual is carried and added to
the next step's gradient), which keeps convergence unbiased in
expectation; tests verify the codec roundtrip error bound and that error
feedback sums to the true gradient over time.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def compress(tree: Any, codec: str) -> Any:
    if codec == "none":
        return tree
    if codec == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), tree)
    if codec == "int8":
        def enc(g):
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            return {"q": q, "scale": scale}
        return jax.tree.map(enc, tree)
    raise ValueError(f"unknown codec {codec!r}")


def decompress(tree: Any, codec: str) -> Any:
    if codec == "none":
        return tree
    if codec == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.float32), tree)
    if codec == "int8":
        def dec(d):
            return d["q"].astype(jnp.float32) * d["scale"]
        return jax.tree.map(dec, tree, is_leaf=lambda x: isinstance(x, dict)
                            and "q" in x)
    raise ValueError(f"unknown codec {codec!r}")


def roundtrip(tree: Any, codec: str) -> Any:
    return decompress(compress(tree, codec), codec)


class ErrorFeedback:
    """Carries the compression residual into the next step's gradient."""

    def __init__(self, codec: str):
        self.codec = codec
        self.residual: Optional[Any] = None

    def apply(self, grads: Any) -> Any:
        if self.codec == "none":
            return grads
        if self.residual is not None:
            grads = jax.tree.map(jnp.add, grads, self.residual)
        sent = roundtrip(grads, self.codec)
        self.residual = jax.tree.map(jnp.subtract, grads, sent)
        return sent


def wire_bytes(tree: Any, codec: str) -> int:
    """Bytes on the wire for one bucket under the codec."""
    leaves = jax.tree.leaves(tree)
    n = sum(l.size for l in leaves)
    return {"none": 4 * n, "bf16": 2 * n, "int8": n + 4 * len(leaves)}[codec]
