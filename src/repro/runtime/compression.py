"""Gradient compression for the cross-pipeline sync path (optional).

Heterogeneous-pipeline sync rides layer buckets (core/sync.py); when the
sync peers span pods the traffic crosses DCN (25 GB/s vs 50 GB/s ICI), so
Oobleck-at-scale benefits from compressing buckets before the all-reduce.
Two codecs:

  * ``bf16``  — cast fp32 grads to bf16 (2x, error ~1e-3 relative);
  * ``int8``  — symmetric quantization with an fp32 scale (4x,
    stochastic-rounding-free deterministic variant).

The compiled data plane (runtime/sync_exec.py) flattens each sync bucket
into ONE contiguous buffer before encoding, so the wire format is
``encode_flat``/``decode_flat``: int8 carries exactly one scale per
bucket — `core.sync.flat_wire_bytes` is the single source of truth for
the byte accounting and tests assert the encoded output matches it.
The tree-shaped ``compress``/``decompress`` (one scale per leaf) remain
for unbucketed use.

Both codecs are used with error feedback (the residual is carried and
added to the next step's gradient), which keeps convergence unbiased in
expectation.  Residuals are keyed by bucket signature: a reconfiguration
changes the bucket layout, and a residual carried across that boundary
would shape-mismatch the new buckets — ``ErrorFeedback.retain`` drops
stale keys on recover/join, and keyed ``apply`` drops a residual whose
structure no longer matches its gradient.
"""
from __future__ import annotations

from typing import Any, Dict, Hashable, Iterable, Optional

import jax
import jax.numpy as jnp

from repro.core.sync import CODEC_WIRE, flat_wire_bytes  # noqa: F401 (re-export)


def compress(tree: Any, codec: str) -> Any:
    if codec == "none":
        return tree
    if codec == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), tree)
    if codec == "int8":
        def enc(g):
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            return {"q": q, "scale": scale}
        return jax.tree.map(enc, tree)
    raise ValueError(f"unknown codec {codec!r}")


def decompress(tree: Any, codec: str) -> Any:
    if codec == "none":
        return tree
    if codec == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.float32), tree)
    if codec == "int8":
        def dec(d):
            return d["q"].astype(jnp.float32) * d["scale"]
        return jax.tree.map(dec, tree, is_leaf=lambda x: isinstance(x, dict)
                            and "q" in x)
    raise ValueError(f"unknown codec {codec!r}")


def roundtrip(tree: Any, codec: str) -> Any:
    return decompress(compress(tree, codec), codec)


# ----------------------------------------------------------------------
# Flat-bucket wire format (what the compiled data plane actually sends)
# ----------------------------------------------------------------------
def encode_flat(flat: jax.Array, codec: str) -> Any:
    """Encode ONE flattened fp32 bucket buffer.  int8 uses a single
    per-bucket scale, so the encoded size is exactly
    ``flat_wire_bytes(flat.size, codec)``."""
    if codec == "none":
        return flat
    if codec == "bf16":
        return flat.astype(jnp.bfloat16)
    if codec == "int8":
        scale = jnp.maximum(jnp.max(jnp.abs(flat)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
        return {"q": q, "scale": scale.astype(jnp.float32)}
    raise ValueError(f"unknown codec {codec!r}")


def decode_flat(enc: Any, codec: str) -> jax.Array:
    if codec == "none":
        return enc
    if codec == "bf16":
        return enc.astype(jnp.float32)
    if codec == "int8":
        return enc["q"].astype(jnp.float32) * enc["scale"]
    raise ValueError(f"unknown codec {codec!r}")


def roundtrip_flat(flat: jax.Array, codec: str) -> jax.Array:
    return decode_flat(encode_flat(flat, codec), codec)


def encoded_nbytes(enc: Any, codec: str) -> int:
    """Actual byte count of an encoded bucket/tree (for the tests that
    pin wire accounting to reality)."""
    if codec == "int8":
        total = 0
        for d in jax.tree.leaves(enc, is_leaf=lambda x: isinstance(x, dict)
                                 and "q" in x):
            total += d["q"].size * d["q"].dtype.itemsize
            total += jnp.asarray(d["scale"]).dtype.itemsize
        return total
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(enc))


class ErrorFeedback:
    """Carries the compression residual into the next step's gradient.

    Residuals are keyed: the sync data plane keys them by (bucket
    signature, replica), so a reconfiguration that changes the bucket
    layout never replays a stale residual into mismatched shapes —
    ``retain`` drops keys the new layout cannot use, and ``apply``
    defensively discards a keyed residual whose structure no longer
    matches the gradient it would be added to.  The legacy single-tree
    usage (``apply`` without a key) still works.
    """

    _LEGACY = ("__legacy__",)

    def __init__(self, codec: str):
        self.codec = codec
        self.residuals: Dict[Hashable, Any] = {}

    # -- legacy single-tree view ---------------------------------------
    @property
    def residual(self) -> Optional[Any]:
        return self.residuals.get(self._LEGACY)

    @residual.setter
    def residual(self, value: Optional[Any]) -> None:
        if value is None:
            self.residuals.pop(self._LEGACY, None)
        else:
            self.residuals[self._LEGACY] = value

    # ------------------------------------------------------------------
    @staticmethod
    def _compatible(res: Any, grads: Any) -> bool:
        try:
            if (jax.tree.structure(res) != jax.tree.structure(grads)):
                return False
            return all(r.shape == g.shape for r, g in
                       zip(jax.tree.leaves(res), jax.tree.leaves(grads)))
        except Exception:
            return False

    def apply(self, grads: Any, key: Hashable = None) -> Any:
        """grads -> what goes on the wire; the residual (what the codec
        lost) is carried into the next call under the same key."""
        if self.codec == "none":
            return grads
        key = self._LEGACY if key is None else key
        res = self.residuals.get(key)
        if res is not None and not self._compatible(res, grads):
            res = None                  # stale layout: drop, don't crash
        if res is not None:
            grads = jax.tree.map(jnp.add, grads, res)
        sent = roundtrip(grads, self.codec)
        self.residuals[key] = jax.tree.map(jnp.subtract, grads, sent)
        return sent

    # -- keyed store used by the compiled data plane -------------------
    def get(self, key: Hashable) -> Optional[Any]:
        return self.residuals.get(key)

    def put(self, key: Hashable, res: Any) -> None:
        self.residuals[key] = res

    def retain(self, keys: Iterable[Hashable]) -> int:
        """Keep only ``keys`` (plus the legacy slot); returns how many
        stale residuals were dropped — called on recover/join."""
        keep = set(keys) | {self._LEGACY}
        stale = [k for k in self.residuals if k not in keep]
        for k in stale:
            del self.residuals[k]
        return len(stale)


def wire_bytes(tree: Any, codec: str) -> int:
    """Bytes on the wire for a TREE-shaped payload (one scale per leaf
    under int8).  Flattened buckets use `flat_wire_bytes` instead —
    one scale per bucket."""
    leaves = jax.tree.leaves(tree)
    n = sum(l.size for l in leaves)
    return {"none": 4 * n, "bf16": 2 * n, "int8": n + 4 * len(leaves)}[codec]
