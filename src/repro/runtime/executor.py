"""Executor interface + template-keyed compiled program cache (DESIGN.md §8).

Oobleck's planning is a table lookup at failure time (templates are
precomputed, §4); this module gives EXECUTION the same property.  Every
runtime — the heterogeneous single-controller trainer
(runtime/pipeline.py), the homogeneous SPMD fast path (runtime/spmd.py)
and the discrete-event simulator's policy (sim/policies.py) — sits
behind one ``Executor`` interface:

    bind()      (re)associate state with the current pipeline set and
                make sure every program it needs is compiled
    step()      one training iteration; metrics come back as device
                arrays (NO host sync inside the schedule)
    recover()   node failure: re-plan via the engine, rebuild bindings
                from surviving replicas, swap programs by cache lookup
    join()      elastic scale-up, same contract as recover()
    snapshot()  a host-side TrainState for checkpointing

``ProgramCache`` holds ahead-of-time compiled executables keyed by
(kind, template-signature, microbatch-count, shapes).  Reconfiguration
then never compiles: the new pipeline set's programs are already in the
cache (warmed at bootstrap for the whole template set), mirroring how
the planner precomputes every template it could ever instantiate.
ReCycle (arXiv:2405.14009) and Bamboo (arXiv:2204.12013) both observe
that post-failure adaptation speed hinges on exactly this reuse.

The cache counts compiles and hits so tests and benchmarks can assert
the zero-recompilation property instead of trusting it
(``track_compiles`` additionally counts XLA backend compiles fired by
anything else via jax.monitoring).
"""
from __future__ import annotations

import abc
import contextlib
import dataclasses
from typing import Any, Callable, Dict, Hashable, Iterator, List, Optional, Set, Tuple

import jax


# ----------------------------------------------------------------------
# Shared aval helper (cache keys and AOT lowering must agree on this)
# ----------------------------------------------------------------------
def avals_of(tree):
    """Pytree of arrays -> pytree of ShapeDtypeStructs (for AOT
    lower/compile and for shape-keyed cache entries)."""
    return jax.tree.map(
        lambda t: jax.ShapeDtypeStruct(t.shape, t.dtype), tree)


def tree_spec(tree) -> Tuple:
    """Hashable (path, shape, dtype) spec of a pytree of arrays/avals —
    the shape component of every ProgramCache key."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return tuple((jax.tree_util.keystr(path), tuple(leaf.shape),
                  str(jax.numpy.dtype(leaf.dtype))) for path, leaf in flat)


# ----------------------------------------------------------------------
# Template signatures
# ----------------------------------------------------------------------
def template_signature(template) -> Tuple[Tuple[int, int], ...]:
    """A PipelineTemplate's computational identity: the stage->layer
    tiling.  Templates with the same tiling run the SAME compiled step
    program regardless of which nodes host the stages, so the cache key
    deliberately ignores node/GPU placement."""
    return tuple((st.layer_start, st.layer_end) for st in template.stages)


# ----------------------------------------------------------------------
# Program cache
# ----------------------------------------------------------------------
@dataclasses.dataclass
class CacheStats:
    compiles: int = 0
    hits: int = 0

    def as_dict(self) -> Dict[str, int]:
        return {"compiles": self.compiles, "hits": self.hits}


class ProgramCache:
    """AOT-compiled executables keyed by (kind, signature, shapes).

    ``get_or_build`` is the only entry point: a miss runs ``builder``
    (expected to return a callable, typically ``jax.jit(f).lower(...)
    .compile()``) and counts a compile; a hit returns the stored
    executable untouched.  Reconfiguration correctness tests assert
    ``stats.compiles`` stays flat across a failure->recover->step cycle.

    ``namespace`` scopes every key: multi-process workers pass their
    process topology (``kernels.ops.process_topology()``) so entries
    compiled under one process layout can never be served to another —
    program kinds whose keys don't already embed ``backend_signature()``
    (the bucket sync/update family) would otherwise collide if caches
    were ever shared across processes (ISSUE 10 satellite).
    """

    def __init__(self, namespace: Hashable = None) -> None:
        self._programs: Dict[Hashable, Callable] = {}
        self.namespace = namespace
        self.stats = CacheStats()

    def _full(self, key: Hashable) -> Hashable:
        return key if self.namespace is None else (self.namespace, key)

    def __contains__(self, key: Hashable) -> bool:
        return self._full(key) in self._programs

    def __len__(self) -> int:
        return len(self._programs)

    def keys(self) -> List[Hashable]:
        return list(self._programs)

    def get_or_build(self, key: Hashable, builder: Callable[[], Callable]
                     ) -> Callable:
        key = self._full(key)
        prog = self._programs.get(key)
        if prog is not None:
            self.stats.hits += 1
            return prog
        prog = builder()
        self._programs[key] = prog
        self.stats.compiles += 1
        return prog


# ----------------------------------------------------------------------
# Compilation-count instrumentation (tests + benchmarks)
# ----------------------------------------------------------------------
_BACKEND_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"


@dataclasses.dataclass
class CompileLog:
    backend_compiles: int = 0
    _active: bool = True


@contextlib.contextmanager
def track_compiles() -> Iterator[CompileLog]:
    """Count XLA backend compiles inside the block via jax.monitoring —
    catches retraces *anywhere*, not just ones routed through a
    ProgramCache.  Usage::

        with track_compiles() as log:
            trainer.recover({victim}); trainer.train_step(batches)
        assert log.backend_compiles == 0
    """
    log = CompileLog()

    def listener(name: str, secs: float, **kw: Any) -> None:
        if log._active and name == _BACKEND_COMPILE_EVENT:
            log.backend_compiles += 1

    jax.monitoring.register_event_duration_secs_listener(listener)
    try:
        yield log
    finally:
        log._active = False
        try:  # best effort: private API, present in jax>=0.4.30
            from jax._src import monitoring as _mon
            _mon._unregister_event_duration_listener_by_callback(listener)
        except Exception:
            pass  # listener stays registered but inert (_active False)


class CompileCounter:
    """Persistent XLA backend-compile counter (the long-lived sibling of
    ``track_compiles``): registered once, never unregistered, so a
    worker process can report compiles-since-warm over RPC at any point
    of its life — the survivors' zero-recompile assertion in the
    multi-process acceptance test reads this."""

    def __init__(self) -> None:
        self.count = 0
        self._mark = 0

        def listener(name: str, secs: float, **kw: Any) -> None:
            if name == _BACKEND_COMPILE_EVENT:
                self.count += 1

        jax.monitoring.register_event_duration_secs_listener(listener)

    def mark(self) -> None:
        self._mark = self.count

    def since_mark(self) -> int:
        return self.count - self._mark


# ----------------------------------------------------------------------
# Host-transfer instrumentation (tests)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class TransferLog:
    device_to_host: int = 0


@contextlib.contextmanager
def track_host_transfers() -> Iterator[TransferLog]:
    """Count device->host materializations inside the block by
    intercepting ``ArrayImpl._value``/``__array__`` — the funnel for
    ``float(arr)``, ``np.asarray(arr)``, ``.item()`` and friends.  The
    no-host-sync contract of Executor.step() is asserted with this
    (``jax.transfer_guard`` does not see these conversions for
    uncommitted arrays on the installed JAX floor)."""
    from jax._src.array import ArrayImpl
    log = TransferLog()
    orig_value = ArrayImpl.__dict__["_value"]
    orig_array = ArrayImpl.__dict__.get("__array__")

    def spy_value(self):
        log.device_to_host += 1
        return orig_value.fget(self)

    def spy_array(self, *a, **kw):
        log.device_to_host += 1
        return orig_array(self, *a, **kw)

    ArrayImpl._value = property(spy_value)
    if orig_array is not None:
        ArrayImpl.__array__ = spy_array
    try:
        yield log
    finally:
        ArrayImpl._value = orig_value
        if orig_array is not None:
            ArrayImpl.__array__ = orig_array


# ----------------------------------------------------------------------
# The interface
# ----------------------------------------------------------------------
class ExecutorUnsupported(RuntimeError):
    """The executor cannot express the requested transition (e.g. the
    single-program SPMD fast path cannot reconfigure in place — the
    caller must rebind a heterogeneous executor)."""


class Executor(abc.ABC):
    """Uniform runtime contract driven by core/engine.py.

    Implementations: runtime.pipeline.HeteroTrainer (heterogeneous
    template sets, compiled per-template programs),
    runtime.spmd.SPMDExecutor (homogeneous zero-failure fast path,
    one donated SPMD program) and sim.policies.OobleckPolicy (simulated
    time; step() reports seconds instead of spending them).
    """

    @abc.abstractmethod
    def bind(self) -> None:
        """(Re)bind state to the current pipeline set and ensure every
        program the set needs is present in the cache."""

    @abc.abstractmethod
    def step(self, batches: Any) -> Dict[str, Any]:
        """Run one training iteration.  Loss/metrics are returned as
        device arrays (or simulated scalars); implementations must not
        force a host sync inside the schedule."""

    @abc.abstractmethod
    def recover(self, dead: Set[str], drained: bool = False) -> Dict[str, Any]:
        """Handle node failures: replan, rebuild state from surviving
        replicas, swap to the new pipeline set's cached programs."""

    @abc.abstractmethod
    def join(self, nodes: List[str]) -> Dict[str, Any]:
        """Elastic scale-up (same copy-plan path as recover, §5)."""

    @abc.abstractmethod
    def snapshot(self, data_state: Optional[Dict] = None,
                 rng_seed: int = 0) -> Any:
        """Host-side TrainState for checkpointing (allowed to sync)."""
