"""SPMD pipeline-parallel train/forward via shard_map (the TPU-native
mapping of one Oobleck pipeline template — DESIGN.md §2, §8).

Each stage of a (uniform) template owns L/S consecutive blocks; the
template's schedule is a static loop of M + S - 1 ticks in which every
stage computes one microbatch and hands its activation to stage+1 with
``jax.lax.ppermute``.  This is the program a pipeline instance launches
per microbatch wave on real hardware; the single-controller
HeteroTrainer (pipeline.py) remains the reference for heterogeneous
stage layouts (SPMD requires every shard to run the same program, so
stages must be uniform here — Oobleck's planner emits near-uniform
splits for homogeneous-cost blocks, making this the production fast
path).

Training runs in ONE SPMD program (``make_pipeline_train_step``):
differentiating through the scheduled scan transposes every
``ppermute``, so the backward pass is the same pipeline run in reverse
— activations hop forward, cotangents hop backward, per-stage gradient
accumulation falls out of the scan transpose exactly as 1F1B
accumulates per-microbatch grads.  Loss and optimizer update live in
the same jitted program with params/opt-state donated, so the
homogeneous zero-failure case trains with no per-step host round trips
at all.

Correctness is pinned by tests/test_spmd_pipeline.py: the pipelined
forward equals the plain forward bit-for-bit on a multi-device host
mesh, and the pipelined train step tracks a plain full-model step.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models import Model
from repro.optim import adamw


def stack_by_stage(params_blocks, num_stages: int):
    """[L, ...] stacked blocks -> [S, L/S, ...]."""
    L = jax.tree.leaves(params_blocks)[0].shape[0]
    assert L % num_stages == 0, (L, num_stages)
    return jax.tree.map(
        lambda t: t.reshape(num_stages, L // num_stages, *t.shape[1:]),
        params_blocks)


def pipeline_forward(model: Model, params: Dict, x_mb: jax.Array,
                     mesh: Mesh, stage_axis: str = "stage") -> jax.Array:
    """Pipelined hidden-state forward.

    x_mb: [M, b, s, d_model] pre-embedded microbatches.  Returns
    [M, b, s, d_model] block-stack outputs (before final norm/head).
    """
    S = mesh.shape[stage_axis]
    M = x_mb.shape[0]
    blocks = stack_by_stage(params["blocks"], S)
    perm = [(i, (i + 1) % S) for i in range(S)]

    def stage_program(stage_blocks, xs):
        # stage_blocks: [1, L/S, ...] local slice; xs: [M, b, s, d] replicated
        local = jax.tree.map(lambda t: t[0], stage_blocks)
        idx = jax.lax.axis_index(stage_axis)
        b, s, d = xs.shape[1:]
        buf = jnp.zeros((b, s, d), xs.dtype)          # activation register
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outs = carry
            inp = jax.lax.ppermute(buf, stage_axis, perm)
            feed = jnp.where(t < M, t, 0)
            inp = jnp.where(idx == 0, xs[feed], inp)
            out, _ = model.run_blocks(local, inp, jnp.zeros((), jnp.float32))
            # last stage finishes microbatch t - (S - 1) at tick t
            done = t - (S - 1)
            valid = (idx == S - 1) & (done >= 0)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_slice(
                    o, out[None], (jnp.maximum(done, 0), 0, 0, 0)),
                lambda o: o, outs)
            return (out, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs),
                                    jnp.arange(M + S - 1))
        # every stage holds its own `outs`; only the last stage's is real
        return outs

    fn = shard_map(
        stage_program, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(stage_axis),
        check_rep=False)
    stacked = fn(blocks, x_mb)          # [S*M, b, s, d] stage-major
    return stacked.reshape(S, M, *x_mb.shape[1:])[-1]


# ----------------------------------------------------------------------
# Training: the same schedule, differentiated — one SPMD program
# ----------------------------------------------------------------------
def pipeline_loss(model: Model, params: Dict, tokens_mb: jax.Array,
                  labels_mb: jax.Array, mesh: Mesh,
                  stage_axis: str = "stage") -> jax.Array:
    """Mean next-token NLL over [M, b, s] microbatches through the
    pipelined forward.  Differentiable: the ppermute/scan schedule
    transposes into the reverse-order backward pipeline."""
    from repro.models.layers import cross_entropy
    logits = pipeline_logits(model, params, tokens_mb, mesh, stage_axis)
    nll = jax.vmap(lambda lg, lb: cross_entropy(lg[:, :-1], lb[:, :-1]))(
        logits, labels_mb)
    return jnp.mean(nll)


def make_pipeline_train_step(model: Model, opt_cfg: adamw.AdamWConfig,
                             mesh: Mesh, stage_axis: str = "stage",
                             donate: bool = True):
    """Jitted train step for the homogeneous fast path: pipelined
    forward, transposed-pipeline backward, AdamW — a single donated
    SPMD program, so a zero-failure cluster never leaves the device
    between steps.  tokens_mb/labels_mb: [M, b, s]."""
    def step(params, opt_state, tokens_mb, labels_mb):
        loss, grads = jax.value_and_grad(
            lambda p: pipeline_loss(model, p, tokens_mb, labels_mb,
                                    mesh, stage_axis))(params)
        params2, opt2, stats = adamw.apply(opt_cfg, params, grads, opt_state)
        return params2, opt2, {"loss": loss, **stats}

    return jax.jit(step, donate_argnums=(0, 1) if donate else ())


def pipeline_logits(model: Model, params: Dict, tokens_mb: jax.Array,
                    mesh: Mesh, stage_axis: str = "stage") -> jax.Array:
    """Embed -> pipelined blocks -> final norm + head. tokens: [M, b, s]."""
    from repro.models.layers import embed, rms_norm, unembed
    x = jax.vmap(lambda t: embed(params["embed"], t, model.dtype))(tokens_mb)
    h = pipeline_forward(model, params, x, mesh, stage_axis)
    h = rms_norm(params["final_norm"].astype(h.dtype), h,
                 model.arch.rms_norm_eps)
    head = params.get("head", params["embed"])
    return jax.vmap(lambda v: unembed(head, v))(h)
