"""SPMD pipeline-parallel forward via shard_map (the TPU-native mapping
of one Oobleck pipeline template — DESIGN.md §2).

Each stage of a (uniform) template owns L/S consecutive blocks; the
template's GPipe-style schedule is a static loop of M + S - 1 ticks in
which every stage computes one microbatch and hands its activation to
stage+1 with ``jax.lax.ppermute``.  This is the program a pipeline
instance launches per microbatch wave on real hardware; the
single-controller HeteroTrainer (pipeline.py) remains the reference for
heterogeneous stage layouts (SPMD requires every shard to run the same
program, so stages must be uniform here — Oobleck's planner emits
near-uniform splits for homogeneous-cost blocks, making this the
production fast path).

Correctness is pinned by tests/test_spmd_pipeline.py: the pipelined
forward equals the plain forward bit-for-bit on a multi-device host mesh.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.models import Model


def stack_by_stage(params_blocks, num_stages: int):
    """[L, ...] stacked blocks -> [S, L/S, ...]."""
    L = jax.tree.leaves(params_blocks)[0].shape[0]
    assert L % num_stages == 0, (L, num_stages)
    return jax.tree.map(
        lambda t: t.reshape(num_stages, L // num_stages, *t.shape[1:]),
        params_blocks)


def pipeline_forward(model: Model, params: Dict, x_mb: jax.Array,
                     mesh: Mesh, stage_axis: str = "stage") -> jax.Array:
    """Pipelined hidden-state forward.

    x_mb: [M, b, s, d_model] pre-embedded microbatches.  Returns
    [M, b, s, d_model] block-stack outputs (before final norm/head).
    """
    S = mesh.shape[stage_axis]
    M = x_mb.shape[0]
    blocks = stack_by_stage(params["blocks"], S)
    perm = [(i, (i + 1) % S) for i in range(S)]

    def stage_program(stage_blocks, xs):
        # stage_blocks: [1, L/S, ...] local slice; xs: [M, b, s, d] replicated
        local = jax.tree.map(lambda t: t[0], stage_blocks)
        idx = jax.lax.axis_index(stage_axis)
        b, s, d = xs.shape[1:]
        buf = jnp.zeros((b, s, d), xs.dtype)          # activation register
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outs = carry
            inp = jax.lax.ppermute(buf, stage_axis, perm)
            feed = jnp.where(t < M, t, 0)
            inp = jnp.where(idx == 0, xs[feed], inp)
            out, _ = model.run_blocks(local, inp, jnp.zeros((), jnp.float32))
            # last stage finishes microbatch t - (S - 1) at tick t
            done = t - (S - 1)
            valid = (idx == S - 1) & (done >= 0)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_slice(
                    o, out[None], (jnp.maximum(done, 0), 0, 0, 0)),
                lambda o: o, outs)
            return (out, outs), None

        (_, outs), _ = jax.lax.scan(tick, (buf, outs),
                                    jnp.arange(M + S - 1))
        # every stage holds its own `outs`; only the last stage's is real
        return outs

    fn = shard_map(
        stage_program, mesh=mesh,
        in_specs=(P(stage_axis), P()),
        out_specs=P(stage_axis),
        check_rep=False)
    stacked = fn(blocks, x_mb)          # [S*M, b, s, d] stage-major
    return stacked.reshape(S, M, *x_mb.shape[1:])[-1]


def pipeline_logits(model: Model, params: Dict, tokens_mb: jax.Array,
                    mesh: Mesh, stage_axis: str = "stage") -> jax.Array:
    """Embed -> pipelined blocks -> final norm + head. tokens: [M, b, s]."""
    from repro.models.layers import embed, rms_norm, unembed
    x = jax.vmap(lambda t: embed(params["embed"], t, model.dtype))(tokens_mb)
    h = pipeline_forward(model, params, x, mesh, stage_axis)
    h = rms_norm(params["final_norm"].astype(h.dtype), h,
                 model.arch.rms_norm_eps)
    head = params.get("head", params["embed"])
    return jax.vmap(lambda v: unembed(head, v))(h)
