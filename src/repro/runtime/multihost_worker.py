"""Worker-process entry point, kept separate from runtime/multihost.py
so ``python -m`` launches don't re-execute a module the ``repro.runtime``
package already imported (runpy's double-import warning)."""
from repro.runtime.multihost import worker_cli

if __name__ == "__main__":
    worker_cli()
