"""Schedule-adaptation recovery planning (ReCycle, arXiv:2405.14009).

When a failure damages some pipeline replicas but leaves others whole,
the cheapest *correct* response is often not a replan: every pipeline
replica holds the full model, so the damaged replicas' microbatches can
be re-routed to surviving peers as decoupled-1F1B "guests" that fill
the hosts' pipeline bubbles — zero state transfer, zero recompilation
(the hosts' programs for the new microbatch counts are already warm).

``AdaptCostModel`` prices that choice in the same per-row accounting
style as ``SyncCostModel`` (core/sync.py): one frozen row per surviving
pipeline, a ``rows()``/aggregate-seconds split, and a breakdown dict
with the same keys as ``OobleckEngine.recovery_breakdown`` plus the
adaptation-specific ``reroute`` exposure term.

Core must not import runtime at module load (circular-import rule), so
the op-level adapted schedules live in ``runtime/schedule.py``; this
module only does count-level planning and pricing on top of
``distribute_batch`` and ``estimate_iteration_time``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

from repro.core.batch import BatchPlan, distribute_batch
from repro.core.planner import estimate_iteration_time
from repro.core.reconfigure import PipelineInstance
from repro.core.templates import PlanningError
from repro.utils import hw as hwlib


class AdaptationError(RuntimeError):
    """Schedule adaptation is infeasible for this failure event (no
    surviving whole pipeline, or batch redistribution impossible)."""


@dataclasses.dataclass(frozen=True)
class AdaptCostRow:
    """One surviving pipeline's slot in the adapted schedule (seconds)."""

    pipeline: int           # index into the surviving-instance list
    native_mb: int          # microbatches it ran before the failure
    guest_mb: int           # re-routed microbatches it hosts now
    base_s: float           # 1F1B makespan at native_mb
    adapted_s: float        # 1F1B makespan at native_mb + guest_mb
    serial_guest_s: float   # guests run serially after drain (no filling)
    bubble_fill_s: float    # serial_guest_s - (adapted_s - base_s), >= 0

    @property
    def total_mb(self) -> int:
        return self.native_mb + self.guest_mb


@dataclasses.dataclass(frozen=True)
class AdaptPlan:
    """Count-level adaptation: which instances survive, which nodes are
    parked as hot spares, and the rebalanced batch.

    The rebalanced counts come from the SAME ``distribute_batch`` (Eq. 6)
    a full replan would apply to the surviving instance set — so when a
    failure kills whole pipelines, adaptation and replan produce
    structurally identical (instances, batch) and the training math is
    bitwise identical; adaptation just skips the transfer/compile legs.
    """

    instances: Tuple[PipelineInstance, ...]   # surviving, original order
    batch: BatchPlan
    mb_before: Tuple[int, ...]     # per surviving instance, pre-failure
    mb_after: Tuple[int, ...]      # per surviving instance, rebalanced
    dropped: Tuple[int, ...]       # instance_ids of damaged replicas
    parked_nodes: Tuple[str, ...]  # healthy nodes of damaged replicas
    replan_seconds: float          # measured planning wall-clock

    @property
    def guest_counts(self) -> Tuple[int, ...]:
        return tuple(max(0, a - b)
                     for a, b in zip(self.mb_after, self.mb_before))

    @property
    def total_guests(self) -> int:
        return sum(self.guest_counts)


def plan_adaptation(instances: Sequence[PipelineInstance],
                    mb_before: Sequence[int],
                    dead: Sequence[str],
                    global_batch: int, microbatch_size: int,
                    replan_seconds: float = 0.0) -> AdaptPlan:
    """Build an AdaptPlan for a failure event, or raise AdaptationError.

    ``mb_before[i]`` is instance i's pre-failure microbatch count (used
    only for guest accounting/pricing — the rebalanced counts are
    authoritative).  An instance touching ANY dead node is damaged; its
    healthy nodes are parked as hot spares for a later consolidating
    replan.
    """
    dead_set = set(dead)
    keep: List[PipelineInstance] = []
    keep_mb: List[int] = []
    dropped: List[int] = []
    parked: List[str] = []
    for inst, mb in zip(instances, mb_before):
        if dead_set & set(inst.nodes):
            dropped.append(inst.instance_id)
            parked.extend(n for n in inst.nodes if n not in dead_set)
        else:
            keep.append(inst)
            keep_mb.append(mb)
    if not dropped:
        raise AdaptationError(f"no instance touches dead nodes {sorted(dead_set)}")
    if not keep:
        raise AdaptationError(
            "adaptation infeasible: every pipeline replica is damaged "
            f"(dead={sorted(dead_set)}) — replan is the only option")
    try:
        batch = distribute_batch([i.template for i in keep],
                                 global_batch, microbatch_size)
    except PlanningError as e:
        raise AdaptationError(f"adaptation infeasible: {e}") from e
    return AdaptPlan(
        instances=tuple(keep), batch=batch,
        mb_before=tuple(keep_mb),
        mb_after=tuple(batch.num_microbatches),
        dropped=tuple(dropped), parked_nodes=tuple(parked),
        replan_seconds=float(replan_seconds))


class AdaptCostModel:
    """ONE pricing of schedule adaptation, consumed by the engine's
    policy selector, the simulator policy and benchmarks/recovery_policy
    — mirror of SyncCostModel's per-row accounting (core/sync.py).

    Per surviving pipeline: the 1F1B makespan at its rebalanced
    microbatch count (affine estimate, core/planner.py).  Guests beyond
    the pipeline-fill point cost exactly one slowest-stage slot each;
    guests absorbed before the fill point ride the warmup/drain bubbles
    for free — ``bubble_fill_s`` reports that saving against the naive
    run-guests-serially baseline.
    """

    #: regroup allowance for an adaptation.  A replan's 1.0 s barrier
    #: (engine.recovery_breakdown) covers collective re-formation across
    #: CHANGED pipeline memberships; an adaptation keeps every surviving
    #: pipeline's membership identical — the re-route is one
    #: control-plane round, and the cross-replica sync groups merely
    #: drop the dead replica, which the bucketed data plane rebinds as
    #: explicit device subsets with no communicator re-init.
    ADAPT_BARRIER_SECONDS = 0.25

    def __init__(self, hw: hwlib.HardwareSpec = hwlib.V5E,
                 barrier_seconds: float = ADAPT_BARRIER_SECONDS):
        self.hw = hw
        self.barrier_seconds = barrier_seconds

    # -- per-pipeline rows ---------------------------------------------
    def rows(self, plan: AdaptPlan) -> List[AdaptCostRow]:
        out: List[AdaptCostRow] = []
        for i, inst in enumerate(plan.instances):
            tpl = inst.template
            native = plan.mb_before[i]
            total = plan.mb_after[i]
            guests = max(0, total - native)
            base = estimate_iteration_time(tpl, native)
            adapted = estimate_iteration_time(tpl, total)
            t_slow = tpl.stage_times[tpl.slowest_stage]
            serial = guests * t_slow
            out.append(AdaptCostRow(
                pipeline=i, native_mb=native, guest_mb=guests,
                base_s=base, adapted_s=adapted, serial_guest_s=serial,
                bubble_fill_s=max(0.0, serial - (adapted - base))))
        return out

    # -- aggregates ------------------------------------------------------
    def adapted_iteration_seconds(self, plan: AdaptPlan) -> float:
        """Post-adaptation iteration compute time: pipelines run
        concurrently, the iteration is gated by the slowest host."""
        rows = self.rows(plan)
        return max((r.adapted_s for r in rows), default=0.0)

    def reroute_exposure_seconds(self, plan: AdaptPlan,
                                 reference_iteration_s: float) -> float:
        """Extra latency of the adapted iteration over what the REPLAN
        outcome would deliver (``reference_iteration_s``, the engine's
        ``adaptation_reference_iteration``) — the compute-side downtime
        adaptation pays for skipping reconfiguration.  Charged once: the
        steady-state difference is already in the iteration time every
        later step reports, so charging against the pre-failure
        iteration would double-count capacity the failure itself
        removed.  Zero when adaptation and replan land on the same
        (instances, batch) — e.g. whole-pipeline kills."""
        return max(0.0, self.adapted_iteration_seconds(plan)
                   - reference_iteration_s)

    def breakdown(self, plan: AdaptPlan,
                  reference_iteration_s: float) -> Dict[str, float]:
        """Same keys as OobleckEngine.recovery_breakdown, plus
        ``reroute``: transfer and compile are structurally zero (no
        state moves; host programs for every microbatch count are
        already warm via warm_templates())."""
        return {
            "replan": plan.replan_seconds,
            "transfer": 0.0,
            "compile": 0.0,
            "barrier": self.barrier_seconds,
            "reroute": self.reroute_exposure_seconds(
                plan, reference_iteration_s),
        }

    def downtime_seconds(self, plan: AdaptPlan,
                         reference_iteration_s: float) -> float:
        return sum(self.breakdown(plan, reference_iteration_s).values())
