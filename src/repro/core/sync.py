"""Layer-granularity gradient synchronization planning (paper §6.1).

Heterogeneous pipelines place the same layer in different stages on
different node sets, so stage-granular data-parallel all-reduce is
impossible.  Oobleck instead synchronizes per *layer*: for every layer,
the nodes holding that layer across all pipeline replicas form a
communication group (a dedicated NCCL subcommunicator in the original; a
per-bucket collective over an explicit device subset in our JAX runtime).

Consecutive layers with identical peer structure are merged into buckets
(PyTorch-style bucketing) so small layers don't issue tiny collectives,
and buckets are emitted in reverse-depth order so the runtime can overlap
each bucket's all-reduce with the backward of earlier layers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Set, Tuple

from repro.core.reconfigure import PipelineInstance


@dataclasses.dataclass(frozen=True)
class LayerGroup:
    """Sync participants for one layer: one entry per pipeline replica."""

    layer: int
    # per replica: ordered tuple of nodes holding this layer's shards
    replicas: Tuple[Tuple[str, ...], ...]

    @property
    def uniform_sharding(self) -> bool:
        """True if every replica shards this layer over the same number of
        nodes — the fast path where shard-wise ring all-reduce applies."""
        widths = {len(r) for r in self.replicas}
        return len(widths) == 1

    def peer_groups(self) -> List[Tuple[str, ...]]:
        """Concrete all-reduce groups.

        Fast path (uniform sharding): shard i of every replica forms one
        group.  Slow path (widths differ): the lead node of each replica
        gathers its pipeline's full layer gradient, leads all-reduce, then
        re-scatter — expressed here as a single lead group; the
        gather/scatter legs are intra-replica.
        """
        if self.uniform_sharding:
            width = len(self.replicas[0])
            return [tuple(rep[i] for rep in self.replicas)
                    for i in range(width)]
        return [tuple(rep[0] for rep in self.replicas)]


@dataclasses.dataclass(frozen=True)
class SyncBucket:
    """Consecutive layers sharing identical peer structure."""

    layer_start: int
    layer_end: int
    groups: Tuple[Tuple[str, ...], ...]
    nbytes: int

    @property
    def num_layers(self) -> int:
        return self.layer_end - self.layer_start


def layer_groups(instances: Sequence[PipelineInstance]) -> List[LayerGroup]:
    if not instances:
        return []
    num_layers = instances[0].template.num_layers
    out: List[LayerGroup] = []
    for l in range(num_layers):
        reps = tuple(tuple(inst.layer_owners(l)) for inst in instances)
        out.append(LayerGroup(layer=l, replicas=reps))
    return out


def build_sync_plan(instances: Sequence[PipelineInstance],
                    layer_bytes: Sequence[int],
                    bucket_cap_bytes: int = 64 * 1024 * 1024) -> List[SyncBucket]:
    """Bucketed, reverse-depth-ordered sync plan.

    ``layer_bytes[l]`` is the gradient payload of layer ``l`` (bf16).
    Buckets close when the peer structure changes or the cap is reached.
    Returned deepest-first: bucket i can be all-reduced while backward of
    shallower layers still runs (compute/comm overlap, §6.1).
    """
    groups = layer_groups(instances)
    buckets: List[SyncBucket] = []
    cur_lo = cur_hi = -1            # current bucket covers [cur_lo, cur_hi)
    cur_groups: Tuple[Tuple[str, ...], ...] = ()
    cur_bytes = 0

    def flush():
        nonlocal cur_lo, cur_hi, cur_bytes
        if cur_lo >= 0:
            buckets.append(SyncBucket(cur_lo, cur_hi, cur_groups, cur_bytes))
        cur_lo, cur_hi, cur_bytes = -1, -1, 0

    for g in reversed(groups):          # deepest layer first
        pg = tuple(g.peer_groups())
        nbytes = int(layer_bytes[g.layer])
        if (cur_lo < 0 or pg != cur_groups
                or cur_bytes + nbytes > bucket_cap_bytes):
            flush()
            cur_lo, cur_hi, cur_groups, cur_bytes = g.layer, g.layer + 1, pg, nbytes
        else:
            cur_lo = g.layer
            cur_bytes += nbytes
    flush()
    return buckets


def layer_owner_map(instances: Sequence[PipelineInstance]
                    ) -> Dict[int, Set[str]]:
    """Layer -> every node holding its state across all replicas: the
    candidate-source set the recovery data plane (runtime/transfer.py)
    draws from, and what the copy plan's ``CopyTask.sources`` records."""
    return {g.layer: {n for rep in g.replicas for n in rep}
            for g in layer_groups(instances)}


def verify_replica_coverage(instances: Sequence[PipelineInstance]) -> bool:
    """Paper §3.2 invariant: every layer has >= 1 owner; recoverability
    needs >= 1 complete set of owners across pipelines."""
    if not instances:
        return False
    return all(len(g.replicas) >= 1 and all(len(r) >= 1 for r in g.replicas)
               for g in layer_groups(instances))
