"""Layer-granularity gradient synchronization planning (paper §6.1).

Heterogeneous pipelines place the same layer in different stages on
different node sets, so stage-granular data-parallel all-reduce is
impossible.  Oobleck instead synchronizes per *layer*: for every layer,
the nodes holding that layer across all pipeline replicas form a
communication group (a dedicated NCCL subcommunicator in the original; a
per-bucket collective over an explicit device subset in our JAX runtime).

Consecutive layers with identical peer structure are merged into buckets
(PyTorch-style bucketing) so small layers don't issue tiny collectives,
and buckets are emitted in reverse-depth order so the runtime can overlap
each bucket's all-reduce with the backward of earlier layers.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.reconfigure import PipelineInstance
from repro.utils import hw as hwlib


@dataclasses.dataclass(frozen=True)
class LayerGroup:
    """Sync participants for one layer: one entry per pipeline replica."""

    layer: int
    # per replica: ordered tuple of nodes holding this layer's shards
    replicas: Tuple[Tuple[str, ...], ...]

    @property
    def uniform_sharding(self) -> bool:
        """True if every replica shards this layer over the same number of
        nodes — the fast path where shard-wise ring all-reduce applies."""
        widths = {len(r) for r in self.replicas}
        return len(widths) == 1

    def peer_groups(self) -> List[Tuple[str, ...]]:
        """Concrete all-reduce groups.

        Fast path (uniform sharding): shard i of every replica forms one
        group.  Slow path (widths differ): the lead node of each replica
        gathers its pipeline's full layer gradient, leads all-reduce, then
        re-scatter — expressed here as a single lead group; the
        gather/scatter legs are intra-replica.
        """
        if self.uniform_sharding:
            width = len(self.replicas[0])
            return [tuple(rep[i] for rep in self.replicas)
                    for i in range(width)]
        return [tuple(rep[0] for rep in self.replicas)]


@dataclasses.dataclass(frozen=True)
class SyncBucket:
    """Consecutive layers sharing identical peer structure."""

    layer_start: int
    layer_end: int
    groups: Tuple[Tuple[str, ...], ...]
    nbytes: int

    @property
    def num_layers(self) -> int:
        return self.layer_end - self.layer_start


def layer_groups(instances: Sequence[PipelineInstance]) -> List[LayerGroup]:
    if not instances:
        return []
    num_layers = instances[0].template.num_layers
    out: List[LayerGroup] = []
    for l in range(num_layers):
        reps = tuple(tuple(inst.layer_owners(l)) for inst in instances)
        out.append(LayerGroup(layer=l, replicas=reps))
    return out


def split_span(layer_start: int, layer_end: int, layer_bytes: Sequence[int],
               bucket_cap_bytes: int) -> List[Tuple[int, int]]:
    """Cap-split one constant-peer-structure run ``[layer_start,
    layer_end)`` into bucket spans, deepest-first — the exact greedy
    descending accumulation ``build_sync_plan`` applies inside a run.

    Shared with the runtime data plane's program warmer
    (runtime/sync_exec.py): any bucket span the planner can emit for any
    reachable instance set is the cap-split of a span between two
    template stage boundaries, so warming over this same function is
    what makes reconfiguration zero-compile for bucket programs too.
    """
    spans: List[Tuple[int, int]] = []
    cur_lo = cur_hi = -1
    cur_bytes = 0
    for l in reversed(range(layer_start, layer_end)):   # deepest first
        nbytes = int(layer_bytes[l])
        if cur_lo < 0 or cur_bytes + nbytes > bucket_cap_bytes:
            if cur_lo >= 0:
                spans.append((cur_lo, cur_hi))
            cur_lo, cur_hi, cur_bytes = l, l + 1, nbytes
        else:
            cur_lo = l
            cur_bytes += nbytes
    if cur_lo >= 0:
        spans.append((cur_lo, cur_hi))
    return spans


def build_sync_plan(instances: Sequence[PipelineInstance],
                    layer_bytes: Sequence[int],
                    bucket_cap_bytes: int = 64 * 1024 * 1024) -> List[SyncBucket]:
    """Bucketed, reverse-depth-ordered sync plan.

    ``layer_bytes[l]`` is the gradient payload of layer ``l`` (bf16).
    Buckets close when the peer structure changes or the cap is reached.
    Returned deepest-first: bucket i can be all-reduced while backward of
    shallower layers still runs (compute/comm overlap, §6.1).
    """
    groups = layer_groups(instances)
    buckets: List[SyncBucket] = []
    # maximal runs of layers with identical peer structure, deepest-first
    run_hi = run_lo = len(groups)
    run_groups: Tuple[Tuple[str, ...], ...] = ()

    def flush_run():
        for (lo, hi) in split_span(run_lo, run_hi, layer_bytes,
                                   bucket_cap_bytes):
            buckets.append(SyncBucket(
                lo, hi, run_groups,
                sum(int(layer_bytes[l]) for l in range(lo, hi))))

    for g in reversed(groups):          # deepest layer first
        pg = tuple(g.peer_groups())
        if run_lo == run_hi or pg != run_groups:
            if run_lo < run_hi:
                flush_run()
            run_lo = run_hi = g.layer + 1
            run_groups = pg
        run_lo = g.layer
    if run_lo < run_hi:
        flush_run()
    return buckets


def layer_owner_map(instances: Sequence[PipelineInstance]
                    ) -> Dict[int, Set[str]]:
    """Layer -> every node holding its state across all replicas: the
    candidate-source set the recovery data plane (runtime/transfer.py)
    draws from, and what the copy plan's ``CopyTask.sources`` records."""
    return {g.layer: {n for rep in g.replicas for n in rep}
            for g in layer_groups(instances)}


def verify_replica_coverage(instances: Sequence[PipelineInstance]) -> bool:
    """Paper §3.2 invariant: every layer has >= 1 owner; recoverability
    needs >= 1 complete set of owners across pipelines."""
    if not instances:
        return False
    return all(len(g.replicas) >= 1 and all(len(r) >= 1 for r in g.replicas)
               for g in layer_groups(instances))


# ----------------------------------------------------------------------
# Wire-format accounting and the shared per-bucket sync cost model
# ----------------------------------------------------------------------
#: codec -> (bytes per element, fixed per-bucket overhead).  The runtime
#: flattens each bucket into ONE contiguous buffer before encoding, so
#: int8 carries exactly one fp32 scale per bucket — not one per leaf.
CODEC_WIRE = {"none": (4, 0), "bf16": (2, 0), "int8": (1, 4)}


def flat_wire_bytes(num_elements: int, codec: str) -> int:
    """Bytes on the wire for one FLATTENED bucket of ``num_elements``
    fp32 gradient elements under ``codec``.  This is the single source
    of truth: runtime/compression.py asserts its encoded output matches,
    and the cost model below prices every leg with it."""
    try:
        per_elem, overhead = CODEC_WIRE[codec]
    except KeyError:
        raise ValueError(f"unknown codec {codec!r}") from None
    return per_elem * int(num_elements) + overhead


@dataclasses.dataclass(frozen=True)
class BucketCostRow:
    """One bucket's slot in the overlapped sync schedule (seconds)."""

    layer_start: int
    layer_end: int
    wire_bytes: int
    comm_s: float       # reduction time of this bucket (hierarchical)
    ready_s: float      # when backward has produced all its gradients
    start_s: float      # when the wire is free for it (deepest-first issue)
    end_s: float
    hierarchical: bool  # True when the peer group spans pods (ICI+DCN legs)


class SyncCostModel:
    """ONE pricing of cross-replica gradient sync, consumed by the
    engine (`iteration_time`), the simulator policy and the benchmarks —
    replacing the old last-bucket-only `_sync_tail_seconds` heuristic.

    Per bucket: the peer groups all-reduce the bucket's wire bytes
    (codec-compressed, one scale per bucket).  A group whose replicas
    sit in one pod rides ICI; a group spanning pods takes the two-level
    path the runtime executes — reduce intra-pod over ICI, all-reduce
    between pod leads over DCN, broadcast back over ICI.  Buckets are
    issued deepest-first and overlap the remaining backward: the tail is
    whatever the last bucket cannot hide (DESIGN.md §10).

    ``topology`` is duck-typed (needs ``pod_of``): core must not import
    runtime at module load, so the engine passes its lazily-built
    runtime.transfer.Topology in.
    """

    def __init__(self, hw: hwlib.HardwareSpec = hwlib.V5E,
                 codec: str = "none", topology=None):
        if codec not in CODEC_WIRE:
            raise ValueError(f"unknown codec {codec!r}")
        self.hw = hw
        self.codec = codec
        self.topology = topology

    # -- one bucket -----------------------------------------------------
    def bucket_wire_bytes(self, bucket: SyncBucket) -> int:
        # bucket.nbytes counts bf16 parameter bytes -> element count
        return flat_wire_bytes(bucket.nbytes // 2, self.codec)

    def _group_seconds(self, nodes: Sequence[str], nbytes: float) -> Tuple[float, bool]:
        k = len(nodes)
        if k <= 1:
            return 0.0, False
        if self.topology is None:
            return hwlib.allreduce_time(nbytes, k, hw=self.hw), False
        pods: Dict = {}
        for n in nodes:
            pods.setdefault(self.topology.pod_of(n), []).append(n)
        if len(pods) == 1:
            return hwlib.allreduce_time(nbytes, k, hw=self.hw), False
        # two-level (NCCL-style hierarchical all-reduce): intra-pod
        # reduce-scatter over ICI, cross-pod all-reduce of the per-lead
        # SHARD over DCN, intra-pod all-gather over ICI.  Pods run their
        # local legs concurrently, so ICI legs cost the largest pod;
        # the DCN leg carries the largest shard (smallest pod).
        k_max = max(len(members) for members in pods.values())
        k_min = min(len(members) for members in pods.values())
        rs = hwlib.allgather_time(nbytes, k_max, hw=self.hw)   # (k-1)/k legs
        cross = hwlib.allreduce_time(nbytes / k_min, len(pods),
                                     bandwidth=self.hw.dcn_bandwidth,
                                     hw=self.hw)
        ag = hwlib.allgather_time(nbytes, k_max, hw=self.hw)
        return rs + cross + ag, True

    def bucket_seconds(self, bucket: SyncBucket) -> Tuple[float, bool]:
        """(reduction seconds, crossed-pods?) for one bucket.  Groups
        shard the payload (shard-wise rings run concurrently), so the
        bucket costs its slowest group."""
        wire = self.bucket_wire_bytes(bucket)
        per_group = wire / max(len(bucket.groups), 1)
        worst, hier = 0.0, False
        for g in bucket.groups:
            s, h = self._group_seconds(g, per_group)
            if s > worst:
                worst = s
            hier = hier or h
        return worst, hier

    # -- the overlapped schedule ---------------------------------------
    def schedule(self, plan: Sequence[SyncBucket],
                 bwd_seconds: Sequence[float]) -> List[BucketCostRow]:
        """Deepest-first issue order against the backward pass.

        Backward produces gradients from the deepest layer down; bucket
        [s, e) is ready once backward passed layer s.  Buckets share one
        wire, so bucket i starts at max(ready_i, end_{i-1}) — reduction
        of deep buckets overlaps the backward of shallow layers, and
        only what spills past the end of backward is exposed."""
        L = len(bwd_seconds)
        suffix = [0.0] * (L + 1)        # suffix[s] = time to bwd layers s..L-1
        for l in reversed(range(L)):
            suffix[l] = suffix[l + 1] + float(bwd_seconds[l])
        rows: List[BucketCostRow] = []
        wire_free = 0.0
        for b in plan:
            comm, hier = self.bucket_seconds(b)
            ready = suffix[min(b.layer_start, L)]
            start = max(ready, wire_free)
            wire_free = start + comm
            rows.append(BucketCostRow(
                layer_start=b.layer_start, layer_end=b.layer_end,
                wire_bytes=self.bucket_wire_bytes(b), comm_s=comm,
                ready_s=ready, start_s=start, end_s=wire_free,
                hierarchical=hier))
        return rows

    def tail_seconds(self, plan: Sequence[SyncBucket],
                     bwd_seconds: Sequence[float]) -> float:
        """Sync time NOT hidden behind backward — the only part a step
        actually pays for cross-replica sync (DESIGN.md §5/§10)."""
        rows = self.schedule(plan, bwd_seconds)
        if not rows:
            return 0.0
        total_bwd = sum(float(t) for t in bwd_seconds)
        return max(0.0, rows[-1].end_s - total_bwd)
