"""Dynamic reconfiguration without restart (paper §5).

On failure, pipelines that lost nodes are replaced by pipelines
instantiated from the precomputed templates, in three escalating steps
(Figure 8):

  1. *simple reinstantiation* — a template for the surviving node count
     exists (sizes are consecutive, so any count in [n0, n_max] works);
  2. *borrow nodes* — steal nodes from pipelines larger than n0 until the
     damaged pipeline reaches n0 (donors reinstantiate too);
  3. *merge pipelines* — absorb another pipeline; Thm B.1 guarantees a
     template exists for the merged size.

After reinstantiation, nodes that now own layers they did not hold before
copy the missing model states (params + optimizer) from surviving
replicas — the copy plan is computed here at layer granularity, the unit
Oobleck syncs and stores state in.  Batch is then redistributed (Eq. 6).

If fewer than (f+1)*n0 nodes survive, recovery is impossible without
violating the fault-tolerance contract: ``InsufficientReplicasError`` is
raised and the engine checkpoints and exits (paper §3.4 lifecycle).
"""
from __future__ import annotations

import dataclasses
import itertools
import time as _time
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.batch import BatchPlan, distribute_batch
from repro.core.templates import NodeSpec, PipelineTemplate, PlanningError


class InsufficientReplicasError(RuntimeError):
    """Fewer than (f+1)*n0 nodes remain; training must stop and checkpoint."""


@dataclasses.dataclass
class PipelineInstance:
    """A live pipeline: a template bound to concrete node ids."""

    instance_id: int
    template: PipelineTemplate
    nodes: List[str]           # one entry per template node slot, in order

    def __post_init__(self):
        assert len(self.nodes) == self.template.num_nodes

    def layer_owners(self, layer: int) -> List[str]:
        """Nodes holding model states of ``layer`` (the stage's node)."""
        st = self.template.stage_of_layer(layer)
        span = max(1, st.num_gpus // self.template.gpus_per_node)
        return self.nodes[st.node_offset:st.node_offset + span]

    def all_layer_owners(self) -> Dict[int, List[str]]:
        return {l: self.layer_owners(l)
                for l in range(self.template.num_layers)}


@dataclasses.dataclass(frozen=True)
class CopyTask:
    layer: int
    src_node: str                  # default pick (least-loaded survivor)
    dst_node: str
    nbytes: int
    # every surviving replica holding this layer: the data plane
    # (runtime/transfer.py) re-chooses among these topology-aware —
    # pod-local/ICI sources beat cross-pod/DCN ones
    sources: Tuple[str, ...] = ()


@dataclasses.dataclass
class ReconfigResult:
    instances: List[PipelineInstance]
    copy_plan: List[CopyTask]
    batch: BatchPlan
    # bookkeeping for the simulator / engine metrics
    merged: int = 0
    borrowed: int = 0
    reinstantiated: int = 0
    globally_replanned: bool = False
    # nodes left idle because no template combination covers them: joins
    # pushing the cluster beyond the original N (the §4.1.1 guarantee
    # covers any count <= N), or a burst-merged pool landing in a gap of
    # a capped template set; spares rejoin on the next reconfiguration
    spare_nodes: List[str] = dataclasses.field(default_factory=list)
    # wall-clock the reconfigurator spent computing this result (the
    # "replan" leg of the recovery-latency decomposition; a table
    # lookup, so microseconds — measured, not assumed)
    replan_seconds: float = 0.0

    def copy_bytes(self) -> int:
        return sum(t.nbytes for t in self.copy_plan)


def _layer_state_bytes(profile, layer: int) -> int:
    """Bytes of model state to copy for one layer: bf16 params + fp32
    master + two fp32 Adam moments (what 'model states' means in §5.1)."""
    p = profile.layers[layer].param_bytes // 2  # param count
    return p * 2 + p * 4 * 3


class Reconfigurator:
    """Executes §5.1/§5.2 against a set of live pipeline instances."""

    def __init__(self, templates: Dict[int, PipelineTemplate], spec: NodeSpec,
                 profile, global_batch: int, microbatch: int):
        self.templates = templates
        self.spec = spec
        self.profile = profile
        self.global_batch = global_batch
        self.microbatch = microbatch
        self._next_id = itertools.count(1_000)

    # ------------------------------------------------------------------
    def on_failure(self, instances: Sequence[PipelineInstance],
                   dead_nodes: Set[str],
                   spares: Sequence[str] = ()) -> ReconfigResult:
        """React to ``dead_nodes`` leaving.  ``spares`` are alive idle
        nodes from an earlier reconfiguration; they enter the recovery
        pool like the survivors of a damaged pipeline, so they rejoin
        service whenever a covering combination exists."""
        t0 = _time.perf_counter()
        spec = self.spec
        spares = [n for n in spares if n not in dead_nodes]
        survivors: List[List[str]] = [
            [n for n in inst.nodes if n not in dead_nodes] for inst in instances]
        total = sum(len(s) for s in survivors) + len(spares)
        if total < (spec.f + 1) * spec.n0:
            raise InsufficientReplicasError(
                f"{total} nodes < (f+1)*n0 = {(spec.f + 1) * spec.n0}; "
                "checkpoint and exit")

        old_owners = self._ownership(instances)
        result = ReconfigResult(instances=[], copy_plan=[], batch=None)  # type: ignore

        healthy: List[Tuple[PipelineInstance, List[str]]] = []
        damaged: List[List[str]] = []
        for inst, nodes in zip(instances, survivors):
            if len(nodes) == inst.template.num_nodes:
                healthy.append((inst, nodes))
            elif nodes:
                damaged.append(nodes)
        if spares:
            damaged.append(list(spares))
        # Damaged pipelines with zero survivors simply disappear.

        new_instances: List[PipelineInstance] = [inst for inst, _ in healthy]

        # --- step 1: simple reinstantiation -------------------------------
        still_small: List[List[str]] = []
        for nodes in damaged:
            if len(nodes) >= spec.n0:
                new_instances.append(self._instantiate(len(nodes), nodes))
                result.reinstantiated += 1
            else:
                still_small.append(nodes)

        # --- step 2: borrow nodes -----------------------------------------
        for nodes in list(still_small):
            need = spec.n0 - len(nodes)
            borrowed: List[str] = []
            # donors: largest pipelines first, may only shrink down to n0
            donors = sorted(new_instances,
                            key=lambda i: i.template.num_nodes, reverse=True)
            for donor in donors:
                while need and donor.template.num_nodes - 1 >= spec.n0:
                    node = donor.nodes[-1]
                    shrunk = self._instantiate(
                        donor.template.num_nodes - 1, donor.nodes[:-1])
                    new_instances[new_instances.index(donor)] = shrunk
                    donor = shrunk
                    borrowed.append(node)
                    need -= 1
                if not need:
                    break
            if not need:
                new_instances.append(
                    self._instantiate(spec.n0, nodes + borrowed))
                result.borrowed += len(borrowed)
                still_small.remove(nodes)
            else:
                # return any partial borrow is unnecessary: donors already
                # reinstantiated smaller; just keep the pool for merging.
                nodes.extend(borrowed)

        # --- step 3: merge pipelines ---------------------------------------
        while still_small:
            nodes = still_small.pop()
            pool = list(nodes)
            while len(pool) < spec.n0:
                if still_small:
                    pool.extend(still_small.pop())
                    continue
                if not new_instances:
                    raise InsufficientReplicasError(
                        "no pipeline left to merge with")
                # absorb the smallest healthy pipeline (Thm B.1: a template
                # for the merged size exists)
                victim = min(new_instances, key=lambda i: i.template.num_nodes)
                new_instances.remove(victim)
                pool.extend(victim.nodes)
                result.merged += 1
            size = len(pool)
            if size in self.templates:
                new_instances.append(self._instantiate(size, pool))
            else:
                # Thm B.1 guarantees a template for a merge of TWO pipelines
                # below n_max, but a correlated burst (whole-rack failure,
                # preemption wave) can leave a pool larger than the largest
                # template after several absorptions.  Split the pool back
                # into covered sizes instead of giving up — fewest pipelines
                # first, so the merged capacity stays in deep/fast pipelines.
                # A capped template set (sizes n0..n_max with n_max < 2n0-1)
                # has gaps no decomposition covers; then the largest
                # coverable prefix runs and the remainder waits as hot
                # spares for the next join/reconfiguration.
                parts, use = self._decompose_prefix(size)
                if not parts:
                    raise InsufficientReplicasError(
                        f"merged pool of {size} nodes is below every "
                        f"template size {sorted(self.templates)}")
                cursor = 0
                for part in parts:
                    new_instances.append(
                        self._instantiate(part, pool[cursor:cursor + part]))
                    cursor += part
                result.spare_nodes.extend(pool[use:])

        # --- fault-tolerance floor: keep >= f+1 pipelines -------------------
        if len(new_instances) < spec.f + 1:
            new_instances = self._global_replan(
                [n for inst in new_instances for n in inst.nodes])
            result.globally_replanned = True

        result.instances = new_instances
        result.copy_plan = self._copy_plan(old_owners, new_instances, dead_nodes)
        result.batch = distribute_batch(
            [i.template for i in new_instances], self.global_batch,
            self.microbatch)
        result.replan_seconds = _time.perf_counter() - t0
        return result

    # ------------------------------------------------------------------
    def on_join(self, instances: Sequence[PipelineInstance],
                new_nodes: Sequence[str]) -> ReconfigResult:
        """Node additions (spot instances coming back): re-plan globally to
        use every node — instantiation is a table lookup (§4.2).  Counts
        beyond the original N may not be exactly coverable; the largest
        coverable subset is used and the rest stay as hot spares."""
        t0 = _time.perf_counter()
        all_nodes = [n for inst in instances for n in inst.nodes]
        all_nodes.extend(new_nodes)
        old_owners = self._ownership(instances)
        new_instances, spares = None, []
        for use in range(len(all_nodes), (self.spec.f + 1) * self.spec.n0 - 1,
                         -1):
            try:
                new_instances = self._global_replan(all_nodes[:use])
                spares = all_nodes[use:]
                break
            except PlanningError:
                continue
        if new_instances is None:
            raise PlanningError("join re-plan found no coverable subset")
        batch = distribute_batch([i.template for i in new_instances],
                                 self.global_batch, self.microbatch)
        return ReconfigResult(
            instances=new_instances,
            copy_plan=self._copy_plan(old_owners, new_instances, set()),
            batch=batch, globally_replanned=True, spare_nodes=spares,
            replan_seconds=_time.perf_counter() - t0)

    # ------------------------------------------------------------------
    def _decompose_prefix(self, total: int) -> Tuple[List[int], int]:
        """Largest ``use <= total`` expressible as a sum of template
        sizes, with its fewest-pipelines decomposition (largest-first
        among optimal ones).  One coin-change DP covers every candidate
        amount.  Returns ``([], 0)`` when even the smallest template
        exceeds ``total``."""
        sizes = sorted(self.templates, reverse=True)
        INF = total + 1
        minc = [0] + [INF] * total
        for amount in range(1, total + 1):
            for s in sizes:
                if s <= amount and minc[amount - s] + 1 < minc[amount]:
                    minc[amount] = minc[amount - s] + 1
        use = total
        while use > 0 and minc[use] >= INF:
            use -= 1
        out: List[int] = []
        rem = use
        while rem:
            for s in sizes:
                if s <= rem and minc[rem - s] == minc[rem] - 1:
                    out.append(s)
                    rem -= s
                    break
        return out, use

    def _decompose(self, total: int) -> List[int]:
        """Exact split of ``total`` into template sizes, fewest pipelines."""
        parts, use = self._decompose_prefix(total)
        if use != total:
            raise PlanningError(
                f"no template combination covers a merged pipeline pool of "
                f"{total} nodes (have {sorted(self.templates)})")
        return parts

    def _instantiate(self, size: int, nodes: List[str]) -> PipelineInstance:
        if size not in self.templates:
            raise PlanningError(f"no template with {size} nodes")
        return PipelineInstance(next(self._next_id), self.templates[size],
                                list(nodes))

    def _global_replan(self, nodes: List[str]) -> List[PipelineInstance]:
        from repro.core.instantiator import choose_plan
        plan = choose_plan(self.templates, self.spec, len(nodes),
                           self.global_batch, self.microbatch)
        out: List[PipelineInstance] = []
        cursor = 0
        for size in plan.pipeline_sizes():
            out.append(self._instantiate(size, nodes[cursor:cursor + size]))
            cursor += size
        return out

    @staticmethod
    def _ownership(instances: Sequence[PipelineInstance]) -> Dict[int, Set[str]]:
        owners: Dict[int, Set[str]] = {}
        for inst in instances:
            for layer, nodes in inst.all_layer_owners().items():
                owners.setdefault(layer, set()).update(nodes)
        return owners

    def _copy_plan(self, old_owners: Dict[int, Set[str]],
                   instances: Sequence[PipelineInstance],
                   dead: Set[str]) -> List[CopyTask]:
        plan: List[CopyTask] = []
        load: Dict[str, int] = {}
        for inst in instances:
            for layer, owners in inst.all_layer_owners().items():
                # sorted: old_owners holds SETS, whose iteration order is
                # per-process (hash randomization).  The source pick below
                # breaks load ties by position, and the pick is part of the
                # plan fingerprint every process must agree on.
                alive_srcs = sorted(
                    n for n in old_owners.get(layer, ()) if n not in dead)
                for node in owners:
                    if node in old_owners.get(layer, ()):
                        continue  # already holds this layer
                    if not alive_srcs:
                        raise InsufficientReplicasError(
                            f"layer {layer} has no surviving replica — more "
                            f"than f simultaneous failures hit one stage")
                    src = min(alive_srcs, key=lambda n: load.get(n, 0))
                    nbytes = _layer_state_bytes(self.profile, layer)
                    load[src] = load.get(src, 0) + nbytes
                    plan.append(CopyTask(layer, src, node, nbytes,
                                         sources=tuple(sorted(alive_srcs))))
        return plan
