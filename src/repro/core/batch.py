"""Batch distribution across heterogeneous pipelines (paper §4.2.2, Eq. 6).

Given pipelines with per-microbatch steady-state times ``t_i`` (the slowest
stage's F+B — the slope of the 1F1B makespan in N_b), global batch ``B``
and microbatch size ``b``, assign integer microbatch counts ``N_b,i``:

    minimize   sum_i (N_b,i * t_i - mean)^2
    s.t.       sum_i N_b,i * b = B,   N_b,i in N, N_b,i >= 1

The paper uses Pyomo/MindtPy; that solver is unavailable offline, so we
solve exactly with (a) a proportional largest-remainder seed at the
continuous optimum ``N_b,i ∝ 1/t_i`` and (b) greedy single-unit exchange
descent.  The objective is separable and convex in each coordinate, and a
single-unit exchange neighbourhood is optimal for such resource-allocation
programs; tests cross-check against brute force on small instances.

If ``B/b`` cannot give every pipeline at least one microbatch, Oobleck
does not silently change B — it raises with a recommended nearby batch
size (paper: "recommends an adjusted global batch size").
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from repro.core.templates import PipelineTemplate, PlanningError


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    num_microbatches: Tuple[int, ...]   # N_b,i per pipeline
    microbatch_size: int
    global_batch: int

    def minibatch_sizes(self) -> Tuple[int, ...]:
        return tuple(n * self.microbatch_size for n in self.num_microbatches)

    def variance_objective(self, times: Sequence[float]) -> float:
        loads = [n * t for n, t in zip(self.num_microbatches, times)]
        mean = sum(loads) / len(loads)
        return sum((l - mean) ** 2 for l in loads)


def _objective(counts: List[int], times: Sequence[float]) -> float:
    loads = [n * t for n, t in zip(counts, times)]
    mean = sum(loads) / len(loads)
    return sum((l - mean) ** 2 for l in loads)


def distribute_microbatches(times: Sequence[float], total_mb: int) -> List[int]:
    """Assign ``total_mb`` microbatches over pipelines with steady-state
    per-microbatch times ``times``; exact for the Eq. 6 objective."""
    x = len(times)
    if total_mb < x:
        raise PlanningError(
            f"{total_mb} microbatches cannot give {x} pipelines >= 1 each")
    # Continuous optimum: loads equal -> N_i ∝ 1/t_i.
    inv = [1.0 / t for t in times]
    scale = total_mb / sum(inv)
    counts = [max(1, int(w * scale)) for w in inv]
    # Largest-remainder style fix-up to hit the exact total.
    while sum(counts) > total_mb:
        donors = [j for j in range(x) if counts[j] > 1]
        if not donors:
            raise PlanningError("cannot satisfy >=1 microbatch per pipeline")
        i = max(donors, key=lambda j: counts[j] * times[j])
        counts[i] -= 1
    while sum(counts) < total_mb:
        i = min(range(x), key=lambda j: (counts[j] + 1) * times[j])
        counts[i] += 1
    # Greedy 1-exchange descent: move one unit from the most-loaded donor
    # to the least-loaded receiver while the objective improves.
    improved = True
    while improved:
        improved = False
        base = _objective(counts, times)
        best_move: Tuple[float, int, int] | None = None
        for i in range(x):
            if counts[i] <= 1:
                continue
            for j in range(x):
                if i == j:
                    continue
                counts[i] -= 1
                counts[j] += 1
                val = _objective(counts, times)
                counts[i] += 1
                counts[j] -= 1
                if val < base - 1e-18 and (best_move is None or val < best_move[0]):
                    best_move = (val, i, j)
        if best_move is not None:
            _, i, j = best_move
            counts[i] -= 1
            counts[j] += 1
            improved = True
    return counts


def recommend_global_batch(num_pipelines: int, microbatch: int,
                           requested: int) -> int:
    """Nearest feasible global batch (>= one microbatch per pipeline,
    divisible by b)."""
    floor_needed = num_pipelines * microbatch
    candidate = max(floor_needed, (requested // microbatch) * microbatch)
    return candidate


def distribute_batch(pipelines: Sequence[PipelineTemplate], global_batch: int,
                     microbatch: int) -> BatchPlan:
    """Eq. 6 entry point over instantiated pipelines (templates repeated
    per instance)."""
    if global_batch % microbatch != 0:
        raise PlanningError(
            f"global batch {global_batch} not divisible by microbatch "
            f"{microbatch}; recommend "
            f"{recommend_global_batch(len(pipelines), microbatch, global_batch)}")
    total_mb = global_batch // microbatch
    times = [t.stage_times[t.slowest_stage] for t in pipelines]
    if total_mb < len(pipelines):
        raise PlanningError(
            f"global batch {global_batch} too small for {len(pipelines)} "
            f"pipelines at microbatch {microbatch}; recommend "
            f"{recommend_global_batch(len(pipelines), microbatch, global_batch)}")
    counts = distribute_microbatches(times, total_mb)
    return BatchPlan(num_microbatches=tuple(counts),
                     microbatch_size=microbatch, global_batch=global_batch)
