"""Batch distribution across heterogeneous pipelines (paper §4.2.2, Eq. 6).

Given pipelines with per-microbatch steady-state times ``t_i`` (the slowest
stage's F+B — the slope of the 1F1B makespan in N_b), global batch ``B``
and microbatch size ``b``, assign integer microbatch counts ``N_b,i``:

    minimize   sum_i (N_b,i * t_i - mean)^2
    s.t.       sum_i N_b,i * b = B,   N_b,i in N, N_b,i >= 1

The paper uses Pyomo/MindtPy; that solver is unavailable offline, so we
solve exactly with (a) a proportional largest-remainder seed at the
continuous optimum ``N_b,i ∝ 1/t_i`` and (b) greedy single-unit exchange
descent.  The objective is separable and convex in each coordinate, and a
single-unit exchange neighbourhood is optimal for such resource-allocation
programs; tests cross-check against brute force on small instances.

If ``B/b`` cannot give every pipeline at least one microbatch, Oobleck
does not silently change B — it raises with a recommended nearby batch
size (paper: "recommends an adjusted global batch size").
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

from repro.core.templates import PipelineTemplate, PlanningError


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    num_microbatches: Tuple[int, ...]   # N_b,i per pipeline
    microbatch_size: int
    global_batch: int

    def minibatch_sizes(self) -> Tuple[int, ...]:
        return tuple(n * self.microbatch_size for n in self.num_microbatches)

    def variance_objective(self, times: Sequence[float]) -> float:
        loads = [n * t for n, t in zip(self.num_microbatches, times)]
        mean = sum(loads) / len(loads)
        return sum((l - mean) ** 2 for l in loads)


def _objective(counts: List[int], times: Sequence[float]) -> float:
    loads = [n * t for n, t in zip(counts, times)]
    mean = sum(loads) / len(loads)
    return sum((l - mean) ** 2 for l in loads)


def _seed_counts(times: Sequence[float], total_mb: int) -> List[int]:
    """Proportional largest-remainder seed at the continuous optimum
    ``N_i ∝ 1/t_i``, fixed up to hit the exact total."""
    x = len(times)
    inv = [1.0 / t for t in times]
    scale = total_mb / sum(inv)
    counts = [max(1, int(w * scale)) for w in inv]
    s = sum(counts)
    while s > total_mb:
        donors = [j for j in range(x) if counts[j] > 1]
        if not donors:
            raise PlanningError("cannot satisfy >=1 microbatch per pipeline")
        i = max(donors, key=lambda j: counts[j] * times[j])
        counts[i] -= 1
        s -= 1
    while s < total_mb:
        i = min(range(x), key=lambda j: (counts[j] + 1) * times[j])
        counts[i] += 1
        s += 1
    return counts


def distribute_microbatches(times: Sequence[float], total_mb: int) -> List[int]:
    """Assign ``total_mb`` microbatches over pipelines with steady-state
    per-microbatch times ``times``; exact for the Eq. 6 objective.

    The 1-exchange descent evaluates each candidate move in O(1) via the
    separable identity  sum_i (l_i - mean)^2 = sum_i l_i^2 - (sum_i l_i)^2/x:
    moving one unit from i to j only touches l_i, l_j and the total, so a
    round over all O(x^2) moves costs O(x^2) instead of the O(x^3) a full
    re-evaluation per candidate costs — the difference between milliseconds
    and minutes at the 100+ pipeline scale the planner targets.

    The identity form rounds differently than the direct form in the last
    ulp, which matters exactly when moves TIE (equal-time pipelines): to
    stay bit-identical to ``_distribute_microbatches_reference`` (the
    retained full-recompute oracle), every candidate within fp noise of
    the round's minimum is re-scored with the direct objective and the
    reference's selection rule decides among them.
    """
    x = len(times)
    if total_mb < x:
        raise PlanningError(
            f"{total_mb} microbatches cannot give {x} pipelines >= 1 each")
    counts = _seed_counts(times, total_mb)

    def deltas():
        """Yield (identity-form candidate value, i, j) in reference
        iteration order, each in O(1)."""
        for i in range(x):
            if counts[i] <= 1:
                continue
            li, ti = loads[i], times[i]
            di = (li - ti) * (li - ti) - li * li       # sumsq delta at i
            for j in range(x):
                if i == j:
                    continue
                lj, tj = loads[j], times[j]
                nt = total + tj - ti
                yield (sumsq + di - lj * lj + (lj + tj) * (lj + tj)
                       - nt * nt / x, i, j)

    improved = True
    while improved:
        improved = False
        loads = [n * t for n, t in zip(counts, times)]
        total = sum(loads)
        sumsq = sum(l * l for l in loads)
        base = _objective(counts, times)
        cand = list(deltas())
        if not cand:
            break
        val_min = min(v for v, _, _ in cand)
        # absolute fp-noise bound of the identity form: the sumsq and
        # (sum)^2/x terms cancel catastrophically near-equal loads, so
        # the error scales with sumsq, not with the objective
        margin = 1e-12 * (sumsq + 1.0)
        best_move: Tuple[float, int, int] | None = None
        for val, i, j in cand:
            if val > val_min + margin:
                continue
            counts[i] -= 1
            counts[j] += 1
            dval = _objective(counts, times)
            counts[i] += 1
            counts[j] -= 1
            if dval < base - 1e-18 and (best_move is None
                                        or dval < best_move[0]):
                best_move = (dval, i, j)
        if best_move is not None:
            _, i, j = best_move
            counts[i] -= 1
            counts[j] += 1
            improved = True
    return counts


def _distribute_microbatches_reference(times: Sequence[float],
                                       total_mb: int) -> List[int]:
    """The pre-optimization descent: full O(x) objective recomputed for
    every candidate move.  Retained as the parity oracle for the
    incremental-delta version above (same seed, same move-selection
    order, same tolerance)."""
    x = len(times)
    if total_mb < x:
        raise PlanningError(
            f"{total_mb} microbatches cannot give {x} pipelines >= 1 each")
    counts = _seed_counts(times, total_mb)
    improved = True
    while improved:
        improved = False
        base = _objective(counts, times)
        best_move: Tuple[float, int, int] | None = None
        for i in range(x):
            if counts[i] <= 1:
                continue
            for j in range(x):
                if i == j:
                    continue
                counts[i] -= 1
                counts[j] += 1
                val = _objective(counts, times)
                counts[i] += 1
                counts[j] -= 1
                if val < base - 1e-18 and (best_move is None or val < best_move[0]):
                    best_move = (val, i, j)
        if best_move is not None:
            _, i, j = best_move
            counts[i] -= 1
            counts[j] += 1
            improved = True
    return counts


def recommend_global_batch(num_pipelines: int, microbatch: int,
                           requested: int) -> int:
    """Nearest feasible global batch (>= one microbatch per pipeline,
    divisible by b)."""
    floor_needed = num_pipelines * microbatch
    candidate = max(floor_needed, (requested // microbatch) * microbatch)
    return candidate


def distribute_batch(pipelines: Sequence[PipelineTemplate], global_batch: int,
                     microbatch: int) -> BatchPlan:
    """Eq. 6 entry point over instantiated pipelines (templates repeated
    per instance)."""
    if global_batch % microbatch != 0:
        raise PlanningError(
            f"global batch {global_batch} not divisible by microbatch "
            f"{microbatch}; recommend "
            f"{recommend_global_batch(len(pipelines), microbatch, global_batch)}")
    total_mb = global_batch // microbatch
    times = [t.stage_times[t.slowest_stage] for t in pipelines]
    if total_mb < len(pipelines):
        raise PlanningError(
            f"global batch {global_batch} too small for {len(pipelines)} "
            f"pipelines at microbatch {microbatch}; recommend "
            f"{recommend_global_batch(len(pipelines), microbatch, global_batch)}")
    counts = distribute_microbatches(times, total_mb)
    return BatchPlan(num_microbatches=tuple(counts),
                     microbatch_size=microbatch, global_batch=global_batch)
