"""Oobleck ConfigurationEngine: cluster-wide planning (paper §3.3–3.4).

The paper splits responsibilities between one cluster-wide
*ConfigurationEngine* (planning, policy selection, reconfiguration-epoch
assignment) and per-node *ExecutionEngines* (device state, compiled
programs).  This module is the configuration side: it owns NO device
state — instances, batch plans, copy plans and cost models only — so a
coordinator process can run it without touching an accelerator, while
every worker process keeps a deterministic replica of it for agreement
(runtime/multihost.py; fingerprints prove the replicas planned the same
transition).  ``OobleckEngine`` remains as an alias for the historical
single-process name.

Ties the planning artifacts together:

  bootstrap:  n0 (memory floor) -> node spec -> pipeline templates
              -> instantiation plan -> pipeline instances + batch plan
  on event:   failure  -> Reconfigurator (reinstantiate/borrow/merge)
                          -> state-copy plan -> batch redistribution
              join     -> global re-instantiation over the larger cluster
              warning  -> drain flag (finish the in-flight iteration)
  exit:       InsufficientReplicas -> checkpoint + raise (user restarts
              later from the stored progress)

The engine is runtime-agnostic through ONE concrete seam: every runtime
implements the Executor interface (runtime/executor.py — bind / step /
recover / join / snapshot) and registers itself with
``attach_executor``.  Cluster events from the monitor are then routed to
the executor, which replans through the engine and swaps its compiled
programs by cache lookup.  The heterogeneous JAX trainer
(runtime/pipeline.py), the homogeneous SPMD fast path
(runtime/spmd.py) and the discrete-event simulator's Oobleck policy
(sim/policies.py) all plug in this way; they only differ in what
"executing an iteration" means.
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Callable, Dict, List, Optional, Sequence, Set

from repro.core import adapt as cm_adapt
from repro.core import cost_model as cm
from repro.core.adapt import AdaptationError, AdaptCostModel, AdaptPlan
from repro.core.batch import BatchPlan
from repro.core.instantiator import InstantiationPlan, choose_plan
from repro.core.monitor import ClusterEvent, NodeChangeMonitor
from repro.core.planner import PipelinePlanner, estimate_iteration_time
from repro.core.reconfigure import (CopyTask, InsufficientReplicasError,
                                    PipelineInstance, ReconfigResult,
                                    Reconfigurator, _layer_state_bytes)
from repro.core import sync as cm_sync
from repro.core.sync import SyncBucket, build_sync_plan
from repro.core.templates import (NodeSpec, PipelineTemplate,
                                  generate_node_spec)


@dataclasses.dataclass
class EngineConfig:
    fault_tolerance: int                 # f
    global_batch: int
    microbatch: int
    gpus_per_node: int = 1
    n0_override: Optional[int] = None    # force n0 (tests / experiments)
    planner_mode: str = "fast"
    max_stages: Optional[int] = None
    bucket_cap_bytes: int = 64 * 1024 * 1024
    # pod size for the default recovery-data-plane topology (DESIGN.md
    # §9): consecutive nodes share a pod/ICI; pods talk over DCN
    nodes_per_pod: int = 8
    # wire codec for cross-replica gradient sync (runtime/compression
    # .py): priced by the shared sync cost model AND executed by the
    # bucketed data plane, so modeled and real wire bytes agree
    codec: str = "none"
    # failure response: "replan" (full reconfiguration, the paper's
    # default), "adapt" (ReCycle-style microbatch re-routing to
    # surviving replicas), "spare" (promote parked hot spares into the
    # dead slots), or "auto" (per-event selection by predicted downtime)
    recovery_policy: str = "replan"
    # auto refuses adaptations whose steady-state iteration would exceed
    # this multiple of the predicted post-replan iteration — forces a
    # consolidating replan instead of limping on overloaded survivors
    adapt_max_slowdown: float = 1.5


@dataclasses.dataclass
class EngineMetrics:
    reconfigurations: int = 0
    restarts: int = 0
    total_copy_bytes: int = 0
    lost_iterations: int = 0
    planning_seconds: float = 0.0
    adaptations: int = 0
    spare_promotions: int = 0


class ConfigurationEngine:
    def __init__(self, profile: cm.ModelProfile, nodes: Sequence[str],
                 config: EngineConfig,
                 monitor: Optional[NodeChangeMonitor] = None,
                 on_checkpoint: Optional[Callable[[], None]] = None,
                 topology=None):
        self.profile = profile
        self.config = config
        self._topology = topology      # runtime.transfer.Topology or None
        self._topology_auto = topology is None
        # node placement order for the auto-built topology; joins append
        # here so late arrivals get real pod slots instead of staying
        # singleton/DCN forever
        self._placement_order = list(nodes)
        self.monitor = monitor or NodeChangeMonitor()
        self.monitor.subscribe(self._on_event)
        self.on_checkpoint = on_checkpoint
        self.metrics = EngineMetrics()
        # the runtime bound to this engine (Executor interface); cluster
        # events are routed through it so state rebuild and program
        # swaps happen together with replanning
        self.executor = None
        # nodes with a pending preemption warning: the runtime finishes
        # the in-flight iteration before they leave, so their eventual
        # failure loses no work (truthy iff a drain is pending)
        self.draining: Set[str] = set()
        self.stopped = False
        # reconfiguration epoch: bumped on every APPLIED reconfiguration
        # (failure, join, adaptation, spare promotion).  In multi-process
        # deployments survivors agree on the epoch at which they switch
        # templates (two-phase, runtime/coordination.py); single-process
        # runs just observe it as a counter.
        self.epoch = 0

        t0 = _time.perf_counter()
        n0 = (config.n0_override if config.n0_override is not None
              else profile.min_nodes(config.gpus_per_node))
        self.spec: NodeSpec = generate_node_spec(
            N=len(nodes), f=config.fault_tolerance, n0=n0,
            max_size=profile.num_layers)
        planner = PipelinePlanner(profile, config.gpus_per_node,
                                  mode=config.planner_mode,
                                  max_stages=config.max_stages)
        self.templates: Dict[int, PipelineTemplate] = planner.plan_all(
            self.spec.sizes)
        self.planner = planner
        self.reconf = Reconfigurator(self.templates, self.spec, profile,
                                     config.global_batch, config.microbatch)
        plan = choose_plan(self.templates, self.spec, len(nodes),
                           config.global_batch, config.microbatch)
        self.metrics.planning_seconds = _time.perf_counter() - t0

        self.instances: List[PipelineInstance] = []
        cursor = 0
        node_list = list(nodes)
        for size in plan.pipeline_sizes():
            self.instances.append(self.reconf._instantiate(
                size, node_list[cursor:cursor + size]))
            cursor += size
        self.batch: BatchPlan = plan.batch
        # alive-but-idle nodes no template combination currently covers
        # (capped-gap merges, joins beyond N); folded back into the pool
        # at the next reconfiguration
        self.spare_nodes: List[str] = []
        self.last_reconfig: Optional[ReconfigResult] = None
        self.last_adaptation: Optional[AdaptPlan] = None

    # ------------------------------------------------------------------
    def attach_executor(self, executor):
        """Bind a runtime (Executor) to this engine.  Once attached,
        monitor-driven failure/join events go through the executor so
        array state and compiled programs stay consistent with the
        plan; detach by attaching None."""
        self.executor = executor
        return executor

    @property
    def nodes(self) -> List[str]:
        return [n for inst in self.instances for n in inst.nodes]

    def plan_fingerprint(self, result: Optional[ReconfigResult] = None) -> str:
        """Digest of a plan (instances + batch + copy plan) — what the
        two-phase reconfiguration protocol compares across the
        coordinator's engine and every worker's deterministic replica to
        prove they computed the SAME transition before any state moves.
        With ``result=None`` it fingerprints the CURRENT configuration."""
        import hashlib
        import json
        instances = self.instances if result is None else result.instances
        batch = self.batch if result is None else result.batch
        copy_plan = [] if result is None else result.copy_plan
        doc = {
            "instances": [
                [inst.instance_id, list(inst.nodes),
                 [[st.layer_start, st.layer_end]
                  for st in inst.template.stages]]
                for inst in instances],
            "num_microbatches": list(batch.num_microbatches),
            "microbatch_size": batch.microbatch_size,
            "copies": [[t.layer, t.src_node, t.dst_node, t.nbytes]
                       for t in copy_plan],
        }
        return hashlib.sha256(
            json.dumps(doc, sort_keys=True).encode()).hexdigest()[:16]

    def sync_plan(self) -> List[SyncBucket]:
        layer_bytes = [l.param_bytes for l in self.profile.layers]
        return build_sync_plan(self.instances, layer_bytes,
                               self.config.bucket_cap_bytes)

    def iteration_time(self) -> float:
        """Estimated wall time of one global step for the current config
        (max over pipelines + layer-sync overhead not hidden by overlap)."""
        times = [estimate_iteration_time(inst.template, nb)
                 for inst, nb in zip(self.instances, self.batch.num_microbatches)]
        return max(times) + self._sync_tail_seconds()

    def throughput(self) -> float:
        return self.config.global_batch / self.iteration_time()

    def sync_cost_model(self) -> cm_sync.SyncCostModel:
        """THE pricing of cross-replica gradient sync — shared with the
        simulator policy and the benchmarks (DESIGN.md §10), pricing
        ICI vs DCN legs from the topology and wire bytes from the
        codec, per bucket."""
        return cm_sync.SyncCostModel(hw=self.profile.hw,
                                     codec=self.config.codec,
                                     topology=self.topology)

    def _sync_tail_seconds(self) -> float:
        """Cross-pipeline grad sync NOT hidden behind backward, per the
        shared per-bucket overlap model: buckets issue deepest-first
        and overlap the remaining backward; whatever the last bucket
        spills past the end of backward is exposed."""
        if len(self.instances) <= 1:
            return 0.0
        return self.sync_cost_model().tail_seconds(
            self.sync_plan(), self.profile.layer_bwd_seconds())

    def sync_schedule(self) -> List[cm_sync.BucketCostRow]:
        """Per-bucket overlapped sync schedule for the current instance
        set (benchmark/report surface of the shared model)."""
        return self.sync_cost_model().schedule(
            self.sync_plan(), self.profile.layer_bwd_seconds())

    @property
    def topology(self):
        """Pod placement for the recovery data plane (lazy: core must
        not import runtime at module load)."""
        if self._topology is None:
            from repro.runtime.transfer import Topology
            self._topology = Topology.regular(
                self._placement_order,
                nodes_per_pod=self.config.nodes_per_pod,
                hw=self.profile.hw)
        return self._topology

    def transfer_plan(self, result: ReconfigResult,
                      dead: Set[str] = frozenset()):
        """Schedule ``result``'s copy plan into parallel topology-aware
        streams (runtime/transfer.py, DESIGN.md §9)."""
        from repro.runtime.transfer import schedule_transfers
        return schedule_transfers(result.copy_plan, self.topology, dead=dead)

    def recovery_breakdown(self, result: ReconfigResult,
                           dead: Set[str] = frozenset()) -> Dict[str, float]:
        """Failure -> first-step latency decomposition (seconds):
        replan   — measured reconfigurator wall-clock (a table lookup);
        transfer — state-copy makespan over parallel streams under link
                   contention (MAX over streams, not sum of bytes);
        compile  — zero by the §8 warm-cache contract (programs for every
                   template are precompiled; swap is a lookup);
        barrier  — regroup/collective re-formation allowance."""
        return {"replan": result.replan_seconds,
                "transfer": self.transfer_plan(result, dead=dead).makespan(),
                "compile": 0.0,
                "barrier": 1.0}

    def reconfiguration_seconds(self, result: ReconfigResult) -> float:
        """Wall-clock estimate of a reconfiguration: state copy dominates
        (paper Fig. 11 'copying overhead') and is charged as the
        max-over-streams transfer makespan of the scheduled data plane."""
        return sum(self.recovery_breakdown(result).values())

    # ------------------------------------------------------------------
    # adaptive recovery: schedule adaptation, spare promotion and the
    # per-event policy selector (ReCycle / Chameleon; DESIGN.md §12)
    # ------------------------------------------------------------------
    def adapt_cost_model(self) -> AdaptCostModel:
        """THE pricing of schedule adaptation — shared with the
        simulator policy and benchmarks/recovery_policy, mirror of
        ``sync_cost_model()``."""
        return AdaptCostModel(hw=self.profile.hw)

    def _compute_iteration_seconds(self) -> float:
        """Compute-only iteration time (no sync tail) — the baseline the
        adapt cost model's reroute exposure is measured against."""
        return max((estimate_iteration_time(inst.template, nb)
                    for inst, nb in zip(self.instances,
                                        self.batch.num_microbatches)),
                   default=0.0)

    def _iteration_time_of(self, instances: Sequence[PipelineInstance],
                           batch: BatchPlan) -> float:
        """``iteration_time()`` for a HYPOTHETICAL (instances, batch) —
        used to price candidate recovery outcomes without mutating."""
        times = [estimate_iteration_time(inst.template, nb)
                 for inst, nb in zip(instances, batch.num_microbatches)]
        tail = 0.0
        if len(instances) > 1:
            layer_bytes = [l.param_bytes for l in self.profile.layers]
            plan = build_sync_plan(list(instances), layer_bytes,
                                   self.config.bucket_cap_bytes)
            tail = self.sync_cost_model().tail_seconds(
                plan, self.profile.layer_bwd_seconds())
        return max(times, default=0.0) + tail

    def adaptation_reference_iteration(self, dead: Set[str]) -> float:
        """Compute-only iteration estimate of the REPLAN outcome for
        ``dead`` — the reference an adaptation's reroute exposure is
        measured against (``reconf.on_failure`` is non-mutating, so this
        is a dry run).  Falls back to the pre-failure iteration when
        replan is infeasible."""
        dead_active = {d for d in dead if d in set(self.nodes)}
        spares = [n for n in self.spare_nodes if n not in dead]
        try:
            res = self.reconf.on_failure(self.instances, dead_active,
                                         spares=spares)
            return max((estimate_iteration_time(inst.template, nb)
                        for inst, nb in zip(res.instances,
                                            res.batch.num_microbatches)),
                       default=0.0)
        except InsufficientReplicasError:
            return self._compute_iteration_seconds()

    def plan_adaptation(self, dead: Set[str]) -> AdaptPlan:
        """Count-level ReCycle adaptation for ``dead`` (non-mutating):
        damaged replicas' microbatches re-route to surviving replicas,
        damaged replicas' healthy nodes park as hot spares.  Raises
        ``AdaptationError`` when infeasible (every replica damaged, or
        the batch cannot redistribute over the survivors)."""
        t0 = _time.perf_counter()
        plan = cm_adapt.plan_adaptation(
            self.instances, self.batch.num_microbatches, sorted(dead),
            self.config.global_batch, self.config.microbatch)
        return dataclasses.replace(
            plan, replan_seconds=_time.perf_counter() - t0)

    def apply_adaptation(self, plan: AdaptPlan, dead: Set[str] = frozenset(),
                         drained: bool = False) -> AdaptPlan:
        """Commit an AdaptPlan: swap in the surviving instances and the
        rebalanced batch; no state moves, no template changes."""
        self.instances = list(plan.instances)
        self.batch = plan.batch
        self.metrics.reconfigurations += 1
        self.epoch += 1
        self.metrics.adaptations += 1
        if not drained:
            self.metrics.lost_iterations += 1
        self.spare_nodes = ([n for n in self.spare_nodes if n not in dead]
                            + [n for n in plan.parked_nodes
                               if n not in self.spare_nodes])
        self.draining -= set(dead)
        self.last_adaptation = plan
        return plan

    def plan_spare_promotion(self, dead: Set[str]) -> ReconfigResult:
        """Hot-spare promotion (non-mutating): every dead slot is filled
        by a parked spare under the SAME templates — no batch change, no
        re-instantiation; only the dead slots' layer states are copied
        from surviving replicas.  Raises ``AdaptationError`` when there
        are not enough spares or a dead layer has no surviving owner."""
        t0 = _time.perf_counter()
        dead_active = sorted(d for d in dead if d in set(self.nodes))
        spares = [n for n in self.spare_nodes if n not in dead]
        if len(spares) < len(dead_active):
            raise AdaptationError(
                f"spare promotion infeasible: {len(dead_active)} dead "
                f"slots, {len(spares)} spares")
        replacement = dict(zip(dead_active, spares))
        used = list(replacement.values())
        owners = cm_sync.layer_owner_map(self.instances)
        copy_plan: List[CopyTask] = []
        load: Dict[str, int] = {}
        new_instances: List[PipelineInstance] = []
        for inst in self.instances:
            if not (set(inst.nodes) & set(replacement)):
                new_instances.append(inst)
                continue
            new_nodes = [replacement.get(n, n) for n in inst.nodes]
            for layer in range(inst.template.num_layers):
                for node in inst.layer_owners(layer):
                    if node not in replacement:
                        continue
                    srcs = sorted(owners[layer] - set(dead_active))
                    if not srcs:
                        raise AdaptationError(
                            f"spare promotion infeasible: layer {layer} "
                            "has no surviving owner")
                    src = min(srcs, key=lambda s: (load.get(s, 0), s))
                    nbytes = _layer_state_bytes(self.profile, layer)
                    load[src] = load.get(src, 0) + nbytes
                    copy_plan.append(CopyTask(layer, src, replacement[node],
                                              nbytes, sources=tuple(srcs)))
            new_instances.append(PipelineInstance(
                instance_id=inst.instance_id, template=inst.template,
                nodes=new_nodes))
        return ReconfigResult(
            instances=new_instances, copy_plan=copy_plan, batch=self.batch,
            spare_nodes=[n for n in spares if n not in used],
            replan_seconds=_time.perf_counter() - t0)

    def apply_spare_promotion(self, result: ReconfigResult,
                              dead: Set[str] = frozenset(),
                              drained: bool = False) -> ReconfigResult:
        """Commit a spare-promotion plan (same bookkeeping as
        ``handle_failure``, but templates and batch are untouched)."""
        self.instances = result.instances
        self.batch = result.batch
        self.metrics.reconfigurations += 1
        self.epoch += 1
        self.metrics.spare_promotions += 1
        self.metrics.total_copy_bytes += result.copy_bytes()
        if not drained:
            self.metrics.lost_iterations += 1
        self.last_reconfig = result
        self.spare_nodes = list(result.spare_nodes)
        self.draining -= set(dead)
        return result

    def predict_recovery(self, dead: Set[str]) -> Dict[str, Dict]:
        """Price every recovery policy for a failure event WITHOUT
        mutating engine state (``reconf.on_failure`` and the planners
        above are all non-mutating).  Per policy: ``feasible``,
        predicted ``downtime`` (sum of its breakdown), the ``breakdown``
        itself, and the steady-state ``iteration_s`` afterwards."""
        dead_active = {d for d in dead if d in set(self.nodes)}
        preds: Dict[str, Dict] = {}
        # -- replan: the full reconfiguration path -----------------------
        spares = [n for n in self.spare_nodes if n not in dead]
        try:
            res = self.reconf.on_failure(self.instances, set(dead_active),
                                         spares=spares)
            bd = self.recovery_breakdown(res, dead=dead_active)
            preds["replan"] = {
                "feasible": True, "downtime": sum(bd.values()),
                "breakdown": bd,
                "iteration_s": self._iteration_time_of(res.instances,
                                                       res.batch)}
        except InsufficientReplicasError as e:
            preds["replan"] = {"feasible": False, "reason": str(e)}
        # -- adapt: ReCycle re-routing ----------------------------------
        try:
            plan = self.plan_adaptation(dead_active)
            bd = self.adapt_cost_model().breakdown(
                plan, self.adaptation_reference_iteration(dead_active))
            it = self._iteration_time_of(plan.instances, plan.batch)
            replan_it = preds["replan"].get("iteration_s")
            slowdown_ok = (replan_it is None
                           or it <= self.config.adapt_max_slowdown * replan_it)
            preds["adapt"] = {
                "feasible": True, "downtime": sum(bd.values()),
                "breakdown": bd, "iteration_s": it,
                "slowdown_ok": slowdown_ok, "plan": plan}
        except AdaptationError as e:
            preds["adapt"] = {"feasible": False, "reason": str(e)}
        # -- spare: hot-spare promotion ---------------------------------
        try:
            res = self.plan_spare_promotion(dead_active)
            bd = self.recovery_breakdown(res, dead=dead_active)
            preds["spare"] = {
                "feasible": True, "downtime": sum(bd.values()),
                "breakdown": bd,
                "iteration_s": self._iteration_time_of(res.instances,
                                                       res.batch),
                "plan": res}
        except AdaptationError as e:
            preds["spare"] = {"feasible": False, "reason": str(e)}
        return preds

    def select_recovery_policy(self, dead: Set[str]) -> Dict:
        """Chameleon-style per-event choice: the feasible policy with
        the least predicted downtime; ties break toward the better
        steady-state iteration time.  Adaptations violating the
        ``adapt_max_slowdown`` cap are excluded (a consolidating replan
        also folds parked spares back in)."""
        preds = self.predict_recovery(dead)
        candidates = [p for p, d in preds.items()
                      if d.get("feasible") and d.get("slowdown_ok", True)]
        if not candidates:
            chosen = "replan"      # let handle_failure raise/escalate
        else:
            chosen = min(candidates,
                         key=lambda p: (preds[p]["downtime"],
                                        preds[p]["iteration_s"], p))
        return {"policy": chosen, "predictions": preds}

    # ------------------------------------------------------------------
    def _on_event(self, ev: ClusterEvent) -> None:
        if ev.kind == NodeChangeMonitor.WARN:
            self.draining |= set(ev.nodes)
            return
        # local import: core must not import runtime at module load
        # (runtime.pipeline imports this module)
        from repro.runtime.executor import ExecutorUnsupported
        if ev.kind == NodeChangeMonitor.FAIL:
            # the monitor path cannot say whether the drain finished, so
            # assume it did iff every victim had a pending warning; the
            # simulator/runtime call handle_failure directly with the
            # ground truth instead
            drained = set(ev.nodes) <= self.draining
            if self.executor is not None:
                try:
                    self.executor.recover(set(ev.nodes), drained=drained)
                    return
                except ExecutorUnsupported:
                    # e.g. the SPMD fast path: keep the PLAN consistent
                    # here; the caller rebinds a HeteroTrainer from
                    # snapshot() against the updated plan
                    pass
            self.handle_failure(set(ev.nodes), drained=drained)
        elif ev.kind == NodeChangeMonitor.JOIN:
            if self.executor is not None:
                try:
                    self.executor.join(list(ev.nodes))
                    return
                except ExecutorUnsupported:
                    pass
            self.handle_join(list(ev.nodes))

    def handle_failure(self, dead: Set[str],
                       drained: bool = False) -> ReconfigResult:
        """Remove ``dead`` nodes and reconfigure.  ``drained=True`` marks
        a proactive removal after a preemption warning: the in-flight
        iteration completed before the nodes left, so no work is lost."""
        self.spare_nodes = [n for n in self.spare_nodes if n not in dead]
        dead = {d for d in dead if d in set(self.nodes)}
        if not dead:
            return ReconfigResult(self.instances, [], self.batch)
        try:
            result = self.reconf.on_failure(self.instances, dead,
                                            spares=self.spare_nodes)
        except InsufficientReplicasError:
            self.stopped = True
            self.metrics.restarts += 1
            if self.on_checkpoint:
                self.on_checkpoint()
            raise
        self.instances = result.instances
        self.batch = result.batch
        self.metrics.reconfigurations += 1
        self.epoch += 1
        self.metrics.total_copy_bytes += result.copy_bytes()
        if not drained:
            self.metrics.lost_iterations += 1  # in-flight iteration lost
        self.last_reconfig = result
        self.spare_nodes = list(result.spare_nodes)
        self.draining -= dead              # their warning is resolved
        return result

    def rebalance(self, observed_times: Sequence[float]) -> BatchPlan:
        """Straggler mitigation: re-run batch distribution (Eq. 6) with
        MEASURED per-pipeline per-microbatch times instead of the cost
        model's estimates.  Call with the last iteration's timings when a
        pipeline runs hot (thermal throttling, shared-fabric noise)."""
        from repro.core.batch import distribute_microbatches
        total_mb = self.config.global_batch // self.config.microbatch
        counts = distribute_microbatches(list(observed_times), total_mb)
        self.batch = BatchPlan(num_microbatches=tuple(counts),
                               microbatch_size=self.config.microbatch,
                               global_batch=self.config.global_batch)
        return self.batch

    def handle_join(self, new_nodes: List[str]) -> ReconfigResult:
        pool = list(new_nodes) + [n for n in self.spare_nodes
                                  if n not in set(new_nodes)]
        # give joiners real pod slots: extend the placement order and
        # rebuild the auto topology (a user-provided one is their call)
        seen = set(self._placement_order)
        fresh = [n for n in pool if n not in seen]
        if fresh and self._topology_auto:
            self._placement_order.extend(fresh)
            self._topology = None
        result = self.reconf.on_join(self.instances, pool)
        self.instances = result.instances
        self.batch = result.batch
        self.metrics.reconfigurations += 1
        self.epoch += 1
        self.metrics.total_copy_bytes += result.copy_bytes()
        self.last_reconfig = result
        self.spare_nodes = list(result.spare_nodes)
        self.draining -= set(new_nodes)    # a returning node isn't leaving
        return result


# Historical single-process name: the class that was both halves of the
# engine before the ExecutionEngine split (runtime/multihost.py).
OobleckEngine = ConfigurationEngine
