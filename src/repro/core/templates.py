"""Pipeline templates and node-specification generation (paper §4.1.1).

A *pipeline template* specifies, for a given number of nodes ``n``:
  - how many stages the pipeline has,
  - which contiguous layer range each stage owns,
  - which node (and how many of its GPUs) each stage runs on.

Node-spec generation chooses the template sizes (n_0 .. n_{p-1}) so that
ANY feasible node count N' with (f+1)*n_0 <= N' <= N is expressible as a
non-negative integer combination of the sizes.  Per Appendix A this holds
when the sizes are consecutive integers and p > n_0 - 1: the Frobenius
number of {n_0, n_0+1, ...} collapses to n_0 - 1, which is below the
feasibility floor.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


class PlanningError(ValueError):
    pass


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """One pipeline stage inside a template."""

    stage_id: int
    layer_start: int          # inclusive
    layer_end: int            # exclusive
    node_offset: int          # first node (template-relative) of this stage
    num_gpus: int             # GPUs assigned (tensor/FSDP parallel degree)
    gpu_offset: int = 0       # first GPU within the node (intra-node splits)

    @property
    def num_layers(self) -> int:
        return self.layer_end - self.layer_start


@dataclasses.dataclass(frozen=True)
class PipelineTemplate:
    """A logically-complete pipeline specification for ``num_nodes`` nodes."""

    num_nodes: int
    gpus_per_node: int
    num_stages: int
    stages: Tuple[StageSpec, ...]
    iteration_time: float       # planner estimate: T1+T2+T3 at N_b=4S
    t1: float
    t2: float
    t3: float
    slowest_stage: int
    stage_times: Tuple[float, ...]  # F+B of each stage (one microbatch)

    @property
    def num_layers(self) -> int:
        return self.stages[-1].layer_end

    def layer_to_stage(self) -> List[int]:
        """layer index -> stage id."""
        out = [0] * self.num_layers
        for st in self.stages:
            for l in range(st.layer_start, st.layer_end):
                out[l] = st.stage_id
        return out

    def stage_of_layer(self, layer: int) -> StageSpec:
        for st in self.stages:
            if st.layer_start <= layer < st.layer_end:
                return st
        raise IndexError(layer)

    def validate(self, num_layers: int) -> None:
        """Structural invariants (also exercised by property tests)."""
        assert self.stages[0].layer_start == 0
        assert self.stages[-1].layer_end == num_layers
        nodes_seen = set()
        for a, b in zip(self.stages, self.stages[1:]):
            assert a.layer_end == b.layer_start, "stages must tile the layers"
        for st in self.stages:
            assert st.num_layers >= 1
            assert 1 <= st.num_gpus <= self.gpus_per_node * self.num_nodes
            # paper constraint: a stage never spans nodes unless it owns
            # them wholly (multi-node stages are whole-node multiples).
            if st.num_gpus < self.gpus_per_node:
                assert st.gpu_offset + st.num_gpus <= self.gpus_per_node
            else:
                assert st.num_gpus % self.gpus_per_node == 0
            nodes_seen.add(st.node_offset)
        used = self.gpu_footprint()
        assert used == self.num_nodes * self.gpus_per_node, (
            f"template must use every GPU: {used} != "
            f"{self.num_nodes * self.gpus_per_node}")

    def gpu_footprint(self) -> int:
        return sum(st.num_gpus for st in self.stages)


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """Output of §4.1.1: the template sizes to pre-plan."""

    n0: int                     # smallest pipeline size (memory floor)
    p: int                      # number of templates
    sizes: Tuple[int, ...]      # consecutive: (n0, n0+1, ..., n0+p-1)
    f: int
    N: int

    def max_size(self) -> int:
        return self.sizes[-1]


def generate_node_spec(N: int, f: int, n0: int,
                       max_size: Optional[int] = None) -> NodeSpec:
    """Choose template sizes per §4.1.1.

    n0 is the memory-driven minimum nodes per pipeline (smallest possible,
    because shallow pipelines are faster).  The largest useful template is
    n_{p-1}^max = N - f*n0 (all other f replicas at minimal size), giving
    the largest p.  Conditions (consecutive sizes, p > n0 - 1) then
    guarantee coverage of every feasible N' >= (f+1)*n0  (Appendix A).

    ``max_size`` additionally caps template sizes (a pipeline cannot have
    more nodes than the model has layers); when the cap binds, coverage
    is re-verified exhaustively rather than by the closed-form theorem.
    """
    if n0 < 1:
        raise PlanningError(f"n0 must be >= 1, got {n0}")
    if f < 0:
        raise PlanningError(f"fault tolerance threshold must be >= 0, got {f}")
    n_max = N - f * n0
    capped = False
    if max_size is not None and n_max > max_size:
        n_max = max_size
        capped = True
    if n_max < n0:
        raise PlanningError(
            f"cluster too small: N={N} cannot hold f+1={f + 1} pipelines "
            f"of n0={n0} nodes (need >= {(f + 1) * n0})")
    p = n_max - n0 + 1
    if capped:
        if not _verify_coverage(range((f + 1) * n0, N + 1),
                                tuple(range(n0, n_max + 1)), f):
            raise PlanningError(
                f"capped node spec (sizes {n0}..{n_max}) cannot cover all "
                f"feasible node counts up to N={N} with f={f}")
    elif p <= n0 - 1:
        # Thm A.1 needs p > n0-1.  With consecutive sizes starting at n0
        # this can only fail when N is barely above (f+1)*n0; the fix used
        # by Oobleck is acceptable here too: coverage is still complete for
        # every N' expressible in range (we verify exhaustively below).
        covered = _verify_coverage(range((f + 1) * n0, N + 1),
                                   tuple(range(n0, n_max + 1)), f)
        if not covered:
            raise PlanningError(
                f"node spec infeasible: p={p} <= n0-1={n0 - 1} and coverage "
                f"check failed for N={N}, f={f}, n0={n0}")
    return NodeSpec(n0=n0, p=p, sizes=tuple(range(n0, n_max + 1)), f=f, N=N)


def _max_count_table(t_max: int, sizes: Tuple[int, ...]) -> List[int]:
    """``table[t]`` = max pipelines in any exact decomposition of ``t``
    into template sizes, or -1 if ``t`` is not expressible.  A combination
    with count >= c exists iff the max count is >= c, so tracking the max
    alone suffices — O(t_max * |sizes|), which is what keeps node-spec
    verification cheap on hundred-node clusters."""
    table = [-1] * (t_max + 1)
    table[0] = 0
    for amount in range(1, t_max + 1):
        best = -1
        for s in sizes:
            if s <= amount and table[amount - s] >= 0:
                cand = table[amount - s] + 1
                if cand > best:
                    best = cand
        table[amount] = best
    return table


def _verify_coverage(targets, sizes: Tuple[int, ...], f: int) -> bool:
    """Exhaustively verify every target is a sum of >= f+1 template sizes."""
    targets = list(targets)
    if not targets:
        return True
    table = _max_count_table(max(targets), sizes)
    return all(table[t] >= f + 1 for t in targets)


def _coverable(t: int, sizes: Tuple[int, ...], min_count: int) -> bool:
    return _max_count_table(t, sizes)[t] >= min_count


def coverable(n_nodes: int, spec: NodeSpec) -> bool:
    """Public check used by tests/engine: can ``n_nodes`` be fully used
    while keeping >= f+1 pipelines?"""
    if n_nodes < (spec.f + 1) * spec.n0:
        return False
    return _coverable(n_nodes, spec.sizes, spec.f + 1)
