"""Pipeline instantiation: enumerate feasible template combinations and
pick the throughput-optimal one (paper §4.2).

``X(p', N')`` is the list of all multisets ``(x_0..x_{p'-1})`` with
``sum x_i * n_i = N'`` — computed with the coin-change dynamic program of
Eq. 5.  Feasible sets additionally need ``sum x_i >= f+1``.  Throughput of
a feasible set is evaluated by running batch distribution (Eq. 6) over the
instantiated pipelines and taking ``B / max_i time_i``.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.batch import BatchPlan, distribute_batch
from repro.core.planner import estimate_iteration_time
from repro.core.templates import NodeSpec, PipelineTemplate, PlanningError


@dataclasses.dataclass(frozen=True)
class InstantiationPlan:
    """How many pipelines to instantiate from each template + batching."""

    counts: Tuple[int, ...]            # x_i per template (indexed like sizes)
    sizes: Tuple[int, ...]             # node count per template
    batch: BatchPlan
    throughput: float                  # samples/sec estimate
    num_nodes: int

    @property
    def num_pipelines(self) -> int:
        return sum(self.counts)

    def pipeline_sizes(self) -> List[int]:
        """Node count of every instantiated pipeline, largest first."""
        out: List[int] = []
        for size, cnt in sorted(zip(self.sizes, self.counts), reverse=True):
            out.extend([size] * cnt)
        return out


def enumerate_feasible_sets(sizes: Sequence[int], N: int, min_count: int,
                            limit: int = 200_000) -> List[Tuple[int, ...]]:
    """All (x_0..x_{p-1}) with sum x_i*n_i == N and sum x_i >= min_count.

    Coin-change DP (Eq. 5): X(p', N') = X(p'-1, N') ++ theta(X(p', N'-n_p')).
    ``limit`` bounds the enumeration; if exceeded we fall back to keeping
    the lexicographically-greedy prefix (documented deviation for very
    large clusters — the paper's eval never exceeds 30 nodes).
    """
    p = len(sizes)
    # table[p'][N'] -> list of tuples over the first p' sizes
    prev: List[List[Tuple[int, ...]]] = [[] for _ in range(N + 1)]
    prev[0] = [()]
    truncated = False
    for j in range(p):
        cur: List[List[Tuple[int, ...]]] = [[] for _ in range(N + 1)]
        n_j = sizes[j]
        for amount in range(N + 1):
            # x_j = 0 branch: extend every prefix with a zero
            combos = [x + (0,) for x in prev[amount]]
            # x_j >= 1 branch: theta() on the same-row entry n_j to the left
            if amount >= n_j:
                for x in cur[amount - n_j]:
                    combos.append(x[:-1] + (x[-1] + 1,))
            if len(combos) > limit:
                combos = combos[:limit]
                truncated = True
            cur[amount] = combos
        prev = cur
    out = [x for x in prev[N] if sum(x) >= min_count]
    if truncated and not out:
        raise PlanningError("feasible-set enumeration truncated to nothing; "
                            "raise `limit`")
    return out


def greedy_counts(sizes: Tuple[int, ...], templates: Dict[int, PipelineTemplate],
                  N: int, min_count: int) -> Tuple[int, ...]:
    """Large-cluster fast path (1000+ nodes): exact enumeration of all
    feasible sets is the number of restricted integer partitions of N —
    astronomically large.  The paper's own observation (§7.4) is that at
    scale Oobleck 'simply instantiates more of the smaller pipelines', so
    we fill with the most per-node-efficient template and patch the
    remainder by coin-change DP for a single exact decomposition."""
    def efficiency(n):
        t = templates[n]
        return 1.0 / (t.stage_times[t.slowest_stage] * n)
    best = max(sizes, key=efficiency)
    # one exact decomposition for every reachable remainder
    reach = {0: {}}
    for amount in range(1, N + 1):
        for s in sizes:
            if s <= amount and (amount - s) in reach:
                reach[amount] = dict(reach[amount - s])
                reach[amount][s] = reach[amount].get(s, 0) + 1
                break
    # largest fill of `best` whose remainder decomposes with enough
    # pipelines overall
    for k in range(N // best, -1, -1):
        rem = N - k * best
        if rem not in reach:
            continue
        n_pipes = k + sum(reach[rem].values())
        if n_pipes >= min_count:
            counts = {s: 0 for s in sizes}
            counts[best] = k
            for s, c in reach[rem].items():
                counts[s] += c
            return tuple(counts[s] for s in sizes)
    raise PlanningError(f"greedy decomposition failed for N={N}")


def choose_plan(templates: Dict[int, PipelineTemplate], spec: NodeSpec,
                num_nodes: int, global_batch: int, microbatch: int,
                limit: int = 200_000,
                exact_threshold: int = 32) -> InstantiationPlan:
    """Pick the max-throughput feasible instantiation for ``num_nodes``.

    Above ``exact_threshold`` nodes the number of restricted partitions —
    and with it the cost of evaluating every feasible set — explodes, so
    the greedy decomposition takes over (within 10% of exact on the sizes
    where both are tractable; see tests/test_scale.py)."""
    sizes = tuple(spec.sizes)
    if num_nodes > exact_threshold:
        feasible = [greedy_counts(sizes, templates, num_nodes, spec.f + 1)]
    else:
        feasible = enumerate_feasible_sets(sizes, num_nodes, spec.f + 1,
                                           limit)
    if not feasible:
        raise PlanningError(
            f"no feasible pipeline set for {num_nodes} nodes with sizes "
            f"{sizes} and f={spec.f}")
    best: Optional[InstantiationPlan] = None
    for counts in feasible:
        # largest-first, matching InstantiationPlan.pipeline_sizes() so the
        # batch plan's N_b,i order lines up with instantiated pipelines.
        tpls: List[PipelineTemplate] = []
        for size, cnt in sorted(zip(sizes, counts), reverse=True):
            tpls.extend([templates[size]] * cnt)
        try:
            batch = distribute_batch(tpls, global_batch, microbatch)
        except PlanningError:
            continue
        times = [estimate_iteration_time(t, nb)
                 for t, nb in zip(tpls, batch.num_microbatches)]
        thpt = global_batch / max(times)
        if best is None or thpt > best.throughput:
            best = InstantiationPlan(counts=tuple(counts), sizes=sizes,
                                     batch=batch, throughput=thpt,
                                     num_nodes=num_nodes)
    if best is None:
        raise PlanningError(
            f"no feasible set admits an integral batch distribution for "
            f"B={global_batch}, b={microbatch} over {num_nodes} nodes")
    return best
