"""Analytical per-layer cost model for Oobleck's planner (paper §4.1.2).

The planner needs, for every model layer ``l`` and every intra-stage device
count ``d``:

    F_{l,d}  — forward time of one microbatch,
    B_{l,d}  — backward time of one microbatch (≈ 2x forward FLOPs + remat),

plus per-layer parameter/activation byte counts for memory-feasibility
(choice of n0) and for the simulator's checkpoint/state-copy timings.

Oobleck profiles these on real GPUs; a CPU container cannot, so we derive
them from first principles over the TARGET hardware (utils/hw.py):
GEMM time at MXU efficiency + TP collective time + an HBM-bandwidth floor
(whichever of compute/memory dominates, plus comm — a per-layer mini
roofline).  The same model feeds the discrete-event simulator, so planner
and simulator are self-consistent.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Sequence

from repro.configs.base import ArchConfig
from repro.utils import hw as hwlib


@dataclasses.dataclass(frozen=True)
class LayerCost:
    """Static per-layer workload description (per ONE microbatch)."""

    name: str
    flops_fwd: float          # forward FLOPs for one microbatch
    param_bytes: int          # bf16 parameter bytes
    act_bytes: int            # boundary activation bytes (pipeline hop size)
    io_bytes_fwd: float       # HBM traffic of the forward pass
    tp_collective_bytes: float  # activation bytes all-reduced per TP step


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """The model as Oobleck sees it: an ordered list of layers.

    Layer 0 is the embedding, layers 1..L are blocks, layer L+1 is the
    final norm + LM head — matching the layer granularity at which
    Oobleck partitions stages, copies state, and syncs gradients.
    """

    arch: ArchConfig
    microbatch: int
    seq_len: int
    layers: Sequence[LayerCost]
    hw: hwlib.HardwareSpec = hwlib.V5E
    # Activation-recompute (remat) multiplies backward FLOPs by ~1.5x
    # fwd instead of storing activations; Oobleck (like Varuna) trains
    # with activation checkpointing on (§7.1), so this defaults on.
    remat: bool = True

    @property
    def num_layers(self) -> int:
        return len(self.layers)

    def param_bytes_total(self) -> int:
        return sum(l.param_bytes for l in self.layers)

    def train_state_bytes(self) -> int:
        """bf16 params + fp32 master/adam-m/adam-v (ZeRO-unsharded)."""
        p = self.param_bytes_total() // 2  # param count
        return p * 2 + p * 4 * 3

    # ------------------------------------------------------------------
    # F / B per layer on d chips (paper notation F_{l,d}, B_{l,d}).
    # ------------------------------------------------------------------
    def fwd_time(self, layer_idx: int, d: int) -> float:
        l = self.layers[layer_idx]
        compute = l.flops_fwd / (d * self.hw.peak_flops_bf16 * self.hw.mxu_efficiency)
        memory = (l.io_bytes_fwd / d) / self.hw.hbm_bandwidth
        comm = hwlib.allreduce_time(l.tp_collective_bytes, d, hw=self.hw)
        return max(compute, memory) + comm

    def bwd_time(self, layer_idx: int, d: int) -> float:
        # backward ≈ 2x forward FLOPs; +1x recompute under remat.
        factor = 3.0 if self.remat else 2.0
        l = self.layers[layer_idx]
        compute = factor * l.flops_fwd / (d * self.hw.peak_flops_bf16 * self.hw.mxu_efficiency)
        memory = factor * (l.io_bytes_fwd / d) / self.hw.hbm_bandwidth
        comm = 2.0 * hwlib.allreduce_time(l.tp_collective_bytes, d, hw=self.hw)
        return max(compute, memory) + comm

    def layer_bwd_seconds(self, d: int = 1) -> List[float]:
        """Per-layer backward time on ``d`` chips, layer order — the
        hiding budget the shared sync cost model (core/sync.py
        SyncCostModel) overlaps bucket reductions against."""
        return [self.bwd_time(l, d) for l in range(self.num_layers)]

    def stage_fwd(self, u: int, v: int, d: int) -> float:
        return sum(self.fwd_time(i, d) for i in range(u, v))

    def stage_bwd(self, u: int, v: int, d: int) -> float:
        return sum(self.bwd_time(i, d) for i in range(u, v))

    # ------------------------------------------------------------------
    # Memory feasibility (choice of n0; Bamboo OOM reproduction).
    # ------------------------------------------------------------------
    def stage_memory_bytes(self, u: int, v: int, d: int,
                           num_inflight_mb: int = 1,
                           redundancy: float = 1.0) -> int:
        """Resident bytes per chip for stage [u, v) on d chips."""
        p = sum(self.layers[i].param_bytes for i in range(u, v)) // 2
        state = (p * 2 + p * 4 * 3) * redundancy / d
        if self.remat:  # only boundary activations retained per microbatch
            act = sum(self.layers[i].act_bytes for i in range(u, v)) * 0.05
            act += max((self.layers[i].act_bytes for i in range(u, v)), default=0)
        else:
            act = sum(self.layers[i].act_bytes for i in range(u, v))
        return int(state + act * num_inflight_mb / max(d // 1, 1))

    def min_nodes(self, gpus_per_node: int, max_stages_per_node: int = 8) -> int:
        """Smallest node count n0 whose aggregate HBM fits training state
        with headroom for activations — Oobleck's memory-driven floor."""
        need = self.train_state_bytes() * 1.35  # 35% activation/frag headroom
        per_node = self.hw.hbm_capacity * gpus_per_node
        n0 = max(1, -(-int(need) // int(per_node)))
        return n0


# ----------------------------------------------------------------------
# Profile construction from an ArchConfig.
# ----------------------------------------------------------------------
def _attn_flops(arch: ArchConfig, s: int, b: int) -> float:
    """Forward FLOPs of one attention layer (projections + SDPA)."""
    if arch.num_heads == 0:
        return 0.0
    d, H, KV, hd = arch.d_model, arch.num_heads, arch.num_kv_heads, arch.head_dim
    proj = 2.0 * b * s * d * (H * hd + 2 * KV * hd + H * hd)  # q,k,v,o GEMMs
    window = min(s, arch.sliding_window) if arch.sliding_window else s
    sdpa = 2.0 * 2.0 * b * H * s * window * hd  # qk^T and att*v
    return proj + sdpa


def _mlp_flops(arch: ArchConfig, s: int, b: int) -> float:
    if arch.moe is not None:
        m = arch.moe
        routed = 2.0 * b * s * d_ff_mats(arch) * arch.d_model * arch.d_ff * m.top_k
        shared = 2.0 * b * s * 3 * arch.d_model * m.shared_expert_d_ff
        router = 2.0 * b * s * arch.d_model * m.num_experts
        return routed + shared + router
    if arch.d_ff == 0:
        return 0.0
    return 2.0 * b * s * d_ff_mats(arch) * arch.d_model * arch.d_ff


def d_ff_mats(arch: ArchConfig) -> int:
    return 3 if arch.mlp_variant == "swiglu" else 2


def _ssm_flops(arch: ArchConfig, s: int, b: int) -> float:
    if arch.ssm is None:
        return 0.0
    c = arch.ssm
    d_inner = c.expand * arch.d_model
    nheads = d_inner // c.head_dim
    proj = 2.0 * b * s * arch.d_model * (2 * d_inner + 2 * c.n_groups * c.state_size + nheads)
    proj += 2.0 * b * s * d_inner * arch.d_model  # out_proj
    # SSD chunked scan: intra-chunk quadratic + inter-chunk state GEMMs.
    Q = c.chunk_size
    intra = 2.0 * b * (s * Q) * d_inner          # (s/Q chunks) * Q^2 * heads*P
    inter = 2.0 * 3.0 * b * s * c.state_size * d_inner
    conv = 2.0 * b * s * c.conv_width * (d_inner + 2 * c.n_groups * c.state_size)
    return proj + intra + inter + conv


def _block_flops(arch: ArchConfig, s: int, b: int) -> float:
    if arch.family == "ssm":
        return _ssm_flops(arch, s, b)
    if arch.hybrid_parallel_heads:
        return _attn_flops(arch, s, b) + _ssm_flops(arch, s, b) + _mlp_flops(arch, s, b)
    return _attn_flops(arch, s, b) + _mlp_flops(arch, s, b)


def build_profile(arch: ArchConfig, *, microbatch: int, seq_len: int,
                  hw: hwlib.HardwareSpec = hwlib.V5E,
                  remat: bool = True) -> ModelProfile:
    """Build the planner's layer-cost profile for one (arch, mb, seq)."""
    b, s, d = microbatch, seq_len, arch.d_model
    act = 2 * b * s * d  # bf16 boundary activation

    emb_p = arch.vocab_size * d * 2
    head_p = 0 if arch.tie_embeddings else arch.vocab_size * d * 2
    block_p = arch.params_per_layer() * 2

    layers: List[LayerCost] = []
    layers.append(LayerCost(
        name="embed", flops_fwd=0.0, param_bytes=emb_p, act_bytes=act,
        io_bytes_fwd=float(act + b * s * 4), tp_collective_bytes=0.0))
    bf = _block_flops(arch, s, b)
    # TP all-reduces: 2 per block fwd (attention out + mlp out), Megatron.
    tp_bytes = 2.0 * act
    io = float(3 * act + block_p)
    for i in range(arch.num_layers):
        layers.append(LayerCost(
            name=f"block{i}", flops_fwd=bf, param_bytes=block_p,
            act_bytes=act, io_bytes_fwd=io, tp_collective_bytes=tp_bytes))
    head_flops = 2.0 * b * s * d * arch.vocab_size
    layers.append(LayerCost(
        name="lm_head", flops_fwd=head_flops,
        param_bytes=head_p + 2 * d, act_bytes=act,
        io_bytes_fwd=float(act + head_p + 2 * b * s * arch.vocab_size),
        tp_collective_bytes=float(act)))
    return ModelProfile(arch=arch, microbatch=b, seq_len=s, layers=layers,
                        hw=hw, remat=remat)


@functools.lru_cache(maxsize=64)
def cached_profile(arch_name: str, microbatch: int, seq_len: int) -> ModelProfile:
    from repro.configs import get_arch
    return build_profile(get_arch(arch_name), microbatch=microbatch, seq_len=seq_len)
