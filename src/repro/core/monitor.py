"""Node change monitor (paper §3.3/§6.2).

The original launches a CPU agent per node with a TCP connection to a
central coordinator; socket disconnects signal failure instantly (NCCL
alone would hang until timeout).  Here the same role is played by an
event bus: real deployments adapt ``ClusterMembership`` to the TPU
coordination service's health callbacks; tests and the simulator inject
events deterministically.  Preemption *warnings* (spot instances' grace
period) are first-class events, used by the engine to drain the current
iteration before the node disappears.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True, order=True)
class ClusterEvent:
    time: float
    seq: int
    kind: str = dataclasses.field(compare=False)  # fail | join | warn
    nodes: Tuple[str, ...] = dataclasses.field(compare=False)


class NodeChangeMonitor:
    """Deterministic event bus: sources push, the engine subscribes."""

    FAIL, JOIN, WARN = "fail", "join", "warn"

    def __init__(self):
        self._queue: List[ClusterEvent] = []
        self._seq = itertools.count()
        self._subscribers: List[Callable[[ClusterEvent], None]] = []

    def subscribe(self, fn: Callable[[ClusterEvent], None]) -> None:
        self._subscribers.append(fn)

    def inject(self, kind: str, nodes: Sequence[str], time: float = 0.0) -> None:
        ev = ClusterEvent(time=time, seq=next(self._seq), kind=kind,
                          nodes=tuple(nodes))
        heapq.heappush(self._queue, ev)

    def pending(self) -> bool:
        return bool(self._queue)

    def next_event_time(self) -> Optional[float]:
        return self._queue[0].time if self._queue else None

    def poll(self, now: float) -> List[ClusterEvent]:
        """Pop and dispatch every event with time <= now."""
        fired: List[ClusterEvent] = []
        while self._queue and self._queue[0].time <= now:
            ev = heapq.heappop(self._queue)
            fired.append(ev)
            for fn in self._subscribers:
                fn(ev)
        return fired
