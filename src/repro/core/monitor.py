"""Node change monitor (paper §3.3/§6.2).

The original launches a CPU agent per node with a TCP connection to a
central coordinator; socket disconnects signal failure instantly (NCCL
alone would hang until timeout).  Here the same role is played by an
event bus: real deployments adapt ``ClusterMembership`` to the TPU
coordination service's health callbacks; tests and the simulator inject
events deterministically.  Preemption *warnings* (spot instances' grace
period) are first-class events, used by the engine to drain the current
iteration before the node disappears.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass(frozen=True, order=True)
class ClusterEvent:
    time: float
    seq: int
    kind: str = dataclasses.field(compare=False)  # fail | join | warn
    nodes: Tuple[str, ...] = dataclasses.field(compare=False)


class NodeChangeMonitor:
    """Deterministic event bus: sources push, the engine subscribes."""

    FAIL, JOIN, WARN = "fail", "join", "warn"

    def __init__(self):
        self._queue: List[ClusterEvent] = []
        self._seq = itertools.count()
        self._subscribers: List[Callable[[ClusterEvent], None]] = []

    def subscribe(self, fn: Callable[[ClusterEvent], None]) -> None:
        self._subscribers.append(fn)

    def inject(self, kind: str, nodes: Sequence[str], time: float = 0.0) -> None:
        ev = ClusterEvent(time=time, seq=next(self._seq), kind=kind,
                          nodes=tuple(nodes))
        heapq.heappush(self._queue, ev)

    def pending(self) -> bool:
        return bool(self._queue)

    def next_event_time(self) -> Optional[float]:
        return self._queue[0].time if self._queue else None

    def poll(self, now: float) -> List[ClusterEvent]:
        """Pop and dispatch every event with time <= now."""
        fired: List[ClusterEvent] = []
        while self._queue and self._queue[0].time <= now:
            ev = heapq.heappop(self._queue)
            fired.append(ev)
            for fn in self._subscribers:
                fn(ev)
        return fired


# ----------------------------------------------------------------------
# Heartbeat-based failure detection (the multi-process monitor source)
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class HeartbeatConfig:
    """Timing of the out-of-band liveness channel (DESIGN.md §15).

    A member is ALIVE while its silence stays within ``timeout``,
    SUSPECT once the silence exceeds it, and DEAD once the silence
    exceeds ``timeout * (1 + backoff)`` — the backoff window absorbs GC
    pauses and long XLA compiles without declaring a healthy worker
    dead.  Senders beat every ``interval`` (<< timeout)."""

    interval: float = 0.5
    timeout: float = 3.0
    backoff: float = 1.0

    @property
    def dead_after(self) -> float:
        return self.timeout * (1.0 + max(self.backoff, 0.0))


class HeartbeatTracker:
    """alive -> suspect -> dead state machine over member heartbeats.

    Deterministically testable: ``now_fn`` injects the clock.  DEAD is
    sticky (fencing) — beats from a member already declared dead are
    ignored, so a zombie process can never resurrect itself into a plan
    that already reconfigured around it; it must re-JOIN instead.  The
    coordinator additionally calls ``mark_dead`` on a socket disconnect
    (the paper's instant-failure signal) without waiting for the
    timeout."""

    ALIVE, SUSPECT, DEAD = "alive", "suspect", "dead"

    def __init__(self, config: Optional[HeartbeatConfig] = None,
                 now_fn: Callable[[], float] = time.monotonic):
        self.config = config or HeartbeatConfig()
        self._now = now_fn
        self._last: Dict[str, float] = {}
        self._dead: Dict[str, float] = {}      # member -> time of death
        self._reported: set = set()

    def register(self, member: str, now: Optional[float] = None) -> None:
        self._last[member] = self._now() if now is None else now

    def beat(self, member: str, now: Optional[float] = None) -> bool:
        """Record a heartbeat; returns False iff the member is fenced
        (already declared dead) and the beat was discarded."""
        if member in self._dead:
            return False
        self._last[member] = self._now() if now is None else now
        return True

    def mark_dead(self, member: str, now: Optional[float] = None) -> None:
        if member in self._last and member not in self._dead:
            self._dead[member] = self._now() if now is None else now

    def status(self, member: str, now: Optional[float] = None) -> str:
        if member in self._dead:
            return self.DEAD
        if member not in self._last:
            raise KeyError(f"unknown heartbeat member {member!r}")
        now = self._now() if now is None else now
        silence = now - self._last[member]
        if silence <= self.config.timeout:
            return self.ALIVE
        if silence <= self.config.dead_after:
            return self.SUSPECT
        return self.DEAD

    def poll(self, now: Optional[float] = None) -> List[str]:
        """Advance the state machine; returns members NEWLY dead since
        the last poll (each member is reported exactly once)."""
        now = self._now() if now is None else now
        fresh: List[str] = []
        for m in list(self._last):
            if self.status(m, now) == self.DEAD:
                self._dead.setdefault(m, now)
                if m not in self._reported:
                    self._reported.add(m)
                    fresh.append(m)
        return fresh

    def members(self) -> List[str]:
        return sorted(self._last)

    def dead(self) -> List[str]:
        return sorted(self._dead)

    def alive(self, now: Optional[float] = None) -> List[str]:
        now = self._now() if now is None else now
        return [m for m in sorted(self._last)
                if self.status(m, now) != self.DEAD]
