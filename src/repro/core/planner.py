"""GPU–stage mapping via divide-and-conquer DP (paper §4.1.2).

Given a pipeline template's node count ``n`` (each node = ``M`` chips), the
planner simultaneously partitions the model's layers into stages and the
``n*M`` chips onto those stages, minimizing the 1F1B critical-path estimate

    T = T1 + T2 + T3          (Figure 5)

where, for a stage sequence with per-stage one-microbatch times
``ts[0..S-1]`` and slowest stage ``k* = argmax ts``:

    T1 = sum(ts)                          # fill + drain
    T2 = (N_b - S + k* - 1) * ts[k*]      # steady phase on the slowest stage
    T3 = sum(ts[k*:])                     # tail after the slowest stage

with ``N_b = 4*S`` during planning (paper: bubble negligible at N_b >= 4S).
For a homogeneous pipeline this reduces to the exact 1F1B makespan
``(N_b + S - 1)(F + B)``.

Three division strategies (stages must not straddle nodes — the paper's
single-node-stage constraint, mapped to ICI neighborhoods per DESIGN.md §2):

  * ``mode="binary"`` — the paper's literal recursion: iterate all
    (s, k, m) stage/layer/chip splits (Eq. 1–3), memoized on
    ``(S', u, v, d, off)`` where ``off`` is the first chip's intra-node
    offset.  Kept pristine as the reference implementation.
  * ``mode="peel"``   — split off the first stage only (s=1).  Every stage
    sequence reachable by binary splits is reachable by peeling, and
    T1/T2/T3 depend only on the resulting stage sequence, so the optimum
    is the same; peeling visits far fewer split trees.  Since the right
    sub-problem always spans layers ``[k, L)``, the memo key tightens to
    ``(S', u, d, off)`` and leaves bypass the memo entirely.  The split
    scan is dominance-pruned: any combined solution satisfies
    ``T >= (3S+1) * t_max``, and the peeled stage's time grows
    monotonically in the layer cut ``k``, so once the first stage alone
    exceeds the incumbent the whole remaining k-scan is abandoned.
  * ``mode="fast"``   — bottom-up vectorized evaluation of exactly the
    peel recursion (DESIGN.md §3.2).  States collapse to ``(S', d')``
    rows of per-``u`` arrays (``off`` is derived: every template root has
    ``off=0`` and ``d ≡ 0 (mod M)``, so ``off = -d' mod M``), and the
    (k, m) split scan becomes a handful of numpy operations over an
    ``(m, u, k)`` grid.  Stage-boundary leaf times are materialized with
    running sums that reproduce ``sum()``'s left-to-right rounding, the
    combine arithmetic mirrors :func:`_combine` operation-for-operation,
    and ties resolve by C-order argmin (m-major, then k) — the same
    first-strict-improvement order the scalar scan uses — so ``fast``
    returns bit-identical iteration times AND stage sequences.  Default.

The memo/row caches are shared across template sizes: planning the largest
template fills the caches for all smaller ones (paper §4.1.2 memoization
note).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cost_model import ModelProfile
from repro.core.templates import PipelineTemplate, PlanningError, StageSpec

INF = float("inf")

MODES = ("fast", "peel", "binary")


@dataclasses.dataclass(frozen=True)
class _Sol:
    """Memoized sub-solution for (S', u, v, d, off)."""

    total: float              # local objective T1 + T2 + T3  (N_b = 4*S')
    t1: float
    t3: float
    k_star: int               # slowest stage index, local numbering
    t_max: float              # ts[k_star]
    # decision: None for a leaf; peel: (1, k, m); binary: (s, k, m)
    cut: Optional[Tuple[int, int, int]]


def _combine(left: _Sol, right: _Sol, s_left: int, s_total: int) -> Tuple[float, float, float, int, float]:
    """Combine two sub-solutions (Eq. 1–3). Returns (total,t1,t3,k*,t_max)."""
    t1 = left.t1 + right.t1
    if left.t_max >= right.t_max:            # k* == k1*  (Eq. 3, first case)
        k_star, t_max = left.k_star, left.t_max
        t3 = left.t3 + right.t1
    else:                                    # k* in the right sub-problem
        k_star, t_max = s_left + right.k_star, right.t_max
        t3 = right.t3
    n_b = 4 * s_total
    t2 = (n_b - s_total + k_star - 1) * t_max
    return t1 + t2 + t3, t1, t3, k_star, t_max


def _min_segments(d: int, off: int, M: int) -> int:
    """Minimum stages needed so no stage straddles a node boundary."""
    first = min(d, M - off)
    rest = d - first
    return 1 + (rest + M - 1) // M if rest else 1


@dataclasses.dataclass
class _FastRow:
    """Per-(S', d') DP row of the vectorized peel recursion, indexed by the
    first-uncovered-layer ``u``.  ``tot[u] == INF`` marks infeasibility."""

    tot: np.ndarray           # float64[L+1]
    t1: np.ndarray            # float64[L+1]
    t3: np.ndarray            # float64[L+1]
    tm: np.ndarray            # float64[L+1]
    ks: np.ndarray            # int32[L+1]
    cut_k: np.ndarray         # int32[L+1]   (-1 for leaves / infeasible)
    cut_m: np.ndarray         # int16[L+1]


class PipelinePlanner:
    """Plans GPU–stage mappings for every template size of one model."""

    def __init__(self, profile: ModelProfile, gpus_per_node: int,
                 mode: str = "fast", max_stages: Optional[int] = None):
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r}; expected one of {MODES}")
        self.profile = profile
        self.M = gpus_per_node
        self.mode = mode
        self.max_stages = max_stages
        self.L = profile.num_layers
        self._memo: Dict[Tuple, _Sol] = {}
        self._leaf_cache: Dict[Tuple[int, int, int], float] = {}
        # fast-mode state, shared across template sizes (tighter memo keys)
        self._rows: Dict[Tuple[int, int], Optional[_FastRow]] = {}
        self._leaf_tables: Dict[int, np.ndarray] = {}

    # ------------------------------------------------------------------
    def plan(self, num_nodes: int) -> PipelineTemplate:
        """Best template for ``num_nodes`` nodes: argmin over S of T(S,...)."""
        n, M, L = num_nodes, self.M, self.L
        d = n * M
        if L < n:
            raise PlanningError(
                f"model has {L} layers < {n} nodes; cannot give every node a stage")
        s_lo = n                       # pigeonhole: >= 1 stage per node
        s_hi = min(L, d)
        if self.max_stages is not None:
            s_hi = min(s_hi, max(s_lo, self.max_stages))
        if self.mode == "fast":
            return self._plan_fast(num_nodes, s_lo, s_hi)
        best: Optional[_Sol] = None
        best_s = -1
        for S in range(s_lo, s_hi + 1):
            sol = self._solve(S, 0, L, d, 0)
            if sol.total < (best.total if best else INF):
                best, best_s = sol, S
        if best is None or math.isinf(best.total):
            raise PlanningError(f"no feasible mapping for {n} nodes x {M} GPUs")
        seq = self._stage_sequence(best_s, 0, self.L, d, 0)
        return self._build_template(seq, num_nodes, best_s)

    def plan_all(self, sizes) -> Dict[int, PipelineTemplate]:
        """Plan every template size, largest first to maximize memo reuse."""
        out: Dict[int, PipelineTemplate] = {}
        for n in sorted(sizes, reverse=True):
            out[n] = self.plan(n)
        return dict(sorted(out.items()))

    # ------------------------------------------------------------------
    def _leaf_time(self, u: int, v: int, d: int) -> float:
        key = (u, v, d)
        t = self._leaf_cache.get(key)
        if t is None:
            t = (self.profile.stage_fwd(u, v, d) + self.profile.stage_bwd(u, v, d))
            self._leaf_cache[key] = t
        return t

    def _leaf_sol(self, u: int, v: int, d: int, off: int) -> _Sol:
        """Single-stage conquer step, bypassing the split memo."""
        if off + d > self.M:            # stage must fit within one node
            return self._infeasible()
        t = self._leaf_time(u, v, d)
        # T1 = F+B; T2 = 2(F+B); T3 = F+B  (Eq. 4) -> total = 4(F+B)
        return _Sol(4.0 * t, t, t, 0, t, None)

    def _solve(self, S: int, u: int, v: int, d: int, off: int) -> _Sol:
        if S == 1:
            if v - u < 1 or d < 1:
                return self._infeasible()
            return self._leaf_sol(u, v, d, off)
        # peel sub-problems always span [u, L): drop v from the key so the
        # memo is shared across template sizes at maximal granularity.
        key = ((S, u, d, off) if self.mode == "peel"
               else (S, u, v, d, off))
        hit = self._memo.get(key)
        if hit is not None:
            return hit
        sol = self._compute(S, u, v, d, off)
        self._memo[key] = sol
        return sol

    def _infeasible(self) -> _Sol:
        return _Sol(INF, INF, INF, 0, INF, None)

    def _compute(self, S: int, u: int, v: int, d: int, off: int) -> _Sol:
        M = self.M
        if v - u < S or d < S:          # each stage needs >= 1 layer, 1 GPU
            return self._infeasible()
        if _min_segments(d, off, M) > S:
            return self._infeasible()
        if self.mode == "peel":
            return self._compute_peel(S, u, v, d, off)
        return self._compute_binary(S, u, v, d, off)

    def _compute_peel(self, S: int, u: int, v: int, d: int, off: int) -> _Sol:
        M = self.M
        best: Optional[_Sol] = None
        m_hi = min(d - (S - 1), M - off)
        for m in range(1, m_hi + 1):
            for k in range(u + 1, v - (S - 1) + 1):
                left = self._leaf_sol(u, k, m, off)
                # Dominance bound: any combined solution has
                # T >= (3S+1) * t_max >= (3S+1) * left.t_max, and the
                # peeled stage's time grows with k, so the rest of the
                # k-scan cannot beat the incumbent either.
                if best is not None and (3 * S + 1) * left.t_max >= best.total:
                    break
                right = self._solve(S - 1, k, v, d - m, (off + m) % M)
                if math.isinf(right.total):
                    continue
                total, t1, t3, k_star, t_max = _combine(left, right, 1, S)
                if best is None or total < best.total:
                    best = _Sol(total, t1, t3, k_star, t_max, (1, k, m))
        return best if best is not None else self._infeasible()

    def _compute_binary(self, S: int, u: int, v: int, d: int, off: int) -> _Sol:
        M = self.M
        best: Optional[_Sol] = None
        splits = [(s, k, m)
                  for s in range(1, S)
                  for k in range(u + s, v - (S - s) + 1)
                  for m in range(s, d - (S - s) + 1)]
        for s, k, m in splits:
            left = self._solve(s, u, k, m, off)
            if math.isinf(left.total):
                continue
            right = self._solve(S - s, k, v, d - m, (off + m) % M)
            if math.isinf(right.total):
                continue
            total, t1, t3, k_star, t_max = _combine(left, right, s, S)
            if best is None or total < best.total:
                best = _Sol(total, t1, t3, k_star, t_max, (s, k, m))
        return best if best is not None else self._infeasible()

    # ------------------------------------------------------------------
    # mode="fast": bottom-up vectorized peel DP.
    # ------------------------------------------------------------------
    def _leaf_table(self, d: int) -> np.ndarray:
        """``t[u, v]`` = leaf time of stage [u, v) on ``d`` chips, with the
        exact left-to-right summation of ``stage_fwd`` / ``stage_bwd`` so
        results are bit-identical to :meth:`_leaf_time`."""
        tbl = self._leaf_tables.get(d)
        if tbl is not None:
            return tbl
        L = self.L
        fwd = [self.profile.fwd_time(i, d) for i in range(L)]
        bwd = [self.profile.bwd_time(i, d) for i in range(L)]
        tbl = np.full((L + 1, L + 1), INF)
        for u in range(L + 1):
            facc = 0.0
            bacc = 0.0
            row = tbl[u]
            for v in range(u + 1, L + 1):
                facc = facc + fwd[v - 1]
                bacc = bacc + bwd[v - 1]
                row[v] = facc + bacc
        self._leaf_tables[d] = tbl
        return tbl

    def _ensure_rows(self, S: int, d: int) -> None:
        """Fill every (s', d') row reachable from root (S, d) bottom-up."""
        M = self.M
        for s in range(1, S + 1):
            lo = max(s, d - (S - s) * M)
            hi = min(s * M, d - (S - s))
            for dp in range(lo, hi + 1):
                if (s, dp) not in self._rows:
                    self._rows[(s, dp)] = self._compute_row(s, dp)

    def _compute_row(self, S: int, d: int) -> Optional[_FastRow]:
        L, M = self.L, self.M
        if d < S or L < S:
            return None
        off = (-d) % M
        if S == 1:
            if d > M:                  # stage must fit within one node
                return None
            t = self._leaf_table(d)[:, L].copy()   # t[u] = leaf(u, L, d)
            ks = np.zeros(L + 1, dtype=np.int32)
            cut_k = np.full(L + 1, -1, dtype=np.int32)
            cut_m = np.zeros(L + 1, dtype=np.int16)
            return _FastRow(4.0 * t, t.copy(), t.copy(), t.copy(), ks,
                            cut_k, cut_m)
        m_hi = min(d - (S - 1), M - off)
        if m_hi < 1:
            return None
        # only u <= L-S can host S further stages; cuts live in (u, L-(S-1)]
        u_hi = L - S                       # inclusive
        k_hi = L - (S - 1)                 # inclusive
        nu, nk = u_hi + 1, k_hi + 1
        k_idx = np.arange(nk)
        k_valid = (k_idx[None, :] > np.arange(nu)[:, None])
        grids: List[np.ndarray] = []
        ms: List[int] = []
        children: List[_FastRow] = []
        for m in range(1, m_hi + 1):
            child = self._rows.get((S - 1, d - m))
            if child is None:
                continue
            t = self._leaf_table(m)[:nu, :nk]            # [u, k]
            t1 = t + child.t1[None, :nk]
            # same association order as _combine: (t1 + t2) + t3
            left_tot = (t1 + (3 * S - 1) * t) + t1
            right_tot = ((t1 + (3 * S + child.ks[None, :nk]) * child.tm[None, :nk])
                         + child.t3[None, :nk])
            tot = np.where(t >= child.tm[None, :nk], left_tot, right_tot)
            grids.append(np.where(k_valid, tot, INF))
            ms.append(m)
            children.append(child)
        if not grids:
            return None
        # m-major, then k: identical tie-breaking to the scalar peel scan.
        stack = np.stack(grids)                          # [m, u, k]
        flat = np.moveaxis(stack, 0, 1).reshape(nu, -1)
        idx = np.argmin(flat, axis=1)
        tot = np.full(L + 1, INF)
        tot[:nu] = flat[np.arange(nu), idx]
        m_sel = np.zeros(L + 1, dtype=np.int64)
        m_sel[:nu] = idx // nk
        k_sel = np.zeros(L + 1, dtype=np.int32)
        k_sel[:nu] = (idx % nk).astype(np.int32)
        feasible = np.isfinite(tot)
        if not feasible.any():
            return None
        t1 = np.full(L + 1, INF)
        t3 = np.full(L + 1, INF)
        tm = np.full(L + 1, INF)
        ks = np.zeros(L + 1, dtype=np.int32)
        cut_k = np.full(L + 1, -1, dtype=np.int32)
        cut_m = np.zeros(L + 1, dtype=np.int16)
        for mi, (m, child) in enumerate(zip(ms, children)):
            sel = feasible & (m_sel == mi)
            if not sel.any():
                continue
            u = np.nonzero(sel)[0]
            k = k_sel[sel]
            t = self._leaf_table(m)[u, k]
            r1 = child.t1[k]
            rtm = child.tm[k]
            cond = t >= rtm
            t1v = t + r1
            t1[sel] = t1v
            tm[sel] = np.where(cond, t, rtm)
            ks[sel] = np.where(cond, 0, 1 + child.ks[k])
            t3[sel] = np.where(cond, t1v, child.t3[k])
            cut_k[sel] = k
            cut_m[sel] = m
        return _FastRow(tot, t1, t3, tm, ks, cut_k, cut_m)

    def _plan_fast(self, num_nodes: int, s_lo: int, s_hi: int) -> PipelineTemplate:
        d = num_nodes * self.M
        best_tot, best_s = INF, -1
        for S in range(s_lo, s_hi + 1):
            self._ensure_rows(S, d)
            row = self._rows.get((S, d))
            if row is None:
                continue
            tot = float(row.tot[0])
            if tot < best_tot:
                best_tot, best_s = tot, S
        if best_s < 0:
            raise PlanningError(
                f"no feasible mapping for {num_nodes} nodes x {self.M} GPUs")
        # walk the stored cuts: (S', u, d') -> peel (u, cut_k, cut_m)
        seq: List[Tuple[int, int, int]] = []
        S, u, dp = best_s, 0, d
        while S > 1:
            row = self._rows[(S, dp)]
            k, m = int(row.cut_k[u]), int(row.cut_m[u])
            if k < 0:
                raise PlanningError("reconstruction reached infeasible state")
            seq.append((u, k, m))
            u, dp, S = k, dp - m, S - 1
        seq.append((u, self.L, dp))
        return self._build_template(seq, num_nodes, best_s)

    # ------------------------------------------------------------------
    def _stage_sequence(self, S: int, u: int, v: int, d: int, off: int
                        ) -> List[Tuple[int, int, int]]:
        """Reconstruct [(layer_start, layer_end, num_gpus), ...]."""
        sol = self._solve(S, u, v, d, off)
        if math.isinf(sol.total):
            raise PlanningError("reconstruction reached infeasible state")
        if sol.cut is None:
            return [(u, v, d)]
        s, k, m = sol.cut
        if s == 1:
            left = [(u, k, m)]
        else:
            left = self._stage_sequence(s, u, k, m, off)
        right = self._stage_sequence(S - s, k, v, d - m, (off + m) % self.M)
        return left + right

    def _build_template(self, seq: List[Tuple[int, int, int]],
                        num_nodes: int, S: int) -> PipelineTemplate:
        stages: List[StageSpec] = []
        cursor = 0
        times: List[float] = []
        for sid, (u, v, d) in enumerate(seq):
            stages.append(StageSpec(
                stage_id=sid, layer_start=u, layer_end=v,
                node_offset=cursor // self.M, num_gpus=d,
                gpu_offset=cursor % self.M))
            times.append(self._leaf_time(u, v, d))
            cursor += d
        k_star = max(range(len(times)), key=lambda i: times[i])
        t_max = times[k_star]
        n_b = 4 * S
        t1 = sum(times)
        t2 = (n_b - S + k_star - 1) * t_max
        t3 = sum(times[k_star:])
        tpl = PipelineTemplate(
            num_nodes=num_nodes, gpus_per_node=self.M, num_stages=S,
            stages=tuple(stages), iteration_time=t1 + t2 + t3,
            t1=t1, t2=t2, t3=t3, slowest_stage=k_star,
            stage_times=tuple(times))
        tpl.validate(self.L)
        return tpl


# ----------------------------------------------------------------------
def estimate_iteration_time(tpl: PipelineTemplate, num_microbatches: int) -> float:
    """1F1B makespan estimate for an instantiated pipeline running
    ``num_microbatches`` microbatches (affine in N_b)."""
    n_b = max(num_microbatches, tpl.num_stages)  # cannot go below fill
    t2 = (n_b - tpl.num_stages + tpl.slowest_stage - 1) * tpl.stage_times[tpl.slowest_stage]
    return tpl.t1 + max(t2, 0.0) + tpl.t3
