"""GPU–stage mapping via divide-and-conquer DP (paper §4.1.2).

Given a pipeline template's node count ``n`` (each node = ``M`` chips), the
planner simultaneously partitions the model's layers into stages and the
``n*M`` chips onto those stages, minimizing the 1F1B critical-path estimate

    T = T1 + T2 + T3          (Figure 5)

where, for a stage sequence with per-stage one-microbatch times
``ts[0..S-1]`` and slowest stage ``k* = argmax ts``:

    T1 = sum(ts)                          # fill + drain
    T2 = (N_b - S + k* - 1) * ts[k*]      # steady phase on the slowest stage
    T3 = sum(ts[k*:])                     # tail after the slowest stage

with ``N_b = 4*S`` during planning (paper: bubble negligible at N_b >= 4S).
For a homogeneous pipeline this reduces to the exact 1F1B makespan
``(N_b + S - 1)(F + B)``.

Two division strategies, both memoized on ``(S', u, v, d, off)`` where
``off`` is the first chip's intra-node offset (stages must not straddle
nodes — paper's single-node-stage constraint, mapped to ICI neighborhoods
per DESIGN.md §2):

  * ``mode="binary"`` — the paper's literal recursion: iterate all
    (s, k, m) stage/layer/chip splits (Eq. 1–3).
  * ``mode="peel"``   — split off the first stage only (s=1).  Every stage
    sequence reachable by binary splits is reachable by peeling, and
    T1/T2/T3 depend only on the resulting stage sequence, so the optimum
    is the same; peeling visits far fewer split trees.  Default.

The memo is shared across template sizes: planning the largest template
fills the caches for all smaller ones (paper §4.1.2 memoization note).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.cost_model import ModelProfile
from repro.core.templates import PipelineTemplate, PlanningError, StageSpec

INF = float("inf")


@dataclasses.dataclass(frozen=True)
class _Sol:
    """Memoized sub-solution for (S', u, v, d, off)."""

    total: float              # local objective T1 + T2 + T3  (N_b = 4*S')
    t1: float
    t3: float
    k_star: int               # slowest stage index, local numbering
    t_max: float              # ts[k_star]
    # decision: None for a leaf; peel: (1, k, m); binary: (s, k, m)
    cut: Optional[Tuple[int, int, int]]


def _combine(left: _Sol, right: _Sol, s_left: int, s_total: int) -> Tuple[float, float, float, int, float]:
    """Combine two sub-solutions (Eq. 1–3). Returns (total,t1,t3,k*,t_max)."""
    t1 = left.t1 + right.t1
    if left.t_max >= right.t_max:            # k* == k1*  (Eq. 3, first case)
        k_star, t_max = left.k_star, left.t_max
        t3 = left.t3 + right.t1
    else:                                    # k* in the right sub-problem
        k_star, t_max = s_left + right.k_star, right.t_max
        t3 = right.t3
    n_b = 4 * s_total
    t2 = (n_b - s_total + k_star - 1) * t_max
    return t1 + t2 + t3, t1, t3, k_star, t_max


def _min_segments(d: int, off: int, M: int) -> int:
    """Minimum stages needed so no stage straddles a node boundary."""
    first = min(d, M - off)
    rest = d - first
    return 1 + (rest + M - 1) // M if rest else 1


class PipelinePlanner:
    """Plans GPU–stage mappings for every template size of one model."""

    def __init__(self, profile: ModelProfile, gpus_per_node: int,
                 mode: str = "peel", max_stages: Optional[int] = None):
        if mode not in ("peel", "binary"):
            raise ValueError(f"unknown mode {mode!r}")
        self.profile = profile
        self.M = gpus_per_node
        self.mode = mode
        self.max_stages = max_stages
        self.L = profile.num_layers
        self._memo: Dict[Tuple[int, int, int, int, int], _Sol] = {}
        self._leaf_cache: Dict[Tuple[int, int, int], float] = {}

    # ------------------------------------------------------------------
    def plan(self, num_nodes: int) -> PipelineTemplate:
        """Best template for ``num_nodes`` nodes: argmin over S of T(S,...)."""
        n, M, L = num_nodes, self.M, self.L
        d = n * M
        if L < n:
            raise PlanningError(
                f"model has {L} layers < {n} nodes; cannot give every node a stage")
        s_lo = n                       # pigeonhole: >= 1 stage per node
        s_hi = min(L, d)
        if self.max_stages is not None:
            s_hi = min(s_hi, max(s_lo, self.max_stages))
        best: Optional[_Sol] = None
        best_s = -1
        for S in range(s_lo, s_hi + 1):
            sol = self._solve(S, 0, L, d, 0)
            if sol.total < (best.total if best else INF):
                best, best_s = sol, S
        if best is None or math.isinf(best.total):
            raise PlanningError(f"no feasible mapping for {n} nodes x {M} GPUs")
        return self._reconstruct(best_s, num_nodes, best)

    def plan_all(self, sizes) -> Dict[int, PipelineTemplate]:
        """Plan every template size, largest first to maximize memo reuse."""
        out: Dict[int, PipelineTemplate] = {}
        for n in sorted(sizes, reverse=True):
            out[n] = self.plan(n)
        return dict(sorted(out.items()))

    # ------------------------------------------------------------------
    def _leaf_time(self, u: int, v: int, d: int) -> float:
        key = (u, v, d)
        t = self._leaf_cache.get(key)
        if t is None:
            t = (self.profile.stage_fwd(u, v, d) + self.profile.stage_bwd(u, v, d))
            self._leaf_cache[key] = t
        return t

    def _solve(self, S: int, u: int, v: int, d: int, off: int) -> _Sol:
        key = (S, u, v, d, off)
        hit = self._memo.get(key)
        if hit is not None:
            return hit
        sol = self._compute(S, u, v, d, off)
        self._memo[key] = sol
        return sol

    def _infeasible(self) -> _Sol:
        return _Sol(INF, INF, INF, 0, INF, None)

    def _compute(self, S: int, u: int, v: int, d: int, off: int) -> _Sol:
        M = self.M
        if v - u < S or d < S:          # each stage needs >= 1 layer, 1 GPU
            return self._infeasible()
        if S == 1:
            if off + d > M:             # conquer: stage within one node
                return self._infeasible()
            t = self._leaf_time(u, v, d)
            # T1 = F+B; T2 = 2(F+B); T3 = F+B  (Eq. 4) -> total = 4(F+B)
            return _Sol(4.0 * t, t, t, 0, t, None)
        if _min_segments(d, off, M) > S:
            return self._infeasible()

        best: Optional[_Sol] = None
        if self.mode == "peel":
            splits = [(1, k, m)
                      for m in range(1, min(d - (S - 1), M - off) + 1)
                      for k in range(u + 1, v - (S - 1) + 1)]
        else:
            splits = [(s, k, m)
                      for s in range(1, S)
                      for k in range(u + s, v - (S - s) + 1)
                      for m in range(s, d - (S - s) + 1)]
        for s, k, m in splits:
            left = self._solve(s, u, k, m, off)
            if math.isinf(left.total):
                continue
            right = self._solve(S - s, k, v, d - m, (off + m) % M)
            if math.isinf(right.total):
                continue
            total, t1, t3, k_star, t_max = _combine(left, right, s, S)
            if best is None or total < best.total:
                best = _Sol(total, t1, t3, k_star, t_max, (s, k, m))
        return best if best is not None else self._infeasible()

    # ------------------------------------------------------------------
    def _stage_sequence(self, S: int, u: int, v: int, d: int, off: int
                        ) -> List[Tuple[int, int, int]]:
        """Reconstruct [(layer_start, layer_end, num_gpus), ...]."""
        sol = self._solve(S, u, v, d, off)
        if math.isinf(sol.total):
            raise PlanningError("reconstruction reached infeasible state")
        if sol.cut is None:
            return [(u, v, d)]
        s, k, m = sol.cut
        left = self._stage_sequence(s, u, k, m, off)
        right = self._stage_sequence(S - s, k, v, d - m, (off + m) % self.M)
        return left + right

    def _reconstruct(self, S: int, num_nodes: int, root: _Sol) -> PipelineTemplate:
        seq = self._stage_sequence(S, 0, self.L, num_nodes * self.M, 0)
        stages: List[StageSpec] = []
        cursor = 0
        times: List[float] = []
        for sid, (u, v, d) in enumerate(seq):
            stages.append(StageSpec(
                stage_id=sid, layer_start=u, layer_end=v,
                node_offset=cursor // self.M, num_gpus=d,
                gpu_offset=cursor % self.M))
            times.append(self._leaf_time(u, v, d))
            cursor += d
        k_star = max(range(len(times)), key=lambda i: times[i])
        t_max = times[k_star]
        n_b = 4 * S
        t1 = sum(times)
        t2 = (n_b - S + k_star - 1) * t_max
        t3 = sum(times[k_star:])
        tpl = PipelineTemplate(
            num_nodes=num_nodes, gpus_per_node=self.M, num_stages=S,
            stages=tuple(stages), iteration_time=t1 + t2 + t3,
            t1=t1, t2=t2, t3=t3, slowest_stage=k_star,
            stage_times=tuple(times))
        tpl.validate(self.L)
        return tpl


# ----------------------------------------------------------------------
def estimate_iteration_time(tpl: PipelineTemplate, num_microbatches: int) -> float:
    """1F1B makespan estimate for an instantiated pipeline running
    ``num_microbatches`` microbatches (affine in N_b)."""
    n_b = max(num_microbatches, tpl.num_stages)  # cannot go below fill
    t2 = (n_b - tpl.num_stages + tpl.slowest_stage - 1) * tpl.stage_times[tpl.slowest_stage]
    return tpl.t1 + max(t2, 0.0) + tpl.t3
