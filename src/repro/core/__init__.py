# The paper's primary contribution: pipeline-template planning and the
# resilient execution engine (Oobleck, SOSP 2023).
from repro.core.adapt import (AdaptationError, AdaptCostModel, AdaptCostRow,
                              AdaptPlan)
from repro.core.batch import BatchPlan, distribute_batch, distribute_microbatches
from repro.core.cost_model import LayerCost, ModelProfile, build_profile
from repro.core.engine import ConfigurationEngine, EngineConfig, OobleckEngine
from repro.core.instantiator import (InstantiationPlan, choose_plan,
                                     enumerate_feasible_sets)
from repro.core.monitor import (ClusterEvent, HeartbeatConfig,
                                HeartbeatTracker, NodeChangeMonitor)
from repro.core.planner import PipelinePlanner, estimate_iteration_time
from repro.core.reconfigure import (CopyTask, InsufficientReplicasError,
                                    PipelineInstance, ReconfigResult,
                                    Reconfigurator)
from repro.core.sync import (LayerGroup, SyncBucket, build_sync_plan,
                             layer_groups, verify_replica_coverage)
from repro.core.templates import (NodeSpec, PipelineTemplate, PlanningError,
                                  StageSpec, coverable, generate_node_spec)

__all__ = [
    "AdaptationError", "AdaptCostModel", "AdaptCostRow", "AdaptPlan",
    "BatchPlan", "distribute_batch", "distribute_microbatches",
    "LayerCost", "ModelProfile", "build_profile",
    "ConfigurationEngine", "EngineConfig", "OobleckEngine",
    "InstantiationPlan", "choose_plan", "enumerate_feasible_sets",
    "ClusterEvent", "HeartbeatConfig", "HeartbeatTracker",
    "NodeChangeMonitor",
    "PipelinePlanner", "estimate_iteration_time",
    "CopyTask", "InsufficientReplicasError", "PipelineInstance",
    "ReconfigResult", "Reconfigurator",
    "LayerGroup", "SyncBucket", "build_sync_plan", "layer_groups",
    "verify_replica_coverage",
    "NodeSpec", "PipelineTemplate", "PlanningError", "StageSpec",
    "coverable", "generate_node_spec",
]
