"""Hardware constants for the target platform (TPU v5e-class).

These drive three things:
  1. the planner's analytical cost model (core/cost_model.py),
  2. the discrete-event simulator's iteration/restore timings (sim/),
  3. the roofline analysis (launch/roofline.py).

The container executes on CPU; the constants describe the TARGET hardware,
per the task spec: 197 TFLOP/s bf16 per chip; 819 GB/s HBM; ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """One accelerator chip + its fabric."""

    peak_flops_bf16: float = 197e12     # FLOP/s per chip (MXU, bf16)
    hbm_bandwidth: float = 819e9        # bytes/s per chip
    hbm_capacity: int = 16 * 1024**3    # bytes per chip (v5e: 16 GiB)
    vmem_capacity: int = 128 * 1024**2  # bytes of VMEM per chip (~128 MiB)
    ici_bandwidth: float = 50e9         # bytes/s per ICI link (one direction)
    ici_links_per_chip: int = 4         # 2D torus: 4 links
    dcn_bandwidth: float = 25e9         # bytes/s per host, cross-pod (DCN)
    mxu_efficiency: float = 0.72        # achievable fraction of peak on GEMMs
    chips_per_node: int = 4             # "node" = ICI neighborhood quartet

    # Storage path used for checkpoints (distributed object store).
    ckpt_write_bandwidth: float = 8e9   # bytes/s aggregate write
    ckpt_read_bandwidth: float = 12e9   # bytes/s aggregate read


#: Default target chip. Everything takes a HardwareSpec parameter and
#: defaults to this, so tests can substitute toy hardware.
V5E = HardwareSpec()


def matmul_time(flops: float, chips: int, hw: HardwareSpec = V5E) -> float:
    """Seconds to execute ``flops`` of GEMM work on ``chips`` chips."""
    return flops / (chips * hw.peak_flops_bf16 * hw.mxu_efficiency)


def allreduce_time(nbytes: float, participants: int,
                   bandwidth: float | None = None,
                   hw: HardwareSpec = V5E) -> float:
    """Ring all-reduce: 2*(k-1)/k * bytes over the slowest link."""
    if participants <= 1:
        return 0.0
    bw = bandwidth if bandwidth is not None else hw.ici_bandwidth
    return 2.0 * (participants - 1) / participants * nbytes / bw


def allgather_time(nbytes: float, participants: int,
                   bandwidth: float | None = None,
                   hw: HardwareSpec = V5E) -> float:
    """Ring all-gather of a ``nbytes`` shard from each of ``participants``."""
    if participants <= 1:
        return 0.0
    bw = bandwidth if bandwidth is not None else hw.ici_bandwidth
    return (participants - 1) / participants * nbytes / bw


def p2p_time(nbytes: float, bandwidth: float | None = None,
             hw: HardwareSpec = V5E) -> float:
    """Point-to-point transfer (pipeline activation hops, state copy)."""
    bw = bandwidth if bandwidth is not None else hw.ici_bandwidth
    return nbytes / bw
