"""musicgen-large — decoder-only over EnCodec tokens.

[arXiv:2306.05284; hf] 48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.
The EnCodec audio frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings; the backbone trains/serves over codec
token ids in the 2048-entry codebook.
"""
from repro.configs.base import ArchConfig, register

MUSICGEN_LARGE = register(ArchConfig(
    name="musicgen_large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    frontend="audio",
    frontend_tokens=256,       # conditioning frame embeddings
    source="arXiv:2306.05284; hf",
))
