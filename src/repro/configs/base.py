"""Architecture + input-shape configuration system.

Every assigned architecture is a single ``ArchConfig`` in its own module
(``src/repro/configs/<id>.py``) registered here via :func:`register`.
``ShapeConfig`` describes one assigned input-shape cell (train / prefill /
decode / long-decode).  The (arch x shape) grid drives smoke tests, the
multi-pod dry-run, and the roofline table.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared_experts: int = 0
    shared_expert_d_ff: int = 0  # d_ff of the (merged) shared expert, if any
    router_aux_loss_coef: float = 0.001


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block hyperparameters."""

    state_size: int = 128       # N: SSM state dimension
    head_dim: int = 64          # P: channels per SSD head
    expand: int = 2             # d_inner = expand * d_model
    conv_width: int = 4
    chunk_size: int = 256       # SSD chunked-scan block length
    n_groups: int = 1           # B/C groups (GVA-style)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """A complete decoder-family architecture description."""

    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int              # 0 for attention-free (ssm)
    num_kv_heads: int
    d_ff: int                   # dense MLP width; for MoE: per-expert width
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    mlp_variant: str = "swiglu"  # swiglu (3 mats) | gelu (2 mats)
    rope_theta: float = 10000.0
    rms_norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (Hymba): each block runs attention heads and SSM heads in
    # parallel and mixes their outputs (mean of the two branch outputs).
    hybrid_parallel_heads: bool = False
    # Sliding-window size used by attention branches at long context; 0 means
    # full (quadratic) attention only.
    sliding_window: int = 0
    # Modality frontend stub: None | "vision" | "audio".  When set,
    # input_specs() provides precomputed frame/patch embeddings and the
    # backbone consumes them directly (task spec: frontend is a STUB).
    frontend: Optional[str] = None
    frontend_tokens: int = 0    # number of prefix embedding tokens (vlm/audio)
    source: str = ""            # provenance note

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------
    # Parameter accounting (used by 6ND, memory planning, and n0 choice).
    # ------------------------------------------------------------------
    def attn_params_per_layer(self) -> int:
        if self.num_heads == 0:
            return 0
        q = self.d_model * self.num_heads * self.head_dim
        kv = 2 * self.d_model * self.num_kv_heads * self.head_dim
        o = self.num_heads * self.head_dim * self.d_model
        bias = (self.num_heads + 2 * self.num_kv_heads) * self.head_dim if self.qkv_bias else 0
        qknorm = 2 * self.head_dim if self.qk_norm else 0
        return q + kv + o + bias + qknorm

    def mlp_params_per_layer(self) -> int:
        if self.moe is not None:
            routed = self.moe.num_experts * 3 * self.d_model * self.d_ff
            shared = 3 * self.d_model * self.moe.shared_expert_d_ff
            router = self.d_model * self.moe.num_experts
            return routed + shared + router
        if self.d_ff == 0:
            return 0
        mats = 3 if self.mlp_variant == "swiglu" else 2
        return mats * self.d_model * self.d_ff

    def ssm_params_per_layer(self) -> int:
        if self.ssm is None:
            return 0
        c = self.ssm
        d_inner = c.expand * self.d_model
        n_heads = d_inner // c.head_dim
        in_proj = self.d_model * (2 * d_inner + 2 * c.n_groups * c.state_size + n_heads)
        conv = c.conv_width * (d_inner + 2 * c.n_groups * c.state_size)
        out_proj = d_inner * self.d_model
        extras = 3 * n_heads + d_inner  # A_log, dt_bias, D, gated-norm weight
        return in_proj + conv + out_proj + extras

    def params_per_layer(self) -> int:
        norms = 2 * self.d_model
        body = self.mlp_params_per_layer() + norms
        if self.hybrid_parallel_heads:
            body += self.attn_params_per_layer() + self.ssm_params_per_layer()
        elif self.family == "ssm":
            body += self.ssm_params_per_layer()
        else:
            body += self.attn_params_per_layer()
        return body

    def embedding_params(self) -> int:
        emb = self.vocab_size * self.d_model
        head = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        return emb + head + self.d_model  # + final norm

    def total_params(self) -> int:
        return self.num_layers * self.params_per_layer() + self.embedding_params()

    def active_params(self) -> int:
        """Per-token active parameters (MoE: routed top-k + shared only)."""
        if self.moe is None:
            return self.total_params()
        m = self.moe
        active_mlp = (m.top_k * 3 * self.d_model * self.d_ff
                      + 3 * self.d_model * m.shared_expert_d_ff
                      + self.d_model * m.num_experts)
        per_layer = (self.attn_params_per_layer() + active_mlp + 2 * self.d_model)
        return self.num_layers * per_layer + self.embedding_params()

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch serve 500k-token contexts? (SSM / hybrid w/ SWA)."""
        return self.family == "ssm" or (self.hybrid_parallel_heads and self.sliding_window > 0)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_training(self) -> bool:
        return self.kind == "train"

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"

    def tokens_per_step(self) -> int:
        if self.is_decode:
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


#: Assigned LM shape set (identical for all 10 archs; applicability filtered
#: by ``cells_for``).
SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": ShapeConfig("prefill_32k", seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": ShapeConfig("decode_32k", seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": ShapeConfig("long_500k", seq_len=524288, global_batch=1, kind="decode"),
}

_REGISTRY: Dict[str, ArchConfig] = {}

#: Assigned architecture module names, in task order.
ARCH_IDS: List[str] = [
    "mamba2_780m", "hymba_1_5b", "phi3_vision_4_2b", "musicgen_large",
    "qwen2_5_32b", "qwen3_1_7b", "qwen2_5_3b", "glm4_9b",
    "qwen2_moe_a2_7b", "granite_moe_1b_a400m",
]

#: Paper-evaluation models (Table 1), used by the simulator benchmarks.
PAPER_IDS: List[str] = [
    "bert_large", "gpt2", "gpt3_medium", "gpt3_2_7b", "gpt3_6_7b",
]


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_arch(name: str) -> ArchConfig:
    """Look up an architecture by id (dashes and underscores equivalent)."""
    key = name.replace("-", "_").replace(".", "_")
    if key not in _REGISTRY:
        for mod in ARCH_IDS + PAPER_IDS:
            if mod not in _REGISTRY:
                importlib.import_module(f"repro.configs.{mod}")
        if key not in _REGISTRY:
            raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[key]


def all_archs() -> List[ArchConfig]:
    return [get_arch(a) for a in ARCH_IDS]


def cells_for(arch: ArchConfig) -> List[ShapeConfig]:
    """The assigned (arch x shape) cells, applying the task's skip rules:
    - ``long_500k`` needs sub-quadratic attention -> SSM/hybrid only;
    - decode shapes skipped for encoder-only archs (none assigned).
    """
    cells = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if arch.subquadratic:
        cells.append(SHAPES["long_500k"])
    return cells


def all_cells() -> List[Tuple[ArchConfig, ShapeConfig]]:
    return [(a, s) for a in all_archs() for s in cells_for(a)]


def reduced(cfg: ArchConfig, *, layers: int = 2, d_model: int = 64,
            vocab: int = 512) -> ArchConfig:
    """A tiny same-family config for CPU smoke tests."""
    heads = min(cfg.num_heads, 4) if cfg.num_heads else 0
    kv = max(1, min(cfg.num_kv_heads, heads)) if heads else 0
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(num_experts=4, top_k=min(2, cfg.moe.top_k),
                        num_shared_experts=min(1, cfg.moe.num_shared_experts),
                        shared_expert_d_ff=32 if cfg.moe.shared_expert_d_ff else 0)
    ssm = None
    if cfg.ssm is not None:
        ssm = SSMConfig(state_size=16, head_dim=16, expand=2, conv_width=4,
                        chunk_size=16, n_groups=1)
    return dataclasses.replace(
        cfg, name=cfg.name + "_smoke", num_layers=layers, d_model=d_model,
        num_heads=heads, num_kv_heads=kv, head_dim=(d_model // heads if heads else 0),
        d_ff=(0 if cfg.d_ff == 0 else d_model * 2), vocab_size=vocab,
        moe=moe, ssm=ssm, frontend_tokens=min(cfg.frontend_tokens, 16),
    )
