"""Architecture & shape registry.  ``get_arch("qwen2.5-32b")`` etc."""
from repro.configs.base import (
    ARCH_IDS, PAPER_IDS, SHAPES, ArchConfig, MoEConfig, SSMConfig,
    ShapeConfig, all_archs, all_cells, cells_for, get_arch, reduced, register,
)

__all__ = [
    "ARCH_IDS", "PAPER_IDS", "SHAPES", "ArchConfig", "MoEConfig", "SSMConfig",
    "ShapeConfig", "all_archs", "all_cells", "cells_for", "get_arch",
    "reduced", "register",
]
