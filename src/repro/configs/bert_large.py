"""BERT-Large (paper Table 1 row 1) — used by the simulator benchmarks."""
from repro.configs.base import ArchConfig, register

BERT_LARGE = register(ArchConfig(
    name="bert_large", family="dense", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=16, d_ff=4096, vocab_size=30522, mlp_variant="gelu",
    source="paper Table 1 [9]",
))
