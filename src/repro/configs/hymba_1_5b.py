"""hymba-1.5b — hybrid: parallel attention + mamba heads per block.

[arXiv:2411.13676; hf] 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16.  The attention branch uses sliding-window
attention (SWA) in most layers, which is what makes long_500k feasible.
"""
from repro.configs.base import ArchConfig, SSMConfig, register

HYMBA_1_5B = register(ArchConfig(
    name="hymba_1_5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    hybrid_parallel_heads=True,
    sliding_window=2048,
    ssm=SSMConfig(state_size=16, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256, n_groups=1),
    source="arXiv:2411.13676; hf",
))
