"""GPT-2 345M (paper Table 1 row 2) — used by the simulator benchmarks."""
from repro.configs.base import ArchConfig, register

GPT2 = register(ArchConfig(
    name="gpt2", family="dense", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=16, d_ff=4096, vocab_size=50257, mlp_variant="gelu",
    tie_embeddings=True, source="paper Table 1 [36] (medium)",
))
