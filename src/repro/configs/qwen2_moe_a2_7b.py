"""qwen2-moe-a2.7b — MoE: 60 routed experts top-4 + 4 shared experts.

[hf:Qwen/Qwen1.5-MoE-A2.7B] 24L d_model=2048 16H (kv=16) expert
d_ff=1408 vocab=151936.  The 4 shared experts are merged into one
shared FFN of width 4*1408=5632 (matching the HF implementation).
"""
from repro.configs.base import ArchConfig, MoEConfig, register

QWEN2_MOE_A2_7B = register(ArchConfig(
    name="qwen2_moe_a2_7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151936,
    qkv_bias=True,
    moe=MoEConfig(num_experts=60, top_k=4, num_shared_experts=4,
                  shared_expert_d_ff=5632),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
))
