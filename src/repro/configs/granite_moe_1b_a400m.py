"""granite-moe-1b-a400m — MoE: 32 experts top-8, no shared experts.

[hf:ibm-granite/granite-3.0-1b-a400m-base] 24L d_model=1024 16H (kv=8)
expert d_ff=512 vocab=49155.
"""
from repro.configs.base import ArchConfig, MoEConfig, register

GRANITE_MOE_1B_A400M = register(ArchConfig(
    name="granite_moe_1b_a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    tie_embeddings=True,
    moe=MoEConfig(num_experts=32, top_k=8),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
))
