"""mamba2-780m — SSD (state-space duality), attention-free.

[arXiv:2405.21060] 48L d_model=1536 d_ff=0 vocab=50280, ssm_state=128.
"""
from repro.configs.base import ArchConfig, SSMConfig, register

MAMBA2_780M = register(ArchConfig(
    name="mamba2_780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,                    # attention-free, MLP-free Mamba2 stack
    vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(state_size=128, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256, n_groups=1),
    source="arXiv:2405.21060 (SSD); unverified",
))
