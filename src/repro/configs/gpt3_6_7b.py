"""GPT-3 6.7B (paper Table 1 row 5) — the paper's largest evaluated model."""
from repro.configs.base import ArchConfig, register

GPT3_6_7B = register(ArchConfig(
    name="gpt3_6_7b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=32, d_ff=16384, vocab_size=50257, mlp_variant="gelu",
    source="paper Table 1 [5]",
))
