"""phi-3-vision-4.2b — phi3-mini backbone + CLIP frontend (stub).

[hf:microsoft/Phi-3-vision-128k-instruct] 32L d_model=3072 32H (kv=32)
d_ff=8192 vocab=32064.  The vision tower is a STUB per the task spec:
``input_specs()`` supplies precomputed patch embeddings (576 tokens of
d_model) prepended to the text stream.
"""
from repro.configs.base import ArchConfig, register

PHI3_VISION_4_2B = register(ArchConfig(
    name="phi3_vision_4_2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    frontend="vision",
    frontend_tokens=576,       # 24x24 CLIP patch grid
    source="hf:microsoft/Phi-3-vision-128k-instruct",
))
