"""GPT-3 2.7B (paper Table 1 row 4)."""
from repro.configs.base import ArchConfig, register

GPT3_2_7B = register(ArchConfig(
    name="gpt3_2_7b", family="dense", num_layers=32, d_model=2560,
    num_heads=32, num_kv_heads=32, d_ff=10240, vocab_size=50257, mlp_variant="gelu",
    source="paper Table 1 [5]",
))
