"""GPT-3 Medium 350M (paper Table 1 row 3)."""
from repro.configs.base import ArchConfig, register

GPT3_MEDIUM = register(ArchConfig(
    name="gpt3_medium", family="dense", num_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=16, d_ff=4096, vocab_size=50257, mlp_variant="gelu",
    source="paper Table 1 [5]",
))
