"""AdamW with global-norm clipping and warmup-cosine schedule.

Pure-pytree implementation (no optax dependency in the container).  The
moments mirror the parameter tree, so any parameter sharding applies to
optimizer state verbatim; ZeRO-1 additionally shards the moments (and the
fp32 master copy) over the data axis — see runtime/sharding.py, which
returns those specs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply(cfg: AdamWConfig, params, grads, state: AdamWState
          ) -> Tuple[Any, AdamWState, Dict[str, jax.Array]]:
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.v, grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        return (p.astype(jnp.float32) - lr * (delta + decay)).astype(p.dtype)

    new_params = jax.tree.map(upd, params, m, v)
    return new_params, AdamWState(step, m, v), {"lr": lr, "grad_norm": gnorm}
