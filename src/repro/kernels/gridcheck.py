"""Grid-write static check for Pallas kernels (DESIGN.md §13).

The PR 5 footgun, turned into an importable assertion: a kernel whose
output block is written from more than one iteration of a PARALLEL grid
axis — or whose scratch carries state across one — is only correct on
backends that execute the grid sequentially (Mosaic).  Triton runs grid
cells concurrently, so the same structure silently corrupts
accumulators instead of failing loudly.  Every pallas_call in this
package is built through ``checked_pallas_call``, which

  1. numerically probes each output BlockSpec index map and derives the
     *revisit axes* — grid axes along which the map keeps returning the
     same block index (i.e. several grid cells write the same block);
  2. asserts revisit axes ⊆ the declared ``sequential_axes`` and that
     scratch state is only carried along declared sequential axes whose
     trailing axes are all sequential too (a carry must ride an
     innermost sequential suffix of the grid);
  3. records the verdict in ``REGISTRY`` so tests/CI can audit every
     kernel structure in one sweep;
  4. injects Mosaic ``dimension_semantics`` from the declaration —
     parallel axes are declared parallel (Mosaic may distribute them),
     sequential axes "arbitrary" (Mosaic serializes, which is what
     makes the carry legal there).

A kernel with NO revisit axes and NO scratch carry is single-writer:
every output block is written by exactly one grid cell, so the grid can
be fully parallel on any backend.  All flash kernels now satisfy this;
the SSD kernels keep their inter-chunk state carry but declare the
chunk axis sequential, which Triton serializes and Mosaic already
guarantees.

The probe evaluates index maps at integer grid coordinates (axis 0,
then 1 and n-1 per axis, others held at 0); maps here are affine or
reversed-affine in each axis, for which that detects revisits exactly.
Scratch usage itself cannot be introspected from the call signature —
``scratch_carry_axes`` is the author's declaration, and the parity
tests versus the jnp oracles are what keep the declaration honest.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

from jax.experimental import pallas as pl


class GridWriteError(AssertionError):
    """A pallas_call writes an output/scratch ref from more than one
    iteration of a parallel grid axis."""


@dataclasses.dataclass(frozen=True)
class CallRecord:
    """Audited structure of one checked pallas_call."""
    name: str
    grid: Tuple[int, ...]
    revisit_axes: Tuple[Tuple[int, ...], ...]   # per output
    sequential_axes: Tuple[int, ...]
    scratch_carry_axes: Tuple[int, ...]
    num_scratch: int

    @property
    def single_writer(self) -> bool:
        return (not self.scratch_carry_axes
                and all(not r for r in self.revisit_axes))


#: name -> most recent CallRecord, for test/CI audits.
REGISTRY: Dict[str, CallRecord] = {}


def _block_index(index_map, coords: Sequence[int]) -> Tuple[int, ...]:
    out = index_map(*coords)
    if not isinstance(out, tuple):
        out = (out,)
    return tuple(int(x) for x in out)


def revisit_axes(grid: Sequence[int], index_map) -> Tuple[int, ...]:
    """Grid axes along which ``index_map`` never moves the block index —
    i.e. every iteration of that axis targets the SAME output block."""
    ndim = len(grid)
    base = [0] * ndim
    ref = _block_index(index_map, base)
    rev = []
    for axis, n in enumerate(grid):
        if n <= 1:
            continue                       # a size-1 axis cannot revisit
        moved = False
        for val in {1, n - 1}:
            probe = list(base)
            probe[axis] = val
            if _block_index(index_map, probe) != ref:
                moved = True
                break
        if not moved:
            rev.append(axis)
    return tuple(rev)


def _normalize_specs(specs) -> Tuple[Any, ...]:
    if isinstance(specs, (list, tuple)):
        return tuple(specs)
    return (specs,)


def check_grid_writes(name: str, *, grid: Sequence[int], out_specs,
                      sequential_axes: Sequence[int] = (),
                      scratch_carry_axes: Sequence[int] = (),
                      num_scratch: int = 0) -> CallRecord:
    """Assert the single-writer/sequential-carry discipline and record
    the verdict.  Raises GridWriteError on violation."""
    grid = tuple(int(g) for g in grid)
    seq = tuple(sorted(set(int(a) for a in sequential_axes)))
    carry = tuple(sorted(set(int(a) for a in scratch_carry_axes)))
    revs = []
    for i, spec in enumerate(_normalize_specs(out_specs)):
        rev = revisit_axes(grid, spec.index_map)
        offending = [a for a in rev if a not in seq]
        if offending:
            raise GridWriteError(
                f"{name}: output {i} is written from every iteration of "
                f"grid axes {offending} (grid {grid}) but those axes are "
                f"not declared sequential ({seq}); a parallel backend "
                f"would race the writes")
        revs.append(rev)
    for a in carry:
        if a not in seq:
            raise GridWriteError(
                f"{name}: scratch carried across grid axis {a} which is "
                f"not declared sequential ({seq}); a parallel backend "
                f"would corrupt the accumulator")
        trailing = [t for t in range(a + 1, len(grid))
                    if grid[t] > 1 and t not in seq]
        if trailing:
            raise GridWriteError(
                f"{name}: scratch carried across axis {a} but later axes "
                f"{trailing} are parallel — the carry would interleave "
                f"with their iterations")
    rec = CallRecord(name=name, grid=grid, revisit_axes=tuple(revs),
                     sequential_axes=seq, scratch_carry_axes=carry,
                     num_scratch=num_scratch)
    REGISTRY[name] = rec
    return rec


def _mosaic_params(grid: Sequence[int],
                   sequential_axes: Sequence[int]) -> Dict[str, Any]:
    sems = tuple("arbitrary" if a in sequential_axes else "parallel"
                 for a in range(len(grid)))
    return dict(mosaic=dict(dimension_semantics=sems))


def checked_pallas_call(name: str, kernel, *, grid, in_specs, out_specs,
                        out_shape, scratch_shapes: Sequence[Any] = (),
                        interpret: bool = False,
                        sequential_axes: Sequence[int] = (),
                        scratch_carry_axes: Sequence[int] = ()):
    """``pl.pallas_call`` behind the grid-write check.

    Raises GridWriteError at call-construction time if any output block
    is written from an undeclared-parallel grid axis, then forwards to
    ``pl.pallas_call`` with Mosaic dimension semantics derived from the
    declaration (parallel axes distributable, sequential serialized).
    """
    check_grid_writes(name, grid=grid, out_specs=out_specs,
                      sequential_axes=sequential_axes,
                      scratch_carry_axes=scratch_carry_axes,
                      num_scratch=len(tuple(scratch_shapes)))
    kwargs: Dict[str, Any] = dict(grid=grid, in_specs=in_specs,
                                  out_specs=out_specs, out_shape=out_shape,
                                  interpret=interpret)
    scratch_shapes = tuple(scratch_shapes)
    if scratch_shapes:
        kwargs["scratch_shapes"] = list(scratch_shapes)
    if not interpret:
        # semantics are a Mosaic-side contract; the interpreter ignores
        # them and some jax versions reject the kwarg there.
        kwargs["compiler_params"] = _mosaic_params(grid, sequential_axes)
    return pl.pallas_call(kernel, **kwargs)
