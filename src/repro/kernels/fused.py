"""Fused stage epilogues — Pallas kernels + XLA-fused references.

Two fusions that sit on the per-template scan-program hot path
(DESIGN.md §13):

  * ``add_rmsnorm``: residual-add + RMSNorm as ONE kernel returning
    BOTH the new residual stream and the normed branch input — the
    ``x = x + branch; h = rms_norm(ln2, x)`` seam inside every
    transformer block, which unfused costs two extra HBM round-trips
    of the [B, S, d] activation.
  * ``qkv``: the three Q/K/V projections as ONE tiled GEMM against the
    concatenated weight (bias add in the kernel epilogue) — one MXU
    pass over x instead of three, one dispatch instead of six.

Both are single-writer parallel-grid kernels (kernels/gridcheck.py) —
the fwd AND the custom_vjp bwd — so they lower compiled wherever the
flash kernels do.  ``dw`` for the norm weight reduces across row blocks
which live on a parallel grid axis, so the kernel emits one [1, d]
partial per row block and the cross-block sum happens outside
(single-writer discipline; same shape as the SSD dA partials).

``add_rmsnorm_ref`` / ``qkv_ref`` are the XLA formulations: identical
math in one traced expression, used BOTH as the parity oracles and as
the runtime fallback wherever the Pallas structure has no compiled
lowering — an *interpreted* Pallas matmul would lose to XLA by orders
of magnitude, so interpret-mode fallback means "let XLA fuse it", not
"run the interpreter" (kernels/ops.py routes this).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.gridcheck import checked_pallas_call

DEFAULT_BLOCK_ROWS = 128
DEFAULT_BLOCK_COLS = 128


# ----------------------------------------------------------------------
# Fused residual-add + RMSNorm
# ----------------------------------------------------------------------
def _add_norm_fwd_kernel(x_ref, r_ref, w_ref, res_ref, h_ref, *,
                         eps: float):
    res = x_ref[...] + r_ref[...]                      # [bm, d], in dtype
    res32 = res.astype(jnp.float32)
    var = jnp.mean(res32 * res32, axis=-1, keepdims=True)
    n = (res32 * jax.lax.rsqrt(var + eps)).astype(res.dtype)
    res_ref[...] = res
    h_ref[...] = n * w_ref[...]


def _add_norm_bwd_kernel(res_ref, w_ref, gres_ref, gh_ref, dres_ref,
                         dw_ref, *, eps: float):
    res32 = res_ref[...].astype(jnp.float32)           # [bm, d]
    var = jnp.mean(res32 * res32, axis=-1, keepdims=True)
    rs = jax.lax.rsqrt(var + eps)
    n = (res32 * rs).astype(res_ref.dtype)             # fwd's rounded n
    gh32 = gh_ref[...].astype(jnp.float32)
    # dw partial for THIS row block (cross-block sum outside)
    dw_ref[...] = jnp.sum(gh32 * n.astype(jnp.float32), axis=0,
                          keepdims=True)
    dn = gh32 * w_ref[...].astype(jnp.float32)
    d = res32.shape[-1]
    proj = jnp.sum(dn * res32, axis=-1, keepdims=True) / (d * (var + eps))
    dres32 = rs * (dn - res32 * proj)
    dres_ref[...] = (dres32
                     + gres_ref[...].astype(jnp.float32)
                     ).astype(dres_ref.dtype)


def _row_call(name, kernel, inputs, out_cols, out_dtypes, *, block_rows,
              interpret, partial_out: bool = False):
    """Run a row-blocked (grid = row blocks) kernel over 2D inputs."""
    M, d = inputs[0].shape
    bm = min(block_rows, M)
    nm = -(-M // bm)
    pad = nm * bm - M
    padded = [jnp.pad(t, ((0, pad), (0, 0))) if t.shape[0] == M else t
              for t in inputs]
    row_spec = pl.BlockSpec((bm, d), lambda i: (i, 0))
    one_spec = pl.BlockSpec((1, d), lambda i: (0, 0))
    in_specs = [row_spec if t.shape[0] != 1 else one_spec for t in padded]
    out_specs, out_shape = [], []
    for cols, dt, is_partial in zip(out_cols, out_dtypes, partial_out):
        if is_partial:                                 # one row per block
            out_specs.append(pl.BlockSpec((1, cols), lambda i: (i, 0)))
            out_shape.append(jax.ShapeDtypeStruct((nm, cols), dt))
        else:
            out_specs.append(pl.BlockSpec((bm, cols), lambda i: (i, 0)))
            out_shape.append(jax.ShapeDtypeStruct((nm * bm, cols), dt))
    outs = checked_pallas_call(
        name, kernel, grid=(nm,), in_specs=in_specs, out_specs=out_specs,
        out_shape=out_shape, interpret=interpret)(*padded)
    return [o if p else o[:M] for o, p in zip(outs, partial_out)]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _add_rmsnorm_p(x2, r2, w2, eps: float, block_rows: int,
                   interpret: bool):
    res, h = _row_call(
        "fused_norm_fwd",
        functools.partial(_add_norm_fwd_kernel, eps=eps),
        [x2, r2, w2], [x2.shape[1]] * 2, [x2.dtype] * 2,
        block_rows=block_rows, interpret=interpret,
        partial_out=(False, False))
    return res, h


def _add_rmsnorm_p_fwd(x2, r2, w2, eps, block_rows, interpret):
    res, h = _add_rmsnorm_p(x2, r2, w2, eps, block_rows, interpret)
    return (res, h), (res, w2)


def _add_rmsnorm_p_bwd(eps, block_rows, interpret, saved, g):
    res, w2 = saved
    gres, gh = g
    d = res.shape[1]
    dres, dwp = _row_call(
        "fused_norm_bwd",
        functools.partial(_add_norm_bwd_kernel, eps=eps),
        [res, w2, gres, gh], [d, d], [res.dtype, jnp.float32],
        block_rows=block_rows, interpret=interpret,
        partial_out=(False, True))
    dw = jnp.sum(dwp, axis=0, keepdims=True).astype(w2.dtype)
    # res = x + r: both addends receive the full residual cotangent
    return dres, dres, dw


_add_rmsnorm_p.defvjp(_add_rmsnorm_p_fwd, _add_rmsnorm_p_bwd)


def add_rmsnorm(x: jax.Array, r: jax.Array, w: jax.Array, *,
                eps: float = 1e-6,
                block_rows: int = DEFAULT_BLOCK_ROWS,
                interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Fused (res, h) = (x + r, rms_norm(w, x + r)) — Pallas kernel.

    x/r: [..., d]; w: [d] already in x.dtype.  Returns both the updated
    residual stream and the normed branch input, each shaped like x.
    """
    d = x.shape[-1]
    res2, h2 = _add_rmsnorm_p(x.reshape(-1, d), r.reshape(-1, d),
                              w.reshape(1, d), float(eps),
                              int(block_rows), bool(interpret))
    return res2.reshape(x.shape), h2.reshape(x.shape)


def add_rmsnorm_ref(x: jax.Array, r: jax.Array, w: jax.Array, *,
                    eps: float = 1e-6) -> Tuple[jax.Array, jax.Array]:
    """XLA formulation — parity oracle AND the no-lowering fallback
    (identical math to models/layers.rms_norm applied to x + r)."""
    res = x + r
    res32 = res.astype(jnp.float32)
    var = jnp.mean(res32 * res32, axis=-1, keepdims=True)
    h = (res32 * jax.lax.rsqrt(var + eps)).astype(res.dtype) * w
    return res, h


# ----------------------------------------------------------------------
# Fused QKV projection (tiled single-GEMM with bias epilogue)
# ----------------------------------------------------------------------
def _matmul_kernel(x_ref, w_ref, b_ref, o_ref):
    acc = jax.lax.dot_general(x_ref[...], w_ref[...],
                              (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    acc = acc + b_ref[...].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


def _matmul_call(x2, w, b, *, block_m: int, block_n: int,
                 interpret: bool) -> jax.Array:
    M, K = x2.shape
    N = w.shape[1]
    bm = min(block_m, M)
    bn = min(block_n, N)
    nm = -(-M // bm)
    nn = -(-N // bn)
    if nm * bm - M:
        x2 = jnp.pad(x2, ((0, nm * bm - M), (0, 0)))
    if nn * bn - N:
        w = jnp.pad(w, ((0, 0), (0, nn * bn - N)))
        b = jnp.pad(b, ((0, 0), (0, nn * bn - N)))
    out = checked_pallas_call(
        "fused_qkv_matmul", _matmul_kernel,
        grid=(nm, nn),
        in_specs=[
            pl.BlockSpec((bm, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bn), lambda i, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((nm * bm, nn * bn), x2.dtype),
        interpret=interpret,
    )(x2, w, b)
    return out[:M, :N]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _matmul_p(x2, w, b, block_m: int, block_n: int, interpret: bool):
    return _matmul_call(x2, w, b, block_m=block_m, block_n=block_n,
                        interpret=interpret)


def _matmul_p_fwd(x2, w, b, block_m, block_n, interpret):
    return (_matmul_call(x2, w, b, block_m=block_m, block_n=block_n,
                         interpret=interpret), (x2, w))


def _matmul_p_bwd(block_m, block_n, interpret, saved, g):
    x2, w = saved
    zb = jnp.zeros((1, x2.shape[1]), g.dtype)
    dx = _matmul_call(g, w.T, zb, block_m=block_m, block_n=block_n,
                      interpret=interpret)
    zb2 = jnp.zeros((1, g.shape[1]), g.dtype)
    dw = _matmul_call(x2.T, g, zb2, block_m=block_m, block_n=block_n,
                      interpret=interpret).astype(w.dtype)
    db = jnp.sum(g.astype(jnp.float32), axis=0, keepdims=True)
    return dx, dw, db.astype(g.dtype)


_matmul_p.defvjp(_matmul_p_fwd, _matmul_p_bwd)


def qkv(x: jax.Array, wq: jax.Array, wk: jax.Array, wv: jax.Array,
        bq: Optional[jax.Array] = None, bk: Optional[jax.Array] = None,
        bv: Optional[jax.Array] = None, *,
        block_m: int = DEFAULT_BLOCK_ROWS,
        block_n: int = DEFAULT_BLOCK_COLS,
        interpret: bool = False
        ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused QKV: one tiled GEMM against the concatenated weight.

    x: [..., d]; wq/wk/wv: [d, cols_*].  Returns the three flat
    projections [..., cols_*] (head reshape stays with the caller).
    """
    d = x.shape[-1]
    cq, ck = wq.shape[1], wk.shape[1]
    wcat = jnp.concatenate([wq, wk, wv], axis=1).astype(x.dtype)
    if bq is not None:
        bcat = jnp.concatenate([bq, bk, bv]).astype(x.dtype).reshape(1, -1)
    else:
        bcat = jnp.zeros((1, wcat.shape[1]), x.dtype)
    y2 = _matmul_p(x.reshape(-1, d), wcat, bcat, int(block_m),
                   int(block_n), bool(interpret))
    y = y2.reshape(x.shape[:-1] + (y2.shape[-1],))
    return tuple(jnp.split(y, [cq, cq + ck], axis=-1))


def qkv_ref(x: jax.Array, wq: jax.Array, wk: jax.Array, wv: jax.Array,
            bq: Optional[jax.Array] = None,
            bk: Optional[jax.Array] = None,
            bv: Optional[jax.Array] = None
            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """XLA formulation: three dots + bias epilogues in a SINGLE traced
    expression (one program, epilogues fused) — the no-lowering
    fallback and the parity oracle versus the Pallas tiles.

    Deliberately NOT the concatenated-weight GEMM: without a tiled
    kernel to exploit the wider N, XLA:CPU runs the wide GEMM slightly
    slower than three narrow ones and pays a full weight copy for the
    concat plus three slice copies for the split.  The fallback's win
    over the unfused path is program fusion (one dispatch, fused
    epilogues), so it keeps the GEMM shapes the backend prefers."""
    outs = []
    for w, b in ((wq, bq), (wk, bk), (wv, bv)):
        y = x @ w.astype(x.dtype)
        if b is not None:
            y = y + b.astype(x.dtype)
        outs.append(y)
    return tuple(outs)
