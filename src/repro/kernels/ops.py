"""Jitted public wrappers around the Pallas kernels (DESIGN.md §11).

Backend gating: ``resolve_backend()`` is consulted at every call (not
frozen at import), and a COMPILED lowering is selected wherever one
exists for these kernel structures (``COMPILED_BACKENDS`` — Mosaic
today; see the note there for why the grid-scratch structure has no
Triton lowering yet), interpreting only where none does.  Because the
selection still happens at trace time, any cache of traced programs
must carry ``backend_signature()`` in its key (the runtime's
ProgramCache does) — otherwise a program traced under the CPU default
and reused on an accelerator mesh would silently run the Python
interpreter at device speed's expense.

Both kernels carry a ``jax.custom_vjp`` whose backward is ALSO a Pallas
kernel (kernels/flash_attention.py, kernels/ssd.py): flash-attention
uses the standard two-pass recompute-free dq/dkv structure from the
saved (out, lse) residuals; SSD replays chunks in reverse from the
saved chunk-boundary states.  The pure-jnp oracles (kernels/ref.py)
remain the parity references — ``oracle_attention_vjp`` /
``oracle_ssd_vjp`` are the OLD recompute-through-oracle backward rules,
retained for tests and the roofline benchmark's baseline.

Block sizes default to the autotuner's (backend, dtype, shape-bucket)
cache (kernels/autotune.py); explicit ``block_q``/``block_k``/``chunk``
arguments override it.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref
from repro.kernels import ssd as _ssd

#: Backends with a compiled Pallas lowering for THESE kernels.  The
#: rule is capability, not platform: interpret only where no lowering
#: exists.  Both kernels are Mosaic-structured — online state lives in
#: ``pltpu.VMEM`` scratch carried across the innermost grid axis, legal
#: because Mosaic executes the grid sequentially.  The Triton lowering
#: has no TPU memory spaces and runs grid blocks in parallel, so on GPU
#: that structure has NO lowering and would corrupt the accumulators if
#: force-lowered; GPU therefore interprets until a Triton-structured
#: variant (in-body kv/chunk fori_loop, grid without the reduction
#: axis) lands — extend this tuple alongside that variant.
COMPILED_BACKENDS = ("tpu",)


def resolve_backend() -> str:
    return jax.default_backend()


def interpret_mode(backend: Optional[str] = None) -> bool:
    """True iff the kernels must run under the Pallas interpreter."""
    return (backend or resolve_backend()) not in COMPILED_BACKENDS


def backend_signature() -> Tuple[str, bool]:
    """(backend, interpret) — REQUIRED component of any cache key over
    traced programs that may contain these kernels (the bug this fixes:
    interpret mode was baked in at trace time, so a program cached on
    the CPU default ran interpreted when reused on an accelerator)."""
    backend = resolve_backend()
    return (backend, interpret_mode(backend))


# ----------------------------------------------------------------------
# Flash attention
# ----------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, window: int, block_q: int, block_k: int,
           interpret: bool):
    return _fa.flash_attention(q, k, v, window=window, block_q=block_q,
                               block_k=block_k, interpret=interpret)


def _flash_fwd(q, k, v, window, block_q, block_k, interpret):
    out, lse = _fa.flash_attention_fwd(
        q, k, v, window=window, block_q=block_q, block_k=block_k,
        interpret=interpret)
    return out, (q, k, v, out, lse)


def _flash_bwd(window, block_q, block_k, interpret, res, g):
    q, k, v, out, lse = res
    return _fa.flash_attention_bwd(
        q, k, v, out, lse, g, window=window, block_q=block_q,
        block_k=block_k, interpret=interpret)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, window: int = 0,
                    block_q: Optional[int] = None,
                    block_k: Optional[int] = None) -> jax.Array:
    """Causal GQA attention with a Pallas forward AND backward.

    q: [B, S, H, D]; k/v: [B, S, KV, D].  Block sizes default to the
    autotuner's choice for (backend, dtype, S-bucket, D).
    """
    backend = resolve_backend()
    if block_q is None or block_k is None:
        cfg = autotune.flash_config(backend, q.dtype, q.shape[1],
                                    q.shape[3])
        block_q = block_q or cfg["block_q"]
        block_k = block_k or cfg["block_k"]
    return _flash(q, k, v, window, block_q, block_k,
                  interpret_mode(backend))


# ----------------------------------------------------------------------
# SSD (Mamba2 chunked scan)
# ----------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _ssd_p(x, dt, A, B, C, chunk: int,
           interpret: bool) -> Tuple[jax.Array, jax.Array]:
    return _ssd.ssd(x, dt, A, B, C, chunk=chunk, interpret=interpret)


def _ssd_fwd(x, dt, A, B, C, chunk, interpret):
    y, state, cstates = _ssd.ssd_fwd(x, dt, A, B, C, chunk=chunk,
                                     interpret=interpret)
    return (y, state), (x, dt, A, B, C, cstates)


def _ssd_bwd(chunk, interpret, res, g):
    x, dt, A, B, C, cstates = res
    gy, gstate = g
    return _ssd.ssd_bwd(x, dt, A, B, C, cstates, gy,
                        gstate.astype(jnp.float32), chunk=chunk,
                        interpret=interpret)


_ssd_p.defvjp(_ssd_fwd, _ssd_bwd)


def ssd(x, dt, A, B, C,
        chunk: Optional[int] = None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD with a Pallas forward AND backward.

    x: [b,S,H,P]; dt: [b,S,H]; A: [H]; B/C: [b,S,H,N].  Returns
    (y, final_state).  ``chunk`` defaults to the autotuner's choice.
    """
    backend = resolve_backend()
    if chunk is None:
        chunk = autotune.ssd_config(backend, x.dtype, x.shape[1],
                                    x.shape[3], B.shape[-1])["chunk"]
    return _ssd_p(x, dt, A, B, C, chunk, interpret_mode(backend))


# ----------------------------------------------------------------------
# Retained oracle backward rules (parity references + bench baselines)
# ----------------------------------------------------------------------
def oracle_attention_vjp(q, k, v, g, window: int = 0):
    """The pre-§11 backward: recompute the forward through the pure-jnp
    oracle and backprop through it (O(S²) score materialization)."""
    _, vjp = jax.vjp(
        lambda q, k, v: _ref.attention_ref(q, k, v, window=window), q, k, v)
    return vjp(g)


def oracle_ssd_vjp(x, dt, A, B, C, g):
    """The pre-§11 backward: recompute through the per-timestep scan
    oracle and backprop through it (S sequential steps)."""
    _, vjp = jax.vjp(lambda *a: _ref.ssd_ref(*a), x, dt, A, B, C)
    return vjp(g)
