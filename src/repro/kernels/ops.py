"""Jitted public wrappers around the Pallas kernels.

``interpret`` auto-selects: on the CPU container the kernels execute via
the Pallas interpreter (Python semantics, exact same kernel body); on TPU
they compile to Mosaic.  Both kernels get a ``jax.custom_vjp`` whose
backward recomputes through the pure-jnp oracle — flash-attention
backward-via-recompute is standard practice under activation
checkpointing, and it keeps the kernel surface auditable.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import ref as _ref
from repro.kernels import ssd as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ----------------------------------------------------------------------
# Flash attention
# ----------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def flash_attention(q, k, v, window: int = 0, block_q: int = 128,
                    block_k: int = 128):
    return _fa.flash_attention(q, k, v, window=window, block_q=block_q,
                               block_k=block_k, interpret=_interpret())


def _fa_fwd(q, k, v, window, block_q, block_k):
    out = flash_attention(q, k, v, window, block_q, block_k)
    return out, (q, k, v)


def _fa_bwd(window, block_q, block_k, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: _ref.attention_ref(q, k, v,
                                                        window=window),
                     q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ----------------------------------------------------------------------
# SSD (Mamba2 chunked scan)
# ----------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def ssd(x, dt, A, B, C, chunk: int = 128) -> Tuple[jax.Array, jax.Array]:
    return _ssd.ssd(x, dt, A, B, C, chunk=chunk, interpret=_interpret())


def _ssd_fwd(x, dt, A, B, C, chunk):
    out = ssd(x, dt, A, B, C, chunk)
    return out, (x, dt, A, B, C)


def _ssd_bwd(chunk, res, g):
    x, dt, A, B, C = res
    _, vjp = jax.vjp(lambda *a: _ref.ssd_ref(*a), x, dt, A, B, C)
    return vjp(g)


ssd.defvjp(_ssd_fwd, _ssd_bwd)
