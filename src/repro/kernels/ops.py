"""Jitted public wrappers around the Pallas kernels (DESIGN.md §11, §13).

Backend gating is a measured LOWERING PROBE, not a platform list: for
each kernel structure (``KERNEL_KINDS``) the first query on the live
backend try-compiles a small representative instance and caches the
verdict one-shot per (kind, backend); kernels whose structure fails to
lower fall back to interpret (or the XLA-fused formulation, for the
fused epilogues) PER KERNEL, not per platform.  For backends that are
not the process default (nothing to compile against), a static
capability table answers: the restructured single-writer kernels lower
on Mosaic and Triton; the SSD carry still rides ``pltpu.VMEM`` scratch,
which Triton has no lowering for, so GPU interprets the SSD pair only.
PR 5's ``COMPILED_BACKENDS = ("tpu",)`` — which forced GPU to interpret
EVERYTHING because the old grid-scratch structure would be corrupted by
Triton's parallel grid — is gone; the restructure (flash_attention.py,
ssd.py, gridcheck.py) is what made the probe meaningful.

Because lowering is resolved at trace time, it is part of program
identity: any cache of traced programs must carry
``backend_signature()`` — now (backend, process topology, per-kind
lowering plan) — in its key (the runtime's ProgramCache does; see
runtime/executor.py).
Otherwise a program traced under the CPU default and reused on an
accelerator mesh would silently run the Python interpreter at device
speed's expense.

Both kernels carry a ``jax.custom_vjp`` whose backward is ALSO a Pallas
kernel (kernels/flash_attention.py, kernels/ssd.py), with separate
fwd/bwd interpret flags so e.g. a backend that lowers the forward but
not the backward still compiles half the pair.  The pure-jnp oracles
(kernels/ref.py) remain the parity references — ``oracle_attention_vjp``
/ ``oracle_ssd_vjp`` are the OLD recompute-through-oracle backward
rules, retained for tests and the roofline benchmark's baseline.

Block sizes default to the autotuner's (backend, dtype, shape-bucket)
cache (kernels/autotune.py); explicit ``block_q``/``block_k``/``chunk``
arguments override it.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels import flash_attention as _fa
from repro.kernels import fused as _fused
from repro.kernels import ref as _ref
from repro.kernels import ssd as _ssd

#: Kernel structures the probe resolves independently.
KERNEL_KINDS = ("flash_fwd", "flash_bwd", "ssd_fwd", "ssd_bwd",
                "fused_norm", "fused_qkv")

#: Capability table for backends that are NOT the process default —
#: there is nothing to try-compile against, so this is the structural
#: answer: single-writer parallel-grid kernels (flash fwd/bwd, both
#: fused epilogues) lower on Mosaic and Triton alike; the SSD pair
#: still carries dstate in pltpu.VMEM scratch along the sequential
#: chunk axis, which has no Triton lowering yet.
_STATIC_LOWERING: Dict[str, Dict[str, bool]] = {
    "tpu": {k: True for k in KERNEL_KINDS},
    "gpu": {k: not k.startswith("ssd") for k in KERNEL_KINDS},
    "cuda": {k: not k.startswith("ssd") for k in KERNEL_KINDS},
    "rocm": {k: not k.startswith("ssd") for k in KERNEL_KINDS},
    "cpu": {k: False for k in KERNEL_KINDS},
}

_LOWERING_CACHE: Dict[Tuple[str, str], bool] = {}


def resolve_backend() -> str:
    return jax.default_backend()


def _probe_flash_fwd():
    q = jnp.zeros((1, 128, 2, 64), jnp.float32)
    k = jnp.zeros((1, 128, 1, 64), jnp.float32)
    _fa.flash_attention.lower(q, k, k, window=0, block_q=128, block_k=128,
                              interpret=False).compile()


def _probe_flash_bwd():
    q = jnp.zeros((1, 128, 2, 64), jnp.float32)
    k = jnp.zeros((1, 128, 1, 64), jnp.float32)
    lse = jnp.zeros((1, 2, 128), jnp.float32)
    _fa.flash_attention_bwd.lower(q, k, k, q, lse, q, window=0,
                                  block_q=128, block_k=128,
                                  interpret=False).compile()


def _probe_ssd_fwd():
    x = jnp.zeros((1, 128, 1, 64), jnp.float32)
    dt = jnp.zeros((1, 128, 1), jnp.float32)
    A = jnp.zeros((1,), jnp.float32)
    B = jnp.zeros((1, 128, 1, 16), jnp.float32)
    _ssd.ssd_fwd.lower(x, dt, A, B, B, chunk=128,
                       interpret=False).compile()


def _probe_ssd_bwd():
    x = jnp.zeros((1, 128, 1, 64), jnp.float32)
    dt = jnp.zeros((1, 128, 1), jnp.float32)
    A = jnp.zeros((1,), jnp.float32)
    B = jnp.zeros((1, 128, 1, 16), jnp.float32)
    cst = jnp.zeros((1, 1, 1, 64, 16), jnp.float32)
    gst = jnp.zeros((1, 1, 64, 16), jnp.float32)
    _ssd.ssd_bwd.lower(x, dt, A, B, B, cst, x, gst, chunk=128,
                       interpret=False).compile()


def _probe_fused_norm():
    x = jnp.zeros((128, 64), jnp.float32)
    w = jnp.zeros((64,), jnp.float32)

    def f(x, r, w):
        res, h = _fused.add_rmsnorm(x, r, w, block_rows=128,
                                    interpret=False)
        return jnp.sum(res) + jnp.sum(h)

    jax.jit(jax.grad(f, argnums=(0, 1, 2))).lower(x, x, w).compile()


def _probe_fused_qkv():
    x = jnp.zeros((128, 64), jnp.float32)
    w = jnp.zeros((64, 128), jnp.float32)

    def f(x, wq, wk, wv):
        q, k, v = _fused.qkv(x, wq, wk, wv, block_m=128, block_n=128,
                             interpret=False)
        return jnp.sum(q) + jnp.sum(k) + jnp.sum(v)

    jax.jit(jax.grad(f, argnums=(0, 1, 2, 3))).lower(x, w, w, w).compile()


_PROBES = {
    "flash_fwd": _probe_flash_fwd,
    "flash_bwd": _probe_flash_bwd,
    "ssd_fwd": _probe_ssd_fwd,
    "ssd_bwd": _probe_ssd_bwd,
    "fused_norm": _probe_fused_norm,
    "fused_qkv": _probe_fused_qkv,
}


def kernel_lowers(kind: str, backend: Optional[str] = None) -> bool:
    """One-shot cached lowering probe: True iff ``kind``'s structure
    compiles on ``backend``.  The live (default) backend is answered by
    an actual try-compile of a representative instance; other backends
    by the static capability table."""
    if kind not in KERNEL_KINDS:
        raise ValueError(f"unknown kernel kind {kind!r}")
    backend = backend or resolve_backend()
    key = (kind, backend)
    if key not in _LOWERING_CACHE:
        if backend == jax.default_backend():
            try:
                _PROBES[kind]()
                _LOWERING_CACHE[key] = True
            except Exception:
                _LOWERING_CACHE[key] = False
        else:
            table = _STATIC_LOWERING.get(backend, {})
            _LOWERING_CACHE[key] = table.get(kind, False)
    return _LOWERING_CACHE[key]


def _reset_lowering_cache() -> None:
    """Test hook: forget probe verdicts (e.g. after monkeypatching)."""
    _LOWERING_CACHE.clear()


def lowering_plan(backend: Optional[str] = None
                  ) -> Tuple[Tuple[str, bool], ...]:
    """Per-kind lowering verdicts, in KERNEL_KINDS order (hashable)."""
    backend = backend or resolve_backend()
    return tuple((k, kernel_lowers(k, backend)) for k in KERNEL_KINDS)


def interpret_mode(backend: Optional[str] = None) -> bool:
    """True iff ANY kernel structure must run under the Pallas
    interpreter on ``backend`` (the conservative aggregate; per-kernel
    callers should ask ``kernel_lowers`` directly)."""
    backend = backend or resolve_backend()
    return any(not lowered for _, lowered in lowering_plan(backend))


def process_topology() -> Tuple[int, int, Tuple[int, ...]]:
    """(process_count, process_index, local device ids) — the process
    placement a program was traced under.  Worker launchers
    (runtime/multihost.py) pin it via ``REPRO_PROC_COUNT`` /
    ``REPRO_PROC_INDEX`` before jax initializes; otherwise it reflects
    ``jax.process_count()`` (1 on a single-controller run)."""
    import os
    count = os.environ.get("REPRO_PROC_COUNT")
    index = os.environ.get("REPRO_PROC_INDEX")
    if count is not None:
        return (int(count), int(index or 0),
                tuple(d.id for d in jax.local_devices()))
    return (jax.process_count(), jax.process_index(),
            tuple(d.id for d in jax.local_devices()))


def backend_signature() -> Tuple:
    """(backend, process topology, per-kind lowering plan) — REQUIRED
    component of any cache key over traced programs that may contain
    these kernels (the bug this fixes: lowering is resolved at trace
    time, so a program cached on the CPU default would run interpreted
    when reused on an accelerator mesh — and, since the probe is per
    kernel, two backends may compile different SUBSETS of the kinds).
    The topology component keeps single-process and multi-process
    compilations of the SAME template from ever colliding in a shared
    cache: a program traced for one process's local device set is not
    interchangeable with one traced for another (ISSUE 10 satellite)."""
    backend = resolve_backend()
    return (backend, process_topology(), lowering_plan(backend))


# ----------------------------------------------------------------------
# Flash attention
# ----------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash(q, k, v, window: int, block_q: int, block_k: int,
           interpret_fwd: bool, interpret_bwd: bool):
    return _fa.flash_attention(q, k, v, window=window, block_q=block_q,
                               block_k=block_k, interpret=interpret_fwd)


def _flash_fwd(q, k, v, window, block_q, block_k, interpret_fwd,
               interpret_bwd):
    out, lse = _fa.flash_attention_fwd(
        q, k, v, window=window, block_q=block_q, block_k=block_k,
        interpret=interpret_fwd)
    return out, (q, k, v, out, lse)


def _flash_bwd(window, block_q, block_k, interpret_fwd, interpret_bwd,
               res, g):
    q, k, v, out, lse = res
    return _fa.flash_attention_bwd(
        q, k, v, out, lse, g, window=window, block_q=block_q,
        block_k=block_k, interpret=interpret_bwd)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, window: int = 0,
                    block_q: Optional[int] = None,
                    block_k: Optional[int] = None) -> jax.Array:
    """Causal GQA attention with a Pallas forward AND backward.

    q: [B, S, H, D]; k/v: [B, S, KV, D].  Block sizes default to the
    autotuner's choice for (backend, dtype, S-bucket, D).
    """
    backend = resolve_backend()
    if block_q is None or block_k is None:
        cfg = autotune.flash_config(backend, q.dtype, q.shape[1],
                                    q.shape[3])
        block_q = block_q or cfg["block_q"]
        block_k = block_k or cfg["block_k"]
    return _flash(q, k, v, window, block_q, block_k,
                  not kernel_lowers("flash_fwd", backend),
                  not kernel_lowers("flash_bwd", backend))


# ----------------------------------------------------------------------
# SSD (Mamba2 chunked scan)
# ----------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _ssd_p(x, dt, A, B, C, chunk: int, interpret_fwd: bool,
           interpret_bwd: bool) -> Tuple[jax.Array, jax.Array]:
    return _ssd.ssd(x, dt, A, B, C, chunk=chunk, interpret=interpret_fwd)


def _ssd_fwd(x, dt, A, B, C, chunk, interpret_fwd, interpret_bwd):
    y, state, cstates = _ssd.ssd_fwd(x, dt, A, B, C, chunk=chunk,
                                     interpret=interpret_fwd)
    return (y, state), (x, dt, A, B, C, cstates)


def _ssd_bwd(chunk, interpret_fwd, interpret_bwd, res, g):
    x, dt, A, B, C, cstates = res
    gy, gstate = g
    return _ssd.ssd_bwd(x, dt, A, B, C, cstates, gy,
                        gstate.astype(jnp.float32), chunk=chunk,
                        interpret=interpret_bwd)


_ssd_p.defvjp(_ssd_fwd, _ssd_bwd)


def ssd(x, dt, A, B, C,
        chunk: Optional[int] = None) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD with a Pallas forward AND backward.

    x: [b,S,H,P]; dt: [b,S,H]; A: [H]; B/C: [b,S,H,N].  Returns
    (y, final_state).  ``chunk`` defaults to the autotuner's choice.
    """
    backend = resolve_backend()
    if chunk is None:
        chunk = autotune.ssd_config(backend, x.dtype, x.shape[1],
                                    x.shape[3], B.shape[-1])["chunk"]
    return _ssd_p(x, dt, A, B, C, chunk,
                  not kernel_lowers("ssd_fwd", backend),
                  not kernel_lowers("ssd_bwd", backend))


# ----------------------------------------------------------------------
# Fused stage epilogues (kernels/fused.py)
# ----------------------------------------------------------------------
def fused_add_rmsnorm(x, r, w, eps: float = 1e-6
                      ) -> Tuple[jax.Array, jax.Array]:
    """Fused (res, h) = (x + r, rms_norm(w, x + r)).

    Routed like the attention/SSD kernels: the Pallas kernel where the
    structure lowers compiled, otherwise the single-expression XLA
    formulation (an INTERPRETED Pallas elementwise kernel would lose to
    XLA's own fusion, so the fallback is XLA-level fusion, not the
    interpreter).  ``w`` must already be in x.dtype.
    """
    backend = resolve_backend()
    if kernel_lowers("fused_norm", backend):
        rows = x.size // x.shape[-1]
        cfg = autotune.fused_config(backend, x.dtype, rows, x.shape[-1])
        return _fused.add_rmsnorm(x, r, w, eps=eps,
                                  block_rows=cfg["block_rows"],
                                  interpret=False)
    return _fused.add_rmsnorm_ref(x, r, w, eps=eps)


def fused_qkv(x, wq, wk, wv, bq=None, bk=None, bv=None
              ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Fused QKV projection, one program either way: Pallas tiles over
    the concatenated weight (one wide GEMM + bias epilogue) where the
    structure lowers compiled; a single XLA program of three dots with
    fused bias epilogues otherwise (XLA:CPU prefers the narrow GEMM
    shapes — see fused.qkv_ref).  Both eliminate the per-op dispatches
    and intermediate materialization of the unfused path."""
    backend = resolve_backend()
    if kernel_lowers("fused_qkv", backend):
        rows = x.size // x.shape[-1]
        cols = wq.shape[1] + wk.shape[1] + wv.shape[1]
        cfg = autotune.fused_config(backend, x.dtype, rows, cols)
        return _fused.qkv(x, wq, wk, wv, bq, bk, bv,
                          block_m=cfg["block_rows"],
                          block_n=cfg["block_cols"], interpret=False)
    return _fused.qkv_ref(x, wq, wk, wv, bq, bk, bv)


# ----------------------------------------------------------------------
# Retained oracle backward rules (parity references + bench baselines)
# ----------------------------------------------------------------------
def oracle_attention_vjp(q, k, v, g, window: int = 0):
    """The pre-§11 backward: recompute the forward through the pure-jnp
    oracle and backprop through it (O(S²) score materialization)."""
    _, vjp = jax.vjp(
        lambda q, k, v: _ref.attention_ref(q, k, v, window=window), q, k, v)
    return vjp(g)


def oracle_ssd_vjp(x, dt, A, B, C, g):
    """The pre-§11 backward: recompute through the per-timestep scan
    oracle and backprop through it (S sequential steps)."""
    _, vjp = jax.vjp(lambda *a: _ref.ssd_ref(*a), x, dt, A, B, C)
    return vjp(g)
