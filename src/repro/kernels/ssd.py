"""Mamba2 SSD chunked scan — Pallas TPU kernel.

TPU-native structure: the grid is (batch, heads, chunks).  Mosaic runs
the grid sequentially with the LAST axis innermost, so the inter-chunk
SSM state lives in VMEM scratch ([P, N] fp32) and flows across the chunk
iterations of one (b, h) pair — the sequential recurrence costs no HBM
round-trips (the GPU version writes chunk states to HBM and runs a
separate scan kernel; on TPU the sequential-grid guarantee makes that
unnecessary — see DESIGN.md hardware-adaptation notes).

Per chunk the kernel computes, entirely in VMEM:
    cum      = cumsum(dt * A)                       [Q,1]
    y_intra  = ((C B^T) ∘ decay ∘ dt) x             [Q,P]  (masked lower-tri)
    y_inter  = (C ∘ exp(cum)) state^T               [Q,P]
    state   <- state * exp(cum_Q) + (x ∘ w_last)^T B [P,N]

Block shapes: Q = chunk length (default 128 — MXU-aligned), P = head dim,
N = SSM state size.  The working set Q*Q + Q*(P+2N) fp32 stays well under
VMEM for every assigned config (mamba2: P=64, N=128; hymba: P=64, N=16).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref,
                state_scratch, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scratch[...] = jnp.zeros_like(state_scratch)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0].astype(jnp.float32)                 # [Q, 1]
    A = a_ref[0, 0]                                    # scalar (negative)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)         # [Q, N]
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)         # [Q, N]

    a = dt * A                                         # [Q, 1]
    cum = jnp.cumsum(a, axis=0)                        # [Q, 1]

    # intra-chunk: W[i,j] = exp(cum_i - cum_j) * (C_i . B_j) * dt_j, j <= i
    decay = jnp.exp(cum - cum.reshape(1, chunk))       # [Q, Q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = ii >= jj
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # [Q, Q]
    w = jnp.where(tri, cb * decay, 0.0) * dt.reshape(1, chunk)
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())))     # [Q, P]

    # inter-chunk: y += (C * exp(cum)) @ state^T
    state = state_scratch[...]                         # [P, N]
    c_scaled = Cm * jnp.exp(cum)                       # [Q, N]
    y = y + jax.lax.dot_general(c_scaled, state,
                                (((1,), (1,)), ((), ())))        # [Q, P]
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update: state * exp(cum_Q) + (x ∘ w_last)^T @ B
    cum_last = cum[chunk - 1]                          # [1]
    w_last = jnp.exp(cum_last.reshape(1, 1) - cum) * dt           # [Q, 1]
    xw = x * w_last                                    # [Q, P]
    new_state = (state * jnp.exp(cum_last)[0]
                 + jax.lax.dot_general(xw, Bm, (((0,), (0,)), ((), ()))))
    state_scratch[...] = new_state
    state_ref[0, 0] = new_state


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
        C: jax.Array, *, chunk: int = DEFAULT_CHUNK,
        interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.  x: [b,S,H,P]; dt: [b,S,H]; A: [H]; B/C: [b,S,H,N].

    Returns (y [b,S,H,P], final_state [b,H,P,N] fp32).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, max(S, 8))
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
    S_p = S + pad
    nc = S_p // chunk
    a2 = A.reshape(H, 1)

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, state = pl.pallas_call(
        kernel,
        grid=(b, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda i, h, c: (i, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, h, c: (i, c, h)),
            pl.BlockSpec((1, 1), lambda i, h, c: (h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda i, h, c: (i, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda i, h, c: (i, c, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda i, h, c: (i, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda i, h, c: (i, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, S_p, H, P), x.dtype),
            jax.ShapeDtypeStruct((b, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, a2, B, C)
    return y[:, :S], state
