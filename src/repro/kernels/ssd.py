"""Mamba2 SSD chunked scan — Pallas kernels (forward AND backward) with
an explicitly SEQUENTIAL chunk axis.

Grid (batch, heads, chunks), built through ``checked_pallas_call``
(kernels/gridcheck.py) with the chunk axis declared sequential and the
inter-chunk SSM state carried in scratch along it.  On Mosaic the grid
is executed sequentially anyway (the declaration maps to
``dimension_semantics=("parallel", "parallel", "arbitrary")`` so batch
and heads may still be distributed); on Triton a sequential
("arbitrary") innermost axis is serialized, which is what makes the
[P, N] fp32 scratch carry legal there too — the recurrence costs no HBM
round-trips on either backend (the classic GPU alternative writes chunk
states to HBM and runs a separate scan kernel; see DESIGN.md §13).

Per chunk the kernel computes, entirely in VMEM:
    cum      = cumsum(dt * A)                       [Q,1]
    y_intra  = ((C B^T) ∘ decay ∘ dt) x             [Q,P]  (masked lower-tri)
    y_inter  = (C ∘ exp(cum)) state^T               [Q,P]
    state   <- state * exp(cum_Q) + (x ∘ w_last)^T B [P,N]

Block shapes: Q = chunk length (default 128 — MXU-aligned), P = head dim,
N = SSM state size.  The working set Q*Q + Q*(P+2N) fp32 stays well under
VMEM for every assigned config (mamba2: P=64, N=128; hymba: P=64, N=16).

The backward mirrors the recurrence in REVERSE chunk order (index maps
c -> nc-1-c), carrying the state cotangent dS in the same scratch slot
the forward carries the state in — the ONLY cross-iteration state.  The
scalar dA reduction that PR 5 accumulated in scratch and wrote once at
the last chunk is now a per-chunk partial output ([b, H, nc], one block
per grid cell — single-writer) summed outside: the kernel has no
finalize step and no write that depends on grid position.  It is
recompute-free in the flash-attention sense: the forward saves only the
[P, N] state at each chunk BOUNDARY (``ssd_fwd``'s third output, S/Q of
them) and every intra-chunk quantity (cum, decay, W) is rebuilt
blockwise in VMEM — never the O(S·Q) full set.  All decay-product terms
mask with ``jnp.where(tri, ..., 0)`` AFTER the multiply: above-diagonal
decays can overflow to inf and 0*inf would poison the block with NaNs.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.gridcheck import checked_pallas_call

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref,
                *rest, chunk: int):
    # the fwd-for-bwd variant adds a cstates output (the state ENTERING
    # each chunk); the plain forward pays nothing for it
    if len(rest) == 2:
        cstates_ref, state_scratch = rest
    else:
        cstates_ref, (state_scratch,) = None, rest
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scratch[...] = jnp.zeros_like(state_scratch)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0].astype(jnp.float32)                 # [Q, 1]
    A = a_ref[0, 0]                                    # scalar (negative)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)         # [Q, N]
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)         # [Q, N]

    a = dt * A                                         # [Q, 1]
    cum = jnp.cumsum(a, axis=0)                        # [Q, 1]

    # intra-chunk: W[i,j] = exp(cum_i - cum_j) * (C_i . B_j) * dt_j, j <= i
    decay = jnp.exp(cum - cum.reshape(1, chunk))       # [Q, Q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = ii >= jj
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # [Q, Q]
    w = jnp.where(tri, cb * decay, 0.0) * dt.reshape(1, chunk)
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())))     # [Q, P]

    # inter-chunk: y += (C * exp(cum)) @ state^T
    state = state_scratch[...]                         # [P, N]
    if cstates_ref is not None:
        cstates_ref[0, 0, 0] = state                   # bwd residual
    c_scaled = Cm * jnp.exp(cum)                       # [Q, N]
    y = y + jax.lax.dot_general(c_scaled, state,
                                (((1,), (1,)), ((), ())))        # [Q, P]
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update: state * exp(cum_Q) + (x ∘ w_last)^T @ B
    cum_last = cum[chunk - 1]                          # [1]
    w_last = jnp.exp(cum_last.reshape(1, 1) - cum) * dt           # [Q, 1]
    xw = x * w_last                                    # [Q, P]
    new_state = (state * jnp.exp(cum_last)[0]
                 + jax.lax.dot_general(xw, Bm, (((0,), (0,)), ((), ()))))
    state_scratch[...] = new_state
    state_ref[0, 0] = new_state


def _pad_seq(t: jax.Array, pad: int) -> jax.Array:
    return jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))


def _ssd_call(x, dt, A, B, C, *, chunk: int, interpret: bool,
              with_cstates: bool):
    b, S, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, max(S, 8))
    pad = (-S) % chunk
    if pad:
        x, dt, B, C = (_pad_seq(t, pad) for t in (x, dt, B, C))
    S_p = S + pad
    nc = S_p // chunk
    a2 = A.reshape(H, 1)

    out_specs = [
        pl.BlockSpec((1, chunk, 1, P), lambda i, h, c: (i, c, h, 0)),
        # final state: every chunk writes the same block — legal only
        # because axis 2 is declared sequential (last write wins)
        pl.BlockSpec((1, 1, P, N), lambda i, h, c: (i, h, 0, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((b, S_p, H, P), x.dtype),
        jax.ShapeDtypeStruct((b, H, P, N), jnp.float32),
    ]
    if with_cstates:
        out_specs.append(
            pl.BlockSpec((1, 1, 1, P, N), lambda i, h, c: (i, h, c, 0, 0)))
        out_shape.append(
            jax.ShapeDtypeStruct((b, H, nc, P, N), jnp.float32))

    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    outs = checked_pallas_call(
        "ssd_fwd", kernel,
        grid=(b, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda i, h, c: (i, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda i, h, c: (i, c, h)),
            pl.BlockSpec((1, 1), lambda i, h, c: (h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda i, h, c: (i, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda i, h, c: (i, c, h, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
        sequential_axes=(2,),
        scratch_carry_axes=(2,),
    )(x, dt, a2, B, C)
    if with_cstates:
        y, state, cstates = outs
        return y[:, :S], state, cstates
    y, state = outs
    return y[:, :S], state, None


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
        C: jax.Array, *, chunk: int = DEFAULT_CHUNK,
        interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD.  x: [b,S,H,P]; dt: [b,S,H]; A: [H]; B/C: [b,S,H,N].

    Returns (y [b,S,H,P], final_state [b,H,P,N] fp32).
    """
    y, state, _ = _ssd_call(x, dt, A, B, C, chunk=chunk,
                            interpret=interpret, with_cstates=False)
    return y, state


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_fwd(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
            C: jax.Array, *, chunk: int = DEFAULT_CHUNK,
            interpret: bool = False
            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Forward that also returns the chunk-boundary states
    (``cstates [b, H, nc, P, N]`` fp32, the state ENTERING each chunk) —
    the only residual the backward kernel needs beyond the inputs."""
    return _ssd_call(x, dt, A, B, C, chunk=chunk, interpret=interpret,
                     with_cstates=True)


# ----------------------------------------------------------------------
# Backward kernel (reverse chunk order, sequential dstate carry)
# ----------------------------------------------------------------------
def _ssd_bwd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, s0_ref, gy_ref,
                    gstate_ref, dx_ref, ddt_ref, db_ref, dc_ref, da_ref,
                    dstate_scratch, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        dstate_scratch[...] = gstate_ref[0, 0]

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0].astype(jnp.float32)                 # [Q, 1]
    A = a_ref[0, 0]                                    # scalar
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)         # [Q, N]
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)         # [Q, N]
    S0 = s0_ref[0, 0, 0]                               # [P, N]
    G = gy_ref[0, :, 0, :].astype(jnp.float32)         # [Q, P]
    dS1 = dstate_scratch[...]                          # [P, N]

    a = dt * A
    cum = jnp.cumsum(a, axis=0)                        # [Q, 1]
    dt_row = dt.reshape(1, chunk)                      # [1, Q]
    decay = jnp.exp(cum - cum.reshape(1, chunk))       # [Q, Q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = ii >= jj
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())))  # [Q, Q]
    W = jnp.where(tri, cb * decay, 0.0) * dt_row       # [Q, Q]
    ecum = jnp.exp(cum)                                # [Q, 1]
    Cs = Cm * ecum                                     # [Q, N]
    cum_last = cum[chunk - 1]                          # [1]
    eQ = jnp.exp(cum_last)[0]                          # scalar
    w_last = jnp.exp(cum_last.reshape(1, 1) - cum) * dt           # [Q, 1]

    # --- y_intra = W x ------------------------------------------------
    dW = jax.lax.dot_general(G, x, (((1,), (1,)), ((), ())))      # [Q, Q]
    # --- S1 = S0 * eQ + (x ∘ w_last)^T B ------------------------------
    BH = jax.lax.dot_general(Bm, dS1, (((1,), (1,)), ((), ())))   # [Q, P]
    dx = (jax.lax.dot_general(W, G, (((0,), (0,)), ((), ())))     # W^T G
          + BH * w_last)
    dx_ref[0, :, 0, :] = dx.astype(dx_ref.dtype)

    # d(cb) = tri * dW * decay * dt_j  (mask AFTER multiply: above-diag
    # decay can be inf; 0 * inf = NaN)
    dcb = jnp.where(tri, dW * decay, 0.0) * dt_row                # [Q, Q]
    GS0 = jax.lax.dot_general(G, S0, (((1,), (0,)), ((), ())))    # [Q, N]
    xdS1 = jax.lax.dot_general(x, dS1, (((1,), (0,)), ((), ())))  # [Q, N]
    dC = (jax.lax.dot_general(dcb, Bm, (((1,), (0,)), ((), ())))
          + GS0 * ecum)
    dB = (jax.lax.dot_general(dcb, Cm, (((0,), (0,)), ((), ())))
          + xdS1 * w_last)
    dc_ref[0, :, 0, :] = dC.astype(dc_ref.dtype)
    db_ref[0, :, 0, :] = dB.astype(db_ref.dtype)

    # --- cum cotangent ------------------------------------------------
    TW = dW * W                                        # [Q, Q], tri via W
    dcum = (jnp.sum(TW, axis=1, keepdims=True)         # decay's +cum_i
            - jnp.sum(TW, axis=0).reshape(chunk, 1)    # decay's -cum_j
            + jnp.sum(GS0 * Cs, axis=1, keepdims=True))  # y_inter's e^cum
    dw = jnp.sum(xdS1 * Bm, axis=1, keepdims=True)     # [Q, 1] d(w_last)
    V = dw * w_last
    dcum = dcum - V                                    # w_last's -cum_j
    # cum_{Q-1} terms: S1's e^{cum_Q} and w_last's +cum_Q
    last = (jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
            == chunk - 1)
    dcum = dcum + jnp.where(
        last, jnp.sum(dS1 * S0) * eQ + jnp.sum(V), 0.0)

    # --- dt cotangent -------------------------------------------------
    ddt = (jnp.sum(jnp.where(tri, dW * decay, 0.0) * cb,
                   axis=0).reshape(chunk, 1)           # W's dt_j factor
           + dw * jnp.exp(cum_last.reshape(1, 1) - cum))  # w_last's dt
    # cumsum backward: da_i = sum_{i' >= i} dcum_{i'}
    da = (jnp.sum(dcum, axis=0, keepdims=True)
          - jnp.cumsum(dcum, axis=0) + dcum)
    ddt = ddt + da * A
    ddt_ref[0] = ddt.astype(ddt_ref.dtype)
    # dA partial for THIS chunk — one [1,1,1] block per grid cell
    # (single-writer; the cross-chunk/batch sum happens outside)
    da_ref[0, 0, 0] = jnp.sum(da * dt)

    # --- state cotangent for the PRECEDING chunk ----------------------
    dstate_scratch[...] = (eQ * dS1
                           + jax.lax.dot_general(G, Cs,
                                                 (((0,), (0,)), ((), ()))))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_bwd(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
            C: jax.Array, cstates: jax.Array, gy: jax.Array,
            gstate: jax.Array, *, chunk: int = DEFAULT_CHUNK,
            interpret: bool = False):
    """Reverse-chunk SSD backward.

    Inputs are the forward primals, the saved chunk-boundary states and
    the cotangents (gy for y, gstate for the final state).  Returns
    (dx, ddt, dA, dB, dC) with the primals' layouts and dtypes.
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    chunk = min(chunk, max(S, 8))
    pad = (-S) % chunk
    if pad:
        x, dt, B, C, gy = (_pad_seq(t, pad) for t in (x, dt, B, C, gy))
    S_p = S + pad
    nc = S_p // chunk
    a2 = A.reshape(H, 1)

    seq_p = lambda i, h, c: (i, nc - 1 - c, h, 0)      # reversed chunks
    seq_p3 = lambda i, h, c: (i, nc - 1 - c, h)
    kernel = functools.partial(_ssd_bwd_kernel, chunk=chunk)
    dx, ddt, dB, dC, dA3 = checked_pallas_call(
        "ssd_bwd", kernel,
        grid=(b, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), seq_p),
            pl.BlockSpec((1, chunk, 1), seq_p3),
            pl.BlockSpec((1, 1), lambda i, h, c: (h, 0)),
            pl.BlockSpec((1, chunk, 1, N), seq_p),
            pl.BlockSpec((1, chunk, 1, N), seq_p),
            pl.BlockSpec((1, 1, 1, P, N),
                         lambda i, h, c: (i, h, nc - 1 - c, 0, 0)),
            pl.BlockSpec((1, chunk, 1, P), seq_p),
            pl.BlockSpec((1, 1, P, N), lambda i, h, c: (i, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), seq_p),
            pl.BlockSpec((1, chunk, 1), seq_p3),
            pl.BlockSpec((1, chunk, 1, N), seq_p),
            pl.BlockSpec((1, chunk, 1, N), seq_p),
            pl.BlockSpec((1, 1, 1), lambda i, h, c: (i, h, nc - 1 - c)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, S_p, H, P), x.dtype),
            jax.ShapeDtypeStruct((b, S_p, H), dt.dtype),
            jax.ShapeDtypeStruct((b, S_p, H, N), B.dtype),
            jax.ShapeDtypeStruct((b, S_p, H, N), C.dtype),
            jax.ShapeDtypeStruct((b, H, nc), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((P, N), jnp.float32),           # dstate carry
        ],
        interpret=interpret,
        sequential_axes=(2,),
        scratch_carry_axes=(2,),
    )(x, dt, a2, B, C, cstates, gy, gstate)
    dA = jnp.sum(dA3, axis=(0, 2)).astype(A.dtype)
    return dx[:, :S], ddt[:, :S], dA, dB[:, :S], dC[:, :S]
