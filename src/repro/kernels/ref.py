"""Pure-jnp oracles for every Pallas kernel (no pallas imports here).

These are the ground truth the kernels must match under interpret=True
(CPU) and on real TPU.  Deliberately written in the most obvious way —
O(S^2) score materialization, per-timestep scan — so they are easy to
audit against the math.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  window: int = 0) -> jax.Array:
    """Causal GQA attention oracle.  q: [B,S,H,D]; k/v: [B,S,KV,D]."""
    B, S, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, kf) / jnp.sqrt(D)
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask[None, None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, vf)
    return out.reshape(B, S, H, D).astype(q.dtype)


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
            C: jax.Array, state=None):
    """SSD oracle: exact per-timestep recurrence.

    x: [b,S,H,P]; dt: [b,S,H] (post-softplus); A: [H] (negative);
    B/C: [b,S,H,N].  Returns (y [b,S,H,P], final_state [b,H,P,N]).
    """
    b, S, H, P = x.shape
    N = B.shape[-1]
    if state is None:
        state = jnp.zeros((b, H, P, N), jnp.float32)

    def step(st, inp):
        xt, dtt, Bt, Ct = inp
        dA = jnp.exp(dtt.astype(jnp.float32) * A)
        upd = jnp.einsum("bh,bhp,bhn->bhpn", dtt.astype(jnp.float32),
                         xt.astype(jnp.float32), Bt.astype(jnp.float32))
        st = st * dA[..., None, None] + upd
        yt = jnp.einsum("bhpn,bhn->bhp", st, Ct.astype(jnp.float32))
        return st, yt

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          B.transpose(1, 0, 2, 3), C.transpose(1, 0, 2, 3))
    state, ys = jax.lax.scan(step, state, xs)
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), state
