"""Pallas TPU kernels for the framework's compute hot spots.

Oobleck itself contributes no kernels (its contribution is planning +
resilient execution), but the training substrate owns two hot spots that
are Pallas-tiled for TPU: causal GQA flash attention and the Mamba2 SSD
chunked scan — forward AND backward (registered as custom_vjp rules in
ops.py, DESIGN.md §11).  Each kernel ships with a jit wrapper (ops.py),
a block-size autotuner (autotune.py) and a pure-jnp oracle (ref.py);
tests sweep shapes/dtypes against the oracle with interpret=True.
"""
from repro.kernels import autotune, ops, ref
from repro.kernels.flash_attention import flash_attention as flash_attention_kernel
from repro.kernels.ssd import ssd as ssd_kernel

__all__ = ["autotune", "ops", "ref", "flash_attention_kernel",
           "ssd_kernel"]
