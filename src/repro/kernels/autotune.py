"""Block-size autotuner for the Pallas kernels (DESIGN.md §11).

Every kernel call needs block sizes (flash: block_q/block_k, SSD: the
chunk length).  The right values depend on the backend (MXU alignment on
TPU, SM occupancy on GPU, grid-step overhead under the CPU interpreter),
the dtype and the problem shape — so they are resolved through a cache
keyed by

    (kernel kind, backend, dtype, shape bucket)

with sequence lengths bucketed to powers of two (one entry serves every
shape that tiles the same way).  Resolution order:

  1. in-memory cache (per process),
  2. the persisted JSON table (``REPRO_AUTOTUNE_CACHE``, default
     ``~/.cache/repro/autotune.json``) — the same
     precompute-once/look-up-forever shape as the template-keyed
     ProgramCache of DESIGN.md §8,
  3. the deterministic OFFLINE table below.

Measured tuning (``tune_flash``/``tune_ssd``) runs ONLY when invoked
explicitly or when ``REPRO_AUTOTUNE=1`` — CI and the zero-recompile
warm path always hit the deterministic table, so program-cache keys
never depend on wall-clock measurements.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

_ENV_PATH = "REPRO_AUTOTUNE_CACHE"
_ENV_ENABLE = "REPRO_AUTOTUNE"


def _bucket(n: int, floor: int = 16) -> int:
    """Power-of-two bucket for a sequence length."""
    b = floor
    while b < n:
        b *= 2
    return b


def _key(kind: str, backend: str, dtype, shape: Tuple[int, ...]) -> str:
    return "|".join([kind, backend, str(jnp.dtype(dtype)),
                     "x".join(str(s) for s in shape)])


# ----------------------------------------------------------------------
# Deterministic offline table
# ----------------------------------------------------------------------
def _offline(kind: str, backend: str, shape: Tuple[int, ...]) -> Dict[str, int]:
    """Fallback block sizes — a pure function of (kind, backend, bucket)
    so CI and warm_templates() are deterministic without ever tuning.

    The discriminator is CAPABILITY, not platform: compiled (Mosaic)
    backends get 128 — MXU-aligned, small VMEM working set; every
    interpreting backend (CPU, and GPU until a Triton-structured kernel
    variant lands) gets blocks as large as the bucket allows, because
    per-grid-step overhead dominates the interpreter (measured 2-3x
    over 128 at 2k sequence).
    """
    from repro.kernels import ops as _ops     # lazy: ops imports us
    compiled = not _ops.interpret_mode(backend)
    seq = shape[0]
    if kind == "flash":
        blk = 128 if compiled else min(512, _bucket(seq))
        return {"block_q": blk, "block_k": blk}
    if kind == "ssd":
        return {"chunk": 128 if compiled else min(128, _bucket(seq))}
    raise KeyError(f"unknown kernel kind {kind!r}")


# ----------------------------------------------------------------------
# The cache
# ----------------------------------------------------------------------
class AutotuneCache:
    """(kind, backend, dtype, bucket) -> block config, with a persisted
    JSON table behind the in-memory dict."""

    def __init__(self, path: Optional[str] = None):
        if path is None:
            path = os.environ.get(
                _ENV_PATH,
                os.path.join(os.path.expanduser("~"), ".cache", "repro",
                             "autotune.json"))
        self.path = path
        self._mem: Dict[str, Dict[str, int]] = {}
        self._disk_loaded = False

    # -- persistence ---------------------------------------------------
    def _load_disk(self) -> None:
        if self._disk_loaded:
            return
        self._disk_loaded = True
        try:
            with open(self.path) as f:
                table = json.load(f)
            for k, v in table.items():
                self._mem.setdefault(k, {str(a): int(b)
                                         for a, b in v.items()})
        except (OSError, ValueError):
            pass

    def save(self) -> None:
        """Atomically persist the current table (tmp + rename), merged
        over what is already on disk — a fresh process tuning ONE shape
        must not clobber previously persisted entries."""
        self._load_disk()
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(self._mem, f, indent=2, sort_keys=True)
        os.replace(tmp, self.path)

    # -- lookup --------------------------------------------------------
    def peek(self, kind: str, backend: str, dtype,
             shape: Tuple[int, ...]) -> Optional[Dict[str, int]]:
        """Tuned entry from memory or disk, or None.  Offline-table
        fallbacks are NOT consulted (and never stored in ``_mem``, so
        ``save()`` persists only genuinely measured entries — a stale
        snapshot of the offline defaults would shadow future updates)."""
        key = _key(kind, backend, dtype, shape)
        cfg = self._mem.get(key)
        if cfg is None:
            self._load_disk()
            cfg = self._mem.get(key)
        return cfg

    def get(self, kind: str, backend: str, dtype,
            shape: Tuple[int, ...]) -> Dict[str, int]:
        cfg = self.peek(kind, backend, dtype, shape)
        return cfg if cfg is not None else _offline(kind, backend, shape)

    def put(self, kind: str, backend: str, dtype, shape: Tuple[int, ...],
            cfg: Dict[str, int], persist: bool = True) -> None:
        self._mem[_key(kind, backend, dtype, shape)] = dict(cfg)
        if persist:
            try:
                self.save()
            except OSError:
                pass               # read-only FS: stay in-memory


_CACHE = AutotuneCache()


def tuning_enabled() -> bool:
    return os.environ.get(_ENV_ENABLE, "") == "1"


def flash_config(backend: str, dtype, seq_len: int, head_dim: int
                 ) -> Dict[str, int]:
    shape = (_bucket(seq_len), head_dim)
    cfg = _CACHE.peek("flash", backend, dtype, shape)
    if cfg is None and tuning_enabled():
        cfg = tune_flash(backend, dtype, seq_len, head_dim)
    return cfg if cfg is not None else _CACHE.get("flash", backend, dtype,
                                                  shape)


def ssd_config(backend: str, dtype, seq_len: int, head_dim: int,
               state: int) -> Dict[str, int]:
    shape = (_bucket(seq_len), head_dim, state)
    cfg = _CACHE.peek("ssd", backend, dtype, shape)
    if cfg is None and tuning_enabled():
        cfg = tune_ssd(backend, dtype, seq_len, head_dim, state)
    return cfg if cfg is not None else _CACHE.get("ssd", backend, dtype,
                                                  shape)


# ----------------------------------------------------------------------
# Measured tuning (explicit or REPRO_AUTOTUNE=1 — never CI's default)
# ----------------------------------------------------------------------
def _time(fn, *args, iters: int = 3) -> float:
    """Min over repeats: the noise-robust estimator — scheduler hiccups
    only ever ADD time, so the minimum is the cleanest measurement (and
    what the roofline's bwd-beats-oracle CI gate compares)."""
    jax.block_until_ready(fn(*args))        # compile outside the clock
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def tune_flash(backend: str, dtype, seq_len: int, head_dim: int, *,
               batch: int = 1, heads: int = 2,
               candidates: Optional[List[int]] = None,
               persist: bool = True) -> Dict[str, int]:
    """Measure fwd+bwd across candidate square blocks; cache the best."""
    from repro.kernels import flash_attention as _fa
    from repro.kernels import ops as _ops
    interpret = _ops.interpret_mode(backend)
    if candidates is None:
        candidates = [64, 128, 256, 512]
    candidates = sorted({min(c, _bucket(seq_len)) for c in candidates})
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (batch, seq_len, heads, head_dim), dtype)
    k = jax.random.normal(ks[1], (batch, seq_len, heads, head_dim), dtype)
    v = jax.random.normal(ks[2], (batch, seq_len, heads, head_dim), dtype)
    g = jax.random.normal(ks[3], q.shape, dtype)
    best, best_t = None, float("inf")
    for blk in candidates:
        def run(q, k, v, g, blk=blk):
            out, lse = _fa.flash_attention_fwd(
                q, k, v, block_q=blk, block_k=blk, interpret=interpret)
            return _fa.flash_attention_bwd(
                q, k, v, out, lse, g, block_q=blk, block_k=blk,
                interpret=interpret)
        t = _time(run, q, k, v, g)
        if t < best_t:
            best, best_t = blk, t
    cfg = {"block_q": best, "block_k": best}
    _CACHE.put("flash", backend, dtype, (_bucket(seq_len), head_dim), cfg,
               persist=persist)
    return cfg


def tune_ssd(backend: str, dtype, seq_len: int, head_dim: int, state: int,
             *, batch: int = 1, heads: int = 2,
             candidates: Optional[List[int]] = None,
             persist: bool = True) -> Dict[str, int]:
    """Measure fwd+bwd across candidate chunk lengths; cache the best."""
    from repro.kernels import ops as _ops
    from repro.kernels import ssd as _ssd
    interpret = _ops.interpret_mode(backend)
    if candidates is None:
        candidates = [32, 64, 128, 256]
    candidates = sorted({min(c, _bucket(seq_len)) for c in candidates})
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (batch, seq_len, heads, head_dim), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (batch, seq_len, heads)))
    A = -jnp.exp(jax.random.normal(ks[2], (heads,)) * 0.5)
    B = jax.random.normal(ks[3], (batch, seq_len, heads, state), dtype)
    C = jax.random.normal(ks[4], (batch, seq_len, heads, state), dtype)
    best, best_t = None, float("inf")
    for chunk in candidates:
        def run(x, dt, A, B, C, chunk=chunk):
            y, st, cst = _ssd.ssd_fwd(x, dt, A, B, C, chunk=chunk,
                                      interpret=interpret)
            return _ssd.ssd_bwd(x, dt, A, B, C, cst, y, st, chunk=chunk,
                                interpret=interpret)
        t = _time(run, x, dt, A, B, C)
        if t < best_t:
            best, best_t = chunk, t
    cfg = {"chunk": best}
    _CACHE.put("ssd", backend, dtype, (_bucket(seq_len), head_dim, state),
               cfg, persist=persist)
    return cfg
